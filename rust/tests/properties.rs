//! Property-based tests over the coordinator substrates.
//!
//! proptest is not available in this offline environment, so this file
//! carries a small in-tree property harness: each property runs against
//! hundreds of randomized cases drawn from seeded generators, and
//! failures report the offending case seed for replay.

use fedsrn::compress::{self, DownlinkEncoder, DownlinkFrame, DownlinkMode, Method};
use fedsrn::config::ExperimentConfig;
use fedsrn::coordinator::Checkpoint;
use fedsrn::fl::transport::{
    self, framed_len, read_frame, write_frame, FrameBuf, FrameKind, Hello, Welcome,
    MAX_FRAME_BYTES, TRANSPORT_VERSION,
};
use fedsrn::data::{partition_iid, partition_noniid, Dataset, SynthSpec, Synthetic};
use fedsrn::mask::{
    empirical_bpp, entropy_bits, mean_client_bpp, sample_mask, topk_mask, BetaAggregator,
    MaskAggregator, ProbMask,
};
use fedsrn::util::{logit, sigmoid, BitVec, Philox4x32, Xoshiro256};

/// Run `prop` for `cases` seeded random cases.
fn forall(cases: u64, prop: impl Fn(&mut Xoshiro256, u64)) {
    for case in 0..cases {
        let mut rng = Xoshiro256::new(0xF00D + case * 7919);
        prop(&mut rng, case);
    }
}

fn arb_mask(rng: &mut Xoshiro256) -> BitVec {
    let n = 1 + rng.below(30_000) as usize;
    let p = rng.next_f64();
    BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
}

// ---------------------------------------------------------------------------
// codec properties
// ---------------------------------------------------------------------------

#[test]
fn prop_codec_roundtrip_identity() {
    forall(120, |rng, case| {
        let m = arb_mask(rng);
        let enc = compress::encode(&m);
        let dec = compress::decode(&enc, m.len()).unwrap();
        assert_eq!(dec, m, "case {case}: len={} ones={}", m.len(), m.count_ones());
    });
}

#[test]
fn prop_all_methods_roundtrip() {
    forall(40, |rng, case| {
        let m = arb_mask(rng);
        for method in [Method::Raw, Method::Arithmetic, Method::Golomb] {
            let enc = compress::encode_with(&m, method);
            assert_eq!(
                compress::decode(&enc, m.len()).unwrap(),
                m,
                "case {case} {method:?}"
            );
        }
    });
}

#[test]
fn prop_truncated_uplink_payloads_never_decode_silently() {
    // Chop coded bytes anywhere: the wire parse or the decode must
    // error — a truncated uplink must never yield a quietly-wrong mask.
    forall(40, |rng, case| {
        let m = arb_mask(rng);
        let enc = compress::encode(&m);
        let bytes = enc.to_bytes();
        let cut = rng.below(bytes.len() as u64) as usize;
        let outcome = compress::Encoded::from_bytes(&bytes[..cut])
            .and_then(|e| compress::decode(&e, m.len()));
        assert!(
            outcome.is_err(),
            "case {case}: {}B of {}B decoded without error",
            cut,
            bytes.len()
        );
    });
}

#[test]
fn prop_coded_size_close_to_entropy() {
    // The winning codec should never exceed raw+header, and for large
    // sparse masks should be within ~15% + 48 bits of n*H(p).
    forall(60, |rng, case| {
        let m = arb_mask(rng);
        let enc = compress::encode(&m);
        assert!(
            enc.payload.len() <= m.raw_bytes(),
            "case {case}: codec worse than raw"
        );
        if m.len() > 5_000 {
            let h = empirical_bpp(&m);
            let rate = enc.payload.len() as f64 * 8.0 / m.len() as f64;
            assert!(
                rate <= h * 1.15 + 48.0 / m.len() as f64 + 0.02,
                "case {case}: rate {rate} vs entropy {h}"
            );
        }
    });
}

#[test]
fn prop_wire_format_roundtrip() {
    forall(60, |rng, case| {
        let m = arb_mask(rng);
        let enc = compress::encode(&m);
        let parsed = compress::Encoded::from_bytes(&enc.to_bytes()).unwrap();
        assert_eq!(compress::decode(&parsed, m.len()).unwrap(), m, "case {case}");
    });
}

// ---------------------------------------------------------------------------
// protocol envelope properties (DESIGN.md §Protocol)
// ---------------------------------------------------------------------------

use fedsrn::fl::{DownlinkMsg, UplinkMsg, UplinkPayload};

fn arb_f32s(rng: &mut Xoshiro256, unit: bool) -> Vec<f32> {
    let n = 1 + rng.below(5_000) as usize;
    (0..n)
        .map(|_| {
            let u = rng.next_f32();
            if unit {
                u
            } else {
                u * 8.0 - 4.0
            }
        })
        .collect()
}

fn arb_downlink(rng: &mut Xoshiro256) -> (DownlinkMsg, Option<Vec<f32>>) {
    match rng.below(4) {
        0 => (DownlinkMsg::Theta(arb_f32s(rng, true)), None),
        1 => (DownlinkMsg::RawF32(arb_f32s(rng, false)), None),
        2 => (
            DownlinkMsg::NoiseTheta { noise_seed: rng.next_u64(), theta: arb_f32s(rng, true) },
            None,
        ),
        _ => {
            let a = arb_f32s(rng, true);
            let b: Vec<f32> = a
                .iter()
                .map(|&v| if rng.next_f64() < 0.3 { (v + 0.05).min(1.0) } else { v })
                .collect();
            let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
            enc.encode_frame(&a);
            (DownlinkMsg::Frame(enc.encode_frame(&b)), Some(a))
        }
    }
}

fn arb_uplink(rng: &mut Xoshiro256) -> UplinkMsg {
    let payload = match rng.below(5) {
        0 => UplinkPayload::CodedMask(compress::encode(&arb_mask(rng))),
        1 => UplinkPayload::SignVector(compress::encode(&arb_mask(rng))),
        2 => UplinkPayload::NoiseMask(compress::encode(&arb_mask(rng))),
        3 => UplinkPayload::Thresholds(
            // per-filter pruning thresholds: finite and non-negative
            arb_f32s(rng, true).into_iter().map(|v| v * 4.0).collect(),
        ),
        _ => UplinkPayload::DenseDelta(arb_f32s(rng, false)),
    };
    UplinkMsg {
        weight: 1.0 + rng.below(1000) as f64,
        train_loss: rng.next_f32(),
        // mix v1-style fresh envelopes with round-tagged v2 ones
        trained_round: if rng.below(4) == 0 { UplinkMsg::FRESH } else { rng.below(1 << 20) },
        payload,
    }
}

/// Every downlink kind round-trips `to_bytes -> from_bytes` into a
/// bit-identical decoded state, and the recorded wire size is the real
/// serialized size.
#[test]
fn prop_downlink_envelope_roundtrip_bit_identical() {
    forall(90, |rng, case| {
        let (msg, prev) = arb_downlink(rng);
        let bytes = msg.to_bytes();
        assert_eq!(bytes.len(), msg.wire_bytes(), "case {case}");
        let back = DownlinkMsg::from_bytes(&bytes).unwrap();
        let p = prev.as_deref();
        let want: Vec<u32> =
            msg.decode_state(p).unwrap().iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> =
            back.decode_state(p).unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "case {case}: {} state changed on the wire", msg.kind_name());
    });
}

/// Every uplink kind round-trips bit-identically: weight, train loss,
/// and payload bytes all survive.
#[test]
fn prop_uplink_envelope_roundtrip_bit_identical() {
    forall(90, |rng, case| {
        let msg = arb_uplink(rng);
        let bytes = msg.to_bytes();
        assert_eq!(bytes.len(), msg.wire_bytes(), "case {case}");
        let back = UplinkMsg::from_bytes(&bytes).unwrap();
        assert_eq!(back.weight.to_bits(), msg.weight.to_bits(), "case {case}");
        assert_eq!(back.train_loss.to_bits(), msg.train_loss.to_bits(), "case {case}");
        assert_eq!(back.trained_round, msg.trained_round, "case {case}");
        assert_eq!(back.to_bytes(), bytes, "case {case}: reserialization must be stable");
    });
}

/// Truncation at any point, trailing garbage, a version bump, or an
/// unknown kind byte must error — never decode garbage.
#[test]
fn prop_envelopes_reject_truncation_and_corruption() {
    forall(60, |rng, case| {
        let dl_bytes = arb_downlink(rng).0.to_bytes();
        let ul_bytes = arb_uplink(rng).to_bytes();
        // every strict prefix must fail (recorded lengths no longer
        // match the bytes present): random cut points plus the edges
        for _ in 0..4 {
            let cut = rng.below(dl_bytes.len() as u64) as usize;
            assert!(
                DownlinkMsg::from_bytes(&dl_bytes[..cut]).is_err(),
                "case {case}: truncated downlink decoded at {cut}/{}",
                dl_bytes.len()
            );
            let cut = rng.below(ul_bytes.len() as u64) as usize;
            assert!(
                UplinkMsg::from_bytes(&ul_bytes[..cut]).is_err(),
                "case {case}: truncated uplink decoded at {cut}/{}",
                ul_bytes.len()
            );
        }
        assert!(DownlinkMsg::from_bytes(&dl_bytes[..dl_bytes.len() - 1]).is_err());
        assert!(UplinkMsg::from_bytes(&ul_bytes[..ul_bytes.len() - 1]).is_err());
        // trailing garbage
        let mut padded = dl_bytes.clone();
        padded.push(0);
        assert!(DownlinkMsg::from_bytes(&padded).is_err(), "case {case}");
        let mut padded = ul_bytes.clone();
        padded.push(0);
        assert!(UplinkMsg::from_bytes(&padded).is_err(), "case {case}");
        // version / kind corruption
        let mut bad = dl_bytes.clone();
        bad[0] ^= 1;
        assert!(DownlinkMsg::from_bytes(&bad).is_err(), "case {case}: version");
        let mut bad = ul_bytes.clone();
        bad[1] = 0xEE;
        assert!(UplinkMsg::from_bytes(&bad).is_err(), "case {case}: kind");
    });
}

/// The v2-introduced envelope kinds (noise mask, thresholds, noise
/// theta) torture-tested on their own: truncation at every random cut
/// must be a typed error — never a panic — and any single-byte flip
/// either fails to decode or decodes to a *visibly different* envelope
/// (reserialization ≠ original bytes). The envelope layer carries no
/// checksum — the transport frame does — so "silently canonicalized
/// back to the original" is the one outcome corruption must never have.
#[test]
fn prop_new_envelope_kinds_truncation_and_flips_never_pass_silently() {
    forall(50, |rng, case| {
        let noise_mask = UplinkMsg {
            weight: 1.0 + rng.below(1000) as f64,
            train_loss: rng.next_f32(),
            trained_round: rng.below(1 << 20),
            payload: UplinkPayload::NoiseMask(compress::encode(&arb_mask(rng))),
        };
        let thresholds = UplinkMsg {
            weight: 1.0 + rng.below(1000) as f64,
            train_loss: rng.next_f32(),
            trained_round: rng.below(1 << 20),
            payload: UplinkPayload::Thresholds(
                arb_f32s(rng, true).into_iter().map(|v| v * 4.0).collect(),
            ),
        };
        for msg in [&noise_mask, &thresholds] {
            let wire = msg.to_bytes();
            for _ in 0..6 {
                let cut = rng.below(wire.len() as u64) as usize;
                let out = std::panic::catch_unwind(|| UplinkMsg::from_bytes(&wire[..cut]));
                match out {
                    Ok(res) => assert!(
                        res.is_err(),
                        "case {case}: truncated {} decoded at {cut}/{}",
                        msg.payload.kind_name(),
                        wire.len()
                    ),
                    Err(_) => panic!("case {case}: truncation at {cut} panicked"),
                }
            }
            for _ in 0..8 {
                let at = rng.below(wire.len() as u64) as usize;
                let mut bad = wire.clone();
                bad[at] ^= 1 + rng.below(255) as u8;
                if let Ok(back) = UplinkMsg::from_bytes(&bad) {
                    assert_ne!(
                        back.to_bytes(),
                        wire,
                        "case {case}: flip at byte {at} canonicalized back to the original",
                    );
                }
            }
        }
        // the downlink's noise-theta kind gets the same torture
        let dl = DownlinkMsg::NoiseTheta { noise_seed: rng.next_u64(), theta: arb_f32s(rng, true) };
        let wire = dl.to_bytes();
        for _ in 0..6 {
            let cut = rng.below(wire.len() as u64) as usize;
            let out = std::panic::catch_unwind(|| DownlinkMsg::from_bytes(&wire[..cut]));
            match out {
                Ok(res) => assert!(res.is_err(), "case {case}: truncated noise theta at {cut}"),
                Err(_) => panic!("case {case}: noise-theta truncation at {cut} panicked"),
            }
        }
        for _ in 0..8 {
            let at = rng.below(wire.len() as u64) as usize;
            let mut bad = wire.clone();
            bad[at] ^= 1 + rng.below(255) as u8;
            if let Ok(back) = DownlinkMsg::from_bytes(&bad) {
                assert_ne!(back.to_bytes(), wire, "case {case}: noise-theta flip at {at}");
            }
        }
    });
}

/// Version-skew contract for the v2-introduced kinds: a v1-stamped
/// envelope can only carry the kinds a v1 peer could have produced.
/// Restamping a noise-mask, thresholds, or noise-theta envelope as v1
/// (including the full v1 header splice, which drops the staleness tag)
/// must be a typed decode error — while the same splice on a v1-era
/// kind still decodes, as FRESH.
#[test]
fn prop_v2_only_kinds_reject_v1_stamp() {
    // serialized layout: [version, kind, weight:8, loss:4, round:8, …]
    const V2_HEAD: usize = 22;
    const V1_HEAD: usize = 14;
    let v1_splice = |wire: &[u8]| -> Vec<u8> {
        let mut v1 = wire[..V1_HEAD].to_vec();
        v1[0] = 1;
        v1.extend_from_slice(&wire[V2_HEAD..]);
        v1
    };
    forall(40, |rng, case| {
        let coded = compress::encode(&arb_mask(rng));
        for payload in [
            UplinkPayload::NoiseMask(coded.clone()),
            UplinkPayload::Thresholds(
                arb_f32s(rng, true).into_iter().map(|v| v * 4.0).collect(),
            ),
        ] {
            let msg = UplinkMsg {
                weight: 1.0 + rng.below(1000) as f64,
                train_loss: rng.next_f32(),
                trained_round: rng.below(1 << 20),
                payload,
            };
            let wire = msg.to_bytes();
            // a bare version restamp (header otherwise intact)…
            let mut restamped = wire.clone();
            restamped[0] = 1;
            assert!(
                UplinkMsg::from_bytes(&restamped).is_err(),
                "case {case}: v1 restamp of {} decoded",
                msg.payload.kind_name()
            );
            // …and the honest v1 header splice must both be rejected
            assert!(
                UplinkMsg::from_bytes(&v1_splice(&wire)).is_err(),
                "case {case}: v1 splice of {} decoded",
                msg.payload.kind_name()
            );
        }
        // contrast: the identical splice on a v1-era kind still decodes,
        // with the staleness tag defaulted to FRESH
        let old = UplinkMsg {
            weight: 2.0,
            train_loss: 0.5,
            trained_round: 7,
            payload: UplinkPayload::CodedMask(coded.clone()),
        };
        let back = UplinkMsg::from_bytes(&v1_splice(&old.to_bytes())).unwrap();
        assert_eq!(back.trained_round, UplinkMsg::FRESH, "case {case}");
        // downlink side: a v1-stamped noise-theta envelope is an error
        let dl =
            DownlinkMsg::NoiseTheta { noise_seed: rng.next_u64(), theta: arb_f32s(rng, true) };
        let mut bad = dl.to_bytes();
        bad[0] = 1;
        assert!(
            DownlinkMsg::from_bytes(&bad).is_err(),
            "case {case}: v1-stamped noise theta decoded"
        );
    });
}

// ---------------------------------------------------------------------------
// transport framing properties (DESIGN.md §Transport)
// ---------------------------------------------------------------------------

const FRAME_KINDS: [FrameKind; 8] = [
    FrameKind::Hello,
    FrameKind::Welcome,
    FrameKind::Round,
    FrameKind::Uplink,
    FrameKind::Dropped,
    FrameKind::Sync,
    FrameKind::Done,
    FrameKind::Error,
];

fn arb_frame(rng: &mut Xoshiro256) -> (FrameKind, Vec<u8>, Vec<u8>) {
    let kind = FRAME_KINDS[rng.below(FRAME_KINDS.len() as u64) as usize];
    let len = rng.below(4096) as usize;
    let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
    let mut wire = Vec::new();
    write_frame(&mut wire, kind, &payload).unwrap();
    (kind, payload, wire)
}

#[test]
fn prop_transport_frame_roundtrip_bit_identical() {
    forall(120, |rng, case| {
        let (kind, payload, wire) = arb_frame(rng);
        assert_eq!(wire.len(), framed_len(payload.len()), "case {case}");
        let (k, p) = read_frame(&mut std::io::Cursor::new(&wire), MAX_FRAME_BYTES)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        assert_eq!(k, kind, "case {case}");
        assert_eq!(p, payload, "case {case}");
        // framing is self-delimiting: two frames back to back parse in
        // order from one stream
        let mut stream = wire.clone();
        stream.extend_from_slice(&wire);
        let mut cur = std::io::Cursor::new(&stream);
        for _ in 0..2 {
            let (k, p) = read_frame(&mut cur, MAX_FRAME_BYTES).unwrap();
            assert_eq!((k, &p), (kind, &payload), "case {case}");
        }
    });
}

#[test]
fn prop_transport_truncated_frames_always_error() {
    // A frame cut anywhere — header, payload, or checksum — must be a
    // typed error, never a panic or a silent short read.
    forall(80, |rng, case| {
        let (_, _, wire) = arb_frame(rng);
        for _ in 0..6 {
            let cut = rng.below(wire.len() as u64) as usize;
            let out = std::panic::catch_unwind(|| {
                read_frame(&mut std::io::Cursor::new(&wire[..cut]), MAX_FRAME_BYTES)
            });
            match out {
                Ok(res) => assert!(
                    res.is_err(),
                    "case {case}: truncated frame decoded at {cut}/{}",
                    wire.len()
                ),
                Err(_) => panic!("case {case}: truncation at {cut} panicked"),
            }
        }
    });
}

#[test]
fn prop_transport_byte_flips_never_decode_silently() {
    // The trailing checksum covers kind, length, and payload: ANY
    // single-byte corruption anywhere in the frame must fail to read —
    // silent garbage can never reach the envelope layer.
    forall(60, |rng, case| {
        let (_, _, wire) = arb_frame(rng);
        for _ in 0..8 {
            let at = rng.below(wire.len() as u64) as usize;
            let flip = 1 + rng.below(255) as u8;
            let mut bad = wire.clone();
            bad[at] ^= flip;
            assert!(
                read_frame(&mut std::io::Cursor::new(&bad), MAX_FRAME_BYTES).is_err(),
                "case {case}: flip {flip:#04x} at byte {at}/{} decoded",
                wire.len()
            );
        }
    });
}

#[test]
fn prop_transport_oversize_length_prefix_rejected() {
    // A hostile or corrupt length prefix past the cap errors before any
    // allocation — with an arbitrarily small backing buffer.
    forall(60, |rng, case| {
        let over = MAX_FRAME_BYTES as u64 + 1 + rng.below(1 << 30);
        // header claiming `over` payload bytes (kind 3 = Round)
        let mut wire = vec![0xF5u8, 3u8];
        wire.extend_from_slice(&(over.min(u32::MAX as u64) as u32).to_le_bytes());
        let err = read_frame(&mut std::io::Cursor::new(&wire), MAX_FRAME_BYTES)
            .expect_err(&format!("case {case}: oversize prefix {over} accepted"));
        assert!(err.to_string().contains("exceeds"), "case {case}: {err:#}");
        // the session can also tighten the cap below the global one
        let (_, payload, wire) = arb_frame(rng);
        if !payload.is_empty() {
            assert!(
                read_frame(&mut std::io::Cursor::new(&wire), payload.len() - 1).is_err(),
                "case {case}: tightened cap not enforced"
            );
        }
    });
}

#[test]
fn prop_sync_and_dropped_frames_roundtrip_and_reject_torture() {
    // The two control frames the reconnect path lives on get the same
    // torture the data frames get. Sync carries a full serialized
    // downlink (the resync state), Dropped is an empty marker — both
    // must round-trip bit-identically and reject truncation, byte
    // flips, and hostile length prefixes with typed errors.
    forall(50, |rng, case| {
        let (msg, prev) = arb_downlink(rng);
        let sync_payload = msg.to_bytes();
        for (kind, payload) in
            [(FrameKind::Sync, sync_payload.as_slice()), (FrameKind::Dropped, &[][..])]
        {
            let mut wire = Vec::new();
            write_frame(&mut wire, kind, payload).unwrap();
            assert_eq!(wire.len(), framed_len(payload.len()), "case {case}");
            let (k, p) =
                read_frame(&mut std::io::Cursor::new(&wire), MAX_FRAME_BYTES).unwrap();
            assert_eq!(k, kind, "case {case}");
            assert_eq!(p, payload, "case {case}");
            // a Sync that survives framing must decode to the exact
            // state the server serialized — this is the resync contract
            if kind == FrameKind::Sync {
                let back = DownlinkMsg::from_bytes(&p).unwrap();
                let pr = prev.as_deref();
                let want: Vec<u32> =
                    msg.decode_state(pr).unwrap().iter().map(|v| v.to_bits()).collect();
                let got: Vec<u32> =
                    back.decode_state(pr).unwrap().iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "case {case}: sync state changed on the wire");
            }
            // truncation: random interior cuts plus both edges
            for cut in
                [0, wire.len() - 1].into_iter().chain((0..6).map(|_| {
                    rng.below(wire.len() as u64) as usize
                }))
            {
                assert!(
                    read_frame(&mut std::io::Cursor::new(&wire[..cut]), MAX_FRAME_BYTES)
                        .is_err(),
                    "case {case}: {} truncated at {cut}/{} decoded",
                    kind.name(),
                    wire.len()
                );
            }
            // single-byte flips anywhere in header, payload, or
            // checksum must fail the trailing integrity check
            for _ in 0..8 {
                let at = rng.below(wire.len() as u64) as usize;
                let mut bad = wire.clone();
                bad[at] ^= 1 + rng.below(255) as u8;
                assert!(
                    read_frame(&mut std::io::Cursor::new(&bad), MAX_FRAME_BYTES).is_err(),
                    "case {case}: {} flip at byte {at}/{} decoded",
                    kind.name(),
                    wire.len()
                );
            }
        }
        // oversize length prefix behind a Sync (6) or Dropped (5) kind
        // byte: rejected before any payload allocation
        for kind_byte in [6u8, 5u8] {
            let over = MAX_FRAME_BYTES as u64 + 1 + rng.below(1 << 30);
            let mut wire = vec![0xF5u8, kind_byte];
            wire.extend_from_slice(&(over.min(u32::MAX as u64) as u32).to_le_bytes());
            let err = read_frame(&mut std::io::Cursor::new(&wire), MAX_FRAME_BYTES)
                .expect_err(&format!("case {case}: oversize kind {kind_byte} accepted"));
            assert!(err.to_string().contains("exceeds"), "case {case}: {err:#}");
        }
    });
}

#[test]
fn prop_framebuf_chunked_feed_matches_whole_stream() {
    // The readiness loop's incremental decoder: a multi-frame stream
    // fed to FrameBuf in arbitrary-size chunks yields exactly the
    // frames a blocking reader would, in order, no matter where the
    // chunk boundaries fall — including boundaries inside a header,
    // payload, or checksum.
    forall(70, |rng, case| {
        let n = 1 + rng.below(6) as usize;
        let mut stream = Vec::new();
        let mut want = Vec::new();
        for _ in 0..n {
            let (kind, payload, wire) = arb_frame(rng);
            stream.extend_from_slice(&wire);
            want.push((kind, payload));
        }
        let mut buf = FrameBuf::new();
        let mut got = Vec::new();
        let mut off = 0;
        while off < stream.len() {
            let step = 1 + rng.below(257) as usize;
            let end = (off + step).min(stream.len());
            buf.extend(&stream[off..end]);
            off = end;
            while let Some(frame) = buf
                .next_frame(MAX_FRAME_BYTES)
                .unwrap_or_else(|e| panic!("case {case}: chunked parse errored: {e:#}"))
            {
                got.push(frame);
            }
        }
        assert_eq!(got, want, "case {case}: chunked parse diverged from the stream");
        assert_eq!(buf.pending(), 0, "case {case}: bytes left over after last frame");
    });
}

#[test]
fn prop_transport_handshake_version_skew_rejected() {
    // Any version other than TRANSPORT_VERSION — older or newer — is a
    // typed handshake error, never a silent reinterpretation.
    forall(60, |rng, _case| {
        let hello = Hello {
            version: TRANSPORT_VERSION,
            fingerprint: rng.next_u64(),
            device_id: rng.below(1 << 20),
            resume_round: rng.below(1 << 20),
        };
        assert_eq!(Hello::from_bytes(&hello.to_bytes()).unwrap(), hello);
        let welcome = Welcome {
            version: TRANSPORT_VERSION,
            fingerprint: rng.next_u64(),
            n_clients: 1 + rng.below(1 << 16),
            rounds: rng.below(1 << 16),
        };
        assert_eq!(Welcome::from_bytes(&welcome.to_bytes()).unwrap(), welcome);
        let skew = (rng.below(255) + 1) as u8;
        let bad_version = TRANSPORT_VERSION.wrapping_add(skew);
        let err = Hello::from_bytes(&Hello { version: bad_version, ..hello }.to_bytes())
            .expect_err("hello version skew accepted");
        assert!(err.to_string().contains("version"), "{err:#}");
        let err =
            Welcome::from_bytes(&Welcome { version: bad_version, ..welcome }.to_bytes())
                .expect_err("welcome version skew accepted");
        assert!(err.to_string().contains("version"), "{err:#}");
        // truncation of the fixed-size handshake payloads
        let hb = hello.to_bytes();
        let cut = rng.below(hb.len() as u64) as usize;
        assert!(Hello::from_bytes(&hb[..cut]).is_err());
        // non-io errors never classify as straggler timeouts
        assert!(!transport::is_timeout(&anyhow::anyhow!("not io")));
    });
}

// ---------------------------------------------------------------------------
// downlink quantizer properties (DESIGN.md §Downlink)
// ---------------------------------------------------------------------------

#[test]
fn prop_downlink_quantize_dequantize_error_bound() {
    // One committed broadcast then a delta frame with the changed
    // fraction under the per-round cap: EVERY coordinate's
    // reconstruction error is bounded by step/2 = max|delta| / (2*qmax)
    // — sent coordinates by rounding, unsent ones because they only
    // stay unsent when their delta rounds to zero.
    forall(40, |rng, case| {
        let n = 64 + rng.below(4_000) as usize;
        let bits = 2 + rng.below(7) as u8; // 2..=8
        let qmax = ((1i64 << (bits - 1)) - 1) as f32;
        let a: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        // perturb exactly every 5th coordinate: 20% < the 25% change
        // cap, so no coordinate is ever withheld by rate control here
        let b: Vec<f32> = a
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                if i % 5 == 0 {
                    v + 0.2 * (rng.next_f32() - 0.5)
                } else {
                    v
                }
            })
            .collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits });
        enc.broadcast(&a);
        assert_eq!(enc.recon(), &a[..], "case {case}: first broadcast must be exact");
        enc.broadcast(&b);
        let max_delta = a.iter().zip(&b).fold(0.0f32, |m, (&x, &y)| m.max((y - x).abs()));
        let bound = max_delta / (2.0 * qmax) * (1.0 + 1e-3) + 1e-6;
        for (i, (&r, &t)) in enc.recon().iter().zip(&b).enumerate() {
            assert!(
                (r - t).abs() <= bound,
                "case {case}: coord {i} err {} > bound {bound} (bits={bits})",
                (r - t).abs()
            );
        }
    });
}

#[test]
fn prop_downlink_residual_feedback_converges() {
    // Broadcasting the same target repeatedly must drive the fleet's
    // reconstruction to the target even though each frame quantizes and
    // ships at most a quarter of the coordinates: what a frame doesn't
    // deliver stays in the residual until it does.
    forall(25, |rng, case| {
        let n = 32 + rng.below(2_000) as usize;
        let bits = 4 + rng.below(5) as u8; // 4..=8
        let a: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let b: Vec<f32> = a.iter().map(|&v| v + rng.next_f32() - 0.5).collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits });
        enc.broadcast(&a);
        let initial = enc.recon().iter().zip(&b).fold(0.0f32, |m, (&r, &t)| m.max((r - t).abs()));
        for _ in 0..16 {
            enc.broadcast(&b);
        }
        let err = enc.recon().iter().zip(&b).fold(0.0f32, |m, (&r, &t)| m.max((r - t).abs()));
        assert!(
            err <= initial * 1e-2 + 1e-6,
            "case {case}: residual feedback stalled at {err} (initial {initial}, bits={bits})"
        );
    });
}

#[test]
fn prop_downlink_delta_bitmap_roundtrip() {
    // Wire roundtrip at fixed change densities incl. the degenerate
    // ends: the client's reconstruction from (bytes, previous state)
    // must be bit-identical to the server's, whatever frame kind the
    // encoder picked (empty delta, sparse delta, dense fallback).
    for &p in &[0.0, 0.01, 0.5, 1.0] {
        forall(12, |rng, case| {
            let n = 16 + rng.below(3_000) as usize;
            let bits = 2 + rng.below(7) as u8;
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let b: Vec<f32> = a
                .iter()
                .map(|&v| {
                    if rng.next_f64() < p {
                        v + 0.3 * (rng.next_f32() - 0.5)
                    } else {
                        v
                    }
                })
                .collect();
            let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits });
            let f0 = enc.encode_frame(&a);
            let client0 = DownlinkFrame::from_bytes(&f0.to_bytes())
                .unwrap()
                .decode(None)
                .unwrap();
            assert_eq!(client0, enc.recon(), "p={p} case {case}: first frame");
            let f1 = enc.encode_frame(&b);
            let client1 = DownlinkFrame::from_bytes(&f1.to_bytes())
                .unwrap()
                .decode(Some(&client0))
                .unwrap();
            let server: Vec<u32> = enc.recon().iter().map(|v| v.to_bits()).collect();
            let client: Vec<u32> = client1.iter().map(|v| v.to_bits()).collect();
            assert_eq!(server, client, "p={p} case {case} bits={bits}");
        });
    }
}

// ---------------------------------------------------------------------------
// aggregation properties (eq. 8)
// ---------------------------------------------------------------------------

#[test]
fn prop_aggregation_output_in_unit_interval_and_convex() {
    forall(60, |rng, _case| {
        let n = 1 + rng.below(2_000) as usize;
        let k = 1 + rng.below(12) as usize;
        let mut agg = MaskAggregator::new(n);
        let mut masks = Vec::new();
        for _ in 0..k {
            let p = rng.next_f64();
            let m = BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n);
            agg.add_mask(&m, 1.0 + rng.below(100) as f64);
            masks.push(m);
        }
        let theta = agg.finalize();
        for (j, &t) in theta.theta().iter().enumerate() {
            assert!((0.0..=1.0).contains(&t));
            // convexity: theta_j is between min and max of the bit values
            let bits: Vec<f64> =
                masks.iter().map(|m| if m.get(j) { 1.0 } else { 0.0 }).collect();
            let lo = bits.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = bits.iter().cloned().fold(0.0f64, f64::max);
            assert!(t as f64 >= lo - 1e-9 && t as f64 <= hi + 1e-9);
        }
    });
}

#[test]
fn prop_weighted_aggregation_is_order_independent() {
    // The federation weights masks by dataset size |D_i| — an integer.
    // Integer-weighted sums of {0,1} bits stay exact in f64 far past any
    // realistic fleet size, so aggregating the same multiset of uplinks
    // in ANY order must produce a bit-identical theta. This is half of
    // the parallel round engine's determinism contract (the other half,
    // ordered reduction, is tested end-to-end in engine_determinism.rs).
    forall(40, |rng, case| {
        let n = 1 + rng.below(3_000) as usize;
        let k = 2 + rng.below(10) as usize;
        let entries: Vec<(BitVec, f64)> = (0..k)
            .map(|_| {
                let p = rng.next_f64();
                let m = BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n);
                (m, (1 + rng.below(500)) as f64)
            })
            .collect();
        let mut order: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut order);

        let mut fwd = MaskAggregator::new(n);
        let mut shuf = MaskAggregator::new(n);
        let mut beta_fwd = BetaAggregator::new(n, 1.5);
        let mut beta_shuf = BetaAggregator::new(n, 1.5);
        for (m, w) in &entries {
            fwd.add_mask(m, *w);
            beta_fwd.add_mask(m, *w);
        }
        for &i in &order {
            shuf.add_mask(&entries[i].0, entries[i].1);
            beta_shuf.add_mask(&entries[i].0, entries[i].1);
        }
        for (x, y) in fwd.finalize().theta().iter().zip(shuf.finalize().theta()) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: mean agg order-dependent");
        }
        for (x, y) in beta_fwd.finalize().theta().iter().zip(beta_shuf.finalize().theta()) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: beta agg order-dependent");
        }
    });
}

#[test]
fn prop_aggregation_unbiased_under_resampling() {
    // E[aggregate of sampled masks] == mean theta (FedPM thm 1, checked
    // statistically).
    let n = 4_000;
    let theta = ProbMask::uniform_random(n, 31);
    let mut agg = MaskAggregator::new(n);
    for round in 0..200u64 {
        agg.add_mask(&sample_mask(&theta, round), 1.0);
    }
    let est = agg.finalize();
    let mean_err: f64 = theta
        .theta()
        .iter()
        .zip(est.theta())
        .map(|(&a, &b)| (a as f64 - b as f64).abs())
        .sum::<f64>()
        / n as f64;
    assert!(mean_err < 0.05, "mean abs err {mean_err}");
}

// ---------------------------------------------------------------------------
// entropy properties (eq. 13)
// ---------------------------------------------------------------------------

#[test]
fn prop_entropy_bounds() {
    forall(200, |rng, _| {
        let p = rng.next_f64();
        let h = entropy_bits(p);
        assert!((0.0..=1.0 + 1e-12).contains(&h));
        assert!((h - entropy_bits(1.0 - p)).abs() < 1e-9);
    });
}

#[test]
fn prop_mean_client_bpp_is_mean() {
    forall(40, |rng, _| {
        let k = 1 + rng.below(8) as usize;
        let masks: Vec<BitVec> = (0..k)
            .map(|_| {
                let n = 100 + rng.below(900) as usize;
                let p = rng.next_f64();
                BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
            })
            .collect();
        let mean = mean_client_bpp(&masks);
        let manual: f64 = masks.iter().map(empirical_bpp).sum::<f64>() / k as f64;
        assert!((mean - manual).abs() < 1e-12);
    });
}

// ---------------------------------------------------------------------------
// sampling / mask-construction properties
// ---------------------------------------------------------------------------

#[test]
fn prop_sampled_density_tracks_theta() {
    forall(30, |rng, case| {
        let n = 20_000;
        let p = rng.next_f32();
        let theta = ProbMask::constant(n, p);
        let m = sample_mask(&theta, rng.next_u64());
        assert!(
            (m.density() - p as f64).abs() < 0.02,
            "case {case}: density {} vs p {p}",
            m.density()
        );
    });
}

#[test]
fn prop_topk_exact_count_and_maximality() {
    forall(60, |rng, case| {
        let n = 1 + rng.below(3_000) as usize;
        let scores: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        let frac = rng.next_f64();
        let k = ((n as f64 * frac).round() as usize).min(n);
        let m = topk_mask(&scores, frac);
        assert_eq!(m.count_ones(), k, "case {case}");
        // maximality: every selected score >= every unselected score
        let min_sel = (0..n)
            .filter(|&i| m.get(i))
            .map(|i| scores[i])
            .fold(f32::INFINITY, f32::min);
        let max_unsel = (0..n)
            .filter(|&i| !m.get(i))
            .map(|i| scores[i])
            .fold(f32::NEG_INFINITY, f32::max);
        if k > 0 && k < n {
            assert!(min_sel >= max_unsel, "case {case}: {min_sel} < {max_unsel}");
        }
    });
}

#[test]
fn prop_sigmoid_logit_inverse_pair() {
    forall(500, |rng, _| {
        let p = rng.next_f32().clamp(1e-6, 1.0 - 1e-6);
        assert!((sigmoid(logit(p)) - p).abs() < 1e-4);
        let s = (rng.next_f32() - 0.5) * 30.0;
        assert!((logit(sigmoid(s)) - s).abs() < 0.05 * s.abs().max(1.0));
    });
}

#[test]
fn prop_philox_streams_are_reproducible_and_index_stable() {
    forall(20, |rng, _| {
        let key = rng.next_u64();
        let p = Philox4x32::new(key);
        let start = rng.below(1 << 40);
        let mut a = vec![0.0f32; 257];
        p.fill_uniform(start, &mut a);
        // random access anywhere inside the range matches
        for _ in 0..16 {
            let off = rng.below(257) as usize;
            assert_eq!(a[off], p.uniform_at(start + off as u64));
        }
    });
}

// ---------------------------------------------------------------------------
// partition properties
// ---------------------------------------------------------------------------

fn arb_dataset(rng: &mut Xoshiro256) -> Dataset {
    let n = 200 + rng.below(800) as usize;
    Synthetic::new(SynthSpec::tiny(), rng.next_u64()).generate(n, 1)
}

#[test]
fn prop_iid_partition_exact_cover() {
    forall(30, |rng, case| {
        let d = arb_dataset(rng);
        let k = 1 + rng.below(20) as usize;
        let shards = partition_iid(&d, k, rng.next_u64());
        let mut seen = vec![false; d.len()];
        for s in &shards {
            for &i in &s.indices {
                assert!(!seen[i], "case {case}: duplicate sample {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "case {case}: dropped samples");
    });
}

#[test]
fn prop_noniid_class_budget_and_cover() {
    forall(30, |rng, case| {
        let d = arb_dataset(rng);
        let k = 5 + rng.below(26) as usize;
        let c = 1 + rng.below(4) as usize;
        let shards = partition_noniid(&d, k, c, rng.next_u64());
        // When k*c < n_classes the budget is impossible without dropping
        // data; devices then keep their round-robin surplus (at most
        // ceil(n_classes/k) classes) so the federation covers everything.
        let budget = c.max(d.n_classes.div_ceil(k));
        let mut count = 0;
        for s in &shards {
            assert!(
                s.classes.len() <= budget,
                "case {case}: {} classes > budget {budget}",
                s.classes.len()
            );
            for &i in &s.indices {
                assert!(s.classes.contains(&(d.y[i] as usize)), "case {case}");
            }
            count += s.indices.len();
        }
        // exact cover in EVERY regime — the k*c < n_classes case used to
        // silently drop whole classes.
        assert_eq!(count, d.len(), "case {case}: samples dropped (k={k} c={c})");
    });
}

// ---------------------------------------------------------------------------
// config / checkpoint properties
// ---------------------------------------------------------------------------

#[test]
fn prop_config_apply_parse_total() {
    // any value accepted by apply() must round-trip through validate
    // or produce an error — never panic.
    forall(100, |rng, _| {
        let keys = [
            "clients", "rounds", "local_epochs", "lambda", "lr", "topk_frac",
            "train_samples", "test_samples", "eval_every", "seed",
        ];
        let mut cfg = ExperimentConfig::default();
        let key = keys[rng.below(keys.len() as u64) as usize];
        let val = format!("{}", rng.below(1000));
        let _ = cfg.apply(key, &val); // must not panic
        let _ = cfg.validate();
    });
}

#[test]
fn prop_checkpoint_roundtrip() {
    forall(25, |rng, case| {
        let m = arb_mask(rng);
        let ck = Checkpoint::new("mlp_tiny", rng.next_u64(), m.len(), &m);
        let path =
            std::env::temp_dir().join(format!("fedsrn_prop_{}_{case}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.decode_mask().unwrap(), m, "case {case}");
        std::fs::remove_file(&path).ok();
    });
}

// ---------------------------------------------------------------------------
// BitVec word-representation invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_bitvec_slack_bits_zero_at_word_boundaries() {
    // The tail-word contract behind the packed compute tier and the
    // word-scan aggregator (util/bitvec.rs module doc): slack bits of
    // the last u64 are zero under EVERY constructor and mutation, so
    // `words()` consumers may popcount whole words. Fuzz lengths
    // hugging the 64-bit boundaries, where the slack math can go wrong.
    forall(200, |rng, case| {
        let base = 64 * (1 + rng.below(6) as usize);
        let delta = rng.below(5) as i64 - 2; // base - 2 ..= base + 2
        let len = (base as i64 + delta).max(1) as usize;
        let p = rng.next_f64();
        let mut m = BitVec::from_iter_len((0..len).map(|_| rng.next_f64() < p), len);
        // flip a handful of random bits through set(), both directions
        for _ in 0..8 {
            let i = rng.below(len as u64) as usize;
            m.set(i, rng.next_f64() < 0.5);
        }
        let ones: usize = (0..len).filter(|&i| m.get(i)).count();
        assert_eq!(m.count_ones(), ones, "case {case}: len={len}");
        let words = m.words();
        assert_eq!(words.len(), len.div_ceil(64), "case {case}: len={len}");
        let word_ones: u32 = words.iter().map(|w| w.count_ones()).sum();
        assert_eq!(word_ones as usize, ones, "case {case}: slack bits leaked (len={len})");
        if len % 64 != 0 {
            let slack = *words.last().unwrap() >> (len % 64);
            assert_eq!(slack, 0, "case {case}: nonzero slack in tail word (len={len})");
        }
    });
}
