//! Fleet-scale aggregation invariants (DESIGN.md §Fleet): hierarchical
//! two-tier folds must be **bit-identical** to flat ordered folds for
//! every strategy family, staleness-discounted folds must be exactly a
//! weighted fresh fold, and the 100k-device simulator must be a pure
//! function of its options — same opts, same report, bit for bit.
//!
//! The inputs are built grouping-exact on purpose: integer |D_i|
//! weights, 0/1 mask bits, ±1 signs, dyadic-grid dense values and
//! dyadic-grid losses, so every f64 accumulator sum is exact and any
//! fold order or contiguous edge grouping must produce identical bits.

use fedsrn::algos::{
    EvalModel, FedAvg, FedMrn, MaskMode, MaskStrategy, RoundStats, ServerLogic, SignSgd, SpaFl,
};
use fedsrn::compress::{self, DownlinkMode};
use fedsrn::config::{Aggregation, Algorithm};
use fedsrn::fl::{
    run_fleet, staleness_scale, AggKind, AggregateMsg, EdgeAggregator, FleetOpts, RoundComm,
    RoundPlan, UplinkMsg, UplinkPayload,
};
use fedsrn::mask::{LayerSlice, LayerSpec};
use fedsrn::util::{BitVec, Xoshiro256};

const N: usize = 96;
/// The SpaFL layout below (one dense 12×8 layer) yields 8 column filters.
const N_FILTERS: usize = 8;

fn plan(round: usize) -> RoundPlan {
    RoundPlan {
        round,
        seed: 9,
        lambda: 0.0,
        lr: 0.1,
        local_epochs: 1,
        topk_frac: 0.3,
        server_lr: 0.05,
        adam: false,
    }
}

/// A value on the dyadic grid k/1024, |v| <= 1: exactly representable,
/// so f64 sums of weight × value never round.
fn dyadic(rng: &mut Xoshiro256) -> f32 {
    (rng.below(2048) as f32 - 1024.0) / 1024.0
}

fn make(name: &str) -> Box<dyn ServerLogic> {
    let mut rng = Xoshiro256::new(0xD0);
    let dense: Vec<f32> = (0..N).map(|_| dyadic(&mut rng)).collect();
    match name {
        "fedpm" => Box::new(MaskStrategy::new(N, 5, MaskMode::Stochastic)),
        "signsgd" => Box::new(SignSgd::new(dense, DownlinkMode::Float32)),
        "fedmrn" => Box::new(FedMrn::new(N, 5)),
        "spafl" => {
            let layers = vec![LayerSlice {
                index: 0,
                spec: LayerSpec::Dense { k: N / N_FILTERS, n: N_FILTERS },
                offset: 0,
            }];
            Box::new(SpaFl::new(dense, &layers, DownlinkMode::Float32))
        }
        _ => Box::new(FedAvg::new(dense, DownlinkMode::Float32)),
    }
}

/// One synthetic device uplink with grouping-exact contents.
fn synth(kind: AggKind, seed: u64, device: u64) -> UplinkMsg {
    let mut rng = Xoshiro256::new(seed ^ device.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let payload = match kind {
        AggKind::MaskSum => {
            let m = BitVec::from_iter_len((0..N).map(|_| rng.next_f64() < 0.4), N);
            UplinkPayload::CodedMask(compress::encode(&m))
        }
        AggKind::SignTally => {
            let m = BitVec::from_iter_len((0..N).map(|_| rng.next_f64() < 0.5), N);
            UplinkPayload::SignVector(compress::encode(&m))
        }
        AggKind::DenseSum => {
            UplinkPayload::DenseDelta((0..N).map(|_| dyadic(&mut rng)).collect())
        }
        AggKind::NoiseMaskSum => {
            let m = BitVec::from_iter_len((0..N).map(|_| rng.next_f64() < 0.5), N);
            UplinkPayload::NoiseMask(compress::encode(&m))
        }
        AggKind::ThresholdSum => UplinkPayload::Thresholds(
            // dyadic, non-negative: weight × tau sums stay exact
            (0..N_FILTERS).map(|_| rng.below(1024) as f32 / 1024.0).collect(),
        ),
    };
    UplinkMsg {
        weight: (1 + rng.below(16)) as f64,
        // dyadic losses keep the f64 loss sum exact under any grouping,
        // so whole-RoundStats comparisons can be bit-strict
        train_loss: rng.below(256) as f32 / 256.0,
        trained_round: 1,
        payload,
    }
}

fn stats_bits(s: &RoundStats) -> [u64; 3] {
    [s.train_loss.to_bits(), s.mean_theta.to_bits(), s.mask_density.to_bits()]
}

fn eval_bits(server: &dyn ServerLogic, round: usize) -> Vec<u32> {
    match server.eval_model(round) {
        EvalModel::Masked(w) | EvalModel::Dense(w) => w.iter().map(|v| v.to_bits()).collect(),
    }
}

/// Fold `ups` directly into the server in the given order.
fn run_flat(
    mut server: Box<dyn ServerLogic>,
    ups: &[UplinkMsg],
    order: &[usize],
) -> ([u64; 3], Vec<u32>, RoundComm) {
    let p = plan(1);
    server.begin_round(&p).unwrap();
    let mut comm = RoundComm::new(N);
    for &i in order {
        server.fold_uplink(&ups[i], &mut comm).unwrap();
    }
    let stats = server.end_round(&p).unwrap();
    (stats_bits(&stats), eval_bits(server.as_ref(), 1), comm)
}

/// Fold `ups` through a tier of `n_edges` edge aggregators (contiguous
/// slices, like the engine and the session route them), shipping each
/// edge's merged envelope upstream through a full serialize/deserialize
/// round trip.
fn run_edged(
    mut server: Box<dyn ServerLogic>,
    ups: &[UplinkMsg],
    n_edges: usize,
) -> ([u64; 3], Vec<u32>, RoundComm) {
    let p = plan(1);
    server.begin_round(&p).unwrap();
    let mut comm = RoundComm::new(N);
    let m = ups.len();
    let mut edges: Vec<EdgeAggregator> =
        (0..n_edges).map(|_| EdgeAggregator::new(server.agg_kind(), N)).collect();
    for (pos, up) in ups.iter().enumerate() {
        edges[pos * n_edges / m].fold(up, 1, 1.0).unwrap();
    }
    for e in &edges {
        if e.reporters() == 0 {
            continue;
        }
        let agg = AggregateMsg::from_bytes(&e.finish().to_bytes()).unwrap();
        server.fold_aggregate(&agg, &mut comm).unwrap();
    }
    let stats = server.end_round(&p).unwrap();
    (stats_bits(&stats), eval_bits(server.as_ref(), 1), comm)
}

#[test]
fn two_tier_folds_bit_identical_to_flat_for_all_strategies() {
    for (name, kind) in [
        ("fedpm", AggKind::MaskSum),
        ("signsgd", AggKind::SignTally),
        ("fedavg", AggKind::DenseSum),
        ("fedmrn", AggKind::NoiseMaskSum),
        ("spafl", AggKind::ThresholdSum),
    ] {
        let m = 23;
        let ups: Vec<UplinkMsg> = (0..m).map(|d| synth(kind, 0xFEE7, d as u64)).collect();
        let in_order: Vec<usize> = (0..m).collect();
        let (flat_stats, flat_eval, flat_comm) = run_flat(make(name), &ups, &in_order);
        // exact accumulators: any fold ORDER gives identical sums…
        let mut shuffled: Vec<usize> = (0..m).collect();
        Xoshiro256::new(3).shuffle(&mut shuffled);
        assert_ne!(shuffled, in_order, "shuffle must actually permute");
        let (p_stats, p_eval, _) = run_flat(make(name), &ups, &shuffled);
        assert_eq!(flat_stats, p_stats, "{name}: permuted fold order changed stats");
        assert_eq!(flat_eval, p_eval, "{name}: permuted fold order changed the model");
        // …and any contiguous GROUPING through an edge tier is
        // bit-identical too, envelope round trip included
        for n_edges in [1usize, 3, 7] {
            let (e_stats, e_eval, e_comm) = run_edged(make(name), &ups, n_edges);
            assert_eq!(flat_stats, e_stats, "{name}/{n_edges} edges: stats");
            assert_eq!(flat_eval, e_eval, "{name}/{n_edges} edges: model");
            assert_eq!(flat_comm.clients, e_comm.clients, "{name}/{n_edges} edges: clients");
            assert_eq!(flat_comm.ul_bits, e_comm.ul_bits, "{name}/{n_edges} edges: UL bits");
        }
    }
}

#[test]
fn stale_fold_is_exactly_a_weighted_fresh_fold() {
    // the contract values
    assert_eq!(staleness_scale(0, 1.0).to_bits(), 1.0f64.to_bits());
    assert!((staleness_scale(1, 1.0) - 0.5).abs() < 1e-15);
    assert!((staleness_scale(3, 1.0) - 0.25).abs() < 1e-15);
    assert!((staleness_scale(4, 0.5) - 1.0 / 5f64.sqrt()).abs() < 1e-15);
    assert_eq!(staleness_scale(9, 0.0).to_bits(), 1.0f64.to_bits());
    // end to end: gap-1 uplinks under beta=1 fold bit-identically to
    // fresh uplinks carrying the discounted weight
    let ups: Vec<UplinkMsg> = (0..6).map(|d| synth(AggKind::MaskSum, 0xA9, d)).collect();
    let p2 = plan(2);
    let mut stale_srv = make("fedpm");
    stale_srv.begin_round(&p2).unwrap();
    let mut comm = RoundComm::new(N);
    for up in &ups {
        // trained_round 1 landing in round 2: gap 1
        stale_srv.fold_uplink_stale(up, &p2, 1.0, &mut comm).unwrap();
    }
    let s_stats = stats_bits(&stale_srv.end_round(&p2).unwrap());
    let mut fresh_srv = make("fedpm");
    fresh_srv.begin_round(&p2).unwrap();
    let mut comm = RoundComm::new(N);
    for up in &ups {
        let mut fresh = up.clone();
        fresh.trained_round = 2;
        fresh.weight *= staleness_scale(1, 1.0);
        fresh_srv.fold_uplink(&fresh, &mut comm).unwrap();
    }
    let f_stats = stats_bits(&fresh_srv.end_round(&p2).unwrap());
    assert_eq!(s_stats, f_stats);
    assert_eq!(eval_bits(stale_srv.as_ref(), 2), eval_bits(fresh_srv.as_ref(), 2));
}

#[test]
fn fleet_simulator_is_deterministic_and_edge_invariant() {
    for algo in [
        Algorithm::FedPMReg,
        Algorithm::SignSGD,
        Algorithm::FedAvg,
        Algorithm::FedMRN,
        Algorithm::SpaFL,
    ] {
        for aggregation in [Aggregation::Sync, Aggregation::Buffered { k: 256 }] {
            let mut opts = FleetOpts::new(2000, 3);
            opts.algorithm = algo;
            opts.aggregation = aggregation;
            opts.churn = 0.02;
            let label = format!("{algo:?}/{aggregation:?}");
            let a = run_fleet(&opts).unwrap();
            let b = run_fleet(&opts).unwrap();
            assert_eq!(a, b, "{label}: same opts must replay bit-for-bit");
            assert_eq!(a.rounds_completed, 3, "{label}");
            assert!(a.folds > 0, "{label}");
            // an 8-edge tier regroups the same exact sums: the model
            // digest and fold counts cannot move (loss is a regrouped
            // f64 sum of arbitrary f32s — ulp-close, not bit-equal)
            let mut edged = opts.clone();
            edged.edges = 8;
            let e = run_fleet(&edged).unwrap();
            assert_eq!(a.model_digest, e.model_digest, "{label}: edge tier moved the model");
            assert_eq!(a.folds, e.folds, "{label}");
            assert_eq!(a.stale_folds, e.stale_folds, "{label}");
            assert_eq!(a.dropouts, e.dropouts, "{label}");
            assert!((a.final_loss - e.final_loss).abs() < 1e-9, "{label}");
        }
    }
}
