//! Integration tests over the REAL PJRT path: load the AOT artifacts,
//! compile, execute, and check the numerics against host-side math.
//!
//! Requires `make artifacts` (the mlp_tiny model). These tests are the
//! Rust half of the AOT contract with python/compile/aot.py.

use std::path::Path;

use fedsrn::runtime::ModelRuntime;
use fedsrn::util::{sigmoid, Xoshiro256};

fn load_tiny() -> ModelRuntime {
    ModelRuntime::load(Path::new("artifacts"), "mlp_tiny")
        .expect("run `make artifacts` before cargo test")
}

fn rand_vec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| (rng.next_normal() as f32) * scale).collect()
}

fn training_inputs(rt: &ModelRuntime, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let m = &rt.manifest;
    let mut rng = Xoshiro256::new(seed);
    let xs = rand_vec(m.steps * m.batch * m.input_dim, 1.0, seed ^ 1);
    let ys: Vec<i32> =
        (0..m.steps * m.batch).map(|_| rng.below(m.n_classes as u64) as i32).collect();
    (xs, ys)
}

#[test]
fn local_train_executes_and_is_deterministic() {
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let scores = rand_vec(n, 0.1, 3);
    let (xs, ys) = training_inputs(&rt, 7);
    let (s1, m1) = rt.local_train(&scores, &xs, &ys, 42, 0.0, 0.1, false, true).unwrap();
    let (s2, m2) = rt.local_train(&scores, &xs, &ys, 42, 0.0, 0.1, false, true).unwrap();
    assert_eq!(s1, s2, "same seed must replay identically");
    assert_eq!(m1.mean_loss, m2.mean_loss);
    assert!(s1.iter().all(|v| v.is_finite()));
    assert_ne!(s1, scores, "training must move the scores");
    // loss should be near ln(10) for random data/weights
    assert!(m1.mean_loss > 1.0 && m1.mean_loss < 5.0, "{}", m1.mean_loss);
    // sparsity stats are consistent: sum_sigma in (0, n), active <= n
    assert!(m1.sum_sigma > 0.0 && m1.sum_sigma < n as f32);
    assert!(m1.active >= 0.0 && m1.active <= n as f32);
}

#[test]
fn local_train_seed_matters_stochastic_only() {
    let rt = load_tiny();
    let scores = rand_vec(rt.manifest.n_params, 0.1, 5);
    let (xs, ys) = training_inputs(&rt, 9);
    let (a, _) = rt.local_train(&scores, &xs, &ys, 1, 0.0, 0.1, false, true).unwrap();
    let (b, _) = rt.local_train(&scores, &xs, &ys, 2, 0.0, 0.1, false, true).unwrap();
    assert_ne!(a, b, "different Bernoulli streams must differ");
    // deterministic mode ignores the seed entirely
    let (c, _) = rt.local_train(&scores, &xs, &ys, 1, 0.0, 0.1, true, true).unwrap();
    let (d, _) = rt.local_train(&scores, &xs, &ys, 2, 0.0, 0.1, true, true).unwrap();
    assert_eq!(c, d, "FedMask mode must be seed-independent");
}

#[test]
fn regularizer_reduces_sum_sigma() {
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let scores = vec![0.0f32; n]; // theta = 0.5 everywhere
    let (xs, ys) = training_inputs(&rt, 11);
    let (_, m_reg) = rt.local_train(&scores, &xs, &ys, 3, 5.0, 0.1, false, true).unwrap();
    let (_, m_noreg) = rt.local_train(&scores, &xs, &ys, 3, 0.0, 0.1, false, true).unwrap();
    assert!(
        m_reg.sum_sigma < m_noreg.sum_sigma - 0.01 * n as f32,
        "reg={} noreg={}",
        m_reg.sum_sigma,
        m_noreg.sum_sigma
    );
}

#[test]
fn eval_mask_counts_match_expectations() {
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let dim = rt.manifest.input_dim;
    // all-zero mask -> logits all zero -> argmax = class 0
    let t = 100;
    let x = rand_vec(t * dim, 1.0, 13);
    let mut rng = Xoshiro256::new(14);
    let y: Vec<i32> = (0..t).map(|_| rng.below(10) as i32).collect();
    let zeros = vec![0.0f32; n];
    let m = rt.eval_mask(&zeros, &x, &y).unwrap();
    let class0 = y.iter().filter(|&&v| v == 0).count() as f64;
    assert_eq!(m.examples, t);
    assert_eq!(m.correct, class0, "empty subnetwork predicts argmax=0");
    // full mask: finite loss, correct count within [0, t]
    let ones = vec![1.0f32; n];
    let m = rt.eval_mask(&ones, &x, &y).unwrap();
    assert!(m.correct <= t as f64);
    assert!(m.mean_loss().is_finite() && m.mean_loss() > 0.0);
}

#[test]
fn eval_chunking_is_exact_across_boundary() {
    // sizes straddling the exported eval_chunk must give identical
    // totals to a manual split
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let dim = rt.manifest.input_dim;
    let chunk = rt.manifest.eval_chunk;
    let total = chunk + chunk / 2 + 3;
    let x = rand_vec(total * dim, 1.0, 17);
    let mut rng = Xoshiro256::new(18);
    let y: Vec<i32> = (0..total).map(|_| rng.below(10) as i32).collect();
    let mask: Vec<f32> =
        (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let whole = rt.eval_mask(&mask, &x, &y).unwrap();
    // manual split at an arbitrary boundary
    let cut = 77;
    let a = rt.eval_mask(&mask, &x[..cut * dim], &y[..cut]).unwrap();
    let b = rt.eval_mask(&mask, &x[cut * dim..], &y[cut..]).unwrap();
    assert_eq!(whole.correct, a.correct + b.correct);
    assert!((whole.loss_sum - (a.loss_sum + b.loss_sum)).abs() < 1e-2);
}

#[test]
fn dense_grad_finite_and_descends() {
    let rt = load_tiny();
    let m = &rt.manifest;
    let mut w = rt.weights().to_vec();
    let rows = m.batch;
    let x = rand_vec(rows * m.input_dim, 1.0, 19);
    let mut rng = Xoshiro256::new(20);
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    let (_, loss0, _) = rt.dense_grad(&w, &x, &y).unwrap();
    for _ in 0..8 {
        let (g, _, _) = rt.dense_grad(&w, &x, &y).unwrap();
        assert!(g.iter().all(|v| v.is_finite()));
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= 0.2 * gi;
        }
    }
    let (_, loss1, _) = rt.dense_grad(&w, &x, &y).unwrap();
    assert!(loss1 < loss0, "descent failed: {loss0} -> {loss1}");
}

#[test]
fn dense_grad_padding_rows_are_ignored() {
    let rt = load_tiny();
    let m = &rt.manifest;
    let w = rt.weights().to_vec();
    let rows = m.batch / 2; // ragged: runtime pads with y=-1
    let x = rand_vec(rows * m.input_dim, 1.0, 21);
    let mut rng = Xoshiro256::new(22);
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    let (g_half, loss_half, correct_half) = rt.dense_grad(&w, &x, &y).unwrap();
    assert!(correct_half <= rows as f32);
    assert!(loss_half.is_finite());
    assert!(g_half.iter().any(|&v| v != 0.0));
}

#[test]
fn weights_match_manifest_and_stay_frozen() {
    let rt = load_tiny();
    let w0 = rt.weights().to_vec();
    let (xs, ys) = training_inputs(&rt, 23);
    let scores = vec![0.0f32; rt.manifest.n_params];
    let _ = rt.local_train(&scores, &xs, &ys, 1, 1.0, 0.5, false, true).unwrap();
    assert_eq!(rt.weights(), &w0[..], "frozen weights must never change");
}
