//! Integration tests over the model runtime: load a model (exported
//! artifacts when present, the built-in native registry otherwise),
//! execute the three programs, and check the numerics against
//! host-side math — including a finite-difference gradient check of
//! the conv layer-graph path (DESIGN.md §Compute-core).

use std::path::Path;

use fedsrn::runtime::{Manifest, ModelRuntime};
use fedsrn::util::{sigmoid, SeedSequence, Xoshiro256};

fn load_tiny() -> ModelRuntime {
    ModelRuntime::load(Path::new("artifacts"), "mlp_tiny")
        .expect("mlp_tiny must resolve (artifact or built-in)")
}

fn load_conv_tiny() -> ModelRuntime {
    ModelRuntime::load(Path::new("artifacts"), "conv_tiny")
        .expect("conv_tiny must resolve from the built-in registry")
}

fn rand_vec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| (rng.next_normal() as f32) * scale).collect()
}

fn training_inputs(rt: &ModelRuntime, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let m = &rt.manifest;
    let mut rng = Xoshiro256::new(seed);
    let xs = rand_vec(m.steps * m.batch * m.input_dim, 1.0, seed ^ 1);
    let ys: Vec<i32> =
        (0..m.steps * m.batch).map(|_| rng.below(m.n_classes as u64) as i32).collect();
    (xs, ys)
}

#[test]
fn local_train_executes_and_is_deterministic() {
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let scores = rand_vec(n, 0.1, 3);
    let (xs, ys) = training_inputs(&rt, 7);
    let (s1, m1) = rt.local_train(&scores, &xs, &ys, 42, 0.0, 0.1, false, true).unwrap();
    let (s2, m2) = rt.local_train(&scores, &xs, &ys, 42, 0.0, 0.1, false, true).unwrap();
    assert_eq!(s1, s2, "same seed must replay identically");
    assert_eq!(m1.mean_loss, m2.mean_loss);
    assert!(s1.iter().all(|v| v.is_finite()));
    assert_ne!(s1, scores, "training must move the scores");
    // loss should be near ln(10) for random data/weights
    assert!(m1.mean_loss > 1.0 && m1.mean_loss < 5.0, "{}", m1.mean_loss);
    // sparsity stats are consistent: sum_sigma in (0, n), active <= n
    assert!(m1.sum_sigma > 0.0 && m1.sum_sigma < n as f32);
    assert!(m1.active >= 0.0 && m1.active <= n as f32);
}

#[test]
fn local_train_seed_matters_stochastic_only() {
    let rt = load_tiny();
    let scores = rand_vec(rt.manifest.n_params, 0.1, 5);
    let (xs, ys) = training_inputs(&rt, 9);
    let (a, _) = rt.local_train(&scores, &xs, &ys, 1, 0.0, 0.1, false, true).unwrap();
    let (b, _) = rt.local_train(&scores, &xs, &ys, 2, 0.0, 0.1, false, true).unwrap();
    assert_ne!(a, b, "different Bernoulli streams must differ");
    // deterministic mode ignores the seed entirely
    let (c, _) = rt.local_train(&scores, &xs, &ys, 1, 0.0, 0.1, true, true).unwrap();
    let (d, _) = rt.local_train(&scores, &xs, &ys, 2, 0.0, 0.1, true, true).unwrap();
    assert_eq!(c, d, "FedMask mode must be seed-independent");
}

#[test]
fn regularizer_reduces_sum_sigma() {
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let scores = vec![0.0f32; n]; // theta = 0.5 everywhere
    let (xs, ys) = training_inputs(&rt, 11);
    let (_, m_reg) = rt.local_train(&scores, &xs, &ys, 3, 5.0, 0.1, false, true).unwrap();
    let (_, m_noreg) = rt.local_train(&scores, &xs, &ys, 3, 0.0, 0.1, false, true).unwrap();
    assert!(
        m_reg.sum_sigma < m_noreg.sum_sigma - 0.01 * n as f32,
        "reg={} noreg={}",
        m_reg.sum_sigma,
        m_noreg.sum_sigma
    );
}

#[test]
fn eval_mask_counts_match_expectations() {
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let dim = rt.manifest.input_dim;
    // all-zero mask -> logits all zero -> argmax = class 0
    let t = 100;
    let x = rand_vec(t * dim, 1.0, 13);
    let mut rng = Xoshiro256::new(14);
    let y: Vec<i32> = (0..t).map(|_| rng.below(10) as i32).collect();
    let zeros = vec![0.0f32; n];
    let m = rt.eval_mask(&zeros, &x, &y).unwrap();
    let class0 = y.iter().filter(|&&v| v == 0).count() as f64;
    assert_eq!(m.examples, t);
    assert_eq!(m.correct, class0, "empty subnetwork predicts argmax=0");
    // full mask: finite loss, correct count within [0, t]
    let ones = vec![1.0f32; n];
    let m = rt.eval_mask(&ones, &x, &y).unwrap();
    assert!(m.correct <= t as f64);
    assert!(m.mean_loss().is_finite() && m.mean_loss() > 0.0);
}

#[test]
fn eval_chunking_is_exact_across_boundary() {
    // sizes straddling the exported eval_chunk must give identical
    // totals to a manual split
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let dim = rt.manifest.input_dim;
    let chunk = rt.manifest.eval_chunk;
    let total = chunk + chunk / 2 + 3;
    let x = rand_vec(total * dim, 1.0, 17);
    let mut rng = Xoshiro256::new(18);
    let y: Vec<i32> = (0..total).map(|_| rng.below(10) as i32).collect();
    let mask: Vec<f32> =
        (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let whole = rt.eval_mask(&mask, &x, &y).unwrap();
    // manual split at an arbitrary boundary
    let cut = 77;
    let a = rt.eval_mask(&mask, &x[..cut * dim], &y[..cut]).unwrap();
    let b = rt.eval_mask(&mask, &x[cut * dim..], &y[cut..]).unwrap();
    assert_eq!(whole.correct, a.correct + b.correct);
    assert!((whole.loss_sum - (a.loss_sum + b.loss_sum)).abs() < 1e-2);
}

#[test]
fn dense_grad_finite_and_descends() {
    let rt = load_tiny();
    let m = &rt.manifest;
    let mut w = rt.weights().to_vec();
    let rows = m.batch;
    let x = rand_vec(rows * m.input_dim, 1.0, 19);
    let mut rng = Xoshiro256::new(20);
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    let (_, loss0, _) = rt.dense_grad(&w, &x, &y).unwrap();
    for _ in 0..8 {
        let (g, _, _) = rt.dense_grad(&w, &x, &y).unwrap();
        assert!(g.iter().all(|v| v.is_finite()));
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= 0.2 * gi;
        }
    }
    let (_, loss1, _) = rt.dense_grad(&w, &x, &y).unwrap();
    assert!(loss1 < loss0, "descent failed: {loss0} -> {loss1}");
}

#[test]
fn dense_grad_padding_rows_are_ignored() {
    let rt = load_tiny();
    let m = &rt.manifest;
    let w = rt.weights().to_vec();
    let rows = m.batch / 2; // ragged: runtime pads with y=-1
    let x = rand_vec(rows * m.input_dim, 1.0, 21);
    let mut rng = Xoshiro256::new(22);
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    let (g_half, loss_half, correct_half) = rt.dense_grad(&w, &x, &y).unwrap();
    assert!(correct_half <= rows as f32);
    assert!(loss_half.is_finite());
    assert!(g_half.iter().any(|&v| v != 0.0));
}

#[test]
fn conv_forward_backward_matches_finite_differences() {
    // Central finite differences on the dense_grad loss, across every
    // parameterized layer of the conv graph (conv -> relu -> pool ->
    // flatten -> dense), validate the im2col/col2im/pool backward path.
    let rt = load_conv_tiny();
    let m = &rt.manifest;
    let rows = 4;
    let x = rand_vec(rows * m.input_dim, 0.7, 31);
    let mut rng = Xoshiro256::new(32);
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    let w0 = rt.weights().to_vec();
    let (grads, loss0, _) = rt.dense_grad(&w0, &x, &y).unwrap();
    assert!(loss0.is_finite() && grads.iter().all(|v| v.is_finite()));

    // check the largest-|g| coordinates of each layer (conv block is
    // [0, 72), dense block [72, 1352)) plus a couple of fixed ones
    let top = |lo: usize, hi: usize, k: usize| -> Vec<usize> {
        let mut idx: Vec<usize> = (lo..hi).collect();
        idx.sort_by(|&a, &b| grads[b].abs().partial_cmp(&grads[a].abs()).unwrap());
        idx.truncate(k);
        idx
    };
    let mut probes = top(0, 72, 3);
    probes.extend(top(72, m.n_params, 3));
    probes.extend([7, 500]);
    // A wrong backward (transposed im2col, bad offsets, mis-routed pool
    // gradient) is off by ~100% on most coordinates; a relu/pool kink
    // inside the +-eps window can distort one probe slightly. Require
    // every probe loosely right and all but one tightly right.
    let eps = 5e-3f32;
    let mut loose_bad = 0;
    let mut tight_bad = 0;
    for j in probes {
        let mut wp = w0.clone();
        wp[j] += eps;
        let (_, lp, _) = rt.dense_grad(&wp, &x, &y).unwrap();
        wp[j] = w0[j] - eps;
        let (_, lm, _) = rt.dense_grad(&wp, &x, &y).unwrap();
        let fd = (lp as f64 - lm as f64) / (2.0 * eps as f64);
        let g = grads[j] as f64;
        let rel = (fd - g).abs() / (fd.abs() + g.abs()).max(1e-3);
        if rel > 0.05 {
            tight_bad += 1;
            eprintln!("param {j}: finite diff {fd} vs analytic {g} (rel {rel:.4})");
        }
        if rel > 0.3 {
            loose_bad += 1;
        }
    }
    assert_eq!(loose_bad, 0, "gradients grossly wrong on {loose_bad} probes");
    assert!(tight_bad <= 1, "{tight_bad} probes outside 5% of finite differences");
}

#[test]
fn conv_local_train_is_deterministic_and_learns_sparsity() {
    // The masked-STE path through the conv graph: replayable, finite,
    // and responsive to the regularizer — same contract as the MLPs.
    let rt = load_conv_tiny();
    let n = rt.manifest.n_params;
    let scores = vec![0.0f32; n];
    let (xs, ys) = training_inputs(&rt, 41);
    let (s1, m1) = rt.local_train(&scores, &xs, &ys, 5, 0.0, 0.1, false, true).unwrap();
    let (s2, _) = rt.local_train(&scores, &xs, &ys, 5, 0.0, 0.1, false, true).unwrap();
    assert_eq!(s1, s2, "same seed must replay identically");
    assert!(s1.iter().all(|v| v.is_finite()));
    assert_ne!(s1, scores, "training must move the scores");
    assert!(m1.mean_loss > 1.0 && m1.mean_loss < 5.0, "{}", m1.mean_loss);
    let (_, m_reg) = rt.local_train(&scores, &xs, &ys, 5, 5.0, 0.1, false, true).unwrap();
    assert!(
        m_reg.sum_sigma < m1.sum_sigma - 0.01 * n as f32,
        "regularizer must prune: reg={} noreg={}",
        m_reg.sum_sigma,
        m1.sum_sigma
    );
}

#[test]
fn eval_mask_ignores_padding_rows() {
    // y < 0 rows must contribute nothing — including to the `examples`
    // denominator (the seed counted them, skewing accuracy/mean_loss).
    let rt = load_tiny();
    let n = rt.manifest.n_params;
    let dim = rt.manifest.input_dim;
    let valid = 50;
    let pad = 14;
    let x = rand_vec((valid + pad) * dim, 1.0, 61);
    let mut rng = Xoshiro256::new(62);
    let mut y: Vec<i32> = (0..valid).map(|_| rng.below(10) as i32).collect();
    y.extend(std::iter::repeat(-1).take(pad));
    let mask: Vec<f32> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
    let padded = rt.eval_mask(&mask, &x, &y).unwrap();
    let clean = rt.eval_mask(&mask, &x[..valid * dim], &y[..valid]).unwrap();
    assert_eq!(padded.examples, valid, "padding rows must not count as examples");
    assert_eq!(padded.correct, clean.correct);
    assert!((padded.loss_sum - clean.loss_sum).abs() < 1e-9);
    assert_eq!(padded.accuracy(), clean.accuracy());
    assert_eq!(padded.mean_loss(), clean.mean_loss());
}

#[test]
fn sparsity_probe_stream_is_domain_separated() {
    use fedsrn::runtime::native::SPARSITY_PROBE_CHILD;
    // The seed probed final sparsity from `root.child(0x5EED)`, which
    // collides with the per-step stream `root.child(h)` once a call
    // runs more than 0x5EED steps. Drive a tiny model past that point
    // and verify the probe comes from the reserved child path.
    assert!(SPARSITY_PROBE_CHILD > 0x5EED, "probe must outrun any step index");
    let steps = 0x5EED + 1;
    let mut man = Manifest::builtin("mlp_tiny").unwrap();
    // shrink to a 4->2 single dense layer so 23278 steps stay cheap
    man.layers = fedsrn::mask::parse_layout("4x2@0").unwrap();
    man.n_params = 8;
    man.input_dim = 4;
    man.n_classes = 2;
    man.batch = 1;
    man.steps = steps;
    let rt = ModelRuntime::from_manifest(man).unwrap();
    let scores = vec![0.25f32; 8];
    let xs = rand_vec(steps * 4, 1.0, 71);
    let mut rng = Xoshiro256::new(72);
    let ys: Vec<i32> = (0..steps).map(|_| rng.below(2) as i32).collect();
    let seed = 7;
    let (s_out, met) =
        rt.local_train(&scores, &xs, &ys, seed, 0.5, 0.05, false, false).unwrap();
    let (s_rep, met_rep) =
        rt.local_train(&scores, &xs, &ys, seed, 0.5, 0.05, false, false).unwrap();
    assert_eq!(s_out, s_rep, "determinism must hold past 0x5EED steps");
    assert_eq!(met.active, met_rep.active);
    // The probe must replay from the reserved path — not from the
    // colliding step stream.
    let root = SeedSequence::new(seed as u32 as u64);
    let mut u_probe = vec![0.0f32; 8];
    root.child(SPARSITY_PROBE_CHILD).philox().fill_uniform(0, &mut u_probe);
    let expect_active = s_out
        .iter()
        .zip(&u_probe)
        .filter(|(&s, &u)| u < sigmoid(s))
        .count() as f32;
    assert_eq!(met.active, expect_active, "probe must use the reserved child");
    let mut u_step = vec![0.0f32; 8];
    root.child(0x5EED).philox().fill_uniform(0, &mut u_step);
    assert_ne!(u_probe, u_step, "probe and step 0x5EED streams must differ");
}

#[test]
fn dense_grad_accepts_batches_larger_than_manifest_batch() {
    // The native graph has no fixed-batch program: rows > manifest
    // batch must work, and the mean-CE gradient must equal the
    // row-count-weighted combination of split-batch gradients.
    let rt = load_tiny();
    let m = &rt.manifest;
    let w = rt.weights().to_vec();
    let rows = m.batch * 2 + 3;
    let x = rand_vec(rows * m.input_dim, 1.0, 81);
    let mut rng = Xoshiro256::new(82);
    let y: Vec<i32> = (0..rows).map(|_| rng.below(10) as i32).collect();
    let (g_all, loss_all, correct_all) = rt.dense_grad(&w, &x, &y).unwrap();
    assert!(g_all.iter().all(|v| v.is_finite()));
    let cut = m.batch;
    let (g_a, loss_a, corr_a) = rt.dense_grad(&w, &x[..cut * m.input_dim], &y[..cut]).unwrap();
    let (g_b, loss_b, corr_b) = rt.dense_grad(&w, &x[cut * m.input_dim..], &y[cut..]).unwrap();
    let (na, nb) = (cut as f64, (rows - cut) as f64);
    assert_eq!(correct_all, corr_a + corr_b);
    let loss_ref = (na * loss_a as f64 + nb * loss_b as f64) / (na + nb);
    assert!((loss_all as f64 - loss_ref).abs() < 1e-4, "{loss_all} vs {loss_ref}");
    for (j, (&g, (&ga, &gb))) in g_all.iter().zip(g_a.iter().zip(&g_b)).enumerate() {
        let g_ref = (na * ga as f64 + nb * gb as f64) / (na + nb);
        assert!(
            (g as f64 - g_ref).abs() < 1e-4,
            "param {j}: {g} vs weighted split {g_ref}"
        );
    }
}

#[test]
fn weights_match_manifest_and_stay_frozen() {
    let rt = load_tiny();
    let w0 = rt.weights().to_vec();
    let (xs, ys) = training_inputs(&rt, 23);
    let scores = vec![0.0f32; rt.manifest.n_params];
    let _ = rt.local_train(&scores, &xs, &ys, 1, 1.0, 0.5, false, true).unwrap();
    assert_eq!(rt.weights(), &w0[..], "frozen weights must never change");
}
