//! End-to-end federation tests: full experiments through the real PJRT
//! runtime on the tiny model — every algorithm, both partitions.
//!
//! These are the system-level correctness gates: they assert the
//! *paper's qualitative claims* hold on the small synthetic task
//! (learning happens, the regularizer buys Bpp, baselines behave).

use fedsrn::compress::DownlinkMode;
use fedsrn::config::{Algorithm, ExperimentConfig, Partition};
use fedsrn::coordinator::Experiment;
use fedsrn::fl::MetricsSink;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.dataset = "tiny".into();
    cfg.clients = 6;
    cfg.rounds = 12;
    cfg.train_samples = 900;
    cfg.test_samples = 240;
    cfg.lr = 0.1;
    cfg.lambda = 0.0;
    cfg.seed = 99;
    cfg
}

fn run(cfg: ExperimentConfig) -> (fedsrn::coordinator::RunSummary, Vec<fedsrn::fl::RoundRecord>) {
    let mut sink = MetricsSink::new("", 1000).unwrap();
    let mut exp = Experiment::build(cfg).unwrap();
    let summary = exp.run(&mut sink).unwrap();
    (summary, sink.records().to_vec())
}

#[test]
fn fedpm_learns_iid() {
    let (summary, recs) = run(base_cfg());
    assert!(
        summary.final_accuracy > 0.8,
        "FedPM should learn the tiny task: acc={}",
        summary.final_accuracy
    );
    // consistent objective -> ~1 Bpp forever (the paper's complaint)
    assert!(summary.avg_est_bpp > 0.95, "bpp={}", summary.avg_est_bpp);
    assert!(recs.len() == 12);
    // accuracy should improve over the run
    assert!(recs.last().unwrap().accuracy > recs[0].accuracy);
}

#[test]
fn regularizer_buys_bpp_without_accuracy_loss() {
    let (base, _) = run(base_cfg());
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::FedPMReg;
    cfg.lambda = 3.0;
    let (reg, recs) = run(cfg);
    assert!(
        reg.avg_est_bpp < base.avg_est_bpp - 0.05,
        "regularizer must reduce Bpp: {} vs {}",
        reg.avg_est_bpp,
        base.avg_est_bpp
    );
    assert!(
        reg.final_accuracy > base.final_accuracy - 0.1,
        "acc must not collapse: {} vs {}",
        reg.final_accuracy,
        base.final_accuracy
    );
    // Bpp should DECREASE over rounds under regularization
    let early = recs[1].est_bpp;
    let late = recs.last().unwrap().est_bpp;
    assert!(late < early, "est_bpp should fall: {early} -> {late}");
    // sparse model stores smaller
    assert!(reg.storage_bits < base.storage_bits);
}

#[test]
fn noniid_partitions_run_and_learn() {
    let mut cfg = base_cfg();
    cfg.clients = 10;
    cfg.partition = Partition::NonIid { c: 2 };
    cfg.rounds = 15;
    let (summary, _) = run(cfg);
    // non-IID with c=2: per-device eval over 2 classes; chance = 0.5
    assert!(
        summary.final_accuracy > 0.6,
        "non-IID accuracy {}",
        summary.final_accuracy
    );
}

#[test]
fn fedmask_runs_deterministically() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::FedMask;
    cfg.rounds = 6;
    let (a, _) = run(cfg.clone());
    let (b, _) = run(cfg);
    assert_eq!(a.final_accuracy, b.final_accuracy, "same seed, same result");
    assert!(a.avg_est_bpp <= 1.0);
}

#[test]
fn topk_controls_uplink_density() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::TopK;
    cfg.topk_frac = 0.2;
    cfg.rounds = 6;
    let (summary, recs) = run(cfg);
    // H(0.2) = 0.72 bits: the est Bpp must sit near that, not 1.0
    assert!(
        (0.55..0.85).contains(&summary.avg_est_bpp),
        "topk bpp {}",
        summary.avg_est_bpp
    );
    assert!(recs.iter().all(|r| r.est_bpp < 0.9));
}

#[test]
fn signsgd_trains_dense_weights_at_one_bpp() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::SignSGD;
    cfg.rounds = 40; // one minibatch step per round needs more rounds
    cfg.server_lr = 0.005;
    let (summary, recs) = run(cfg);
    // sign bits ~ 1 Bpp
    assert!((0.9..1.05).contains(&summary.avg_est_bpp), "{}", summary.avg_est_bpp);
    // learns at least somewhat above chance
    assert!(summary.final_accuracy > 0.3, "{}", summary.final_accuracy);
    // dense storage (no seed+mask trick)
    assert_eq!(summary.storage_bits, 4736 * 32);
    assert!(recs.last().unwrap().accuracy >= recs[0].accuracy);
}

#[test]
fn fedavg_reference_point_is_32bpp_and_accurate() {
    let mut cfg = base_cfg();
    cfg.algorithm = Algorithm::FedAvg;
    cfg.rounds = 8;
    cfg.server_lr = 0.1; // dense local lr
    let (summary, _) = run(cfg);
    assert!((summary.avg_est_bpp - 32.0).abs() < 1e-9);
    assert!(summary.final_accuracy > 0.8, "{}", summary.final_accuracy);
}

#[test]
fn qdelta8_downlink_under_4bpp_with_matched_accuracy() {
    // The fig-1-shaped IID acceptance check: switching the downlink from
    // raw floats to qdelta8 must cut measured DL Bpp below 4.0 (vs 32.0)
    // while final accuracy stays matched, with the uplink untouched. The
    // drift guard is 3 points on this 240-sample eval (1 point = 2.4
    // samples, inside per-run granularity); the paper-scale fig-1 config
    // is where the 1-point budget is meaningful.
    let mk = |downlink| {
        let mut cfg = base_cfg();
        cfg.algorithm = Algorithm::FedPMReg;
        cfg.lambda = 1.0;
        cfg.clients = 10;
        cfg.rounds = 30;
        cfg.downlink = downlink;
        cfg
    };
    let (base, _) = run(mk(DownlinkMode::Float32));
    let (q, recs) = run(mk(DownlinkMode::QDelta { bits: 8 }));
    // measured = actual serialized envelope: raw floats (32 Bpp) plus a
    // few header bytes amortized over n_params
    assert!(
        base.avg_dl_bpp >= 32.0 && base.avg_dl_bpp < 32.05,
        "float32 DL must measure ~32 Bpp (raw floats + envelope header), got {}",
        base.avg_dl_bpp
    );
    assert!(q.avg_dl_bpp < 4.0, "qdelta8 measured DL Bpp {}", q.avg_dl_bpp);
    assert!(
        (q.final_accuracy - base.final_accuracy).abs() < 0.03,
        "accuracy drifted: qdelta {} vs float32 {}",
        q.final_accuracy,
        base.final_accuracy
    );
    // the uplink codec path is untouched by the downlink mode
    assert!(
        (q.avg_est_bpp - base.avg_est_bpp).abs() < 0.2,
        "uplink est Bpp moved: {} vs {}",
        q.avg_est_bpp,
        base.avg_est_bpp
    );
    // round 1 is the dense bootstrap; steady-state rounds are cheap
    assert!(recs[0].dl_bpp > 31.0, "first broadcast is dense, got {}", recs[0].dl_bpp);
    assert!(
        recs.last().unwrap().dl_bpp < 4.0,
        "steady-state DL Bpp {}",
        recs.last().unwrap().dl_bpp
    );
    // totals: DL no longer dominates the uplink by 32x
    assert!(q.total_dl_mb < base.total_dl_mb / 8.0);
}

#[test]
fn comm_accounting_consistency() {
    let mut cfg = base_cfg();
    cfg.rounds = 5;
    let mut sink = MetricsSink::new("", 1000).unwrap();
    let mut exp = Experiment::build(cfg).unwrap();
    let _ = exp.run(&mut sink).unwrap();
    // measured UL bytes: ~K mask envelopes of ~n bits per round
    let expect_bits = 5u64 * 6 * 4736;
    let got = exp.totals.ul_bits;
    assert!(
        got > expect_bits / 2 && got < expect_bits * 2,
        "ul_bits {got} vs expectation ~{expect_bits}"
    );
    // DL accounting = exact serialized theta-broadcast envelope per
    // device per round
    let broadcast_bits = fedsrn::fl::DownlinkMsg::Theta(vec![0.5; 4736]).wire_bits();
    assert_eq!(exp.totals.dl_bits, 5 * 6 * broadcast_bits);
}

#[test]
fn same_seed_same_run_full_system() {
    let (a, ra) = run(base_cfg());
    let (b, rb) = run(base_cfg());
    assert_eq!(a.final_accuracy, b.final_accuracy);
    assert_eq!(a.avg_est_bpp, b.avg_est_bpp);
    for (x, y) in ra.iter().zip(&rb) {
        assert_eq!(x.accuracy, y.accuracy, "round {}", x.round);
        assert_eq!(x.est_bpp, y.est_bpp);
    }
}

#[test]
fn partial_participation_still_learns() {
    let mut cfg = base_cfg();
    cfg.clients = 10;
    cfg.participation = 0.4; // 4 of 10 devices per round
    cfg.rounds = 15;
    let (summary, _) = run(cfg);
    assert!(
        summary.final_accuracy > 0.7,
        "partial participation acc {}",
        summary.final_accuracy
    );
}

#[test]
fn dropout_failure_injection_tolerated() {
    let mut cfg = base_cfg();
    cfg.clients = 8;
    cfg.dropout = 0.4; // ~40% of uplinks vanish mid-round
    cfg.rounds = 12;
    let (summary, recs) = run(cfg);
    // the federation survives and still learns
    assert_eq!(recs.len(), 12, "no round may abort on dropped uplinks");
    assert!(summary.final_accuracy > 0.6, "{}", summary.final_accuracy);
}

#[test]
fn bayes_aggregation_matches_mean_in_the_limit_and_runs() {
    let mut cfg = base_cfg();
    cfg.bayes_prior = 1.0;
    cfg.rounds = 10;
    let (summary, _) = run(cfg);
    assert!(summary.final_accuracy > 0.7, "{}", summary.final_accuracy);
    // prior damping cannot push est Bpp above the 1-bit bound
    assert!(summary.avg_est_bpp <= 1.0 + 1e-9);
}
