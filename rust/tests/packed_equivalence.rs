//! Packed popcount tier == blocked f32 reference (DESIGN.md §Packed-tier).
//!
//! The packed forward path reorders the contraction — sign-select
//! adds over bitplane words instead of per-element multiplies — so it
//! is tolerance-equivalent to the blocked kernels, never bitwise.
//! These tests pin that equivalence for every layer kind the graph
//! executes (dense, conv2d, maxpool, flatten, relu) across mask
//! densities, prove the `compute=packed` runtime knob agrees with the
//! blocked default end-to-end, and prove the packed probe degrades to
//! the blocked path bit-for-bit when the packed contract cannot hold.

use std::path::Path;

use fedsrn::runtime::graph::{Plan, Workspace};
use fedsrn::runtime::packed::PackedModel;
use fedsrn::runtime::{Compute, Manifest, ModelRuntime};
use fedsrn::util::Xoshiro256;

/// A strictly-binary mask at density `p` (endpoints exact: every bit
/// off at 0.0, every bit on at 1.0).
fn mask_at_density(n: usize, p: f64, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            if p <= 0.0 {
                0.0
            } else if p >= 1.0 {
                1.0
            } else if rng.next_f64() < p {
                1.0
            } else {
                0.0
            }
        })
        .collect()
}

fn close(packed: f32, blocked: f32) -> bool {
    (packed - blocked).abs() <= 1e-3 + 1e-3 * blocked.abs()
}

/// Run one model's full graph forward both ways and compare every
/// activation buffer elementwise: Dense/Conv2d land through the packed
/// GEMM, MaxPool/Flatten/Relu must pass the (tolerance-close) values
/// through identically.
fn assert_forward_equivalent(model: &str, p: f64, rows: usize, seed: u64) {
    let man = Manifest::builtin(model).expect("builtin model");
    let plan = Plan::build(&man).expect("plan builds");
    let weights = man.load_weights().expect("weights");
    let mask = mask_at_density(man.n_params, p, seed);
    let pm = PackedModel::try_build(&plan, &weights, &mask)
        .expect("binary mask over signed-constant weights must pack");
    let w_eff: Vec<f32> = weights.iter().zip(&mask).map(|(&w, &m)| w * m).collect();
    let mut rng = Xoshiro256::new(seed ^ 0xABCD);
    let x: Vec<f32> = (0..rows * man.input_dim).map(|_| rng.next_normal() as f32).collect();
    let mut ws_b = Workspace::for_eval(&plan, rows);
    let mut ws_p = Workspace::for_eval(&plan, rows);
    plan.forward(&w_eff, &x, rows, &mut ws_b);
    plan.forward_packed(&pm, &x, rows, &mut ws_p);
    for (buf, (bb, pb)) in ws_b.acts.iter().zip(&ws_p.acts).enumerate() {
        for (i, (&b, &pv)) in bb.iter().zip(pb).enumerate() {
            assert!(
                close(pv, b),
                "{model} p={p}: buffer {buf} elem {i}: packed {pv} vs blocked {b}"
            );
        }
    }
}

#[test]
fn dense_relu_stack_matches_blocked_at_all_densities() {
    for (i, &p) in [0.0, 0.01, 0.5, 1.0].iter().enumerate() {
        assert_forward_equivalent("mlp_tiny", p, 5, 100 + i as u64);
    }
}

#[test]
fn conv_pool_flatten_stack_matches_blocked_at_all_densities() {
    for (i, &p) in [0.0, 0.01, 0.5, 1.0].iter().enumerate() {
        assert_forward_equivalent("conv_tiny", p, 3, 200 + i as u64);
    }
}

/// End-to-end: the `compute=packed` knob routes `eval_mask` through
/// the packed tier and produces the same metrics the blocked default
/// does, up to the kernel tolerance. `correct` may differ only where a
/// borderline argmax tie flips under the reordered sum.
#[test]
fn eval_mask_packed_agrees_with_blocked_end_to_end() {
    for model in ["mlp_tiny", "conv_tiny"] {
        let mut rt =
            ModelRuntime::load(Path::new("artifacts"), model).expect("model resolves");
        let man = &rt.manifest;
        let (n, dim, classes) = (man.n_params, man.input_dim, man.n_classes);
        let mask = mask_at_density(n, 0.5, 17);
        let mut rng = Xoshiro256::new(23);
        let x: Vec<f32> = (0..64 * dim).map(|_| rng.next_normal() as f32).collect();
        let y: Vec<i32> = (0..64).map(|_| rng.below(classes as u64) as i32).collect();
        let mb = rt.eval_mask(&mask, &x, &y).unwrap();
        rt.set_compute(Compute::Packed);
        let mp = rt.eval_mask(&mask, &x, &y).unwrap();
        assert_eq!(mb.examples, mp.examples, "{model}");
        assert!(
            (mb.loss_sum - mp.loss_sum).abs() <= 1e-3 * (1.0 + mb.loss_sum.abs()),
            "{model}: packed loss_sum {} vs blocked {}",
            mp.loss_sum,
            mb.loss_sum
        );
        assert!(
            (mb.correct - mp.correct).abs() <= 1.0,
            "{model}: packed correct {} vs blocked {}",
            mp.correct,
            mb.correct
        );
    }
}

/// A soft (probabilistic) mask violates the packed contract; the probe
/// must reject it and `compute=packed` must fall through to the
/// blocked path bit-for-bit — the knob can never change semantics for
/// inputs the packed tier cannot represent.
#[test]
fn packed_probe_falls_back_bitwise_on_soft_masks() {
    let mut rt =
        ModelRuntime::load(Path::new("artifacts"), "mlp_tiny").expect("model resolves");
    let plan = Plan::build(&rt.manifest).expect("plan builds");
    let (n, dim, classes) =
        (rt.manifest.n_params, rt.manifest.input_dim, rt.manifest.n_classes);
    let mut rng = Xoshiro256::new(31);
    let mask: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
    assert!(
        PackedModel::try_build(&plan, rt.weights(), &mask).is_none(),
        "a soft mask must not pack"
    );
    let x: Vec<f32> = (0..16 * dim).map(|_| rng.next_normal() as f32).collect();
    let y: Vec<i32> = (0..16).map(|_| rng.below(classes as u64) as i32).collect();
    let mb = rt.eval_mask(&mask, &x, &y).unwrap();
    rt.set_compute(Compute::Packed);
    let mp = rt.eval_mask(&mask, &x, &y).unwrap();
    assert_eq!(
        mb.loss_sum.to_bits(),
        mp.loss_sum.to_bits(),
        "fallback must be the blocked path bit-for-bit"
    );
    assert_eq!(mb.correct.to_bits(), mp.correct.to_bits());
}
