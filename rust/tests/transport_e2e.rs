//! Networked-runtime end-to-end test: a loopback federation with a real
//! `Session` server and independent `run_device` fleets — real threads,
//! real TCP sockets, every hop framed by `fl::transport` — must be
//! **bit-identical** to the in-process `RoundEngine` path, for every
//! strategy family and both downlink wire formats. This is the proof
//! that `fedsrn serve` / `fedsrn device` compute the same federation
//! `fedsrn train` simulates, down to the last accuracy bit and the last
//! accounted byte.

use std::thread;
use std::time::Duration;

use fedsrn::compress::DownlinkMode;
use fedsrn::config::{Algorithm, ExperimentConfig, Partition};
use fedsrn::coordinator::{Experiment, RunSummary};
use fedsrn::fl::{
    run_device, run_fingerprint, ChaosSpec, DelayProfile, DeviceOpts, DeviceReport,
    MetricsSink, Participation, RoundRecord, Session, SessionConfig, SessionStats,
};

fn config(algo: Algorithm, downlink: DownlinkMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.dataset = "tiny".into();
    cfg.algorithm = algo;
    cfg.downlink = downlink;
    cfg.clients = 4;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 256;
    cfg.test_samples = 64;
    cfg.lambda = 1.0;
    cfg.lr = 0.1;
    cfg.server_lr = 0.05;
    cfg.seed = 321;
    cfg
}

fn run_in_process(cfg: &ExperimentConfig) -> (RunSummary, Vec<RoundRecord>) {
    let mut sink = MetricsSink::new("", 10_000).unwrap();
    let mut exp = Experiment::build(cfg.clone()).unwrap();
    let summary = exp.run(&mut sink).unwrap();
    (summary, sink.records().to_vec())
}

/// The same federation over loopback TCP: one `Session` server thread-
/// of-control plus `clients` independent device threads, each running
/// the full `fedsrn device` code path (own data derivation, own shard,
/// own reconstruction state, real handshake and framed envelopes).
fn run_networked(
    cfg: &ExperimentConfig,
) -> (RunSummary, Vec<RoundRecord>, SessionStats, Vec<DeviceReport>) {
    let mut exp = Experiment::build(cfg.clone()).unwrap();
    let fingerprint = run_fingerprint(&exp.cfg, &exp.runtime().manifest);
    let scfg =
        SessionConfig::from_experiment(&exp.cfg, fingerprint, Duration::from_secs(30), 0);
    let mut session = Session::bind("127.0.0.1:0", scfg).unwrap();
    let addr = session.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..cfg.clients)
        .map(|id| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || {
                let opts = DeviceOpts {
                    addr,
                    device_id: id,
                    connect_timeout: Duration::from_secs(30),
                    chaos: None,
                    delay: None,
                    deadline_ticks: u64::MAX,
                };
                run_device(&cfg, &opts)
            })
        })
        .collect();
    session.wait_for_fleet(Duration::from_secs(30)).unwrap();
    let mut sink = MetricsSink::new("", 10_000).unwrap();
    let summary = exp.run_served(&mut session, &mut sink).unwrap();
    session.finish().unwrap();
    let reports: Vec<DeviceReport> =
        handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
    (summary, sink.records().to_vec(), session.stats, reports)
}

fn assert_bit_identical(
    label: &str,
    (ref_sum, ref_recs): &(RunSummary, Vec<RoundRecord>),
    net_sum: &RunSummary,
    net_recs: &[RoundRecord],
) {
    let s = |v: f64| v.to_bits();
    assert_eq!(s(ref_sum.final_accuracy), s(net_sum.final_accuracy), "{label}: accuracy");
    assert_eq!(s(ref_sum.avg_est_bpp), s(net_sum.avg_est_bpp), "{label}: est Bpp");
    assert_eq!(s(ref_sum.avg_coded_bpp), s(net_sum.avg_coded_bpp), "{label}: coded Bpp");
    assert_eq!(s(ref_sum.avg_dl_bpp), s(net_sum.avg_dl_bpp), "{label}: DL Bpp");
    assert_eq!(s(ref_sum.total_ul_mb), s(net_sum.total_ul_mb), "{label}: UL MB");
    assert_eq!(s(ref_sum.total_dl_mb), s(net_sum.total_dl_mb), "{label}: DL MB");
    assert_eq!(ref_sum.storage_bits, net_sum.storage_bits, "{label}: storage");
    assert_eq!(ref_sum.rounds, net_sum.rounds, "{label}: rounds");
    assert_eq!(ref_recs.len(), net_recs.len(), "{label}: record count");
    for (r, n) in ref_recs.iter().zip(net_recs) {
        let round = r.round;
        assert_eq!(r.round, n.round, "{label}");
        // every logged metric except wall-clock must match bit-for-bit
        assert_eq!(s(r.accuracy), s(n.accuracy), "{label} r{round}: accuracy");
        assert_eq!(s(r.loss), s(n.loss), "{label} r{round}: loss");
        assert_eq!(s(r.train_loss), s(n.train_loss), "{label} r{round}: train loss");
        assert_eq!(s(r.est_bpp), s(n.est_bpp), "{label} r{round}: est Bpp");
        assert_eq!(s(r.coded_bpp), s(n.coded_bpp), "{label} r{round}: coded Bpp");
        assert_eq!(s(r.dl_bpp), s(n.dl_bpp), "{label} r{round}: dl Bpp");
        assert_eq!(s(r.mean_theta), s(n.mean_theta), "{label} r{round}: mean theta");
        assert_eq!(s(r.mask_density), s(n.mask_density), "{label} r{round}: density");
    }
}

#[test]
fn loopback_serve_device_bit_identical_to_in_process() {
    // FedMRN is float32-downlink only (the noise seed rides every
    // broadcast; config::validate rejects the qdelta pairing), so it
    // contributes one pair while the others cover both wire formats.
    let mut pairs: Vec<(Algorithm, DownlinkMode)> = Vec::new();
    for algo in [Algorithm::FedPMReg, Algorithm::SignSGD, Algorithm::FedAvg, Algorithm::SpaFL] {
        for downlink in [DownlinkMode::Float32, DownlinkMode::QDelta { bits: 8 }] {
            pairs.push((algo, downlink));
        }
    }
    pairs.push((Algorithm::FedMRN, DownlinkMode::Float32));
    for (algo, downlink) in pairs {
        let cfg = config(algo, downlink);
        let label = format!("{algo:?}/{}", downlink.name());
        let reference = run_in_process(&cfg);
        let (net_sum, net_recs, stats, reports) = run_networked(&cfg);
        assert_bit_identical(&label, &reference, &net_sum, &net_recs);
        // a clean loopback run has no degraded-path events
        assert_eq!(stats.stragglers, 0, "{label}");
        assert_eq!(stats.missing, 0, "{label}");
        assert_eq!(stats.reconnects, 0, "{label}");
        // the transport moved at least the envelope bytes, plus
        // frame headers/checksums/handshakes
        let envelope_bytes = ((net_sum.total_ul_mb + net_sum.total_dl_mb) * 1e6) as u64;
        assert!(
            stats.tx_bytes + stats.rx_bytes > envelope_bytes,
            "{label}: framed bytes {} must exceed envelope bytes {envelope_bytes}",
            stats.tx_bytes + stats.rx_bytes
        );
        // every device saw every broadcast it was owed and trained
        for (id, rep) in reports.iter().enumerate() {
            assert_eq!(rep.trained, cfg.rounds, "{label}: device {id} trained");
            assert_eq!(rep.dropped, 0, "{label}: device {id} dropped");
            assert_eq!(rep.reconnects, 0, "{label}: device {id} reconnects");
        }
    }
}

#[test]
fn loopback_partial_participation_and_dropout_match_simulation() {
    // Sampled cohorts + injected dropout must follow the exact same
    // seeded decisions on both sides of the socket. Pick (by search,
    // deterministically) a seed whose 3 rounds provably exercise both a
    // partial cohort and at least one dropped uplink.
    let mut cfg = config(Algorithm::FedPMReg, DownlinkMode::QDelta { bits: 8 });
    cfg.participation = 0.75;
    cfg.dropout = 0.5;
    cfg.rounds = 3;
    let participation = Participation::new(cfg.participation, cfg.dropout);
    let expected_drops = |seed: u64| -> usize {
        (1..=cfg.rounds)
            .map(|round| {
                let cohort = participation.sample_round(cfg.clients, seed, round);
                cohort
                    .iter()
                    .enumerate()
                    .filter(|(pos, &id)| participation.drops(*pos, seed, round, id))
                    .count()
            })
            .sum()
    };
    cfg.seed = (100..200).find(|&s| expected_drops(s) > 0).unwrap();
    let want_drops = expected_drops(cfg.seed);

    let reference = run_in_process(&cfg);
    let (net_sum, net_recs, stats, reports) = run_networked(&cfg);
    assert_bit_identical("dropout-parity", &reference, &net_sum, &net_recs);
    assert_eq!(stats.stragglers, 0);
    let total_dropped: usize = reports.iter().map(|r| r.dropped).sum();
    assert_eq!(total_dropped, want_drops, "device-side drops follow the seeded model");
    let total_trained: usize = reports.iter().map(|r| r.trained).sum();
    let cohort_sum: usize = (1..=cfg.rounds)
        .map(|round| participation.sample_round(cfg.clients, cfg.seed, round).len())
        .sum();
    assert_eq!(total_trained, cohort_sum, "only cohort members train");
    assert!(cohort_sum < cfg.rounds * cfg.clients, "cohorts must be partial");
}

/// Deterministically pick a seed whose run provably injects at least
/// one dropout while leaving every round at least one surviving uplink
/// (an all-dropped round is a *typed failure* on both sides, not a
/// comparable run).
fn find_dropout_seed(cfg: &ExperimentConfig) -> (u64, usize) {
    let participation = Participation::new(cfg.participation, cfg.dropout);
    let round_drops = |seed: u64| -> Option<usize> {
        let mut total = 0;
        for round in 1..=cfg.rounds {
            let cohort = participation.sample_round(cfg.clients, seed, round);
            let d = cohort
                .iter()
                .enumerate()
                .filter(|(pos, &id)| participation.drops(*pos, seed, round, id))
                .count();
            if d == cohort.len() {
                return None; // a whole cohort lost: typed error, skip
            }
            total += d;
        }
        (total > 0).then_some(total)
    };
    (100..400)
        .find_map(|s| round_drops(s).map(|d| (s, d)))
        .expect("no seed in [100, 400) both drops and survives")
}

#[test]
fn loopback_noniid_dropout_bit_identical_per_strategy() {
    // Non-IID partitioning changes every shard (and, for the mask
    // strategies, every per-device eval target), and seeded dropout
    // must follow the exact same decisions on both sides of the socket
    // — one noniid configuration per strategy family.
    for (algo, downlink) in [
        (Algorithm::FedPMReg, DownlinkMode::QDelta { bits: 8 }),
        (Algorithm::SignSGD, DownlinkMode::Float32),
        (Algorithm::FedAvg, DownlinkMode::QDelta { bits: 8 }),
        (Algorithm::FedMRN, DownlinkMode::Float32),
        (Algorithm::SpaFL, DownlinkMode::QDelta { bits: 8 }),
    ] {
        let mut cfg = config(algo, downlink);
        cfg.partition = Partition::NonIid { c: 2 };
        cfg.participation = 0.75;
        cfg.dropout = 0.5;
        cfg.rounds = 3;
        let (seed, want_drops) = find_dropout_seed(&cfg);
        cfg.seed = seed;
        let label = format!("noniid/{algo:?}/{}", downlink.name());
        let reference = run_in_process(&cfg);
        let (net_sum, net_recs, stats, reports) = run_networked(&cfg);
        assert_bit_identical(&label, &reference, &net_sum, &net_recs);
        assert_eq!(stats.stragglers, 0, "{label}");
        assert_eq!(stats.missing, 0, "{label}");
        let total_dropped: usize = reports.iter().map(|r| r.dropped).sum();
        assert_eq!(total_dropped, want_drops, "{label}: seeded drops over the socket");
    }
}

#[test]
fn fleet_of_256_devices_bit_identical_to_in_process() {
    // The acceptance bar for the readiness loop: one server thread
    // multiplexing 256 real sockets (full `fedsrn device` code path in
    // every thread) computes the same federation as the in-process
    // engine, bit for bit. Partial participation keeps training costs
    // sane while the qdelta chain link still reaches all 256 devices.
    let mut cfg = config(Algorithm::FedPMReg, DownlinkMode::QDelta { bits: 8 });
    cfg.clients = 256;
    cfg.rounds = 1;
    cfg.participation = 0.25;
    cfg.train_samples = 512;
    cfg.test_samples = 32;
    let reference = run_in_process(&cfg);
    let (net_sum, net_recs, stats, reports) = run_networked(&cfg);
    assert_bit_identical("fleet-256", &reference, &net_sum, &net_recs);
    assert_eq!(stats.stragglers, 0);
    assert_eq!(stats.missing, 0);
    assert_eq!(stats.reconnects, 0);
    assert_eq!(stats.protocol_errors, 0);
    let cohort =
        Participation::new(cfg.participation, cfg.dropout).sample_round(cfg.clients, cfg.seed, 1);
    assert!(cohort.len() < cfg.clients, "cohort must be partial");
    let trained: usize = reports.iter().map(|r| r.trained).sum();
    assert_eq!(trained, cohort.len(), "only cohort members train");
    for (id, rep) in reports.iter().enumerate() {
        // the chain link reached every device, cohort member or not
        assert_eq!(rep.rounds_seen, cfg.rounds, "device {id} rounds_seen");
        assert_eq!(rep.reconnects, 0, "device {id} reconnects");
    }
}

#[test]
fn chaos_schedules_end_bit_identical_or_typed() {
    // Whole-session chaos invariant (the session-level extension of the
    // byte-flip torture properties): for 64 seeded chaos schedules —
    // spanning near-clean to heavily faulted — every run must end in
    // either a bit-identical summary (no degradation observed) or a
    // typed dropout/reconnect/error. Never a hang, panic, or a silently
    // wrong aggregate.
    let mut cfg = config(Algorithm::FedPMReg, DownlinkMode::QDelta { bits: 8 });
    cfg.clients = 3;
    cfg.rounds = 2;
    cfg.train_samples = 96;
    cfg.test_samples = 32;
    let reference = run_in_process(&cfg);
    for chaos_seed in 0..64u64 {
        let spec = ChaosSpec::from_seed(chaos_seed);
        let mut exp = Experiment::build(cfg.clone()).unwrap();
        let fingerprint = run_fingerprint(&exp.cfg, &exp.runtime().manifest);
        let scfg =
            SessionConfig::from_experiment(&exp.cfg, fingerprint, Duration::from_secs(2), 0);
        let mut session = Session::bind("127.0.0.1:0", scfg).unwrap();
        let addr = session.local_addr().unwrap().to_string();
        let handles: Vec<_> = (0..cfg.clients)
            .map(|id| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    let opts = DeviceOpts {
                        addr,
                        device_id: id,
                        connect_timeout: Duration::from_secs(2),
                        chaos: Some(spec),
                        delay: None,
                        deadline_ticks: u64::MAX,
                    };
                    run_device(&cfg, &opts)
                })
            })
            .collect();
        // chaos arms only after the handshake: assembly is always clean
        session.wait_for_fleet(Duration::from_secs(30)).unwrap();
        let mut sink = MetricsSink::new("", 10_000).unwrap();
        let outcome = exp.run_served(&mut session, &mut sink);
        let _ = session.finish();
        let stats = session.stats;
        // Close the listener and every server-side socket BEFORE
        // joining the device threads: a device mid-reconnect must see a
        // typed refusal/EOF, not a silent server.
        drop(session);
        let device_trouble = handles
            .into_iter()
            .map(|h| h.join().expect("device thread must never panic"))
            .filter(|r| match r {
                Ok(rep) => rep.reconnects > 0,
                Err(_) => true, // typed device-side failure
            })
            .count();
        match outcome {
            Ok(net_sum) => {
                let degraded = stats.stragglers
                    + stats.missing
                    + stats.reconnects
                    + stats.protocol_errors
                    > 0
                    || device_trouble > 0;
                if !degraded {
                    // nothing faulted its way into the round: the run
                    // must be indistinguishable from the clean path
                    assert_bit_identical(
                        &format!("chaos seed {chaos_seed}"),
                        &reference,
                        &net_sum,
                        sink.records(),
                    );
                }
            }
            Err(e) => {
                // a server-side abort must be the typed round failure
                // (e.g. a whole cohort wiped out mid-round), never a
                // panic or a transport desync
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("round") && msg.contains("failed"),
                    "untyped serve error under chaos seed {chaos_seed}: {msg}"
                );
            }
        }
    }
}

#[test]
fn delay_profile_self_straggler_is_deterministic() {
    // The deadline→dropout path, exercised without wall-clock races: a
    // device whose virtual compute delay always exceeds the tick
    // deadline self-reports `Dropped` every round — no `thread::sleep`,
    // no server-side straggler timer involved — and two runs of the
    // same federation are bit-identical.
    let cfg = config(Algorithm::FedPMReg, DownlinkMode::Float32);
    let run = || {
        let mut exp = Experiment::build(cfg.clone()).unwrap();
        let fingerprint = run_fingerprint(&exp.cfg, &exp.runtime().manifest);
        let scfg =
            SessionConfig::from_experiment(&exp.cfg, fingerprint, Duration::from_secs(30), 0);
        let mut session = Session::bind("127.0.0.1:0", scfg).unwrap();
        let addr = session.local_addr().unwrap().to_string();
        let handles: Vec<_> = (0..cfg.clients)
            .map(|id| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                thread::spawn(move || {
                    let opts = DeviceOpts {
                        addr,
                        device_id: id,
                        connect_timeout: Duration::from_secs(30),
                        chaos: None,
                        // device 3 computes slower than the virtual
                        // deadline every round; everyone else is fast
                        delay: (id == 3).then_some(DelayProfile { base: 500, jitter: 100 }),
                        deadline_ticks: 100,
                    };
                    run_device(&cfg, &opts)
                })
            })
            .collect();
        session.wait_for_fleet(Duration::from_secs(30)).unwrap();
        let mut sink = MetricsSink::new("", 10_000).unwrap();
        let summary = exp.run_served(&mut session, &mut sink).unwrap();
        session.finish().unwrap();
        let reports: Vec<DeviceReport> =
            handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
        (summary, session.stats, reports)
    };
    let (a_sum, a_stats, a_reports) = run();
    let (b_sum, b_stats, b_reports) = run();
    assert_eq!(a_stats.stragglers, 0, "no wall-clock deadline fired");
    assert_eq!(b_stats.stragglers, 0);
    assert_eq!(a_reports[3].trained, cfg.rounds, "the slow device still trains");
    assert_eq!(a_reports[3].dropped, cfg.rounds, "…but self-straggles every round");
    for rep in &a_reports[..3] {
        assert_eq!(rep.dropped, 0, "fast devices never self-straggle");
    }
    assert_eq!(
        a_sum.final_accuracy.to_bits(),
        b_sum.final_accuracy.to_bits(),
        "self-straggling is deterministic"
    );
    assert_eq!(a_reports[3].dropped, b_reports[3].dropped);
}

#[test]
fn mismatched_device_is_rejected_and_fleet_times_out() {
    let cfg = config(Algorithm::FedPMReg, DownlinkMode::Float32);
    let exp = Experiment::build(cfg.clone()).unwrap();
    let fingerprint = run_fingerprint(&exp.cfg, &exp.runtime().manifest);
    let scfg =
        SessionConfig::from_experiment(&exp.cfg, fingerprint, Duration::from_secs(5), 0);
    let mut session = Session::bind("127.0.0.1:0", scfg).unwrap();
    let addr = session.local_addr().unwrap().to_string();
    // a device from a *different* experiment (other seed -> other
    // fingerprint) must be turned away at the handshake
    let mut other = cfg.clone();
    other.seed ^= 1;
    let handle = thread::spawn(move || {
        let opts = DeviceOpts {
            addr,
            device_id: 0,
            connect_timeout: Duration::from_secs(10),
            chaos: None,
            delay: None,
            deadline_ticks: u64::MAX,
        };
        run_device(&other, &opts)
    });
    // wait_for_fleet is what processes (and rejects) the handshake; the
    // imposter never registers, so the fleet times out naming every id
    let err = session.wait_for_fleet(Duration::from_secs(2)).unwrap_err();
    assert!(err.to_string().contains("missing ids"), "{err:#}");
    let err = handle.join().unwrap().unwrap_err();
    assert!(err.to_string().contains("fingerprint"), "{err:#}");
}
