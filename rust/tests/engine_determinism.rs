//! Determinism contract of the parallel round engine: a full experiment
//! must produce bit-identical round metrics and bit-identical final
//! models at ANY worker-thread count — the sequential path (threads = 1)
//! is the reference. See DESIGN.md §Parallel round engine.
//!
//! Every run here goes through the protocol API (DESIGN.md §Protocol):
//! `RoundEngine::run_round` drives `ServerLogic::begin_round` ->
//! `ClientTask` waves -> streaming `fold_uplink` in cohort order ->
//! `end_round`, for all three strategy families, so these tests re-prove
//! the bit-identity contract over typed wire messages — with the same
//! accuracy, est/coded Bpp and DL Bpp at every thread count.

use fedsrn::algos::EvalModel;
use fedsrn::compress::DownlinkMode;
use fedsrn::config::{Algorithm, ExperimentConfig, Partition};
use fedsrn::coordinator::Experiment;
use fedsrn::fl::{MetricsSink, RoundRecord};
use fedsrn::runtime::Compute;

fn base_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        model: "mlp_tiny".into(),
        dataset: "tiny".into(),
        algorithm: Algorithm::FedPMReg,
        lambda: 2.0,
        clients: 8,
        rounds: 5,
        train_samples: 640,
        test_samples: 160,
        lr: 0.1,
        seed: 77,
        threads,
        ..ExperimentConfig::default()
    }
}

/// Run one experiment, returning its per-round records and the final
/// model as exact bit patterns.
fn run(cfg: ExperimentConfig) -> (Vec<RoundRecord>, Vec<u32>) {
    let mut sink = MetricsSink::new("", 10_000).unwrap();
    let mut exp = Experiment::build(cfg).unwrap();
    exp.run(&mut sink).unwrap();
    let model_bits: Vec<u32> = match exp.global_model() {
        EvalModel::Masked(m) => m.iter().map(|v| v.to_bits()).collect(),
        EvalModel::Dense(w) => w.iter().map(|v| v.to_bits()).collect(),
    };
    (sink.records().to_vec(), model_bits)
}

/// Exact equality on every deterministic metric (wall-clock excluded).
fn assert_records_identical(a: &[RoundRecord], b: &[RoundRecord], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: round count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round, "{what}");
        let r = x.round;
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{what} r{r} accuracy");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what} r{r} loss");
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{what} r{r} train_loss");
        assert_eq!(x.est_bpp.to_bits(), y.est_bpp.to_bits(), "{what} r{r} est_bpp");
        assert_eq!(x.coded_bpp.to_bits(), y.coded_bpp.to_bits(), "{what} r{r} coded_bpp");
        assert_eq!(x.dl_bpp.to_bits(), y.dl_bpp.to_bits(), "{what} r{r} dl_bpp");
        assert_eq!(x.mean_theta.to_bits(), y.mean_theta.to_bits(), "{what} r{r} mean_theta");
        assert_eq!(
            x.mask_density.to_bits(),
            y.mask_density.to_bits(),
            "{what} r{r} mask_density"
        );
    }
}

#[test]
fn fedpm_reg_bit_identical_at_1_2_8_threads() {
    let (ref_records, ref_model) = run(base_cfg(1));
    for threads in [2, 8] {
        let (records, model) = run(base_cfg(threads));
        assert_records_identical(&ref_records, &records, &format!("threads={threads}"));
        assert_eq!(ref_model, model, "threads={threads}: final mask must be bit-identical");
    }
}

#[test]
fn every_strategy_is_thread_count_invariant() {
    for algo in [
        Algorithm::FedPM,
        Algorithm::FedMask,
        Algorithm::TopK,
        Algorithm::SignSGD,
        Algorithm::FedAvg,
        Algorithm::FedMRN,
        Algorithm::SpaFL,
    ] {
        let mk = |threads| {
            let mut cfg = base_cfg(threads);
            cfg.algorithm = algo;
            cfg.rounds = 3;
            cfg
        };
        let (ref_records, ref_model) = run(mk(1));
        let (records, model) = run(mk(4));
        assert_records_identical(&ref_records, &records, &format!("{algo:?}"));
        assert_eq!(ref_model, model, "{algo:?}: final model must be bit-identical");
    }
}

#[test]
fn fedmrn_and_spafl_bit_identical_at_1_2_8_threads() {
    // The two newest strategy families get the full thread ladder the
    // seed strategies got: sequential reference, then 2 and 8 workers.
    for algo in [Algorithm::FedMRN, Algorithm::SpaFL] {
        let mk = |threads| {
            let mut cfg = base_cfg(threads);
            cfg.algorithm = algo;
            cfg.rounds = 3;
            cfg
        };
        let (ref_records, ref_model) = run(mk(1));
        for threads in [2, 8] {
            let (records, model) = run(mk(threads));
            assert_records_identical(&ref_records, &records, &format!("{algo:?} threads={threads}"));
            assert_eq!(ref_model, model, "{algo:?} threads={threads}: final model differs");
        }
    }
}

#[test]
fn partial_participation_and_dropout_are_thread_count_invariant() {
    let mk = |threads| {
        let mut cfg = base_cfg(threads);
        cfg.clients = 10;
        cfg.participation = 0.5;
        cfg.dropout = 0.3;
        cfg.rounds = 6;
        cfg
    };
    let (ref_records, ref_model) = run(mk(1));
    for threads in [2, 8] {
        let (records, model) = run(mk(threads));
        assert_records_identical(&ref_records, &records, &format!("threads={threads}"));
        assert_eq!(ref_model, model, "threads={threads}");
    }
}

#[test]
fn qdelta_downlink_bit_identical_at_1_2_8_threads() {
    // The compressed downlink must not weaken the determinism contract:
    // encoding happens once per round on the coordinator thread, so the
    // quantized broadcast — and everything trained on it — is identical
    // at any worker count.
    let mk = |threads| {
        let mut cfg = base_cfg(threads);
        cfg.downlink = DownlinkMode::QDelta { bits: 8 };
        cfg
    };
    let (ref_records, ref_model) = run(mk(1));
    // qdelta actually engaged: downlink cheaper than raw floats
    let avg_dl: f64 =
        ref_records.iter().map(|r| r.dl_bpp).sum::<f64>() / ref_records.len() as f64;
    assert!(avg_dl < 32.0, "qdelta should undercut raw floats, got {avg_dl}");
    for threads in [2, 8] {
        let (records, model) = run(mk(threads));
        assert_records_identical(&ref_records, &records, &format!("qdelta threads={threads}"));
        assert_eq!(ref_model, model, "qdelta threads={threads}: final mask differs");
    }
}

#[test]
fn qdelta_every_strategy_is_thread_count_invariant() {
    // FedMRN is absent by design: config::validate rejects the
    // fedmrn+qdelta pairing (the noise seed must ride every broadcast).
    for algo in [
        Algorithm::FedPM,
        Algorithm::FedMask,
        Algorithm::TopK,
        Algorithm::SignSGD,
        Algorithm::FedAvg,
        Algorithm::SpaFL,
    ] {
        let mk = |threads| {
            let mut cfg = base_cfg(threads);
            cfg.algorithm = algo;
            cfg.downlink = DownlinkMode::QDelta { bits: 4 };
            cfg.rounds = 3;
            cfg
        };
        let (ref_records, ref_model) = run(mk(1));
        let (records, model) = run(mk(4));
        assert_records_identical(&ref_records, &records, &format!("qdelta {algo:?}"));
        assert_eq!(ref_model, model, "qdelta {algo:?}: final model must be bit-identical");
    }
}

#[test]
fn conv_model_bit_identical_at_1_2_8_threads() {
    // The layer-graph compute core (conv_tiny: conv -> relu -> pool ->
    // flatten -> dense, DESIGN.md §Compute-core) must satisfy the same
    // determinism contract as the MLPs: per-round records and the final
    // mask bit-identical at any worker count.
    let mk = |threads| {
        let mut cfg = base_cfg(threads);
        cfg.model = "conv_tiny".into();
        cfg.clients = 4;
        cfg.rounds = 2;
        cfg.train_samples = 320;
        cfg.test_samples = 80;
        cfg
    };
    let (ref_records, ref_model) = run(mk(1));
    assert!(
        ref_records.iter().all(|r| r.accuracy.is_finite() && r.train_loss.is_finite()),
        "conv rounds must produce finite metrics"
    );
    for threads in [2, 8] {
        let (records, model) = run(mk(threads));
        assert_records_identical(&ref_records, &records, &format!("conv threads={threads}"));
        assert_eq!(ref_model, model, "conv threads={threads}: final mask must be bit-identical");
    }
}

#[test]
fn packed_eval_keeps_training_bit_identical_at_1_2_8_threads() {
    // `compute=packed` (DESIGN.md §Packed-tier) reroutes eval-time
    // forward passes only; mask training — STE gradients, aggregation,
    // every uplink — must stay on the f32 path untouched. So a packed
    // run is (a) bit-identical to itself at any worker count and
    // (b) ends on the exact final model of the blocked run; only the
    // evaluated metrics may move, within the packed-kernel tolerance.
    let mk = |threads| {
        let mut cfg = base_cfg(threads);
        cfg.compute = Compute::Packed;
        cfg
    };
    let (ref_records, ref_model) = run(mk(1));
    for threads in [2, 8] {
        let (records, model) = run(mk(threads));
        assert_records_identical(&ref_records, &records, &format!("packed threads={threads}"));
        assert_eq!(ref_model, model, "packed threads={threads}: final mask differs");
    }
    let (blocked_records, blocked_model) = run(base_cfg(1));
    assert_eq!(ref_model, blocked_model, "packed eval must not perturb training");
    for (p, b) in ref_records.iter().zip(&blocked_records) {
        assert_eq!(p.train_loss.to_bits(), b.train_loss.to_bits(), "r{}", p.round);
        assert!(
            (p.accuracy - b.accuracy).abs() <= 0.05,
            "r{}: packed accuracy {} vs blocked {}",
            p.round,
            p.accuracy,
            b.accuracy
        );
    }
}

#[test]
fn noniid_partition_is_thread_count_invariant() {
    let mk = |threads| {
        let mut cfg = base_cfg(threads);
        cfg.clients = 10;
        cfg.partition = Partition::NonIid { c: 2 };
        cfg.rounds = 4;
        cfg
    };
    let (ref_records, ref_model) = run(mk(1));
    let (records, model) = run(mk(8));
    assert_records_identical(&ref_records, &records, "noniid");
    assert_eq!(ref_model, model);
}
