//! The same step against caller-provided buffers; allocation happens
//! once, outside the fence, like `runtime::graph::Workspace` does it.

pub fn make_scratch(n: usize) -> Vec<f32> {
    vec![0.0f32; n]
}

// audit:no-alloc-begin
pub fn step(xs: &[f32], out: &mut [f32]) {
    for (o, v) in out.iter_mut().zip(xs) {
        *o = v * 2.0;
    }
}
// audit:no-alloc-end
