//! An `unsafe` block carrying its justification. The test feeds this
//! text to the auditor under the one path the budget allows.

pub fn read_first(xs: &[f32]) -> f32 {
    // SAFETY: the caller guarantees xs is non-empty, so the pointer
    // read stays in bounds.
    unsafe { *xs.as_ptr() }
}
