//! Aggregation state behind a hash map and a wall clock: the fold
//! order (and so the float sums) would differ from run to run.
//!
//! audit: deterministic

use std::collections::HashMap;
use std::time::Instant;

pub fn fold(scores: &HashMap<u32, f32>) -> f32 {
    let t0 = Instant::now();
    let mut acc = 0.0;
    for v in scores.values() {
        acc += *v;
    }
    let _ = t0.elapsed();
    acc
}
