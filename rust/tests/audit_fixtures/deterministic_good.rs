//! The same fold over an order-stable map. The test module may use
//! whatever it likes: `#[cfg(test)]` items sit outside the policy.
//!
//! audit: deterministic

use std::collections::BTreeMap;

pub fn fold(scores: &BTreeMap<u32, f32>) -> f32 {
    scores.values().sum()
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;
    use std::time::Instant;

    #[test]
    fn exempt() {
        let _ = (HashSet::<u8>::new(), Instant::now());
        assert!(super::fold(&super::BTreeMap::new()) == 0.0);
    }
}
