//! The same decoder written the way the policy wants: bounds checked
//! up front, widening casts only, and a waiver naming the local guard.
//!
//! audit: wire-decode

pub fn parse(buf: &[u8], at: usize) -> Option<(u8, u64)> {
    if at >= buf.len() || buf.len() < 3 {
        return None;
    }
    // audit:checked(the bounds test above guarantees at < buf.len())
    let kind = buf[at];
    let len = u64::from(buf[1]) | (u64::from(buf[2]) << 8);
    Some((kind, u64::from(kind) + len))
}
