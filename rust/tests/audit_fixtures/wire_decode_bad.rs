//! A frame decoder that trusts its input. Every flagged line below is
//! a shape the wire-decode policy exists to catch.
//!
//! audit: wire-decode

pub fn parse(buf: &[u8], at: usize) -> (u8, u16) {
    let kind = buf[at];
    let len = u16::from_le_bytes(buf[1..3].try_into().unwrap());
    if kind > 9 {
        panic!("bad frame kind {kind}");
    }
    (kind, buf.len() as u16 + len)
}
