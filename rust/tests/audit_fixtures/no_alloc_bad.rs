//! A "hot loop" that allocates on every step: each banned shape once.

pub fn step(xs: &[f32]) -> Vec<f32> {
    // audit:no-alloc-begin
    let zeros = vec![0.0f32; xs.len()];
    let doubled: Vec<f32> = xs.iter().map(|v| v * 2.0).collect();
    let copy = doubled.to_vec();
    let again = copy.clone();
    // audit:no-alloc-end
    let _ = zeros;
    again
}
