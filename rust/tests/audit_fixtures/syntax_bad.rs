//! Three ways to hold the annotation grammar wrong.
//!
//! audit: wire-safety

// audit:checked()
pub fn nothing() {}

// audit:no-alloc-end
