//! Protocol-level end-to-end test: full federated rounds driven purely
//! over serialized wire bytes — the server and the simulated devices
//! exchange nothing but `Vec<u8>` (downlink envelope, round plan, uplink
//! envelopes), exactly what a real transport would carry. The result
//! must be bit-identical to the in-process `RoundEngine::run_round`
//! path, for every strategy family and both downlink wire formats —
//! proving the envelopes are lossless and the engine adds no hidden
//! side channel.

use fedsrn::algos::{build_server, ClientTask as _, EvalModel, RoundStats, ServerLogic};
use fedsrn::compress::DownlinkMode;
use fedsrn::config::{Algorithm, ExperimentConfig};
use fedsrn::coordinator::RoundEngine;
use fedsrn::data::{partition_iid, Dataset, SynthSpec, Synthetic};
use fedsrn::fl::{
    derive_client_seed, Client, DownlinkMsg, Participation, RoundComm, RoundPlan, UplinkMsg,
};
use fedsrn::runtime::ModelRuntime;

const ROUNDS: usize = 3;

fn config(algo: Algorithm, downlink: DownlinkMode) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp_tiny".into();
    cfg.dataset = "tiny".into();
    cfg.algorithm = algo;
    cfg.downlink = downlink;
    cfg.clients = 4;
    cfg.rounds = ROUNDS;
    cfg.train_samples = 256;
    cfg.lambda = 1.0;
    cfg.lr = 0.1;
    cfg.server_lr = 0.05;
    cfg.seed = 321;
    cfg
}

/// Mirror `Experiment::build`'s data + fleet derivation so both drivers
/// below start from the identical simulated federation.
fn setup(cfg: &ExperimentConfig) -> (ModelRuntime, Dataset, Vec<Client>) {
    let rt = ModelRuntime::load(std::path::Path::new(&cfg.artifacts_dir), &cfg.model).unwrap();
    let mut spec = SynthSpec::by_name(&cfg.dataset).unwrap();
    spec.n_classes = rt.manifest.n_classes;
    let train = Synthetic::new(spec, cfg.seed ^ 0xDA7A).generate(cfg.train_samples, 1);
    let clients: Vec<Client> = partition_iid(&train, cfg.clients, cfg.seed ^ 0x5A)
        .into_iter()
        .map(|s| {
            let seed = derive_client_seed(cfg.seed, s.client_id);
            Client::new(s, seed)
        })
        .collect();
    (rt, train, clients)
}

fn plan_for(cfg: &ExperimentConfig, round: usize) -> RoundPlan {
    RoundPlan {
        round,
        seed: cfg.seed,
        lambda: cfg.effective_lambda(),
        lr: cfg.lr,
        local_epochs: cfg.local_epochs,
        topk_frac: cfg.topk_frac,
        server_lr: cfg.server_lr,
        adam: cfg.adam,
    }
}

/// Everything a run produces, as exact bit patterns.
#[derive(Debug, PartialEq)]
struct Outcome {
    model_bits: Vec<u32>,
    stats_bits: Vec<[u64; 3]>,
    ul_bits: u64,
    dl_bits: u64,
    clients: usize,
    broadcasts: usize,
    est_bpp_bits: Vec<u64>,
}

fn stats_bits(s: &RoundStats) -> [u64; 3] {
    [s.train_loss.to_bits(), s.mean_theta.to_bits(), s.mask_density.to_bits()]
}

fn model_bits(server: &dyn ServerLogic) -> Vec<u32> {
    match server.eval_model(ROUNDS) {
        EvalModel::Masked(m) => m.iter().map(|v| v.to_bits()).collect(),
        EvalModel::Dense(w) => w.iter().map(|v| v.to_bits()).collect(),
    }
}

/// Reference: the in-process engine path every experiment uses.
fn run_in_process(cfg: &ExperimentConfig) -> Outcome {
    let (rt, train, mut clients) = setup(cfg);
    let mut server = build_server(cfg, rt.manifest.n_params, rt.weights(), &rt.manifest.layers);
    let engine = RoundEngine::new(1);
    let mut fleet_state: Option<Vec<f32>> = None;
    let mut out = Outcome {
        model_bits: Vec::new(),
        stats_bits: Vec::new(),
        ul_bits: 0,
        dl_bits: 0,
        clients: 0,
        broadcasts: 0,
        est_bpp_bits: Vec::new(),
    };
    for round in 1..=ROUNDS {
        let mut comm = RoundComm::new(rt.manifest.n_params);
        let stats = engine
            .run_round(
                server.as_mut(),
                &rt,
                &train,
                &mut clients,
                &mut fleet_state,
                Participation::default(),
                &plan_for(cfg, round),
                &mut comm,
            )
            .unwrap();
        out.stats_bits.push(stats_bits(&stats));
        out.ul_bits += comm.ul_bits;
        out.dl_bits += comm.dl_bits;
        out.clients += comm.clients;
        out.broadcasts += comm.broadcasts;
        out.est_bpp_bits.push(comm.est_bpp().to_bits());
    }
    out.model_bits = model_bits(server.as_ref());
    out
}

/// The same federation, but every server<->client hop is a `Vec<u8>`:
/// the broadcast and the round plan travel as serialized bytes to the
/// device side, every uplink travels back as serialized bytes, and each
/// is re-parsed (with full validation) before use.
fn run_over_wire_bytes(cfg: &ExperimentConfig) -> Outcome {
    let (rt, train, mut clients) = setup(cfg);
    let mut server = build_server(cfg, rt.manifest.n_params, rt.weights(), &rt.manifest.layers);
    // the device side's own reconstruction of the broadcast state
    let mut device_state: Option<Vec<f32>> = None;
    let mut out = Outcome {
        model_bits: Vec::new(),
        stats_bits: Vec::new(),
        ul_bits: 0,
        dl_bits: 0,
        clients: 0,
        broadcasts: 0,
        est_bpp_bits: Vec::new(),
    };
    for round in 1..=ROUNDS {
        let mut comm = RoundComm::new(rt.manifest.n_params);
        let plan = plan_for(cfg, round);

        // server -> wire
        let dl_wire: Vec<u8> = server.begin_round(&plan).unwrap().to_bytes();
        let plan_wire: Vec<u8> = plan.to_bytes();

        // wire -> device side
        let dl = DownlinkMsg::from_bytes(&dl_wire).unwrap();
        let device_plan = RoundPlan::from_bytes(&plan_wire).unwrap();
        assert_eq!(device_plan, plan, "the plan must survive the wire");
        // full participation: the cohort is the fleet, so every device
        // receives the broadcast whatever its kind
        for _ in 0..clients.len() {
            comm.add_downlink_msg(&dl);
        }

        // each device computes its uplink and ships bytes back
        let task = server.client_task();
        let prev = device_state.take();
        let mut ul_wires: Vec<Vec<u8>> = Vec::new();
        for client in clients.iter_mut() {
            let up = task
                .run(&rt, &train, client, &dl, prev.as_deref(), &device_plan)
                .unwrap();
            ul_wires.push(up.to_bytes());
        }
        device_state = Some(dl.decode_state(prev.as_deref()).unwrap());

        // wire -> server: parse + fold each envelope as it lands
        for ul_wire in &ul_wires {
            let up = UplinkMsg::from_bytes(ul_wire).unwrap();
            server.fold_uplink(&up, &mut comm).unwrap();
        }
        let stats = server.end_round(&plan).unwrap();

        out.stats_bits.push(stats_bits(&stats));
        out.ul_bits += comm.ul_bits;
        out.dl_bits += comm.dl_bits;
        out.clients += comm.clients;
        out.broadcasts += comm.broadcasts;
        out.est_bpp_bits.push(comm.est_bpp().to_bits());
    }
    out.model_bits = model_bits(server.as_ref());
    out
}

#[test]
fn wire_bytes_round_is_bit_identical_to_in_process() {
    // FedMRN only rides float32 downlinks (the noise seed must be on
    // every broadcast — config::validate rejects the qdelta pairing),
    // so it gets a single-mode entry while the rest cover both modes.
    let mut pairs: Vec<(Algorithm, DownlinkMode)> = Vec::new();
    for algo in [Algorithm::FedPMReg, Algorithm::SignSGD, Algorithm::FedAvg, Algorithm::SpaFL] {
        for downlink in [DownlinkMode::Float32, DownlinkMode::QDelta { bits: 8 }] {
            pairs.push((algo, downlink));
        }
    }
    pairs.push((Algorithm::FedMRN, DownlinkMode::Float32));
    for (algo, downlink) in pairs {
        let cfg = config(algo, downlink);
        let reference = run_in_process(&cfg);
        let wired = run_over_wire_bytes(&cfg);
        assert_eq!(
            reference, wired,
            "{algo:?}/{}: a round driven purely over serialized bytes \
             must match the in-process engine bit-for-bit",
            downlink.name()
        );
        assert!(reference.ul_bits > 0 && reference.dl_bits > 0);
    }
}

#[test]
fn tampered_wire_bytes_never_fold() {
    // A corrupted uplink envelope must be rejected before it can touch
    // the aggregator — the server's fold state stays clean.
    let cfg = config(Algorithm::FedPMReg, DownlinkMode::Float32);
    let (rt, train, mut clients) = setup(&cfg);
    let mut server = build_server(&cfg, rt.manifest.n_params, rt.weights(), &rt.manifest.layers);
    let plan = plan_for(&cfg, 1);
    let dl = DownlinkMsg::from_bytes(&server.begin_round(&plan).unwrap().to_bytes()).unwrap();
    let task = server.client_task();
    let mut comm = RoundComm::new(rt.manifest.n_params);
    let up = task.run(&rt, &train, &mut clients[0], &dl, None, &plan).unwrap();
    let wire = up.to_bytes();
    // flip the version, truncate, and pad — all must fail to parse
    let mut bad = wire.clone();
    bad[0] ^= 0xFF;
    assert!(UplinkMsg::from_bytes(&bad).is_err());
    assert!(UplinkMsg::from_bytes(&wire[..wire.len() - 3]).is_err());
    let mut padded = wire.clone();
    padded.push(7);
    assert!(UplinkMsg::from_bytes(&padded).is_err());
    // the intact envelope still folds
    server.fold_uplink(&UplinkMsg::from_bytes(&wire).unwrap(), &mut comm).unwrap();
    assert_eq!(comm.clients, 1);
}
