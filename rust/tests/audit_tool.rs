//! Integration tests for `fedsrn audit` (DESIGN.md §Static-analysis).
//!
//! Each rule family has a fixture under `tests/audit_fixtures/` that
//! trips it and a twin that passes clean — the fixtures are read as
//! text, never compiled — plus a self-audit proving the shipped source
//! tree satisfies every policy it declares.

use std::fs;
use std::path::Path;

use fedsrn::analysis::{audit_file, audit_tree, UNSAFE_BUDGET_FILES};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/audit_fixtures").join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Audit a fixture under a pretend source-root-relative path and
/// return `(rule, line)` per finding.
fn findings(rel: &str, name: &str) -> Vec<(&'static str, usize)> {
    audit_file(rel, &fixture(name)).iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn wire_decode_fixture_trips_all_four_shapes() {
    let got = findings("fl/fixture.rs", "wire_decode_bad.rs");
    let want =
        [("wire-decode", 7), ("wire-decode", 8), ("wire-decode", 10), ("wire-decode", 12)];
    assert_eq!(got, want, "dynamic index, unwrap, panic!, as-narrowing");
}

#[test]
fn wire_decode_fixture_passes_when_guarded() {
    let got = findings("fl/fixture.rs", "wire_decode_good.rs");
    assert!(got.is_empty(), "{got:?}");
}

#[test]
fn deterministic_fixture_trips_on_clocks_and_hashers() {
    let got = findings("mask/fixture.rs", "deterministic_bad.rs");
    let want = [
        ("deterministic", 6),
        ("deterministic", 7),
        ("deterministic", 9),
        ("deterministic", 10),
    ];
    assert_eq!(got, want, "HashMap and Instant, at use and call sites");
}

#[test]
fn deterministic_fixture_passes_with_ordered_maps() {
    let got = findings("mask/fixture.rs", "deterministic_good.rs");
    assert!(got.is_empty(), "test-module HashSet/Instant must be exempt: {got:?}");
}

#[test]
fn no_alloc_fixture_trips_inside_the_fence() {
    let got = findings("runtime/fixture.rs", "no_alloc_bad.rs");
    let want = [("no-alloc", 5), ("no-alloc", 6), ("no-alloc", 7), ("no-alloc", 8)];
    assert_eq!(got, want, "vec!, collect, to_vec, clone");
}

#[test]
fn no_alloc_fixture_passes_with_workspace_buffers() {
    let got = findings("runtime/fixture.rs", "no_alloc_good.rs");
    assert!(got.is_empty(), "allocation outside the fence is fine: {got:?}");
}

#[test]
fn unsafe_fixture_trips_with_and_without_budget() {
    for file in UNSAFE_BUDGET_FILES {
        let undocumented = findings(file, "unsafe_bad.rs");
        assert_eq!(undocumented, [("unsafe-budget", 4)], "no SAFETY comment in {file}");
    }
    let outside = findings("fl/fixture.rs", "unsafe_bad.rs");
    assert_eq!(outside, [("unsafe-budget", 4)], "outside the budgeted files");
}

#[test]
fn unsafe_fixture_passes_documented_in_budget() {
    for file in UNSAFE_BUDGET_FILES {
        let got = findings(file, "unsafe_good.rs");
        assert!(got.is_empty(), "{file}: {got:?}");
    }
}

#[test]
fn malformed_directives_are_findings_not_silence() {
    let got = findings("fl/fixture.rs", "syntax_bad.rs");
    let want = [("audit-syntax", 3), ("audit-syntax", 5), ("audit-syntax", 8)];
    assert_eq!(got, want, "unknown policy, empty waiver reason, unpaired fence");
}

/// The gate CI enforces: the shipped tree is clean under its own
/// declared policies, and the policies actually cover the crate.
#[test]
fn shipped_tree_passes_its_own_audit() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = audit_tree(&src).expect("walking src");
    assert!(report.is_clean(), "audit findings in shipped tree:\n{}", report.render());
    assert!(
        report.annotated >= 17,
        "expected >= 17 modules under policy, got {}",
        report.annotated
    );
    assert!(report.files > report.annotated, "some modules are intentionally unannotated");
}
