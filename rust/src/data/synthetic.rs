//! Seed-deterministic synthetic image datasets.
//!
//! Stand-ins for MNIST / CIFAR10 / CIFAR100 (DESIGN.md §Substitutions):
//! each class gets a smooth random template (a sum of a few random 2-D
//! cosine modes, i.e. low-frequency structure like natural images);
//! samples are template + white noise + a random global intensity jitter,
//! normalized to zero mean / unit variance per dataset. The task is
//! learnable to high accuracy but not linearly trivial at high noise —
//! which is what the paper's accuracy-vs-Bpp trade-off needs to show up.

use super::Dataset;
use crate::util::Xoshiro256;

/// Generator parameters for one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub n_classes: usize,
    /// White-noise std relative to template std (1.0 = equal power).
    pub noise: f64,
    /// Number of cosine modes per class template.
    pub modes: usize,
}

impl SynthSpec {
    /// MNIST-shaped: 28x28x1, 10 classes.
    pub fn mnist_like() -> Self {
        Self { height: 28, width: 28, channels: 1, n_classes: 10, noise: 0.8, modes: 6 }
    }

    /// CIFAR10-shaped: 32x32x3, 10 classes (noisier: harder task).
    pub fn cifar10_like() -> Self {
        Self { height: 32, width: 32, channels: 3, n_classes: 10, noise: 1.2, modes: 8 }
    }

    /// CIFAR100-shaped: 32x32x3, 100 classes.
    pub fn cifar100_like() -> Self {
        Self { height: 32, width: 32, channels: 3, n_classes: 100, noise: 1.0, modes: 8 }
    }

    /// Tiny 8x8x1 dataset matching the `mlp_tiny` model (tests, CI).
    pub fn tiny() -> Self {
        Self { height: 8, width: 8, channels: 1, n_classes: 10, noise: 0.6, modes: 4 }
    }

    pub fn dim(&self) -> usize {
        self.height * self.width * self.channels
    }

    /// Resolve by dataset name used across configs/CLI.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "mnist" => Some(Self::mnist_like()),
            "cifar10" => Some(Self::cifar10_like()),
            "cifar100" => Some(Self::cifar100_like()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }
}

/// A sampled synthetic task: fixed class templates + a generator.
#[derive(Debug, Clone)]
pub struct Synthetic {
    spec: SynthSpec,
    templates: Vec<Vec<f32>>, // [class][dim]
    seed: u64,
}

impl Synthetic {
    /// Build class templates deterministically from `seed`.
    pub fn new(spec: SynthSpec, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed ^ 0x5EED_7E3A_17E5);
        let dim = spec.dim();
        let mut templates = Vec::with_capacity(spec.n_classes);
        for _class in 0..spec.n_classes {
            let mut t = vec![0.0f32; dim];
            for _ in 0..spec.modes {
                // Random 2-D cosine mode with random phase/orientation,
                // shared across channels with per-channel gain.
                let fy = rng.next_f64() * 4.0 + 0.5;
                let fx = rng.next_f64() * 4.0 + 0.5;
                let phase = rng.next_f64() * std::f64::consts::TAU;
                let gains: Vec<f64> =
                    (0..spec.channels).map(|_| rng.next_normal()).collect();
                for yy in 0..spec.height {
                    for xx in 0..spec.width {
                        let v = (std::f64::consts::TAU
                            * (fy * yy as f64 / spec.height as f64
                                + fx * xx as f64 / spec.width as f64)
                            + phase)
                            .cos();
                        for (c, g) in gains.iter().enumerate() {
                            let idx = (yy * spec.width + xx) * spec.channels + c;
                            t[idx] += (v * g) as f32;
                        }
                    }
                }
            }
            // Normalize template to unit std so `noise` is interpretable.
            let mean = t.iter().sum::<f32>() / dim as f32;
            let var = t.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / dim as f32;
            let std = var.sqrt().max(1e-6);
            for v in t.iter_mut() {
                *v = (*v - mean) / std;
            }
            templates.push(t);
        }
        Self { spec, templates, seed }
    }

    pub fn spec(&self) -> &SynthSpec {
        &self.spec
    }

    /// Generate `n` labeled samples with a fresh stream `stream_seed`
    /// (train/test use different streams over the SAME templates).
    pub fn generate(&self, n: usize, stream_seed: u64) -> Dataset {
        let mut rng = Xoshiro256::new(self.seed ^ stream_seed.wrapping_mul(0x9E3779B97F4A7C15));
        let dim = self.spec.dim();
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let class = rng.below(self.spec.n_classes as u64) as usize;
            let jitter = 1.0 + 0.1 * rng.next_normal();
            let t = &self.templates[class];
            for &tv in t.iter() {
                let noise = self.spec.noise * rng.next_normal();
                x.push((tv as f64 * jitter + noise) as f32);
            }
            y.push(class as i32);
        }
        Dataset::new(x, y, dim, self.spec.n_classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seeds() {
        let a = Synthetic::new(SynthSpec::tiny(), 1).generate(50, 2);
        let b = Synthetic::new(SynthSpec::tiny(), 1).generate(50, 2);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = Synthetic::new(SynthSpec::tiny(), 1).generate(50, 3);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn shapes_and_labels() {
        let spec = SynthSpec::mnist_like();
        let d = Synthetic::new(spec.clone(), 7).generate(100, 1);
        assert_eq!(d.dim, 784);
        assert_eq!(d.len(), 100);
        assert_eq!(d.n_classes, 10);
        assert!(d.y.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn all_classes_appear() {
        let d = Synthetic::new(SynthSpec::tiny(), 3).generate(500, 1);
        let per = d.class_indices();
        assert!(per.iter().all(|v| !v.is_empty()));
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // Nearest-template classification should beat chance by a lot —
        // the data carries real class signal for the models to find.
        let gen = Synthetic::new(SynthSpec::tiny(), 11);
        let d = gen.generate(400, 9);
        let mut correct = 0;
        for i in 0..d.len() {
            let row = d.row(i);
            let best = (0..gen.templates.len())
                .max_by(|&a, &b| {
                    let ca: f32 =
                        row.iter().zip(&gen.templates[a]).map(|(x, t)| x * t).sum();
                    let cb: f32 =
                        row.iter().zip(&gen.templates[b]).map(|(x, t)| x * t).sum();
                    ca.partial_cmp(&cb).unwrap()
                })
                .unwrap();
            if best as i32 == d.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.len() as f64;
        assert!(acc > 0.5, "template-matching accuracy {acc}");
    }

    #[test]
    fn by_name_lookup() {
        assert!(SynthSpec::by_name("mnist").is_some());
        assert!(SynthSpec::by_name("cifar10").is_some());
        assert!(SynthSpec::by_name("cifar100").is_some());
        assert!(SynthSpec::by_name("tiny").is_some());
        assert!(SynthSpec::by_name("imagenet").is_none());
    }

    #[test]
    fn cifar_shapes() {
        assert_eq!(SynthSpec::cifar10_like().dim(), 3072);
        assert_eq!(SynthSpec::cifar100_like().n_classes, 100);
    }
}
