//! Dataset substrate: in-memory datasets, shards, and batch iteration.
//!
//! The paper trains on MNIST / CIFAR10 / CIFAR100. Real files are loaded
//! when present (see [`loader`]); otherwise the seed-deterministic
//! synthetic generators in [`synthetic`] produce shape-compatible,
//! learnable class-template data (DESIGN.md §Substitutions). Either way
//! the rest of the system only ever sees this module's `Dataset`.

pub mod loader;
pub mod partition;
pub mod synthetic;

pub use partition::{partition_dirichlet, partition_iid, partition_noniid, Shard};
pub use synthetic::{SynthSpec, Synthetic};

use anyhow::{ensure, Context, Result};

use crate::config::{ExperimentConfig, Partition};
use crate::util::Xoshiro256;

/// A dense in-memory classification dataset.
///
/// Rows are flattened f32 features (the wire layout the PJRT programs
/// take); labels are int32 class ids in [0, n_classes).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    pub n_classes: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<i32>, dim: usize, n_classes: usize) -> Self {
        assert_eq!(x.len(), y.len() * dim, "feature/label size mismatch");
        assert!(y.iter().all(|&l| (l as usize) < n_classes));
        Self { x, y, dim, n_classes }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// One row's features.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather rows by index into contiguous (x, y) buffers.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        (x, y)
    }

    /// Per-class index lists.
    pub fn class_indices(&self) -> Vec<Vec<usize>> {
        let mut per = vec![Vec::new(); self.n_classes];
        for (i, &l) in self.y.iter().enumerate() {
            per[l as usize].push(i);
        }
        per
    }
}

/// Random subsample (without replacement) to the requested size —
/// shared by the experiment builder and the checkpoint evaluator so
/// that, given the same seed, a `--samples` cap means the same draw.
pub fn subsample(d: Dataset, n: usize, seed: u64) -> Dataset {
    if n >= d.len() {
        return d;
    }
    let mut rng = Xoshiro256::new(seed);
    let mut idx: Vec<usize> = (0..d.len()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(n);
    let (x, y) = d.gather(&idx);
    Dataset::new(x, y, d.dim, d.n_classes)
}

/// Derive an experiment's (train, test) datasets from its config: the
/// real files when present, the seed-deterministic synthetic generator
/// otherwise. Shared by the in-process experiment builder and the
/// networked device runtime (`fedsrn device`), so both sides of a
/// socket derive byte-identical data from the same config.
pub fn load_experiment_data(
    cfg: &ExperimentConfig,
    dim: usize,
    n_classes: usize,
) -> Result<(Dataset, Dataset)> {
    if let (Some(tr), Some(te)) =
        (loader::try_load(&cfg.dataset, true), loader::try_load(&cfg.dataset, false))
    {
        eprintln!(
            "using real {} data ({} train / {} test)",
            cfg.dataset,
            tr.len(),
            te.len()
        );
        return Ok((
            subsample(tr, cfg.train_samples, cfg.seed),
            subsample(te, cfg.test_samples, cfg.seed ^ 1),
        ));
    }
    let mut spec = SynthSpec::by_name(&cfg.dataset)
        .with_context(|| format!("unknown dataset '{}'", cfg.dataset))?;
    // Model and dataset must agree on geometry; the synthetic
    // generator adapts to the model's class count (e.g. cifar100).
    ensure!(
        spec.dim() == dim,
        "dataset '{}' dim {} != model input {}",
        cfg.dataset,
        spec.dim(),
        dim
    );
    spec.n_classes = n_classes;
    let gen = Synthetic::new(spec, cfg.seed ^ 0xDA7A);
    Ok((gen.generate(cfg.train_samples, 1), gen.generate(cfg.test_samples, 2)))
}

/// Partition a training set into the config's device shards — the other
/// half of the shared derivation: shard membership is a pure function of
/// (dataset, partition scheme, clients, seed).
pub fn partition_fleet(cfg: &ExperimentConfig, train: &Dataset) -> Vec<Shard> {
    match cfg.partition {
        Partition::Iid => partition_iid(train, cfg.clients, cfg.seed ^ 0x5A),
        Partition::NonIid { c } => partition_noniid(train, cfg.clients, c, cfg.seed ^ 0x5A),
        Partition::Dirichlet { alpha } => {
            partition_dirichlet(train, cfg.clients, alpha, cfg.seed ^ 0x5A)
        }
    }
}

/// Cyclic minibatch sampler over a shard's indices: reshuffles each epoch
/// with its own RNG stream, yielding exactly `batch` indices per call
/// (wrapping across epochs like the usual FL local loader).
#[derive(Debug, Clone)]
pub struct BatchSampler {
    order: Vec<usize>,
    cursor: usize,
    rng: Xoshiro256,
}

impl BatchSampler {
    pub fn new(indices: Vec<usize>, seed: u64) -> Self {
        assert!(!indices.is_empty(), "cannot sample from an empty shard");
        let mut rng = Xoshiro256::new(seed);
        let mut order = indices;
        rng.shuffle(&mut order);
        Self { order, cursor: 0, rng }
    }

    /// Next `batch` indices (wraps + reshuffles at epoch boundaries).
    pub fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.cursor == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.cursor = 0;
            }
            out.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        out
    }

    /// Number of batches per epoch (ceil).
    pub fn batches_per_epoch(&self, batch: usize) -> usize {
        self.order.len().div_ceil(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let n = 10;
        let dim = 3;
        let x: Vec<f32> = (0..n * dim).map(|i| i as f32).collect();
        let y: Vec<i32> = (0..n as i32).map(|i| i % 2).collect();
        Dataset::new(x, y, dim, 2)
    }

    #[test]
    fn row_and_gather() {
        let d = toy();
        assert_eq!(d.row(2), &[6.0, 7.0, 8.0]);
        let (x, y) = d.gather(&[0, 3]);
        assert_eq!(x, vec![0.0, 1.0, 2.0, 9.0, 10.0, 11.0]);
        assert_eq!(y, vec![0, 1]);
    }

    #[test]
    fn class_indices_partition_everything() {
        let d = toy();
        let per = d.class_indices();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].len() + per[1].len(), d.len());
        assert!(per[0].iter().all(|&i| d.y[i] == 0));
    }

    #[test]
    fn sampler_covers_epoch() {
        let mut s = BatchSampler::new((0..10).collect(), 1);
        let mut seen = vec![0u32; 10];
        for _ in 0..5 {
            for i in s.next_batch(2) {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn sampler_wraps_and_reshuffles() {
        let mut s = BatchSampler::new((0..4).collect(), 2);
        let b = s.next_batch(10); // 2.5 epochs
        assert_eq!(b.len(), 10);
        let mut counts = [0; 4];
        for &i in &b {
            counts[i] += 1;
        }
        // every element appears 2 or 3 times
        assert!(counts.iter().all(|&c| c == 2 || c == 3), "{counts:?}");
    }

    #[test]
    fn sampler_deterministic() {
        let a: Vec<_> = BatchSampler::new((0..8).collect(), 9).next_batch(16);
        let b: Vec<_> = BatchSampler::new((0..8).collect(), 9).next_batch(16);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn bad_sizes_panic() {
        Dataset::new(vec![0.0; 5], vec![0, 1], 3, 2);
    }

    #[test]
    fn subsample_caps_size_and_is_deterministic() {
        let d = toy();
        let a = subsample(d.clone(), 4, 7);
        let b = subsample(d.clone(), 4, 7);
        assert_eq!(a.len(), 4);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        // asking for more than available is a no-op
        assert_eq!(subsample(d.clone(), 100, 7).len(), d.len());
    }
}
