//! Federated dataset partitioning (paper sec. IV).
//!
//! * IID: shuffle, split evenly across K devices.
//! * Non-IID: each device is randomly assigned `c` of the classes
//!   (c ∈ {2,4} in the paper) and only receives samples of those
//!   classes; each class's sample pool is split evenly among the
//!   devices holding that class.
//! * Dirichlet(alpha): per class, client proportions are drawn from a
//!   symmetric Dirichlet — the standard heterogeneity benchmark axis
//!   (SparsyFed/SpaFL). Small alpha concentrates each class on a few
//!   devices; large alpha approaches IID.
//!
//! audit: deterministic

use super::Dataset;
use crate::util::Xoshiro256;

/// One device's view of the dataset: indices into the parent `Dataset`.
#[derive(Debug, Clone)]
pub struct Shard {
    pub client_id: usize,
    pub indices: Vec<usize>,
    /// Classes present on this device (== all classes for IID).
    pub classes: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// |D_i| as the aggregation weight of eq. 2 / eq. 8.
    pub fn weight(&self) -> f64 {
        self.indices.len() as f64
    }
}

/// Evenly distribute shuffled samples across `k` devices.
pub fn partition_iid(data: &Dataset, k: usize, seed: u64) -> Vec<Shard> {
    assert!(k > 0 && k <= data.len(), "need 1..=len clients");
    let mut rng = Xoshiro256::new(seed);
    let mut idx: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut idx);
    let all_classes: Vec<usize> = (0..data.n_classes).collect();
    let base = data.len() / k;
    let extra = data.len() % k;
    let mut shards = Vec::with_capacity(k);
    let mut cursor = 0;
    for c in 0..k {
        let take = base + usize::from(c < extra);
        shards.push(Shard {
            client_id: c,
            indices: idx[cursor..cursor + take].to_vec(),
            classes: all_classes.clone(),
        });
        cursor += take;
    }
    shards
}

/// Label-heterogeneous split: each device gets `c` random classes.
///
/// Every class is guaranteed at least one holder (otherwise some samples
/// would silently vanish from the federation): classes are dealt
/// round-robin first, then devices fill up to `c` with random extra
/// classes. When `k*c < n_classes` the per-device budget is impossible
/// to honor without dropping whole classes, so the round-robin surplus
/// is kept instead — devices then hold up to `ceil(n_classes/k)` classes
/// and the federation still covers the dataset exactly.
pub fn partition_noniid(data: &Dataset, k: usize, c: usize, seed: u64) -> Vec<Shard> {
    assert!(k > 0, "need at least one client");
    assert!(c >= 1 && c <= data.n_classes, "c must be in 1..=n_classes");
    let mut rng = Xoshiro256::new(seed);
    let n_classes = data.n_classes;

    // --- assign classes to devices ------------------------------------
    let mut device_classes: Vec<Vec<usize>> = vec![Vec::new(); k];
    // Round-robin over a shuffled class list so every class has >= 1
    // holder. Dealt classes are never dropped: truncating to `c` here
    // (as the seed did) silently erased every sample of a class with no
    // other holder whenever k*c < n_classes.
    let mut classes: Vec<usize> = (0..n_classes).collect();
    rng.shuffle(&mut classes);
    let mut di = 0;
    for &cl in &classes {
        device_classes[di % k].push(cl);
        di += 1;
    }
    // Fill remaining slots (if any) with distinct random classes.
    for slots in device_classes.iter_mut() {
        while slots.len() < c {
            let cl = rng.below(n_classes as u64) as usize;
            if !slots.contains(&cl) {
                slots.push(cl);
            }
        }
        slots.sort_unstable();
    }

    // --- split each class pool among its holders ----------------------
    let mut per_class = data.class_indices();
    for pool in per_class.iter_mut() {
        rng.shuffle(pool);
    }
    let mut holders: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (dev, cls) in device_classes.iter().enumerate() {
        for &cl in cls {
            holders[cl].push(dev);
        }
    }
    let mut shards: Vec<Shard> = (0..k)
        .map(|client_id| Shard {
            client_id,
            indices: Vec::new(),
            classes: device_classes[client_id].clone(),
        })
        .collect();
    for cl in 0..n_classes {
        let hs = &holders[cl];
        debug_assert!(!hs.is_empty(), "round-robin deal leaves no class unheld");
        if hs.is_empty() {
            continue; // unreachable: kept as a belt against future edits
        }
        for (j, &sample) in per_class[cl].iter().enumerate() {
            shards[hs[j % hs.len()]].indices.push(sample);
        }
    }
    shards
}

/// One Gamma(alpha, 1) draw via Marsaglia–Tsang squeeze (alpha >= 1),
/// with the standard `U^(1/alpha)` boost for alpha < 1. Dirichlet
/// proportions are normalized Gamma draws, so this is all the sampler
/// the partitioner needs.
fn gamma_sample(rng: &mut Xoshiro256, alpha: f64) -> f64 {
    debug_assert!(alpha.is_finite() && alpha > 0.0);
    if alpha < 1.0 {
        let boost = rng.next_f64().max(f64::MIN_POSITIVE).powf(1.0 / alpha);
        return gamma_sample_ge1(rng, alpha + 1.0) * boost;
    }
    gamma_sample_ge1(rng, alpha)
}

fn gamma_sample_ge1(rng: &mut Xoshiro256, alpha: f64) -> f64 {
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.next_normal();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v = v * v * v;
        let u = rng.next_f64().max(f64::MIN_POSITIVE);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

/// Dirichlet(alpha) label-heterogeneous split: for every class, draw
/// client proportions p ~ Dir(alpha, ..., alpha) and deal that class's
/// shuffled sample pool by largest-remainder apportionment (exact
/// coverage — every sample lands on exactly one device). Devices left
/// empty by an extreme draw are deterministically backfilled with one
/// sample stolen from the currently largest shard, so every shard
/// satisfies the samplers' non-empty invariant.
pub fn partition_dirichlet(data: &Dataset, k: usize, alpha: f64, seed: u64) -> Vec<Shard> {
    assert!(k > 0 && k <= data.len(), "need 1..=len clients");
    assert!(alpha.is_finite() && alpha > 0.0, "alpha must be positive");
    let mut rng = Xoshiro256::new(seed);
    let mut per_class = data.class_indices();
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); k];
    for pool in per_class.iter_mut() {
        if pool.is_empty() {
            continue;
        }
        rng.shuffle(pool);
        // Symmetric Dirichlet draw = normalized Gamma(alpha) draws; the
        // floor keeps the normalizing sum positive even when a tiny
        // alpha underflows a draw to zero.
        let draws: Vec<f64> =
            (0..k).map(|_| gamma_sample(&mut rng, alpha).max(1e-300)).collect();
        let total: f64 = draws.iter().sum();
        let m = pool.len();
        // largest-remainder apportionment of m samples by proportion
        let mut take: Vec<usize> = Vec::with_capacity(k);
        let mut rem: Vec<(f64, usize)> = Vec::with_capacity(k);
        let mut dealt = 0usize;
        for (dev, &g) in draws.iter().enumerate() {
            let exact = g / total * m as f64;
            let floor = exact.floor().min(m as f64) as usize;
            take.push(floor);
            dealt += floor;
            rem.push((exact - floor as f64, dev));
        }
        // ties break toward the lower device id for determinism
        rem.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        for &(_, dev) in rem.iter().take(m.saturating_sub(dealt)) {
            take[dev] += 1;
        }
        let mut cursor = 0usize;
        for (dev, &t) in take.iter().enumerate() {
            assigned[dev].extend_from_slice(&pool[cursor..cursor + t]);
            cursor += t;
        }
        debug_assert_eq!(cursor, m, "largest remainder must deal the whole pool");
    }
    // Backfill empty shards (possible at tiny alpha): steal one sample
    // from the largest shard, ties toward the lower device id.
    while let Some(empty) = assigned.iter().position(Vec::is_empty) {
        let donor = (0..k)
            .max_by(|&a, &b| assigned[a].len().cmp(&assigned[b].len()).then(b.cmp(&a)))
            .expect("k > 0");
        assert!(assigned[donor].len() > 1, "dataset too small to cover {k} devices");
        let sample = assigned[donor].pop().expect("donor shard non-empty");
        assigned[empty].push(sample);
    }
    assigned
        .into_iter()
        .enumerate()
        .map(|(client_id, indices)| {
            let mut classes: Vec<usize> =
                indices.iter().map(|&i| data.y[i] as usize).collect();
            classes.sort_unstable();
            classes.dedup();
            Shard { client_id, indices, classes }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthSpec, Synthetic};

    fn dataset() -> Dataset {
        Synthetic::new(SynthSpec::tiny(), 5).generate(1000, 1)
    }

    #[test]
    fn iid_covers_exactly() {
        let d = dataset();
        let shards = partition_iid(&d, 7, 3);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..d.len()).collect::<Vec<_>>());
        // sizes within 1 of each other
        let sizes: Vec<usize> = shards.iter().map(Shard::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn iid_deterministic() {
        let d = dataset();
        let a = partition_iid(&d, 4, 9);
        let b = partition_iid(&d, 4, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn noniid_respects_class_budget() {
        let d = dataset();
        for c in [2usize, 4] {
            let shards = partition_noniid(&d, 30, c, 11);
            for s in &shards {
                assert!(s.classes.len() <= c, "client {} classes {:?}", s.client_id, s.classes);
                // every sample's label is in the device's class list
                for &i in &s.indices {
                    assert!(s.classes.contains(&(d.y[i] as usize)));
                }
            }
        }
    }

    #[test]
    fn noniid_covers_exactly_when_all_classes_held() {
        let d = dataset();
        let shards = partition_noniid(&d, 30, 2, 13);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), d.len(), "every sample on exactly one device");
    }

    #[test]
    fn noniid_every_class_has_a_holder() {
        let d = dataset();
        let shards = partition_noniid(&d, 10, 2, 17);
        let mut held = vec![false; d.n_classes];
        for s in &shards {
            for &c in &s.classes {
                held[c] = true;
            }
        }
        assert!(held.iter().all(|&h| h), "{held:?}");
    }

    #[test]
    fn noniid_heterogeneity_differs_across_clients() {
        let d = dataset();
        let shards = partition_noniid(&d, 30, 2, 23);
        let distinct: std::collections::HashSet<Vec<usize>> =
            shards.iter().map(|s| s.classes.clone()).collect();
        assert!(distinct.len() > 3, "class assignments should vary");
    }

    #[test]
    fn weights_sum_to_dataset_size() {
        let d = dataset();
        let shards = partition_noniid(&d, 30, 4, 29);
        let total: f64 = shards.iter().map(Shard::weight).sum();
        assert_eq!(total as usize, d.len());
    }

    #[test]
    fn small_federation_regime_covers_dataset_exactly() {
        // k*c < n_classes (3*2 = 6 < 10): the seed silently dropped
        // every sample of the 4 unheld classes. The round-robin surplus
        // must keep full coverage instead.
        let d = dataset();
        for seed in [31u64, 32, 33] {
            let shards = partition_noniid(&d, 3, 2, seed);
            let mut all: Vec<usize> =
                shards.iter().flat_map(|s| s.indices.clone()).collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), d.len(), "seed {seed}: samples dropped");
            let total: f64 = shards.iter().map(Shard::weight).sum();
            assert_eq!(total as usize, d.len(), "seed {seed}");
            // every class held, budget relaxed only to the dealt surplus
            let mut held = vec![false; d.n_classes];
            for s in &shards {
                assert!(
                    s.classes.len() <= d.n_classes.div_ceil(3),
                    "seed {seed}: device {} holds {:?}",
                    s.client_id,
                    s.classes
                );
                for &cl in &s.classes {
                    held[cl] = true;
                }
                for &i in &s.indices {
                    assert!(s.classes.contains(&(d.y[i] as usize)), "seed {seed}");
                }
            }
            assert!(held.iter().all(|&h| h), "seed {seed}: {held:?}");
        }
    }

    #[test]
    fn single_client_noniid_gets_everything() {
        // extreme k*c < n_classes corner: one device, c=1, ten classes
        let d = dataset();
        let shards = partition_noniid(&d, 1, 1, 41);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), d.len());
        assert_eq!(shards[0].classes.len(), d.n_classes);
    }

    #[test]
    fn dirichlet_covers_exactly_and_shards_non_empty() {
        let d = dataset();
        for alpha in [0.05, 0.5, 10.0] {
            for k in [3usize, 10, 30] {
                let shards = partition_dirichlet(&d, k, alpha, 43);
                assert_eq!(shards.len(), k);
                let mut all: Vec<usize> =
                    shards.iter().flat_map(|s| s.indices.clone()).collect();
                all.sort_unstable();
                assert_eq!(
                    all,
                    (0..d.len()).collect::<Vec<_>>(),
                    "alpha={alpha} k={k}: every sample on exactly one device"
                );
                for s in &shards {
                    assert!(!s.is_empty(), "alpha={alpha} k={k} client {}", s.client_id);
                    // class list matches the labels actually present
                    let mut want: Vec<usize> =
                        s.indices.iter().map(|&i| d.y[i] as usize).collect();
                    want.sort_unstable();
                    want.dedup();
                    assert_eq!(s.classes, want, "alpha={alpha} k={k}");
                }
                let total: f64 = shards.iter().map(Shard::weight).sum();
                assert_eq!(total as usize, d.len());
            }
        }
    }

    #[test]
    fn dirichlet_deterministic() {
        let d = dataset();
        let a = partition_dirichlet(&d, 12, 0.3, 47);
        let b = partition_dirichlet(&d, 12, 0.3, 47);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
            assert_eq!(x.classes, y.classes);
        }
        // a different seed moves samples around
        let c = partition_dirichlet(&d, 12, 0.3, 48);
        assert!(a.iter().zip(&c).any(|(x, y)| x.indices != y.indices));
    }

    #[test]
    fn dirichlet_alpha_controls_heterogeneity() {
        // Mean per-shard label entropy must be lower (more skewed) at
        // small alpha than at large alpha, averaged over several seeds
        // so one benign draw can't flip the ordering.
        let d = dataset();
        let mean_entropy = |alpha: f64| -> f64 {
            let mut acc = 0.0;
            let mut shard_count = 0usize;
            for seed in [51u64, 52, 53, 54, 55] {
                for s in partition_dirichlet(&d, 10, alpha, seed) {
                    let mut counts = vec![0usize; d.n_classes];
                    for &i in &s.indices {
                        counts[d.y[i] as usize] += 1;
                    }
                    let n = s.len() as f64;
                    acc -= counts
                        .iter()
                        .filter(|&&c| c > 0)
                        .map(|&c| {
                            let p = c as f64 / n;
                            p * p.log2()
                        })
                        .sum::<f64>();
                    shard_count += 1;
                }
            }
            acc / shard_count as f64
        };
        let skewed = mean_entropy(0.05);
        let flat = mean_entropy(50.0);
        assert!(
            skewed + 0.5 < flat,
            "alpha=0.05 entropy {skewed} should be well below alpha=50 entropy {flat}"
        );
    }

    #[test]
    fn dirichlet_backfill_keeps_tiny_federations_legal() {
        // 1000 samples, 200 devices, extreme skew: some devices would
        // get nothing without the backfill.
        let d = dataset();
        let shards = partition_dirichlet(&d, 200, 0.01, 57);
        assert!(shards.iter().all(|s| !s.is_empty()));
        let total: f64 = shards.iter().map(Shard::weight).sum();
        assert_eq!(total as usize, d.len());
    }
}
