//! Federated dataset partitioning (paper sec. IV).
//!
//! * IID: shuffle, split evenly across K devices.
//! * Non-IID: each device is randomly assigned `c` of the classes
//!   (c ∈ {2,4} in the paper) and only receives samples of those
//!   classes; each class's sample pool is split evenly among the
//!   devices holding that class.
//!
//! audit: deterministic

use super::Dataset;
use crate::util::Xoshiro256;

/// One device's view of the dataset: indices into the parent `Dataset`.
#[derive(Debug, Clone)]
pub struct Shard {
    pub client_id: usize,
    pub indices: Vec<usize>,
    /// Classes present on this device (== all classes for IID).
    pub classes: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// |D_i| as the aggregation weight of eq. 2 / eq. 8.
    pub fn weight(&self) -> f64 {
        self.indices.len() as f64
    }
}

/// Evenly distribute shuffled samples across `k` devices.
pub fn partition_iid(data: &Dataset, k: usize, seed: u64) -> Vec<Shard> {
    assert!(k > 0 && k <= data.len(), "need 1..=len clients");
    let mut rng = Xoshiro256::new(seed);
    let mut idx: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut idx);
    let all_classes: Vec<usize> = (0..data.n_classes).collect();
    let base = data.len() / k;
    let extra = data.len() % k;
    let mut shards = Vec::with_capacity(k);
    let mut cursor = 0;
    for c in 0..k {
        let take = base + usize::from(c < extra);
        shards.push(Shard {
            client_id: c,
            indices: idx[cursor..cursor + take].to_vec(),
            classes: all_classes.clone(),
        });
        cursor += take;
    }
    shards
}

/// Label-heterogeneous split: each device gets `c` random classes.
///
/// Every class is guaranteed at least one holder (otherwise some samples
/// would silently vanish from the federation): classes are dealt
/// round-robin first, then devices fill up to `c` with random extra
/// classes. When `k*c < n_classes` the per-device budget is impossible
/// to honor without dropping whole classes, so the round-robin surplus
/// is kept instead — devices then hold up to `ceil(n_classes/k)` classes
/// and the federation still covers the dataset exactly.
pub fn partition_noniid(data: &Dataset, k: usize, c: usize, seed: u64) -> Vec<Shard> {
    assert!(k > 0, "need at least one client");
    assert!(c >= 1 && c <= data.n_classes, "c must be in 1..=n_classes");
    let mut rng = Xoshiro256::new(seed);
    let n_classes = data.n_classes;

    // --- assign classes to devices ------------------------------------
    let mut device_classes: Vec<Vec<usize>> = vec![Vec::new(); k];
    // Round-robin over a shuffled class list so every class has >= 1
    // holder. Dealt classes are never dropped: truncating to `c` here
    // (as the seed did) silently erased every sample of a class with no
    // other holder whenever k*c < n_classes.
    let mut classes: Vec<usize> = (0..n_classes).collect();
    rng.shuffle(&mut classes);
    let mut di = 0;
    for &cl in &classes {
        device_classes[di % k].push(cl);
        di += 1;
    }
    // Fill remaining slots (if any) with distinct random classes.
    for slots in device_classes.iter_mut() {
        while slots.len() < c {
            let cl = rng.below(n_classes as u64) as usize;
            if !slots.contains(&cl) {
                slots.push(cl);
            }
        }
        slots.sort_unstable();
    }

    // --- split each class pool among its holders ----------------------
    let mut per_class = data.class_indices();
    for pool in per_class.iter_mut() {
        rng.shuffle(pool);
    }
    let mut holders: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (dev, cls) in device_classes.iter().enumerate() {
        for &cl in cls {
            holders[cl].push(dev);
        }
    }
    let mut shards: Vec<Shard> = (0..k)
        .map(|client_id| Shard {
            client_id,
            indices: Vec::new(),
            classes: device_classes[client_id].clone(),
        })
        .collect();
    for cl in 0..n_classes {
        let hs = &holders[cl];
        debug_assert!(!hs.is_empty(), "round-robin deal leaves no class unheld");
        if hs.is_empty() {
            continue; // unreachable: kept as a belt against future edits
        }
        for (j, &sample) in per_class[cl].iter().enumerate() {
            shards[hs[j % hs.len()]].indices.push(sample);
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthSpec, Synthetic};

    fn dataset() -> Dataset {
        Synthetic::new(SynthSpec::tiny(), 5).generate(1000, 1)
    }

    #[test]
    fn iid_covers_exactly() {
        let d = dataset();
        let shards = partition_iid(&d, 7, 3);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..d.len()).collect::<Vec<_>>());
        // sizes within 1 of each other
        let sizes: Vec<usize> = shards.iter().map(Shard::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn iid_deterministic() {
        let d = dataset();
        let a = partition_iid(&d, 4, 9);
        let b = partition_iid(&d, 4, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn noniid_respects_class_budget() {
        let d = dataset();
        for c in [2usize, 4] {
            let shards = partition_noniid(&d, 30, c, 11);
            for s in &shards {
                assert!(s.classes.len() <= c, "client {} classes {:?}", s.client_id, s.classes);
                // every sample's label is in the device's class list
                for &i in &s.indices {
                    assert!(s.classes.contains(&(d.y[i] as usize)));
                }
            }
        }
    }

    #[test]
    fn noniid_covers_exactly_when_all_classes_held() {
        let d = dataset();
        let shards = partition_noniid(&d, 30, 2, 13);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), d.len(), "every sample on exactly one device");
    }

    #[test]
    fn noniid_every_class_has_a_holder() {
        let d = dataset();
        let shards = partition_noniid(&d, 10, 2, 17);
        let mut held = vec![false; d.n_classes];
        for s in &shards {
            for &c in &s.classes {
                held[c] = true;
            }
        }
        assert!(held.iter().all(|&h| h), "{held:?}");
    }

    #[test]
    fn noniid_heterogeneity_differs_across_clients() {
        let d = dataset();
        let shards = partition_noniid(&d, 30, 2, 23);
        let distinct: std::collections::HashSet<Vec<usize>> =
            shards.iter().map(|s| s.classes.clone()).collect();
        assert!(distinct.len() > 3, "class assignments should vary");
    }

    #[test]
    fn weights_sum_to_dataset_size() {
        let d = dataset();
        let shards = partition_noniid(&d, 30, 4, 29);
        let total: f64 = shards.iter().map(Shard::weight).sum();
        assert_eq!(total as usize, d.len());
    }

    #[test]
    fn small_federation_regime_covers_dataset_exactly() {
        // k*c < n_classes (3*2 = 6 < 10): the seed silently dropped
        // every sample of the 4 unheld classes. The round-robin surplus
        // must keep full coverage instead.
        let d = dataset();
        for seed in [31u64, 32, 33] {
            let shards = partition_noniid(&d, 3, 2, seed);
            let mut all: Vec<usize> =
                shards.iter().flat_map(|s| s.indices.clone()).collect();
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), d.len(), "seed {seed}: samples dropped");
            let total: f64 = shards.iter().map(Shard::weight).sum();
            assert_eq!(total as usize, d.len(), "seed {seed}");
            // every class held, budget relaxed only to the dealt surplus
            let mut held = vec![false; d.n_classes];
            for s in &shards {
                assert!(
                    s.classes.len() <= d.n_classes.div_ceil(3),
                    "seed {seed}: device {} holds {:?}",
                    s.client_id,
                    s.classes
                );
                for &cl in &s.classes {
                    held[cl] = true;
                }
                for &i in &s.indices {
                    assert!(s.classes.contains(&(d.y[i] as usize)), "seed {seed}");
                }
            }
            assert!(held.iter().all(|&h| h), "seed {seed}: {held:?}");
        }
    }

    #[test]
    fn single_client_noniid_gets_everything() {
        // extreme k*c < n_classes corner: one device, c=1, ten classes
        let d = dataset();
        let shards = partition_noniid(&d, 1, 1, 41);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), d.len());
        assert_eq!(shards[0].classes.len(), d.n_classes);
    }
}
