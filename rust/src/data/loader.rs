//! Real-dataset loaders: MNIST IDX and CIFAR binary formats.
//!
//! If the user drops the standard files under `data/mnist/` or
//! `data/cifar10/`, experiments transparently run on real data; the
//! synthetic generators remain the default when files are absent
//! (DESIGN.md §Substitutions). Pixels are scaled to [0,1] then
//! standardized per dataset, matching the usual FedPM preprocessing.

use std::fs;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::Dataset;

/// Parse an IDX file (the MNIST container format).
/// Returns (dims, payload bytes).
fn read_idx(path: &Path) -> Result<(Vec<usize>, Vec<u8>)> {
    let raw = fs::read(path).with_context(|| format!("reading {path:?}"))?;
    // gzip? decompress transparently (files often ship as .gz)
    let raw = if raw.len() > 2 && raw[0] == 0x1F && raw[1] == 0x8B {
        let mut out = Vec::new();
        flate_decompress(&raw, &mut out)?;
        out
    } else {
        raw
    };
    ensure!(raw.len() >= 4, "IDX too short");
    ensure!(raw[0] == 0 && raw[1] == 0, "bad IDX magic");
    ensure!(raw[2] == 0x08, "only u8 IDX supported (got type {:#x})", raw[2]);
    let ndim = raw[3] as usize;
    ensure!(raw.len() >= 4 + 4 * ndim, "IDX header truncated");
    let mut dims = Vec::with_capacity(ndim);
    for d in 0..ndim {
        let o = 4 + 4 * d;
        dims.push(u32::from_be_bytes(raw[o..o + 4].try_into().unwrap()) as usize);
    }
    let total: usize = dims.iter().product();
    let body = &raw[4 + 4 * ndim..];
    ensure!(body.len() >= total, "IDX payload truncated");
    Ok((dims, body[..total].to_vec()))
}

/// Minimal DEFLATE/gzip inflater is out of scope for this repo; we shell
/// out to the always-present `gzip` binary instead of vendoring a
/// decompressor (build-time convenience path only — never on the
/// training hot path).
fn flate_decompress(raw: &[u8], out: &mut Vec<u8>) -> Result<()> {
    use std::process::{Command, Stdio};
    let mut child = Command::new("gzip")
        .arg("-dc")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()
        .context("spawning gzip")?;
    use std::io::Write;
    child.stdin.as_mut().unwrap().write_all(raw)?;
    child.stdin.take();
    child.stdout.as_mut().unwrap().read_to_end(out)?;
    let status = child.wait()?;
    ensure!(status.success(), "gzip failed");
    Ok(())
}

fn standardize(x: &mut [f32]) {
    let n = x.len().max(1);
    let mean = x.iter().sum::<f32>() / n as f32;
    let var = x.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n as f32;
    let std = var.sqrt().max(1e-6);
    for v in x.iter_mut() {
        *v = (*v - mean) / std;
    }
}

/// Load MNIST train or test split from `dir` containing the canonical
/// `*-images-idx3-ubyte[.gz]` / `*-labels-idx1-ubyte[.gz]` files.
pub fn load_mnist(dir: &Path, train: bool) -> Result<Dataset> {
    let stem = if train { "train" } else { "t10k" };
    let find = |suffix: &str| -> Result<std::path::PathBuf> {
        for ext in ["", ".gz"] {
            let p = dir.join(format!("{stem}-{suffix}{ext}"));
            if p.exists() {
                return Ok(p);
            }
        }
        bail!("missing {stem}-{suffix} under {dir:?}")
    };
    let (idim, ibytes) = read_idx(&find("images-idx3-ubyte")?)?;
    let (ldim, lbytes) = read_idx(&find("labels-idx1-ubyte")?)?;
    ensure!(idim.len() == 3, "expected 3-D image IDX");
    ensure!(ldim.len() == 1 && ldim[0] == idim[0], "label/image count mismatch");
    let dim = idim[1] * idim[2];
    let mut x: Vec<f32> = ibytes.iter().map(|&b| b as f32 / 255.0).collect();
    standardize(&mut x);
    let y: Vec<i32> = lbytes.iter().map(|&b| b as i32).collect();
    Ok(Dataset::new(x, y, dim, 10))
}

/// Load CIFAR-10 from `dir` containing `data_batch_{1..5}.bin` /
/// `test_batch.bin` (the "binary version" distribution).
pub fn load_cifar10(dir: &Path, train: bool) -> Result<Dataset> {
    let files: Vec<String> = if train {
        (1..=5).map(|i| format!("data_batch_{i}.bin")).collect()
    } else {
        vec!["test_batch.bin".to_string()]
    };
    const REC: usize = 1 + 3072;
    let mut x = Vec::new();
    let mut y = Vec::new();
    for f in files {
        let p = dir.join(&f);
        let raw = fs::read(&p).with_context(|| format!("reading {p:?}"))?;
        ensure!(raw.len() % REC == 0, "bad CIFAR batch size in {f}");
        for rec in raw.chunks_exact(REC) {
            y.push(rec[0] as i32);
            // CHW u8 -> HWC f32 (match the synthetic/JAX layout)
            for pix in 0..1024 {
                for ch in 0..3 {
                    x.push(rec[1 + ch * 1024 + pix] as f32 / 255.0);
                }
            }
        }
    }
    standardize(&mut x);
    Ok(Dataset::new(x, y, 3072, 10))
}

/// Try to load a real dataset by name from the conventional location
/// (`data/<name>/`); `None` means "use synthetic".
pub fn try_load(name: &str, train: bool) -> Option<Dataset> {
    let dir = Path::new("data").join(name);
    if !dir.exists() {
        return None;
    }
    let res = match name {
        "mnist" => load_mnist(&dir, train),
        "cifar10" => load_cifar10(&dir, train),
        _ => return None,
    };
    match res {
        Ok(d) => Some(d),
        Err(e) => {
            eprintln!("warning: failed to load real {name}: {e:#}; falling back to synthetic");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_idx(path: &Path, dims: &[u32], body: &[u8]) {
        let mut f = fs::File::create(path).unwrap();
        f.write_all(&[0, 0, 0x08, dims.len() as u8]).unwrap();
        for &d in dims {
            f.write_all(&d.to_be_bytes()).unwrap();
        }
        f.write_all(body).unwrap();
    }

    #[test]
    fn idx_roundtrip_via_mnist_loader() {
        let dir = std::env::temp_dir().join(format!("fedsrn_idx_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        // 3 fake 4x4 "images"
        let imgs: Vec<u8> = (0..3 * 16).map(|i| (i * 5 % 256) as u8).collect();
        write_idx(&dir.join("train-images-idx3-ubyte"), &[3, 4, 4], &imgs);
        write_idx(&dir.join("train-labels-idx1-ubyte"), &[3], &[0, 1, 2]);
        let d = load_mnist(&dir, true).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim, 16);
        assert_eq!(d.y, vec![0, 1, 2]);
        // standardized: near-zero mean
        let mean: f32 = d.x.iter().sum::<f32>() / d.x.len() as f32;
        assert!(mean.abs() < 1e-4);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cifar_record_parsing() {
        let dir = std::env::temp_dir().join(format!("fedsrn_cifar_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        // 2 records
        let mut raw = Vec::new();
        for label in [3u8, 7] {
            raw.push(label);
            raw.extend((0..3072).map(|i| (i % 251) as u8));
        }
        fs::write(dir.join("test_batch.bin"), &raw).unwrap();
        let d = load_cifar10(&dir, false).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d.y, vec![3, 7]);
        assert_eq!(d.dim, 3072);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn try_load_missing_is_none() {
        assert!(try_load("nonexistent_dataset", true).is_none());
    }

    #[test]
    fn idx_rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("fedsrn_bad_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("train-images-idx3-ubyte");
        fs::write(&p, [1, 2, 3, 4, 5]).unwrap();
        assert!(read_idx(&p).is_err());
        fs::remove_dir_all(&dir).ok();
    }
}
