//! Mask entropy accounting (paper eq. 11 / eq. 13).
//!
//! The figures' lower rows plot the *average estimated entropy of the
//! binary source producing the uplink masks*: for each device k, the
//! normalized frequencies p̂_{k,0/1} of zeros/ones in its transmitted
//! mask give Ĥ_k = H(p̂_{k,1}); the reported Bpp is the mean over
//! devices. We log this estimate alongside the *achieved* coded bits
//! from [`crate::compress`].

use crate::util::BitVec;

/// Binary entropy H(p) in bits; 0 at p ∈ {0, 1}.
pub fn entropy_bits(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        return 0.0;
    }
    -(p * p.log2() + (1.0 - p) * (1.0 - p).log2())
}

/// Empirical Bpp of one transmitted mask (eq. 13 for a single device).
pub fn empirical_bpp(mask: &BitVec) -> f64 {
    entropy_bits(mask.density())
}

/// Eq. 13: mean empirical entropy across the devices' uplink masks.
pub fn mean_client_bpp(masks: &[BitVec]) -> f64 {
    if masks.is_empty() {
        return 0.0;
    }
    masks.iter().map(empirical_bpp).sum::<f64>() / masks.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn entropy_known_values() {
        assert_eq!(entropy_bits(0.0), 0.0);
        assert_eq!(entropy_bits(1.0), 0.0);
        assert!((entropy_bits(0.5) - 1.0).abs() < 1e-12);
        assert!((entropy_bits(0.11) - 0.4999).abs() < 1e-3); // H(0.11)≈0.5
    }

    #[test]
    fn entropy_symmetry() {
        for &p in &[0.01, 0.2, 0.35] {
            assert!((entropy_bits(p) - entropy_bits(1.0 - p)).abs() < 1e-12);
        }
    }

    #[test]
    fn empirical_matches_density() {
        let mut rng = Xoshiro256::new(4);
        let n = 100_000;
        let m = BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < 0.1), n);
        assert!((empirical_bpp(&m) - entropy_bits(0.1)).abs() < 0.01);
    }

    #[test]
    fn mean_over_clients() {
        let a = BitVec::from_bools(&[true; 100]);            // H = 0
        let b = BitVec::from_bools(&[false; 100]);           // H = 0
        let mut half = BitVec::zeros(100);
        for i in 0..50 {
            half.set(i, true);                               // H = 1
        }
        let bpp = mean_client_bpp(&[a, b, half]);
        assert!((bpp - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean_client_bpp(&[]), 0.0);
    }
}
