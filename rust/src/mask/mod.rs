//! Probability masks, binary masks, entropy accounting, aggregation.
//!
//! The server-side math of the paper lives here:
//!   * sampling `m ~ Bernoulli(theta)` (eq. 5) — [`sample_mask`]
//!   * empirical Bpp of a transmitted mask (eq. 13) — [`entropy`]
//!   * weighted mask averaging into the next global probability mask
//!     (eq. 8) — [`aggregate::MaskAggregator`]
//!
//! audit: deterministic

pub mod aggregate;
pub mod entropy;
pub mod layers;

pub use aggregate::{BetaAggregator, MaskAggregator};
pub use entropy::{empirical_bpp, entropy_bits, mean_client_bpp};
pub use layers::{format_layout, layer_stats, parse_layout, LayerSlice, LayerSpec, LayerStats};

use crate::util::{logit, sigmoid, BitVec, Philox4x32};

/// A global probability mask theta in [0,1]^n (the server state).
#[derive(Debug, Clone)]
pub struct ProbMask {
    theta: Vec<f32>,
}

impl ProbMask {
    /// Initial global mask: theta_j ~ U[0,1) (paper footnote 2).
    pub fn uniform_random(n: usize, seed: u64) -> Self {
        let philox = Philox4x32::new(seed);
        let mut theta = vec![0.0f32; n];
        philox.fill_uniform(0, &mut theta);
        Self { theta }
    }

    /// Constant-probability mask (useful for tests and FedMask's 0.5 init).
    pub fn constant(n: usize, p: f32) -> Self {
        assert!((0.0..=1.0).contains(&p));
        Self { theta: vec![p; n] }
    }

    pub fn from_theta(theta: Vec<f32>) -> Self {
        debug_assert!(theta.iter().all(|&t| (0.0..=1.0).contains(&t)));
        Self { theta }
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Scores s = sigma^{-1}(theta) (eq. 4) — what the DL ships to
    /// clients, and what local_train optimizes.
    pub fn to_scores(&self) -> Vec<f32> {
        self.theta.iter().map(|&t| logit(t)).collect()
    }

    /// Rebuild theta from a score vector (theta = sigma(s)).
    pub fn from_scores(scores: &[f32]) -> Self {
        Self { theta: scores.iter().map(|&s| sigmoid(s)).collect() }
    }

    /// Mean keep-probability (sparsity telemetry).
    pub fn mean_theta(&self) -> f64 {
        if self.theta.is_empty() {
            return 0.0;
        }
        self.theta.iter().map(|&t| t as f64).sum::<f64>() / self.theta.len() as f64
    }

    /// Deterministic mask: 1[theta > 0.5] (FedMask-style thresholding,
    /// also the low-variance evaluation mask).
    pub fn threshold(&self) -> BitVec {
        BitVec::from_iter_len(self.theta.iter().map(|&t| t > 0.5), self.len())
    }
}

/// Sample `m ~ Bernoulli(theta)` with a counter-based stream so the same
/// (seed, round) always yields the same mask regardless of call order.
pub fn sample_mask(theta: &ProbMask, seed: u64) -> BitVec {
    let philox = Philox4x32::new(seed);
    let mut u = vec![0.0f32; theta.len()];
    philox.fill_uniform(0, &mut u);
    BitVec::from_iter_len(
        theta.theta().iter().zip(&u).map(|(&t, &ui)| ui < t),
        theta.len(),
    )
}

/// Top-k mask: keep the k largest entries of `scores` (the Top-k baseline
/// of Fig. 2; k = round(frac * n)).
pub fn topk_mask(scores: &[f32], frac: f64) -> BitVec {
    let n = scores.len();
    let k = ((n as f64 * frac).round() as usize).min(n);
    if k == 0 {
        return BitVec::zeros(n);
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    // Partial selection: O(n) average via select_nth_unstable.
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut m = BitVec::zeros(n);
    for &i in &idx[..k] {
        m.set(i as usize, true);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_random_theta_in_range_and_mean_half() {
        let pm = ProbMask::uniform_random(100_000, 3);
        assert!(pm.theta().iter().all(|&t| (0.0..1.0).contains(&t)));
        assert!((pm.mean_theta() - 0.5).abs() < 0.01);
    }

    #[test]
    fn scores_roundtrip() {
        let pm = ProbMask::uniform_random(1000, 9);
        let s = pm.to_scores();
        let back = ProbMask::from_scores(&s);
        for (a, b) in pm.theta().iter().zip(back.theta()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn sample_mask_matches_theta_statistically() {
        let pm = ProbMask::constant(200_000, 0.2);
        let m = sample_mask(&pm, 5);
        assert!((m.density() - 0.2).abs() < 0.01, "{}", m.density());
    }

    #[test]
    fn sample_mask_deterministic_in_seed() {
        let pm = ProbMask::uniform_random(10_000, 1);
        assert_eq!(sample_mask(&pm, 7), sample_mask(&pm, 7));
        assert_ne!(sample_mask(&pm, 7), sample_mask(&pm, 8));
    }

    #[test]
    fn sample_extremes() {
        let ones = sample_mask(&ProbMask::constant(1000, 1.0), 2);
        assert_eq!(ones.count_ones(), 1000);
        let zeros = sample_mask(&ProbMask::constant(1000, 0.0), 2);
        assert_eq!(zeros.count_ones(), 0);
    }

    #[test]
    fn threshold_mask() {
        let pm = ProbMask::from_theta(vec![0.1, 0.6, 0.5, 0.9]);
        let m = pm.threshold();
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![false, true, false, true]);
    }

    #[test]
    fn topk_selects_largest() {
        let scores = vec![0.1, 5.0, -2.0, 3.0, 0.0];
        let m = topk_mask(&scores, 0.4); // k = 2
        assert_eq!(m.count_ones(), 2);
        assert!(m.get(1) && m.get(3));
    }

    #[test]
    fn topk_extremes() {
        let scores: Vec<f32> = (0..100).map(|i| i as f32).collect();
        assert_eq!(topk_mask(&scores, 0.0).count_ones(), 0);
        assert_eq!(topk_mask(&scores, 1.0).count_ones(), 100);
        let half = topk_mask(&scores, 0.5);
        assert_eq!(half.count_ones(), 50);
        assert!((50..100).all(|i| half.get(i)));
    }
}
