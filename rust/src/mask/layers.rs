//! Layer-graph layout + layer-resolved sparsity telemetry.
//!
//! The flat mask layout is opaque to the coordinator except for the
//! `layers` line in the AOT manifest. Historically that line described
//! an MLP ("KxN@offset" per dense layer); it is now a v2 **layer-graph
//! grammar** covering the paper's conv model family (DESIGN.md
//! §Compute-core):
//!
//! ```text
//! layers = entry ("," entry)*
//! entry  = KxN@off                      # v1 compat: dense layer
//!        | dense:KxN@off                # dense K -> N
//!        | conv:CINxCOUT:kK[:sS][:pP]@off   # 2-D conv, square kernel
//!        | pool:S                       # max-pool SxS, stride S
//!        | flatten                      # HxWxC -> H*W*C
//!        | relu                         # elementwise activation
//! ```
//!
//! Parameterized entries (dense/conv) carry `@offset` into the flat
//! parameter vector and must tile it contiguously from 0; structural
//! entries (pool/flatten/relu) carry no parameters. A layout made only
//! of dense entries is the v1 MLP form — the runtime inserts the
//! implicit inter-layer ReLUs it always had (`runtime/graph.rs`).
//!
//! This module also reports per-layer density / entropy per
//! [`LayerSpec`] kind — the unstructured-sparsity telemetry that shows
//! WHERE the regularizer prunes (the paper's sec. III intuition:
//! redundant sub-network features get eliminated, which concentrates in
//! the over-provisioned layers).
//!
//! audit: deterministic

use anyhow::{bail, ensure, Context, Result};

use crate::util::BitVec;

use super::entropy_bits;

/// One node of the layer graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerSpec {
    /// Fully-connected K -> N (row-major K x N weight block).
    Dense { k: usize, n: usize },
    /// 2-D convolution, square `kernel`, NHWC activations, weights laid
    /// out `[kernel, kernel, in_ch, out_ch]` (DESIGN.md §Compute-core).
    Conv2d { in_ch: usize, out_ch: usize, kernel: usize, stride: usize, pad: usize },
    /// Max-pool `size` x `size` with stride `size` (non-overlapping).
    MaxPool { size: usize },
    /// Reshape HxWxC -> H*W*C (no-op on already-flat activations).
    Flatten,
    /// Elementwise max(0, x).
    Relu,
}

impl LayerSpec {
    /// Number of parameters this node owns in the flat vector.
    pub fn params(&self) -> usize {
        match *self {
            LayerSpec::Dense { k, n } => k * n,
            LayerSpec::Conv2d { in_ch, out_ch, kernel, .. } => kernel * kernel * in_ch * out_ch,
            _ => 0,
        }
    }

    /// Fan-in for signed-constant Kaiming initialization.
    pub fn fan_in(&self) -> usize {
        match *self {
            LayerSpec::Dense { k, .. } => k,
            LayerSpec::Conv2d { in_ch, kernel, .. } => in_ch * kernel * kernel,
            _ => 0,
        }
    }

    /// Short kind tag for telemetry tables.
    pub fn kind_name(&self) -> &'static str {
        match self {
            LayerSpec::Dense { .. } => "dense",
            LayerSpec::Conv2d { .. } => "conv2d",
            LayerSpec::MaxPool { .. } => "maxpool",
            LayerSpec::Flatten => "flatten",
            LayerSpec::Relu => "relu",
        }
    }

    /// Compact shape label ("64x10", "3>16 k3s1p1", "2x2", "-").
    pub fn shape_label(&self) -> String {
        match *self {
            LayerSpec::Dense { k, n } => format!("{k}x{n}"),
            LayerSpec::Conv2d { in_ch, out_ch, kernel, stride, pad } => {
                format!("{in_ch}>{out_ch} k{kernel}s{stride}p{pad}")
            }
            LayerSpec::MaxPool { size } => format!("{size}x{size}"),
            LayerSpec::Flatten | LayerSpec::Relu => "-".into(),
        }
    }
}

/// One graph node's position in the layout plus its slice of the flat
/// parameter vector (empty slice for structural nodes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSlice {
    /// Position in the layout line (counts structural nodes too).
    pub index: usize,
    pub spec: LayerSpec,
    /// Offset into the flat vector; for structural nodes this is the
    /// running offset (their slice is empty).
    pub offset: usize,
}

impl LayerSlice {
    pub fn len(&self) -> usize {
        self.spec.params()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn parse_dense(shape: &str) -> Result<LayerSpec> {
    let (k, n) = shape
        .split_once('x')
        .with_context(|| format!("layer shape '{shape}' missing KxN"))?;
    Ok(LayerSpec::Dense {
        k: k.trim().parse().context("layer rows")?,
        n: n.trim().parse().context("layer cols")?,
    })
}

/// `CINxCOUT:kK[:sS][:pP]` — stride defaults to 1, pad to 0.
fn parse_conv(body: &str) -> Result<LayerSpec> {
    let mut parts = body.split(':');
    let chans = parts.next().context("conv entry missing channels")?;
    let (cin, cout) = chans
        .split_once('x')
        .with_context(|| format!("conv channels '{chans}' missing CINxCOUT"))?;
    let (mut kernel, mut stride, mut pad) = (None, 1usize, 0usize);
    for p in parts {
        let p = p.trim();
        let parse = |v: &str| -> Result<usize> {
            v.parse().with_context(|| format!("conv field '{p}'"))
        };
        if let Some(v) = p.strip_prefix('k') {
            kernel = Some(parse(v)?);
        } else if let Some(v) = p.strip_prefix('s') {
            stride = parse(v)?;
        } else if let Some(v) = p.strip_prefix('p') {
            pad = parse(v)?;
        } else {
            bail!("unknown conv field '{p}' (want kK / sS / pP)");
        }
    }
    let kernel = kernel.context("conv entry missing kernel size (kK)")?;
    ensure!(kernel > 0 && stride > 0, "conv kernel/stride must be > 0");
    Ok(LayerSpec::Conv2d {
        in_ch: cin.trim().parse().context("conv in_ch")?,
        out_ch: cout.trim().parse().context("conv out_ch")?,
        kernel,
        stride,
        pad,
    })
}

/// Parse the manifest `layers=` line (v1 + v2 grammar, module docs).
pub fn parse_layout(s: &str) -> Result<Vec<LayerSlice>> {
    let mut out: Vec<LayerSlice> = Vec::new();
    if s.trim().is_empty() {
        return Ok(out);
    }
    let mut running = 0usize; // params consumed so far
    for (index, item) in s.split(',').enumerate() {
        let item = item.trim();
        let (body, off) = match item.split_once('@') {
            Some((b, o)) => (b.trim(), Some(o.trim())),
            None => (item, None),
        };
        let spec = if let Some(rest) = body.strip_prefix("dense:") {
            parse_dense(rest)?
        } else if let Some(rest) = body.strip_prefix("conv:") {
            parse_conv(rest)?
        } else if let Some(rest) = body.strip_prefix("pool:") {
            let size: usize = rest.trim().parse().context("pool size")?;
            ensure!(size > 0, "pool size must be > 0");
            LayerSpec::MaxPool { size }
        } else if body == "flatten" {
            LayerSpec::Flatten
        } else if body == "relu" {
            LayerSpec::Relu
        } else {
            // v1 compat: bare "KxN" is a dense layer
            parse_dense(body)?
        };
        if spec.params() > 0 {
            let off: usize = off
                .with_context(|| format!("parameterized entry '{item}' missing @offset"))?
                .parse()
                .context("layer offset")?;
            ensure!(
                off == running,
                "layer layout not contiguous at entry {index}: offset {off}, expected {running}"
            );
            running += spec.params();
        } else {
            ensure!(off.is_none(), "structural entry '{item}' must not carry @offset");
        }
        out.push(LayerSlice { index, spec, offset: running - spec.params() });
    }
    Ok(out)
}

/// True when every entry uses the bare v1 `KxN@off` dense syntax — the
/// pre-graph MLP manifests, whose runtime semantics include implicit
/// inter-layer ReLUs. v2 layouts name every node explicitly (a v2
/// `dense:...,dense:...` chain really is linear).
pub fn layout_is_v1(s: &str) -> bool {
    !s.trim().is_empty()
        && s.split(',').all(|e| {
            let e = e.trim();
            !e.contains(':') && e != "flatten" && e != "relu"
        })
}

/// Render a layout back to a `layers=` string. `v1` must be the
/// layout's [`layout_is_v1`] provenance (`Manifest.layers_v1`): a v1
/// layout round-trips to the bare `KxN@off` form (keeping its implicit
/// inter-layer ReLUs on re-parse), while a v2 layout — even a
/// dense-only, deliberately linear chain — keeps its explicit `dense:`
/// spelling so re-parsing never injects activations that were not
/// there.
pub fn format_layout(layout: &[LayerSlice], v1: bool) -> String {
    layout
        .iter()
        .map(|l| match l.spec {
            LayerSpec::Dense { k, n } if v1 => format!("{k}x{n}@{}", l.offset),
            LayerSpec::Dense { k, n } => format!("dense:{k}x{n}@{}", l.offset),
            LayerSpec::Conv2d { in_ch, out_ch, kernel, stride, pad } => {
                format!("conv:{in_ch}x{out_ch}:k{kernel}:s{stride}:p{pad}@{}", l.offset)
            }
            LayerSpec::MaxPool { size } => format!("pool:{size}"),
            LayerSpec::Flatten => "flatten".into(),
            LayerSpec::Relu => "relu".into(),
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Per-layer sparsity report for one mask (parameterized layers only).
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub layer: LayerSlice,
    pub ones: usize,
    pub density: f64,
    pub entropy: f64,
}

/// Compute per-layer density/entropy of `mask` under `layout`.
/// Structural nodes (pool/flatten/relu) own no parameters and are
/// skipped; each report row is tagged with its [`LayerSpec`] kind.
pub fn layer_stats(mask: &BitVec, layout: &[LayerSlice]) -> Vec<LayerStats> {
    layout
        .iter()
        .filter(|l| !l.is_empty())
        .map(|l| {
            let ones = (l.offset..l.offset + l.len())
                .filter(|&i| mask.get(i))
                .count();
            let density = ones as f64 / l.len() as f64;
            LayerStats {
                layer: l.clone(),
                ones,
                density,
                entropy: entropy_bits(density),
            }
        })
        .collect()
}

/// Render a compact per-layer table (used by `fedsrn eval` / analyze).
pub fn format_table(stats: &[LayerStats]) -> String {
    let mut out =
        String::from("layer  kind     shape             params    density   H(bits)\n");
    for s in stats {
        out.push_str(&format!(
            "{:<6} {:<8} {:<15} {:>8}   {:>7.4}   {:>7.4}\n",
            s.layer.index,
            s.layer.spec.kind_name(),
            s.layer.spec.shape_label(),
            s.layer.len(),
            s.density,
            s.entropy
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_v1_round_trip() {
        let layout = parse_layout("64x64@0,64x10@4096").unwrap();
        assert_eq!(layout.len(), 2);
        assert_eq!(layout[0].spec, LayerSpec::Dense { k: 64, n: 64 });
        assert_eq!(layout[0].len(), 4096);
        assert_eq!(layout[1].offset, 4096);
        assert_eq!(layout[1].len(), 640);
        assert_eq!(format_layout(&layout, true), "64x64@0,64x10@4096");
        // a v2-origin dense chain must KEEP its explicit spelling:
        // rendering it bare would gain implicit ReLUs on re-parse
        let rendered = format_layout(&layout, false);
        assert_eq!(rendered, "dense:64x64@0,dense:64x10@4096");
        assert!(!layout_is_v1(&rendered));
        assert_eq!(parse_layout(&rendered).unwrap(), layout);
    }

    #[test]
    fn parse_v2_conv_graph() {
        let s = "conv:3x16:k3:s1:p1@0,relu,pool:2,conv:16x32:k3:s1:p1@432,relu,\
                 pool:2,flatten,dense:2048x64@5040,relu,dense:64x10@136112";
        let layout = parse_layout(s).unwrap();
        assert_eq!(layout.len(), 10);
        assert_eq!(
            layout[0].spec,
            LayerSpec::Conv2d { in_ch: 3, out_ch: 16, kernel: 3, stride: 1, pad: 1 }
        );
        assert_eq!(layout[0].len(), 432);
        assert_eq!(layout[1].spec, LayerSpec::Relu);
        assert_eq!(layout[2].spec, LayerSpec::MaxPool { size: 2 });
        assert_eq!(layout[3].offset, 432);
        assert_eq!(layout[6].spec, LayerSpec::Flatten);
        assert_eq!(layout[7].offset, 5040);
        assert_eq!(layout[9].offset, 136112);
        let total: usize = layout.iter().map(|l| l.len()).sum();
        assert_eq!(total, 136752);
        // canonical render re-parses to the same layout
        assert_eq!(parse_layout(&format_layout(&layout, false)).unwrap(), layout);
    }

    #[test]
    fn conv_stride_pad_default() {
        let layout = parse_layout("conv:1x4:k5@0,flatten,dense:144x2@100").unwrap();
        assert_eq!(
            layout[0].spec,
            LayerSpec::Conv2d { in_ch: 1, out_ch: 4, kernel: 5, stride: 1, pad: 0 }
        );
        assert_eq!(layout[0].len(), 100);
    }

    #[test]
    fn empty_layout_ok() {
        assert!(parse_layout("").unwrap().is_empty());
    }

    #[test]
    fn bad_entries_rejected() {
        assert!(parse_layout("4x4@0,4x4@99").is_err()); // gap
        assert!(parse_layout("4x4@7").is_err()); // nonzero start
        assert!(parse_layout("4y4@0").is_err()); // bad shape
        assert!(parse_layout("conv:3x16@0").is_err()); // missing kernel
        assert!(parse_layout("conv:3x16:k3:q9@0").is_err()); // bad field
        assert!(parse_layout("pool:2@0").is_err()); // offset on structural
        assert!(parse_layout("4x4").is_err()); // missing offset on dense
        assert!(parse_layout("pool:0").is_err()); // degenerate pool
    }

    #[test]
    fn v1_detection_keys_on_syntax() {
        assert!(layout_is_v1("64x64@0,64x10@4096"));
        assert!(!layout_is_v1("dense:64x64@0,dense:64x10@4096"));
        assert!(!layout_is_v1("64x64@0,relu,64x10@4096"));
        assert!(!layout_is_v1("conv:1x8:k3:s1:p1@0,flatten,dense:128x10@72"));
        assert!(!layout_is_v1(""));
    }

    #[test]
    fn fan_in_per_kind() {
        assert_eq!(LayerSpec::Dense { k: 64, n: 10 }.fan_in(), 64);
        assert_eq!(
            LayerSpec::Conv2d { in_ch: 3, out_ch: 16, kernel: 3, stride: 1, pad: 1 }.fan_in(),
            27
        );
        assert_eq!(LayerSpec::Relu.fan_in(), 0);
    }

    #[test]
    fn stats_per_layer_skip_structural() {
        let layout = parse_layout("2x4@0,relu,4x2@8").unwrap();
        // layer 0: 8 params, set 2; layer 2: 8 params, set all
        let mut m = BitVec::zeros(16);
        m.set(0, true);
        m.set(5, true);
        for i in 8..16 {
            m.set(i, true);
        }
        let stats = layer_stats(&m, &layout);
        assert_eq!(stats.len(), 2, "relu owns no params and reports no row");
        assert_eq!(stats[0].ones, 2);
        assert!((stats[0].density - 0.25).abs() < 1e-12);
        assert_eq!(stats[1].ones, 8);
        assert_eq!(stats[1].density, 1.0);
        assert_eq!(stats[1].entropy, 0.0);
        let table = format_table(&stats);
        assert!(table.contains("0.2500"));
        assert!(table.contains("dense"));
    }

    #[test]
    fn conv_stats_report_kind() {
        let layout = parse_layout("conv:1x2:k3@0,relu,flatten,dense:8x2@18").unwrap();
        let m = BitVec::zeros(18 + 16);
        let stats = layer_stats(&m, &layout);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].layer.spec.kind_name(), "conv2d");
        assert_eq!(stats[0].layer.len(), 18);
        let table = format_table(&stats);
        assert!(table.contains("conv2d"));
        assert!(table.contains("1>2 k3s1p0"));
    }
}
