//! Layer-resolved sparsity telemetry.
//!
//! The flat mask layout is opaque to the coordinator except for the
//! `layers` line in the AOT manifest ("KxN@offset" per parameterized
//! layer). This module decodes that line and reports per-layer density
//! / entropy — the unstructured-sparsity telemetry that shows WHERE the
//! regularizer prunes (the paper's sec. III intuition: redundant
//! sub-network features get eliminated, which concentrates in the
//! over-provisioned layers).

use anyhow::{bail, Context, Result};

use crate::util::BitVec;

use super::entropy_bits;

/// One parameterized layer's slice of the flat vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSlice {
    pub index: usize,
    pub rows: usize,
    pub cols: usize,
    pub offset: usize,
}

impl LayerSlice {
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parse the manifest `layers=` line: comma-separated "KxN@offset".
pub fn parse_layout(s: &str) -> Result<Vec<LayerSlice>> {
    let mut out = Vec::new();
    if s.trim().is_empty() {
        return Ok(out);
    }
    for (index, item) in s.split(',').enumerate() {
        let (shape, off) = item
            .split_once('@')
            .with_context(|| format!("layer entry '{item}' missing @offset"))?;
        let (k, n) = shape
            .split_once('x')
            .with_context(|| format!("layer shape '{shape}' missing KxN"))?;
        let slice = LayerSlice {
            index,
            rows: k.trim().parse().context("layer rows")?,
            cols: n.trim().parse().context("layer cols")?,
            offset: off.trim().parse().context("layer offset")?,
        };
        if let Some(prev) = out.last() {
            let prev: &LayerSlice = prev;
            if slice.offset != prev.offset + prev.len() {
                bail!("layer layout not contiguous at entry {index}");
            }
        } else if slice.offset != 0 {
            bail!("first layer must start at offset 0");
        }
        out.push(slice);
    }
    Ok(out)
}

/// Per-layer sparsity report for one mask.
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub layer: LayerSlice,
    pub ones: usize,
    pub density: f64,
    pub entropy: f64,
}

/// Compute per-layer density/entropy of `mask` under `layout`.
pub fn layer_stats(mask: &BitVec, layout: &[LayerSlice]) -> Vec<LayerStats> {
    layout
        .iter()
        .map(|l| {
            let ones = (l.offset..l.offset + l.len())
                .filter(|&i| mask.get(i))
                .count();
            let density = if l.len() == 0 { 0.0 } else { ones as f64 / l.len() as f64 };
            LayerStats {
                layer: l.clone(),
                ones,
                density,
                entropy: entropy_bits(density),
            }
        })
        .collect()
}

/// Render a compact per-layer table (used by `fedsrn eval` / analyze).
pub fn format_table(stats: &[LayerStats]) -> String {
    let mut out = String::from("layer      shape          params    density   H(bits)\n");
    for s in stats {
        out.push_str(&format!(
            "{:<10} {:>6}x{:<7} {:>8}   {:>7.4}   {:>7.4}\n",
            s.layer.index,
            s.layer.rows,
            s.layer.cols,
            s.layer.len(),
            s.density,
            s.entropy
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        let layout = parse_layout("64x64@0,64x10@4096").unwrap();
        assert_eq!(layout.len(), 2);
        assert_eq!(layout[0].len(), 4096);
        assert_eq!(layout[1].offset, 4096);
        assert_eq!(layout[1].len(), 640);
    }

    #[test]
    fn empty_layout_ok() {
        assert!(parse_layout("").unwrap().is_empty());
    }

    #[test]
    fn non_contiguous_rejected() {
        assert!(parse_layout("4x4@0,4x4@99").is_err());
        assert!(parse_layout("4x4@7").is_err());
        assert!(parse_layout("4y4@0").is_err());
    }

    #[test]
    fn stats_per_layer() {
        let layout = parse_layout("2x4@0,4x2@8").unwrap();
        // layer 0: 8 params, set 2; layer 1: 8 params, set all
        let mut m = BitVec::zeros(16);
        m.set(0, true);
        m.set(5, true);
        for i in 8..16 {
            m.set(i, true);
        }
        let stats = layer_stats(&m, &layout);
        assert_eq!(stats[0].ones, 2);
        assert!((stats[0].density - 0.25).abs() < 1e-12);
        assert_eq!(stats[1].ones, 8);
        assert_eq!(stats[1].density, 1.0);
        assert_eq!(stats[1].entropy, 0.0);
        let table = format_table(&stats);
        assert!(table.contains("0.2500"));
    }
}
