//! Server-side aggregation (paper eq. 8) and the SignSGD majority vote.
//!
//! Eq. 8: theta(t+1) = (1 / sum_k |D_k|) * sum_i |D_i| * m_i(t) — a
//! dataset-size-weighted average of the received binary masks, which is
//! an unbiased estimate of the average of the clients' local probability
//! masks (FedPM, thm. 1). Implemented as a streaming accumulator so the
//! server never holds all masks in memory at once.
//!
//! audit: deterministic

use crate::util::BitVec;

use super::ProbMask;

/// Streaming weighted-average aggregator for uplink masks.
#[derive(Debug, Clone)]
pub struct MaskAggregator {
    acc: Vec<f64>,
    weight_sum: f64,
    n_clients: usize,
}

impl MaskAggregator {
    pub fn new(n_params: usize) -> Self {
        Self { acc: vec![0.0; n_params], weight_sum: 0.0, n_clients: 0 }
    }

    /// Add one client's mask with weight |D_i|.
    ///
    /// Word-scans the set bits (O(words + ones)); the regularized masks
    /// this server exists for are sparse, so this is the hot-loop form.
    pub fn add_mask(&mut self, mask: &BitVec, weight: f64) {
        assert_eq!(mask.len(), self.acc.len(), "mask length mismatch");
        assert!(weight > 0.0, "client weight must be positive");
        for i in mask.iter_ones() {
            self.acc[i] += weight;
        }
        self.weight_sum += weight;
        self.n_clients += 1;
    }

    /// Bit-by-bit reference path, kept for the §Perf A/B benchmark.
    pub fn add_mask_scalar(&mut self, mask: &BitVec, weight: f64) {
        assert_eq!(mask.len(), self.acc.len(), "mask length mismatch");
        assert!(weight > 0.0, "client weight must be positive");
        for (i, bit) in mask.iter().enumerate() {
            if bit {
                self.acc[i] += weight;
            }
        }
        self.weight_sum += weight;
        self.n_clients += 1;
    }

    /// Add a client update that is already a probability vector (used by
    /// algorithms that upload thetas rather than sampled masks, e.g. a
    /// FedPM variant ablation).
    pub fn add_probs(&mut self, probs: &[f32], weight: f64) {
        assert_eq!(probs.len(), self.acc.len());
        assert!(weight > 0.0);
        for (a, &p) in self.acc.iter_mut().zip(probs) {
            *a += weight * p as f64;
        }
        self.weight_sum += weight;
        self.n_clients += 1;
    }

    /// Fold a cohort-local partial sum produced by an edge aggregator
    /// (`fl::aggregator`, DESIGN.md §Fleet): elementwise add of the
    /// per-parameter weighted sums plus the scalar tallies. This is the
    /// grouping step of eq. 8 — each entry of `acc` is the same f64 sum
    /// of the same integer-weighted terms the flat fold would have
    /// accumulated, so for integer |D_i| weights the merged state is
    /// bit-identical to folding the constituent masks directly.
    pub fn merge_sums(&mut self, acc: &[f64], weight_sum: f64, n_clients: usize) {
        assert_eq!(acc.len(), self.acc.len(), "partial-sum length mismatch");
        assert!(weight_sum > 0.0 && n_clients > 0, "empty partial sum");
        for (a, &p) in self.acc.iter_mut().zip(acc) {
            *a += p;
        }
        self.weight_sum += weight_sum;
        self.n_clients += n_clients;
    }

    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Finalize into the next global probability mask (eq. 8).
    pub fn finalize(&self) -> ProbMask {
        assert!(self.weight_sum > 0.0, "no clients aggregated");
        ProbMask::from_theta(
            self.acc.iter().map(|&a| (a / self.weight_sum) as f32).collect(),
        )
    }

    pub fn reset(&mut self) {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        self.weight_sum = 0.0;
        self.n_clients = 0;
    }
}

/// Bayesian (Beta-posterior) aggregation — the FedPM-family alternative
/// to the plain mean of eq. 8 (Isik et al. use a Beta(alpha, beta)
/// prior updated by the received mask bits; the posterior mean becomes
/// the next theta). With prior Beta(l0, l0) and K received bits b_k
/// (weight w_k):
///     theta_j = (l0 + sum_k w_k b_kj) / (2*l0 + sum_k w_k)
/// As l0 -> 0 this recovers eq. 8; larger l0 damps sampling noise in
/// early rounds — the `agg=bayes` ablation quantifies the effect.
#[derive(Debug, Clone)]
pub struct BetaAggregator {
    ones: Vec<f64>,
    weight_sum: f64,
    prior: f64,
    n_clients: usize,
}

impl BetaAggregator {
    pub fn new(n_params: usize, prior: f64) -> Self {
        assert!(prior > 0.0, "Beta prior must be positive");
        Self { ones: vec![0.0; n_params], weight_sum: 0.0, prior, n_clients: 0 }
    }

    pub fn add_mask(&mut self, mask: &BitVec, weight: f64) {
        assert_eq!(mask.len(), self.ones.len());
        assert!(weight > 0.0);
        for i in mask.iter_ones() {
            self.ones[i] += weight;
        }
        self.weight_sum += weight;
        self.n_clients += 1;
    }

    /// Edge-tier partial-sum fold — same contract as
    /// [`MaskAggregator::merge_sums`]; the Beta posterior only ever sees
    /// the summed one-counts, so grouping exactness carries over.
    pub fn merge_sums(&mut self, ones: &[f64], weight_sum: f64, n_clients: usize) {
        assert_eq!(ones.len(), self.ones.len(), "partial-sum length mismatch");
        assert!(weight_sum > 0.0 && n_clients > 0, "empty partial sum");
        for (a, &p) in self.ones.iter_mut().zip(ones) {
            *a += p;
        }
        self.weight_sum += weight_sum;
        self.n_clients += n_clients;
    }

    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    pub fn finalize(&self) -> ProbMask {
        assert!(self.n_clients > 0, "no clients aggregated");
        let denom = 2.0 * self.prior + self.weight_sum;
        ProbMask::from_theta(
            self.ones.iter().map(|&o| ((self.prior + o) / denom) as f32).collect(),
        )
    }

    pub fn reset(&mut self) {
        self.ones.iter_mut().for_each(|o| *o = 0.0);
        self.weight_sum = 0.0;
        self.n_clients = 0;
    }
}

/// Majority-vote aggregation for MV-SignSGD: the server keeps the sign
/// of the weighted sum of client sign vectors (Bernstein et al. '18).
/// Client signs travel as BitVec (1 = positive).
pub fn majority_vote_signs(signs: &[BitVec], weights: &[f64]) -> BitVec {
    assert!(!signs.is_empty());
    assert_eq!(signs.len(), weights.len());
    let n = signs[0].len();
    let mut tally = vec![0.0f64; n];
    for (mask, &w) in signs.iter().zip(weights) {
        assert_eq!(mask.len(), n);
        for (i, bit) in mask.iter().enumerate() {
            tally[i] += if bit { w } else { -w };
        }
    }
    BitVec::from_iter_len(tally.iter().map(|&t| t > 0.0), n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_of(bits: &[u8]) -> BitVec {
        BitVec::from_iter_len(bits.iter().map(|&b| b == 1), bits.len())
    }

    #[test]
    fn equal_weights_is_mean() {
        let mut agg = MaskAggregator::new(4);
        agg.add_mask(&mask_of(&[1, 1, 0, 0]), 1.0);
        agg.add_mask(&mask_of(&[1, 0, 0, 0]), 1.0);
        agg.add_mask(&mask_of(&[1, 0, 1, 0]), 1.0);
        let theta = agg.finalize();
        let want = [1.0, 1.0 / 3.0, 1.0 / 3.0, 0.0];
        for (t, w) in theta.theta().iter().zip(want) {
            assert!((t - w as f32).abs() < 1e-6);
        }
        assert_eq!(agg.n_clients(), 3);
    }

    #[test]
    fn dataset_size_weighting() {
        // eq. 8 with |D_1|=10, |D_2|=30: theta = (10*m1 + 30*m2)/40
        let mut agg = MaskAggregator::new(2);
        agg.add_mask(&mask_of(&[1, 0]), 10.0);
        agg.add_mask(&mask_of(&[0, 1]), 30.0);
        let theta = agg.finalize();
        assert!((theta.theta()[0] - 0.25).abs() < 1e-6);
        assert!((theta.theta()[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn output_always_valid_probability() {
        let mut agg = MaskAggregator::new(100);
        for i in 0..7 {
            let m = BitVec::from_iter_len((0..100).map(|j| (i + j) % 3 == 0), 100);
            agg.add_mask(&m, (i + 1) as f64);
        }
        let theta = agg.finalize();
        assert!(theta.theta().iter().all(|&t| (0.0..=1.0).contains(&t)));
    }

    #[test]
    fn probs_path_matches_mask_path_in_expectation() {
        let mut a = MaskAggregator::new(3);
        a.add_probs(&[0.5, 0.25, 1.0], 2.0);
        a.add_probs(&[0.5, 0.75, 0.0], 2.0);
        let theta = a.finalize();
        assert!((theta.theta()[0] - 0.5).abs() < 1e-6);
        assert!((theta.theta()[1] - 0.5).abs() < 1e-6);
        assert!((theta.theta()[2] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn reset_clears_state() {
        let mut agg = MaskAggregator::new(2);
        agg.add_mask(&mask_of(&[1, 1]), 1.0);
        agg.reset();
        assert_eq!(agg.n_clients(), 0);
        agg.add_mask(&mask_of(&[0, 1]), 1.0);
        let theta = agg.finalize();
        assert_eq!(theta.theta()[0], 0.0);
        assert_eq!(theta.theta()[1], 1.0);
    }

    #[test]
    #[should_panic(expected = "no clients")]
    fn finalize_without_clients_panics() {
        MaskAggregator::new(3).finalize();
    }

    #[test]
    fn word_scan_matches_scalar_path() {
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(3);
        let n = 1000;
        let masks: Vec<BitVec> = (0..5)
            .map(|_| {
                let p = rng.next_f64();
                BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
            })
            .collect();
        let mut a = MaskAggregator::new(n);
        let mut b = MaskAggregator::new(n);
        for (i, m) in masks.iter().enumerate() {
            a.add_mask(m, (i + 1) as f64);
            b.add_mask_scalar(m, (i + 1) as f64);
        }
        assert_eq!(a.finalize().theta(), b.finalize().theta());
    }

    #[test]
    fn beta_aggregator_recovers_mean_at_small_prior() {
        let mut plain = MaskAggregator::new(4);
        let mut bayes = BetaAggregator::new(4, 1e-9);
        for (m, w) in [(mask_of(&[1, 1, 0, 0]), 2.0), (mask_of(&[1, 0, 1, 0]), 1.0)] {
            plain.add_mask(&m, w);
            bayes.add_mask(&m, w);
        }
        for (a, b) in plain.finalize().theta().iter().zip(bayes.finalize().theta()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn beta_prior_shrinks_toward_half() {
        let mut bayes = BetaAggregator::new(2, 10.0);
        bayes.add_mask(&mask_of(&[1, 0]), 1.0);
        let theta = bayes.finalize();
        // posterior mean (10+1)/21 and 10/21: pulled toward 0.5
        assert!((theta.theta()[0] - 11.0 / 21.0).abs() < 1e-6);
        assert!((theta.theta()[1] - 10.0 / 21.0).abs() < 1e-6);
    }

    #[test]
    fn beta_output_valid_probability() {
        let mut bayes = BetaAggregator::new(50, 0.5);
        for i in 0..5u64 {
            let m = BitVec::from_iter_len((0..50).map(|j| (i as usize + j) % 2 == 0), 50);
            bayes.add_mask(&m, (i + 1) as f64);
        }
        assert!(bayes.finalize().theta().iter().all(|&t| (0.0..=1.0).contains(&t)));
    }

    #[test]
    fn majority_vote() {
        let signs = vec![
            mask_of(&[1, 0, 1]),
            mask_of(&[1, 1, 0]),
            mask_of(&[0, 0, 1]),
        ];
        let mv = majority_vote_signs(&signs, &[1.0, 1.0, 1.0]);
        assert_eq!(mv.iter().collect::<Vec<_>>(), vec![true, false, true]);
        // weights flip the result
        let mv_w = majority_vote_signs(&signs, &[1.0, 5.0, 1.0]);
        assert_eq!(mv_w.iter().collect::<Vec<_>>(), vec![true, true, false]);
    }
}
