//! Metrics sink: per-round records to JSONL / CSV + console.
//!
//! Dependency-free JSON emission (flat records only — nothing here needs
//! nesting). One record per round is the contract the figure harnesses
//! and the plotting snippets in EXPERIMENTS.md consume.
//!
//! Audit policy: intentionally unannotated. This module only *emits*
//! bytes — it never parses untrusted input (no `wire-decode` surface)
//! and never feeds a value back into aggregation (no `deterministic`
//! obligation). Protocol role: observer of round outcomes, downstream
//! of [`crate::fl::comm`]'s accounting.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// One federated round's logged metrics.
#[derive(Debug, Clone, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean validation accuracy over the devices' target distributions.
    pub accuracy: f64,
    /// Mean validation loss.
    pub loss: f64,
    /// Mean train loss reported by the clients this round.
    pub train_loss: f64,
    /// Estimated uplink Bpp (eq. 13).
    pub est_bpp: f64,
    /// Measured (entropy-coded) uplink Bpp.
    pub coded_bpp: f64,
    /// Measured downlink Bpp (32.0 raw floats; coded delta frames under
    /// `downlink=qdelta`).
    pub dl_bpp: f64,
    /// Mean global keep-probability (sparsity telemetry).
    pub mean_theta: f64,
    /// Density of a mask sampled from the current global state.
    pub mask_density: f64,
    /// Wall-clock seconds spent in this round.
    pub secs: f64,
}

impl RoundRecord {
    /// Flat JSON object (stable key order).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(256);
        s.push('{');
        let mut first = true;
        let mut kv = |s: &mut String, k: &str, v: String| {
            if !first {
                s.push(',');
            }
            first = false;
            let _ = write!(s, "\"{k}\":{v}");
        };
        kv(&mut s, "round", self.round.to_string());
        kv(&mut s, "accuracy", fmt_f64(self.accuracy));
        kv(&mut s, "loss", fmt_f64(self.loss));
        kv(&mut s, "train_loss", fmt_f64(self.train_loss));
        kv(&mut s, "est_bpp", fmt_f64(self.est_bpp));
        kv(&mut s, "coded_bpp", fmt_f64(self.coded_bpp));
        kv(&mut s, "dl_bpp", fmt_f64(self.dl_bpp));
        kv(&mut s, "mean_theta", fmt_f64(self.mean_theta));
        kv(&mut s, "mask_density", fmt_f64(self.mask_density));
        kv(&mut s, "secs", fmt_f64(self.secs));
        s.push('}');
        s
    }

    pub const CSV_HEADER: &'static str =
        "round,accuracy,loss,train_loss,est_bpp,coded_bpp,dl_bpp,mean_theta,mask_density,secs";

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{},{}",
            self.round,
            fmt_f64(self.accuracy),
            fmt_f64(self.loss),
            fmt_f64(self.train_loss),
            fmt_f64(self.est_bpp),
            fmt_f64(self.coded_bpp),
            fmt_f64(self.dl_bpp),
            fmt_f64(self.mean_theta),
            fmt_f64(self.mask_density),
            fmt_f64(self.secs),
        )
    }
}

/// JSON-safe float formatting (no NaN/inf in the output files).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

/// Where round records go: optional JSONL file + console cadence.
pub struct MetricsSink {
    file: Option<BufWriter<File>>,
    pub echo_every: usize,
    records: Vec<RoundRecord>,
}

impl MetricsSink {
    /// `path` empty -> in-memory + console only.
    pub fn new(path: &str, echo_every: usize) -> Result<Self> {
        let file = if path.is_empty() {
            None
        } else {
            if let Some(parent) = Path::new(path).parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            Some(BufWriter::new(
                File::create(path).with_context(|| format!("creating {path}"))?,
            ))
        };
        Ok(Self { file, echo_every: echo_every.max(1), records: Vec::new() })
    }

    pub fn push(&mut self, rec: RoundRecord) -> Result<()> {
        if let Some(f) = &mut self.file {
            writeln!(f, "{}", rec.to_json())?;
        }
        if rec.round % self.echo_every == 0 {
            eprintln!(
                "round {:>4}  acc={:.4}  loss={:.4}  estBpp={:.4}  codedBpp={:.4}  theta={:.4}",
                rec.round, rec.accuracy, rec.loss, rec.est_bpp, rec.coded_bpp, rec.mean_theta
            );
        }
        self.records.push(rec);
        Ok(())
    }

    pub fn records(&self) -> &[RoundRecord] {
        &self.records
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    /// Mean of the last `k` records' field (for end-of-run summaries).
    pub fn tail_mean(&self, k: usize, f: impl Fn(&RoundRecord) -> f64) -> f64 {
        let n = self.records.len();
        if n == 0 {
            return 0.0;
        }
        let take = k.min(n);
        self.records[n - take..].iter().map(&f).sum::<f64>() / take as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape() {
        let rec = RoundRecord { round: 3, accuracy: 0.5, ..Default::default() };
        let j = rec.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"round\":3"));
        assert!(j.contains("\"accuracy\":0.500000"));
        // no NaN leakage
        let rec = RoundRecord { loss: f64::NAN, ..Default::default() };
        assert!(rec.to_json().contains("\"loss\":null"));
    }

    #[test]
    fn csv_columns_match_header() {
        let rec = RoundRecord::default();
        assert_eq!(
            rec.to_csv().split(',').count(),
            RoundRecord::CSV_HEADER.split(',').count()
        );
    }

    #[test]
    fn sink_writes_jsonl() {
        let path = std::env::temp_dir().join(format!("fedsrn_m_{}.jsonl", std::process::id()));
        let mut sink = MetricsSink::new(path.to_str().unwrap(), 1000).unwrap();
        for r in 0..3 {
            sink.push(RoundRecord { round: r, accuracy: r as f64 * 0.1, ..Default::default() })
                .unwrap();
        }
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.lines().all(|l| l.starts_with('{')));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tail_mean() {
        let mut sink = MetricsSink::new("", 1000).unwrap();
        for r in 0..10 {
            sink.push(RoundRecord { round: r, accuracy: r as f64, ..Default::default() })
                .unwrap();
        }
        assert_eq!(sink.tail_mean(2, |r| r.accuracy), 8.5);
        assert_eq!(sink.tail_mean(100, |r| r.accuracy), 4.5);
    }
}
