//! The federation transport: checksummed, length-prefixed framing over
//! `std::net::TcpStream` plus the version/fingerprint handshake.
//!
//! [`crate::fl::protocol`] defines *what* crosses between server and
//! device — typed, versioned envelopes. This module defines *how* those
//! envelope bytes move over a real socket, with the same
//! validate-everything discipline:
//!
//! * **Framing** — every message is one frame:
//!   `[magic u8][kind u8][len u32 LE][payload][fnv64 LE]`, where the
//!   trailing FNV-1a checksum covers `kind || len || payload`. A
//!   truncated frame, an oversize length prefix (checked *before* any
//!   allocation), an unknown kind, a bad magic byte, or any byte flip
//!   anywhere in the frame is a clean `Err` — never a panic, never
//!   silent garbage (property-torture-tested in `tests/properties.rs`).
//! * **Handshake** — a device opens with [`Hello`] (transport version,
//!   run fingerprint, device id, resume round); the server answers
//!   [`Welcome`] or a [`FrameKind::Error`] frame naming the mismatch.
//!   The [`run_fingerprint`] hashes everything both sides must agree on
//!   for the federation to be well-defined — model geometry, dataset
//!   derivation, partition, seeds, participation model, algorithm and
//!   downlink wire mode — so a device from a different experiment can
//!   never fold garbage into a round.
//! * **Timeouts** — [`Conn`] exposes `set_read_timeout`; the session
//!   layer ([`crate::fl::session`]) uses it to turn stragglers into the
//!   existing dropout path. [`is_timeout`] classifies the resulting
//!   errors.
//!
//! Framing is generic over `io::Read`/`io::Write` so the property tests
//! drive it with in-memory cursors; [`Conn`] specializes it to a boxed
//! [`Wire`] (a real `TcpStream`, or a chaos-wrapped one — see
//! `fl::chaos`) and counts the actual framed bytes both directions.
//! [`FrameBuf`] is the incremental flip side of [`read_frame`]: it
//! accumulates whatever bytes a non-blocking socket happens to deliver
//! and yields complete validated frames, which is what the session
//! readiness loop parses against.
//!
//! audit: wire-decode, deterministic

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::config::ExperimentConfig;
use crate::runtime::Manifest;

/// Transport/handshake version; a mismatch is a hard handshake error.
pub const TRANSPORT_VERSION: u8 = 1;

/// First byte of every frame — catches stream desync immediately.
pub const FRAME_MAGIC: u8 = 0xF5;

/// Hard cap on a single frame's payload; length prefixes beyond this are
/// rejected before any allocation happens. Generous: the largest real
/// payload is a dense f32 broadcast (4 bytes/param).
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// magic + kind + u32 length prefix.
const FRAME_HEAD: usize = 1 + 1 + 4;
/// Trailing FNV-1a 64 checksum.
const FRAME_TAIL: usize = 8;

/// Total on-the-wire size of a frame carrying `payload_len` bytes.
pub fn framed_len(payload_len: usize) -> usize {
    FRAME_HEAD + payload_len + FRAME_TAIL
}

/// What a frame carries — the session-layer message alphabet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Device -> server: handshake open ([`Hello`] payload).
    Hello,
    /// Server -> device: handshake accept ([`Welcome`] payload).
    Welcome,
    /// Server -> device: one round — serialized `RoundPlan` + `DownlinkMsg`.
    Round,
    /// Device -> server: one serialized `UplinkMsg` envelope.
    Uplink,
    /// Device -> server: trained, but the injected failure model says
    /// this uplink never lands (the simulated-dropout path).
    Dropped,
    /// Server -> device: full-state resync for a reconnecting device
    /// that missed `qdelta` chain links (serialized `DownlinkMsg`).
    Sync,
    /// Server -> device: the run is over.
    Done,
    /// Either direction: fatal condition, UTF-8 message payload.
    Error,
}

impl FrameKind {
    fn to_u8(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::Welcome => 2,
            FrameKind::Round => 3,
            FrameKind::Uplink => 4,
            FrameKind::Dropped => 5,
            FrameKind::Sync => 6,
            FrameKind::Done => 7,
            FrameKind::Error => 8,
        }
    }

    fn from_u8(b: u8) -> Result<Self> {
        Ok(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::Welcome,
            3 => FrameKind::Round,
            4 => FrameKind::Uplink,
            5 => FrameKind::Dropped,
            6 => FrameKind::Sync,
            7 => FrameKind::Done,
            8 => FrameKind::Error,
            other => bail!("unknown frame kind {other}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FrameKind::Hello => "hello",
            FrameKind::Welcome => "welcome",
            FrameKind::Round => "round",
            FrameKind::Uplink => "uplink",
            FrameKind::Dropped => "dropped",
            FrameKind::Sync => "sync",
            FrameKind::Done => "done",
            FrameKind::Error => "error",
        }
    }
}

/// FNV-1a 64 over a sequence of byte slices (dependency-free integrity
/// check against random corruption — not an adversarial MAC).
pub fn fnv1a64(parts: &[&[u8]]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Write one frame. Returns the total bytes written (header + payload +
/// checksum), which is what the socket actually carries.
pub fn write_frame<W: Write>(w: &mut W, kind: FrameKind, payload: &[u8]) -> Result<usize> {
    ensure!(
        payload.len() <= MAX_FRAME_BYTES,
        "frame payload {} exceeds the {} byte cap",
        payload.len(),
        MAX_FRAME_BYTES
    );
    // audit:checked(the ensure above caps payload.len() at MAX_FRAME_BYTES < 2^32)
    let len = (payload.len() as u32).to_le_bytes();
    let kind_byte = [kind.to_u8()];
    let sum = fnv1a64(&[&kind_byte[..], &len[..], payload]).to_le_bytes();
    let mut out = Vec::with_capacity(framed_len(payload.len()));
    out.push(FRAME_MAGIC);
    out.push(kind_byte[0]);
    out.extend_from_slice(&len);
    out.extend_from_slice(payload);
    out.extend_from_slice(&sum);
    w.write_all(&out).context("writing frame")?;
    Ok(out.len())
}

/// Read and validate one frame. The length prefix is checked against
/// `max_frame` before any payload allocation; the trailing checksum must
/// match over `kind || len || payload`, so a byte flip anywhere in the
/// frame fails here instead of surfacing as a corrupt envelope upstream.
pub fn read_frame<R: Read>(r: &mut R, max_frame: usize) -> Result<(FrameKind, Vec<u8>)> {
    let mut head = [0u8; FRAME_HEAD];
    r.read_exact(&mut head).context("reading frame header")?;
    ensure!(
        head[0] == FRAME_MAGIC,
        "bad frame magic {:#04x} (stream desync?)",
        head[0]
    );
    let kind = FrameKind::from_u8(head[1])?;
    let len = u32::from_le_bytes(head[2..6].try_into()?) as usize;
    ensure!(
        len <= max_frame,
        "frame length prefix {len} exceeds the {max_frame} byte cap"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    let mut sum = [0u8; FRAME_TAIL];
    r.read_exact(&mut sum).context("reading frame checksum")?;
    let expect = fnv1a64(&[&head[1..2], &head[2..6], &payload[..]]);
    ensure!(
        u64::from_le_bytes(sum) == expect,
        "frame checksum mismatch ({} frame, {len} payload bytes)",
        kind.name()
    );
    Ok((kind, payload))
}

/// Incremental frame decoder for non-blocking sockets: feed it whatever
/// bytes the kernel delivered, take complete validated frames out. The
/// validation discipline is identical to [`read_frame`] — bad magic,
/// unknown kind, and oversize length prefixes are rejected as soon as
/// the offending byte arrives (before the payload is buffered or
/// allocated), and the trailing checksum must match before a frame is
/// yielded. `Ok(None)` means "incomplete, keep feeding".
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Buffered bytes not yet consumed by a complete frame.
    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Pop the next complete frame, if the buffer holds one.
    pub fn next_frame(&mut self, max_frame: usize) -> Result<Option<(FrameKind, Vec<u8>)>> {
        // validate eagerly: a desynced or hostile prefix fails on its
        // first bytes, not after max_frame bytes of buffering
        if let Some(&magic) = self.buf.first() {
            ensure!(
                magic == FRAME_MAGIC,
                "bad frame magic {magic:#04x} (stream desync?)"
            );
        }
        if let Some(&kind) = self.buf.get(1) {
            FrameKind::from_u8(kind)?;
        }
        if self.buf.len() < FRAME_HEAD {
            return Ok(None);
        }
        let kind = FrameKind::from_u8(self.buf[1])?;
        let len = u32::from_le_bytes(self.buf[2..6].try_into()?) as usize;
        ensure!(
            len <= max_frame,
            "frame length prefix {len} exceeds the {max_frame} byte cap"
        );
        let total = framed_len(len);
        if self.buf.len() < total {
            return Ok(None);
        }
        let payload_end = FRAME_HEAD + len;
        // audit:checked(the early return above guarantees buf.len() >= total > payload_end)
        let expect = fnv1a64(&[&self.buf[1..2], &self.buf[2..6], &self.buf[FRAME_HEAD..payload_end]]);
        // audit:checked(the early return above guarantees buf.len() >= total)
        let sum = u64::from_le_bytes(self.buf[payload_end..total].try_into()?);
        ensure!(
            sum == expect,
            "frame checksum mismatch ({} frame, {len} payload bytes)",
            kind.name()
        );
        // audit:checked(the early return above guarantees buf.len() >= total > payload_end)
        let payload = self.buf[FRAME_HEAD..payload_end].to_vec();
        self.buf.drain(..total);
        Ok(Some((kind, payload)))
    }
}

/// Device -> server handshake open.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hello {
    pub version: u8,
    /// [`run_fingerprint`] of the device's config + model manifest.
    pub fingerprint: u64,
    pub device_id: u64,
    /// Highest round index this device holds reconstruction state for
    /// (0 = fresh). A reconnecting device that missed `qdelta` chain
    /// links reports it so the server can send a [`FrameKind::Sync`].
    pub resume_round: u64,
}

const HELLO_BYTES: usize = 1 + 8 + 8 + 8;

impl Hello {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HELLO_BYTES);
        out.push(self.version);
        out.extend_from_slice(&self.fingerprint.to_le_bytes());
        out.extend_from_slice(&self.device_id.to_le_bytes());
        out.extend_from_slice(&self.resume_round.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(
            bytes.len() == HELLO_BYTES,
            "hello must be exactly {HELLO_BYTES} bytes, got {}",
            bytes.len()
        );
        ensure!(
            bytes[0] == TRANSPORT_VERSION,
            "hello transport version {} != supported {TRANSPORT_VERSION}",
            bytes[0]
        );
        Ok(Self {
            version: bytes[0],
            fingerprint: u64::from_le_bytes(bytes[1..9].try_into()?),
            device_id: u64::from_le_bytes(bytes[9..17].try_into()?),
            resume_round: u64::from_le_bytes(bytes[17..25].try_into()?),
        })
    }
}

/// Server -> device handshake accept.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Welcome {
    pub version: u8,
    /// The server's own [`run_fingerprint`] — echoed so the check is
    /// mutual, not just server-side.
    pub fingerprint: u64,
    pub n_clients: u64,
    pub rounds: u64,
}

const WELCOME_BYTES: usize = 1 + 8 + 8 + 8;

impl Welcome {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(WELCOME_BYTES);
        out.push(self.version);
        out.extend_from_slice(&self.fingerprint.to_le_bytes());
        out.extend_from_slice(&self.n_clients.to_le_bytes());
        out.extend_from_slice(&self.rounds.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(
            bytes.len() == WELCOME_BYTES,
            "welcome must be exactly {WELCOME_BYTES} bytes, got {}",
            bytes.len()
        );
        ensure!(
            bytes[0] == TRANSPORT_VERSION,
            "welcome transport version {} != supported {TRANSPORT_VERSION}",
            bytes[0]
        );
        Ok(Self {
            version: bytes[0],
            fingerprint: u64::from_le_bytes(bytes[1..9].try_into()?),
            n_clients: u64::from_le_bytes(bytes[9..17].try_into()?),
            rounds: u64::from_le_bytes(bytes[17..25].try_into()?),
        })
    }
}

/// Hash of everything server and device must agree on for a federated
/// run to be well-defined: model geometry and frozen-weight seed,
/// dataset derivation, partition, client count, root seed, participation
/// model, algorithm family, and downlink wire mode. Built from a
/// canonical string so a mismatch is debuggable by diffing the inputs.
pub fn run_fingerprint(cfg: &ExperimentConfig, man: &Manifest) -> u64 {
    let canon = format!(
        "fedsrn/v{TRANSPORT_VERSION};model={};n_params={};weight_seed={};input_dim={};\
         n_classes={};dataset={};train_samples={};partition={:?};clients={};seed={};\
         algorithm={};downlink={};participation={};dropout={}",
        man.model,
        man.n_params,
        man.weight_seed,
        man.input_dim,
        man.n_classes,
        cfg.dataset,
        cfg.train_samples,
        cfg.partition,
        cfg.clients,
        cfg.seed,
        cfg.algorithm.name(),
        cfg.downlink.name(),
        cfg.participation.to_bits(),
        cfg.dropout.to_bits(),
    );
    fnv1a64(&[canon.as_bytes()])
}

/// Is this anyhow error a socket read timeout (straggler deadline)?
pub fn is_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|cause| {
        cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        })
    })
}

/// What a [`Conn`] moves bytes through: a plain `TcpStream`, or a
/// fault-injecting wrapper around one (`fl::chaos::ChaosStream`). The
/// supertrait `Read`/`Write` pair carries the data; the extra methods
/// are the socket controls the session and device loops need.
pub trait Wire: Read + Write + Send {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()>;
    fn set_nonblocking(&self, on: bool) -> std::io::Result<()>;
    /// Best-effort close of both directions (peer sees EOF/RST).
    fn shutdown(&self);
    fn peer_desc(&self) -> String;
}

impl Wire for TcpStream {
    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        TcpStream::set_read_timeout(self, d)
    }

    fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        TcpStream::set_nonblocking(self, on)
    }

    fn shutdown(&self) {
        let _ = TcpStream::shutdown(self, Shutdown::Both);
    }

    fn peer_desc(&self) -> String {
        self.peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".to_string())
    }
}

/// One framed connection, counting the actual bytes both directions
/// (frame headers and checksums included — the transport-level totals
/// the session reports next to the envelope-level `RoundComm` numbers).
pub struct Conn {
    wire: Box<dyn Wire>,
    max_frame: usize,
    pub tx_bytes: u64,
    pub rx_bytes: u64,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Result<Self> {
        // A fresh TCP stream starts in blocking mode (some platforms let
        // accepted sockets inherit the listener's O_NONBLOCK; clear it —
        // the readiness loop opts back in via `set_nonblocking`).
        stream.set_nonblocking(false).context("clearing O_NONBLOCK")?;
        // Frames are written in one syscall; never Nagle-delay them.
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        Ok(Self::from_wire(Box::new(stream)))
    }

    /// Wrap an already-configured wire (e.g. a `ChaosStream`).
    pub fn from_wire(wire: Box<dyn Wire>) -> Self {
        Self { wire, max_frame: MAX_FRAME_BYTES, tx_bytes: 0, rx_bytes: 0 }
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Self::new(stream)
    }

    pub fn peer_addr(&self) -> String {
        self.wire.peer_desc()
    }

    /// `None` blocks forever; `Some(d)` turns a silent peer into a
    /// [`is_timeout`] error after `d`.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.wire.set_read_timeout(d).context("setting read timeout")
    }

    /// Flip the connection between blocking sends/recvs and the
    /// readiness-loop discipline (`read_some`/`write_some`).
    pub fn set_nonblocking(&self, on: bool) -> Result<()> {
        self.wire.set_nonblocking(on).context("toggling O_NONBLOCK")
    }

    /// Close both directions; the peer observes EOF.
    pub fn shutdown(&self) {
        self.wire.shutdown();
    }

    /// One non-blocking read into `scratch`. `Ok(0)` is EOF; a
    /// `WouldBlock` error means "no bytes right now".
    pub fn read_some(&mut self, scratch: &mut [u8]) -> std::io::Result<usize> {
        let n = self.wire.read(scratch)?;
        self.rx_bytes += n as u64;
        Ok(n)
    }

    /// One non-blocking write of as much of `bytes` as the socket
    /// accepts; `WouldBlock` means "send buffer full, try later".
    pub fn write_some(&mut self, bytes: &[u8]) -> std::io::Result<usize> {
        let n = self.wire.write(bytes)?;
        self.tx_bytes += n as u64;
        Ok(n)
    }

    pub fn send(&mut self, kind: FrameKind, payload: &[u8]) -> Result<()> {
        let n = write_frame(&mut self.wire, kind, payload)?;
        self.tx_bytes += n as u64;
        Ok(())
    }

    pub fn recv(&mut self) -> Result<(FrameKind, Vec<u8>)> {
        let (kind, payload) = read_frame(&mut self.wire, self.max_frame)?;
        self.rx_bytes += framed_len(payload.len()) as u64;
        Ok((kind, payload))
    }

    /// Receive and require a specific frame kind; an [`FrameKind::Error`]
    /// frame surfaces its message, anything else is a protocol error.
    pub fn recv_expect(&mut self, want: FrameKind) -> Result<Vec<u8>> {
        let (kind, payload) = self.recv()?;
        if kind == FrameKind::Error {
            bail!("peer error: {}", String::from_utf8_lossy(&payload));
        }
        ensure!(
            kind == want,
            "expected a {} frame, got {}",
            want.name(),
            kind.name()
        );
        Ok(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(kind: FrameKind, payload: &[u8]) -> (FrameKind, Vec<u8>) {
        let mut buf = Vec::new();
        let n = write_frame(&mut buf, kind, payload).unwrap();
        assert_eq!(n, buf.len());
        assert_eq!(n, framed_len(payload.len()));
        read_frame(&mut Cursor::new(buf), MAX_FRAME_BYTES).unwrap()
    }

    #[test]
    fn frame_roundtrip_all_kinds() {
        for kind in [
            FrameKind::Hello,
            FrameKind::Welcome,
            FrameKind::Round,
            FrameKind::Uplink,
            FrameKind::Dropped,
            FrameKind::Sync,
            FrameKind::Done,
            FrameKind::Error,
        ] {
            let payload: Vec<u8> = (0..97u8).collect();
            let (k, p) = roundtrip(kind, &payload);
            assert_eq!(k, kind);
            assert_eq!(p, payload);
        }
        // empty payloads are legal (Dropped / Done)
        let (k, p) = roundtrip(FrameKind::Done, &[]);
        assert_eq!(k, FrameKind::Done);
        assert!(p.is_empty());
    }

    #[test]
    fn oversize_length_prefix_rejected_before_allocation() {
        // craft a header claiming a huge payload over a tiny buffer
        let mut buf = vec![FRAME_MAGIC, FrameKind::Round.to_u8()];
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf), MAX_FRAME_BYTES).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
        // and the writer refuses to emit one
        let mut sink = Vec::new();
        // (can't allocate 256 MiB in a unit test; check the boundary math)
        assert!(write_frame(&mut sink, FrameKind::Round, &[0u8; 16]).is_ok());
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Uplink, b"some envelope bytes").unwrap();
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x41;
            assert!(
                read_frame(&mut Cursor::new(bad), MAX_FRAME_BYTES).is_err(),
                "flip at byte {i} must not decode"
            );
        }
    }

    #[test]
    fn truncation_always_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FrameKind::Round, &[7u8; 33]).unwrap();
        for cut in 0..buf.len() {
            assert!(
                read_frame(&mut Cursor::new(&buf[..cut]), MAX_FRAME_BYTES).is_err(),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn hello_welcome_roundtrip_and_version_skew() {
        let hello = Hello {
            version: TRANSPORT_VERSION,
            fingerprint: 0xDEAD_BEEF,
            device_id: 3,
            resume_round: 17,
        };
        assert_eq!(Hello::from_bytes(&hello.to_bytes()).unwrap(), hello);
        let skew = Hello { version: TRANSPORT_VERSION + 1, ..hello };
        assert!(Hello::from_bytes(&skew.to_bytes()).is_err());
        assert!(Hello::from_bytes(&hello.to_bytes()[..10]).is_err());

        let welcome = Welcome {
            version: TRANSPORT_VERSION,
            fingerprint: 1,
            n_clients: 4,
            rounds: 9,
        };
        assert_eq!(Welcome::from_bytes(&welcome.to_bytes()).unwrap(), welcome);
        let skew = Welcome { version: 0, ..welcome };
        assert!(Welcome::from_bytes(&skew.to_bytes()).is_err());
    }

    #[test]
    fn fingerprint_separates_runs() {
        let man = Manifest::builtin("mlp_tiny").unwrap();
        let cfg = ExperimentConfig {
            model: "mlp_tiny".into(),
            dataset: "tiny".into(),
            ..ExperimentConfig::default()
        };
        let base = run_fingerprint(&cfg, &man);
        assert_eq!(base, run_fingerprint(&cfg, &man), "deterministic");
        let other_seed = ExperimentConfig { seed: cfg.seed + 1, ..cfg.clone() };
        assert_ne!(base, run_fingerprint(&other_seed, &man));
        let other_clients = ExperimentConfig { clients: cfg.clients + 1, ..cfg.clone() };
        assert_ne!(base, run_fingerprint(&other_clients, &man));
        let other_model = Manifest::builtin("mlp_mnist").unwrap();
        assert_ne!(base, run_fingerprint(&cfg, &other_model));
    }

    #[test]
    fn framebuf_yields_frames_fed_byte_by_byte() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Uplink, b"envelope").unwrap();
        write_frame(&mut wire, FrameKind::Dropped, &[]).unwrap();
        let mut fb = FrameBuf::new();
        let mut got = Vec::new();
        for &b in &wire {
            fb.extend(&[b]);
            while let Some(frame) = fb.next_frame(MAX_FRAME_BYTES).unwrap() {
                got.push(frame);
            }
        }
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], (FrameKind::Uplink, b"envelope".to_vec()));
        assert_eq!(got[1], (FrameKind::Dropped, Vec::new()));
        assert_eq!(fb.pending(), 0);
    }

    #[test]
    fn framebuf_rejects_bad_prefixes_before_buffering_payload() {
        // bad magic fails on the very first byte
        let mut fb = FrameBuf::new();
        fb.extend(&[0x00]);
        assert!(fb.next_frame(MAX_FRAME_BYTES).is_err());
        // unknown kind fails on the second byte
        let mut fb = FrameBuf::new();
        fb.extend(&[FRAME_MAGIC, 0xEE]);
        assert!(fb.next_frame(MAX_FRAME_BYTES).is_err());
        // oversize length prefix fails as soon as the header is whole
        let mut fb = FrameBuf::new();
        fb.extend(&[FRAME_MAGIC, FrameKind::Round.to_u8()]);
        fb.extend(&u32::MAX.to_le_bytes());
        let err = fb.next_frame(MAX_FRAME_BYTES).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn framebuf_detects_checksum_corruption() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Sync, &[9u8; 64]).unwrap();
        // flip one payload byte: the whole frame arrives, then fails
        let flip = FRAME_HEAD + 10;
        wire[flip] ^= 0x41;
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        assert!(fb.next_frame(MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn fnv_is_stable() {
        // pinned value so the wire format cannot drift silently
        assert_eq!(fnv1a64(&[b""]), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(&[b"a", b"b"]), fnv1a64(&[b"ab"]));
    }
}
