//! The federated-learning engine: server, simulated device fleet,
//! communication accounting, metrics.
//!
//! The round loop itself lives in [`crate::algos`] (each algorithm owns
//! its round semantics) and is driven by [`crate::coordinator`].

pub mod client;
pub mod participation;
pub mod comm;
pub mod metrics;
pub mod server;

pub use client::Client;
pub use participation::Participation;
pub use comm::{CommTotals, RoundComm};
pub use metrics::{MetricsSink, RoundRecord};
pub use server::Server;
