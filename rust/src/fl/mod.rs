//! The federated-learning engine: wire protocol, server, simulated
//! device fleet, communication accounting, metrics.
//!
//! A round is an exchange of the typed messages in [`protocol`]
//! (DESIGN.md §Protocol); the strategy halves that speak them live in
//! [`crate::algos`] and the round driver in [`crate::coordinator`].

pub mod client;
pub mod participation;
pub mod comm;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use client::Client;
pub use participation::Participation;
pub use comm::{CommTotals, RoundComm};
pub use metrics::{MetricsSink, RoundRecord};
pub use protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload, PROTOCOL_VERSION};
pub use server::Server;
