//! The federated-learning engine: wire protocol, transport, networked
//! sessions, server, simulated device fleet, communication accounting,
//! metrics.
//!
//! A round is an exchange of the typed messages in [`protocol`]
//! (DESIGN.md §Protocol); the strategy halves that speak them live in
//! [`crate::algos`] and the in-process round driver in
//! [`crate::coordinator`]. The [`transport`] module frames those
//! messages over real TCP sockets and [`session`] drives full federated
//! rounds across independent server/device processes (`fedsrn serve` /
//! `fedsrn device` — DESIGN.md §Transport), bit-identical to the
//! in-process path.
//!
//! Audit policy map (DESIGN.md §Static-analysis; enforced by
//! `fedsrn audit`): the modules that parse untrusted bytes —
//! [`protocol`], [`transport`], [`aggregator`] — carry
//! `//! audit: wire-decode, deterministic`; [`session`]'s readiness
//! loop carries `panic-free` (its parse regions are fenced); the
//! aggregate-affecting state modules — [`client`], [`comm`], [`fleet`],
//! [`participation`] — carry `deterministic`. [`chaos`], [`metrics`],
//! and [`server`] are intentionally unannotated; each states why in its
//! own module doc.

pub mod aggregator;
pub mod chaos;
pub mod client;
pub mod participation;
pub mod comm;
pub mod fleet;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod session;
pub mod transport;

pub use aggregator::{staleness_scale, AggKind, AggregateMsg, EdgeAggregator};
pub use chaos::{ChaosEvents, ChaosSpec, ChaosStream, ChaosSwitch};
pub use client::{derive_client_seed, Client};
pub use participation::Participation;
pub use comm::{CommTotals, RoundComm};
pub use fleet::{run_fleet, DelayProfile, FleetOpts, FleetReport};
pub use metrics::{MetricsSink, RoundRecord};
pub use protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload, PROTOCOL_VERSION};
pub use server::Server;
pub use session::{
    run_device, DeviceOpts, DeviceReport, Session, SessionConfig, SessionStats,
};
pub use transport::{
    run_fingerprint, Conn, FrameBuf, FrameKind, Hello, Welcome, Wire, TRANSPORT_VERSION,
};
