//! Communication accounting: every bit that would cross the network.
//!
//! The paper's evaluation axis is uplink bits per parameter, so this is
//! first-class state, not an afterthought. Since the protocol redesign
//! (DESIGN.md §Protocol) the counters record the **actual serialized
//! envelope bytes** of the wire messages — [`crate::fl::UplinkMsg`] per
//! received uplink, [`crate::fl::DownlinkMsg`] per receiving device —
//! plus the estimated source entropy of each uplink (eq. 13: H(p) for a
//! binary payload, 32 for dense floats).
//!
//! Accounting is *merge-based* (DESIGN.md §Parallel round engine): all
//! counters are plain sums, so per-client contributions can be recorded
//! into independent `RoundComm` values on worker threads and folded into
//! the round total with [`RoundComm::merge`] — no `&mut` interleaving
//! per client, and the merged result is independent of merge order.
//!
//! audit: deterministic

use super::protocol::DownlinkMsg;

/// One round's communication totals across all clients.
#[derive(Debug, Clone, Default)]
pub struct RoundComm {
    /// Measured uplink bits (entropy-coded payloads, incl. headers).
    pub ul_bits: u64,
    /// Measured downlink bits (global state broadcast; raw floats or
    /// coded delta frames, whatever the `downlink` config actually ships).
    pub dl_bits: u64,
    /// Number of client uplinks this round.
    pub clients: usize,
    /// Number of per-client downlink broadcasts this round (the DL Bpp
    /// denominator; differs from `clients` under dropout, where a device
    /// receives the broadcast but its uplink never lands).
    pub broadcasts: usize,
    /// Model parameter count (denominator for Bpp).
    pub n_params: usize,
    /// Sum over clients of the per-client estimated Bpp (eq. 13).
    est_bpp_sum: f64,
}

impl RoundComm {
    pub fn new(n_params: usize) -> Self {
        Self { n_params, ..Default::default() }
    }

    /// Record one received uplink envelope: its actual serialized size
    /// (`UplinkMsg::wire_bits`) plus the estimated source Bpp of its
    /// payload (eq. 13 for binary payloads, 32.0 for dense floats).
    pub fn add_uplink(&mut self, wire_bits: u64, est_bpp: f64) {
        self.ul_bits += wire_bits;
        self.est_bpp_sum += est_bpp;
        self.clients += 1;
    }

    /// Record a batch of uplinks that arrived pre-folded in one edge
    /// `AggregateMsg` (hierarchical aggregation, DESIGN.md §Fleet): the
    /// summed wire bits and est-Bpp contributions of the constituent
    /// envelopes, counted as `clients` uplinks so every Bpp denominator
    /// matches the flat path exactly.
    pub fn add_uplinks(&mut self, wire_bits: u64, est_bpp_sum: f64, clients: usize) {
        self.ul_bits += wire_bits;
        self.est_bpp_sum += est_bpp_sum;
        self.clients += clients;
    }

    /// Record a downlink broadcast of `bits` wire bits to one client.
    pub fn add_downlink_bits(&mut self, bits: u64) {
        self.dl_bits += bits;
        self.broadcasts += 1;
    }

    /// Record the delivery of one serialized downlink envelope to one
    /// receiving device (called once per receiver — a frame chain link
    /// reaches the whole fleet, a stateless broadcast only the cohort).
    pub fn add_downlink_msg(&mut self, msg: &DownlinkMsg) {
        self.add_downlink_bits(msg.wire_bits());
    }

    /// Fold another accumulator (e.g. a per-client or per-worker record)
    /// into this one. All fields are sums, so merging is associative and
    /// commutative up to f64 rounding of `est_bpp`.
    pub fn merge(&mut self, other: &RoundComm) {
        debug_assert!(
            self.n_params == other.n_params || other.clients == 0,
            "merging accounting for different models"
        );
        self.ul_bits += other.ul_bits;
        self.dl_bits += other.dl_bits;
        self.clients += other.clients;
        self.broadcasts += other.broadcasts;
        self.est_bpp_sum += other.est_bpp_sum;
    }

    /// Mean estimated uplink Bpp via eq. 13 (mean over clients).
    pub fn est_bpp(&self) -> f64 {
        if self.clients == 0 {
            0.0
        } else {
            self.est_bpp_sum / self.clients as f64
        }
    }

    /// Measured mean uplink bits per parameter per client.
    pub fn measured_bpp(&self) -> f64 {
        if self.clients == 0 || self.n_params == 0 {
            0.0
        } else {
            self.ul_bits as f64 / (self.clients as f64 * self.n_params as f64)
        }
    }

    /// Measured mean downlink bits per parameter per broadcast (32.0 for
    /// raw floats; well below with `downlink=qdelta`).
    pub fn measured_dl_bpp(&self) -> f64 {
        if self.broadcasts == 0 || self.n_params == 0 {
            0.0
        } else {
            self.dl_bits as f64 / (self.broadcasts as f64 * self.n_params as f64)
        }
    }
}

/// Accumulates communication across rounds (for totals / summaries).
#[derive(Debug, Clone, Default)]
pub struct CommTotals {
    pub ul_bits: u64,
    pub dl_bits: u64,
    pub rounds: usize,
}

impl CommTotals {
    pub fn add_round(&mut self, rc: &RoundComm) {
        self.ul_bits += rc.ul_bits;
        self.dl_bits += rc.dl_bits;
        self.rounds += 1;
    }

    pub fn ul_megabytes(&self) -> f64 {
        self.ul_bits as f64 / 8.0 / 1e6
    }

    pub fn dl_megabytes(&self) -> f64 {
        self.dl_bits as f64 / 8.0 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress;
    use crate::fl::protocol::{UplinkMsg, UplinkPayload};
    use crate::mask::empirical_bpp;
    use crate::util::{BitVec, Xoshiro256};

    fn mask(n: usize, p: f64, seed: u64) -> BitVec {
        let mut rng = Xoshiro256::new(seed);
        BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
    }

    /// A coded-mask uplink envelope the way the strategies build one.
    fn mask_msg(m: &BitVec) -> UplinkMsg {
        UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::CodedMask(compress::encode(m)),
        }
    }

    #[test]
    fn mask_uplink_accounting() {
        let n = 10_000;
        let mut rc = RoundComm::new(n);
        for i in 0..5 {
            let m = mask(n, 0.5, i);
            rc.add_uplink(mask_msg(&m).wire_bits(), empirical_bpp(&m));
        }
        assert_eq!(rc.clients, 5);
        // p=0.5 masks: measured ~1 Bpp (+ envelope headers), est ~1.0
        assert!((rc.est_bpp() - 1.0).abs() < 0.01, "est={}", rc.est_bpp());
        assert!((rc.measured_bpp() - 1.0).abs() < 0.05, "meas={}", rc.measured_bpp());
    }

    #[test]
    fn sparse_masks_account_below_one_bpp() {
        let n = 50_000;
        let mut rc = RoundComm::new(n);
        let m = mask(n, 0.02, 1);
        rc.add_uplink(mask_msg(&m).wire_bits(), empirical_bpp(&m));
        assert!(rc.measured_bpp() < 0.25);
        assert!(rc.est_bpp() < 0.25);
    }

    #[test]
    fn dense_uplink_envelope_measures_serialized_bytes() {
        let n = 1000;
        let mut rc = RoundComm::new(n);
        let msg = UplinkMsg {
            weight: 10.0,
            train_loss: 0.1,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; n]),
        };
        rc.add_uplink(msg.wire_bits(), 32.0);
        // envelope = serialized bytes exactly; est stays the source's 32
        assert_eq!(rc.ul_bits, msg.to_bytes().len() as u64 * 8);
        assert!(rc.measured_bpp() > 32.0 && rc.measured_bpp() < 32.2);
        assert_eq!(rc.est_bpp(), 32.0);
    }

    #[test]
    fn merge_matches_interleaved_accounting() {
        let n = 8_000;
        let masks: Vec<BitVec> = (0..6).map(|i| mask(n, 0.3, i)).collect();
        // one accumulator, clients recorded in order
        let mut whole = RoundComm::new(n);
        for m in &masks {
            whole.add_downlink_bits(n as u64 * 32);
            whole.add_uplink(mask_msg(m).wire_bits(), empirical_bpp(m));
        }
        // per-client accumulators merged in a scrambled order
        let mut parts: Vec<RoundComm> = masks
            .iter()
            .map(|m| {
                let mut rc = RoundComm::new(n);
                rc.add_downlink_bits(n as u64 * 32);
                rc.add_uplink(mask_msg(m).wire_bits(), empirical_bpp(m));
                rc
            })
            .collect();
        parts.reverse();
        let mut merged = RoundComm::new(n);
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.ul_bits, whole.ul_bits);
        assert_eq!(merged.dl_bits, whole.dl_bits);
        assert_eq!(merged.clients, whole.clients);
        assert_eq!(merged.broadcasts, whole.broadcasts);
        assert!((merged.est_bpp() - whole.est_bpp()).abs() < 1e-12);
    }

    #[test]
    fn downlink_bpp_uses_broadcast_count() {
        let mut rc = RoundComm::new(1000);
        // 4 devices receive the broadcast, only 3 uplinks land
        for _ in 0..4 {
            rc.add_downlink_bits(2_000);
        }
        for i in 0..3 {
            let m = mask(1000, 0.5, i);
            rc.add_uplink(mask_msg(&m).wire_bits(), empirical_bpp(&m));
        }
        assert_eq!(rc.broadcasts, 4);
        assert_eq!(rc.clients, 3);
        assert!((rc.measured_dl_bpp() - 2.0).abs() < 1e-12, "{}", rc.measured_dl_bpp());
    }

    #[test]
    fn downlink_envelope_measures_serialized_bytes() {
        let mut rc = RoundComm::new(1000);
        let msg = DownlinkMsg::Theta(vec![0.5; 1000]);
        rc.add_downlink_msg(&msg);
        assert_eq!(rc.dl_bits, msg.to_bytes().len() as u64 * 8);
        // raw floats + the few envelope header bytes
        assert!(rc.measured_dl_bpp() > 32.0 && rc.measured_dl_bpp() < 32.1);
    }

    #[test]
    fn totals_accumulate() {
        let mut t = CommTotals::default();
        let mut rc = RoundComm::new(8000);
        rc.add_uplink(8000 * 32, 32.0);
        rc.add_downlink_bits(8000 * 32);
        t.add_round(&rc);
        t.add_round(&rc);
        assert_eq!(t.rounds, 2);
        assert_eq!(t.ul_bits, 2 * 8000 * 32);
        assert_eq!(t.dl_bits, 2 * 8000 * 32);
        assert!(t.ul_megabytes() > 0.0);
    }

    #[test]
    fn empty_round_is_zero() {
        let rc = RoundComm::new(100);
        assert_eq!(rc.measured_bpp(), 0.0);
        assert_eq!(rc.est_bpp(), 0.0);
    }
}
