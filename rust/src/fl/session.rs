//! The networked federation runtime: server-side device sessions and the
//! device-side run loop.
//!
//! `fedsrn serve` drives the same [`crate::algos::ServerLogic`] round
//! (`begin_round -> fold_uplink* -> end_round`) as the in-process
//! [`crate::coordinator::RoundEngine`], but every hop crosses a real
//! [`crate::fl::transport`] socket:
//!
//! * **Registry** — [`Session`] owns one framed connection per device
//!   id. Devices register with the [`crate::fl::transport::Hello`]
//!   handshake (version + run fingerprint validated, mismatches get a
//!   typed error frame back); a reconnecting device replaces its stale
//!   connection and, when the `qdelta` chain made its state
//!   irrecoverable, receives a full-state `Sync` frame first.
//! * **Round barrier** — [`Session::run_round`] mirrors the engine's
//!   schedule exactly: sample the cohort, broadcast one `Round` frame
//!   (chain links go to the whole fleet, stateless broadcasts only to
//!   the cohort), then collect uplinks **in cohort order** in bounded
//!   waves of ~2x the worker count, folding each envelope the moment it
//!   lands — coordinator memory stays O(wave × n_params) at any cohort
//!   size, and the fold order (hence the aggregate) is bit-identical to
//!   the in-process path.
//! * **Straggler deadline** — every uplink read carries a wall-clock
//!   deadline; a device that blows it is converted into the existing
//!   dropout path ("trained, but the uplink never lands"), its
//!   connection is dropped, and the round continues. Injected dropout
//!   (the `dropout` config key) is decided device-side from the same
//!   seeded [`Participation::drops`] the engine uses, shipped as a tiny
//!   `Dropped` frame so accounting matches the simulation bit-for-bit.
//! * **Accounting** — [`crate::fl::RoundComm`] records the serialized
//!   envelope bytes exactly as the in-process engine does (the envelope
//!   is byte-identical on the socket); [`SessionStats`] additionally
//!   reports the transport-level totals (frame headers, checksums,
//!   handshakes) actually moved.
//!
//! The device half, [`run_device`], derives its shard, seeds, cohort
//! membership, and dropout decisions from the shared config — pure
//! functions of `(seed, round, id)` — so a fleet of independent
//! processes reproduces the simulated federation exactly.

use std::net::{SocketAddr, TcpListener};
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algos::{build_server, RoundStats, ServerLogic};
use crate::compress::DownlinkMode;
use crate::config::ExperimentConfig;
use crate::coordinator::RoundEngine;
use crate::data::{load_experiment_data, partition_fleet};
use crate::fl::client::derive_client_seed;
use crate::fl::protocol::{DownlinkMsg, RoundPlan};
use crate::fl::transport::{
    is_timeout, run_fingerprint, Conn, FrameKind, Hello, Welcome, TRANSPORT_VERSION,
};
use crate::fl::{Client, Participation, RoundComm, UplinkMsg};
use crate::runtime::ModelRuntime;

/// How long a registering device may take to complete its handshake.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Accept-loop poll cadence (the listener is non-blocking).
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Server-session knobs (the CLI flags of `fedsrn serve`).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Devices the federation expects (= the config's `clients`).
    pub expected: usize,
    /// [`run_fingerprint`] every device must present.
    pub fingerprint: u64,
    /// Total rounds (echoed in the handshake for operator sanity).
    pub rounds: usize,
    /// Straggler deadline per uplink read.
    pub deadline: Duration,
    /// Uplink collection wave size; 0 = the round engine's sizing.
    pub wave: usize,
    /// `downlink=qdelta`: a reconnecting device that missed chain links
    /// needs a full-state `Sync` frame before its next round.
    pub needs_state_sync: bool,
}

impl SessionConfig {
    /// Derive the session parameters a config implies.
    pub fn from_experiment(
        cfg: &ExperimentConfig,
        fingerprint: u64,
        deadline: Duration,
        wave: usize,
    ) -> Self {
        Self {
            expected: cfg.clients,
            fingerprint,
            rounds: cfg.rounds,
            deadline,
            wave,
            needs_state_sync: matches!(cfg.downlink, DownlinkMode::QDelta { .. }),
        }
    }
}

/// Transport-level telemetry for one serve run.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionStats {
    /// Bytes actually written to sockets (frames, headers, checksums).
    pub tx_bytes: u64,
    /// Bytes actually read from sockets.
    pub rx_bytes: u64,
    /// Uplinks that blew the straggler deadline (-> dropout path).
    pub stragglers: usize,
    /// Cohort members with no live connection when their turn came.
    pub missing: usize,
    /// Devices that re-registered after a drop.
    pub reconnects: usize,
    /// Full-state resync frames sent to reconnecting devices.
    pub syncs: usize,
}

/// The server side of the networked runtime: listener + device registry
/// + the socket-driven round barrier.
pub struct Session {
    listener: TcpListener,
    devices: Vec<Option<Conn>>,
    cfg: SessionConfig,
    rounds_completed: usize,
    pub stats: SessionStats,
}

impl Session {
    /// Bind the coordinator socket (`addr` may use port 0; see
    /// [`Session::local_addr`]).
    pub fn bind(addr: &str, cfg: SessionConfig) -> Result<Self> {
        ensure!(cfg.expected > 0, "a session needs at least one device");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        let devices = (0..cfg.expected).map(|_| None).collect();
        Ok(Self { listener, devices, cfg, rounds_completed: 0, stats: SessionStats::default() })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading listener address")
    }

    /// Registered devices with a live connection.
    pub fn connected(&self) -> usize {
        self.devices.iter().filter(|d| d.is_some()).count()
    }

    /// Block (polling) until every expected device has registered, or
    /// fail after `timeout` naming the ids still missing.
    pub fn wait_for_fleet(&mut self, timeout: Duration) -> Result<()> {
        let start = Instant::now();
        while self.connected() < self.cfg.expected {
            if !self.accept_pending(&None)? && start.elapsed() > timeout {
                let missing: Vec<usize> = self
                    .devices
                    .iter()
                    .enumerate()
                    .filter_map(|(i, d)| d.is_none().then_some(i))
                    .collect();
                bail!(
                    "{}/{} devices registered after {:.0?}; missing ids {missing:?}",
                    self.connected(),
                    self.cfg.expected,
                    timeout
                );
            }
            std::thread::sleep(ACCEPT_POLL);
        }
        Ok(())
    }

    /// Drain the accept queue, handshaking every pending connection.
    /// Returns whether any registration happened. `fleet_state` is the
    /// current broadcast reconstruction, used to resync reconnects.
    fn accept_pending(&mut self, fleet_state: &Option<Vec<f32>>) -> Result<bool> {
        let mut any = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    match self.handshake(Conn::new(stream)?, fleet_state) {
                        Ok(id) => {
                            any = true;
                            eprintln!("session: device {id} registered");
                        }
                        Err(e) => eprintln!("session: handshake rejected: {e:#}"),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                // A peer that connected and reset before we got to it
                // is its problem, not the federation's: skip it.
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting device connection"),
            }
        }
        Ok(any)
    }

    /// Validate one device's `Hello`, reply `Welcome` (or a typed error
    /// frame), register the connection, and resync a reconnect that
    /// missed `qdelta` chain links.
    fn handshake(&mut self, mut conn: Conn, fleet_state: &Option<Vec<f32>>) -> Result<usize> {
        conn.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let hello = match conn
            .recv_expect(FrameKind::Hello)
            .and_then(|p| Hello::from_bytes(&p))
        {
            Ok(h) => h,
            Err(e) => {
                let _ = conn.send(FrameKind::Error, format!("{e:#}").as_bytes());
                self.retire(conn);
                return Err(e);
            }
        };
        let reject = if hello.fingerprint != self.cfg.fingerprint {
            Some(format!(
                "run fingerprint {:#018x} != server's {:#018x} \
                 (different config/model on the two sides?)",
                hello.fingerprint, self.cfg.fingerprint
            ))
        } else if hello.device_id >= self.cfg.expected as u64 {
            Some(format!(
                "device id {} out of range for a {}-device federation",
                hello.device_id, self.cfg.expected
            ))
        } else {
            None
        };
        if let Some(msg) = reject {
            let _ = conn.send(FrameKind::Error, msg.as_bytes());
            self.retire(conn);
            bail!("device {} rejected: {msg}", hello.device_id);
        }
        let id = hello.device_id as usize;
        let welcome = Welcome {
            version: TRANSPORT_VERSION,
            fingerprint: self.cfg.fingerprint,
            n_clients: self.cfg.expected as u64,
            rounds: self.cfg.rounds as u64,
        };
        conn.send(FrameKind::Welcome, &welcome.to_bytes())?;
        // A device that missed chain links cannot decode the next frame;
        // bring it back in sync with a full-state broadcast.
        if self.cfg.needs_state_sync && (hello.resume_round as usize) < self.rounds_completed {
            if let Some(state) = fleet_state {
                conn.send(FrameKind::Sync, &DownlinkMsg::RawF32(state.clone()).to_bytes())?;
                self.stats.syncs += 1;
            }
        }
        if let Some(old) = self.devices[id].take() {
            self.stats.reconnects += 1;
            self.retire(old);
        }
        self.devices[id] = Some(conn);
        Ok(id)
    }

    /// Fold a dead or replaced connection's byte counters into the
    /// session totals before dropping it.
    fn retire(&mut self, conn: Conn) {
        self.stats.tx_bytes += conn.tx_bytes;
        self.stats.rx_bytes += conn.rx_bytes;
    }

    fn drop_device(&mut self, id: usize) {
        if let Some(conn) = self.devices[id].take() {
            self.retire(conn);
        }
    }

    /// Send one frame to a device; returns whether it was delivered. A
    /// write failure retires the connection (the device will reconnect).
    /// Missed *cohort turns* are counted once, in [`Self::collect_uplink`].
    fn send_to(&mut self, id: usize, kind: FrameKind, payload: &[u8]) -> bool {
        let Some(conn) = &mut self.devices[id] else {
            return false;
        };
        match conn.send(kind, payload) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("session: device {id} send failed ({e:#}); dropping connection");
                self.drop_device(id);
                false
            }
        }
    }

    /// Wave size: the engine's sizing unless overridden.
    fn wave(&self) -> usize {
        if self.cfg.wave > 0 {
            self.cfg.wave
        } else {
            RoundEngine::new(0).wave_size()
        }
    }

    /// Drive one full round over the connected fleet — the socket twin
    /// of [`RoundEngine::run_round`], same schedule, same accounting,
    /// same fold order.
    pub fn run_round(
        &mut self,
        server: &mut dyn ServerLogic,
        fleet_state: &mut Option<Vec<f32>>,
        participation: Participation,
        plan: &RoundPlan,
        comm: &mut RoundComm,
    ) -> Result<RoundStats> {
        // Reconnecting devices re-register between rounds.
        self.accept_pending(fleet_state)?;
        let n = self.cfg.expected;
        let cohort = participation.sample_round(n, plan.seed, plan.round);
        let msg = server.begin_round(plan)?;
        let payload = round_payload(plan, &msg);
        // A frame chain link must reach every device (one missed link
        // and the chain is undecodable); stateless broadcasts only the
        // cohort. Mirrors the engine's receiver accounting exactly.
        if matches!(msg, DownlinkMsg::Frame(_)) {
            for id in 0..n {
                if cohort.binary_search(&id).is_err()
                    && self.send_to(id, FrameKind::Round, &payload)
                {
                    comm.add_downlink_msg(&msg);
                }
            }
        }
        let prev = fleet_state.take();
        let wave = self.wave();
        for ids in cohort.chunks(wave) {
            for &id in ids {
                if self.send_to(id, FrameKind::Round, &payload) {
                    comm.add_downlink_msg(&msg);
                }
            }
            // Ordered streaming fold: envelopes land in cohort order, so
            // the aggregate is bit-identical to the in-process engine.
            for &id in ids {
                self.collect_uplink(id, server, comm)?;
            }
        }
        *fleet_state = Some(msg.decode_state(prev.as_deref())?);
        self.rounds_completed = plan.round;
        server.end_round(plan)
    }

    /// Read one device's round reply under the straggler deadline and
    /// fold it. Timeouts, disconnects, protocol violations, and corrupt
    /// envelopes all become the dropout path: the uplink never lands,
    /// the round goes on.
    fn collect_uplink(
        &mut self,
        id: usize,
        server: &mut dyn ServerLogic,
        comm: &mut RoundComm,
    ) -> Result<()> {
        let deadline = self.cfg.deadline;
        let Some(conn) = &mut self.devices[id] else {
            self.stats.missing += 1;
            return Ok(());
        };
        conn.set_read_timeout(Some(deadline))?;
        match conn.recv() {
            Ok((FrameKind::Uplink, bytes)) => match UplinkMsg::from_bytes(&bytes) {
                Ok(up) => {
                    debug_assert_eq!(up.wire_bytes(), bytes.len());
                    server.fold_uplink(&up, comm)?;
                }
                Err(e) => {
                    eprintln!("session: device {id} sent a corrupt envelope ({e:#}); dropping");
                    self.drop_device(id);
                }
            },
            // Injected failure model: trained, uplink never lands.
            Ok((FrameKind::Dropped, _)) => {}
            Ok((kind, _)) => {
                eprintln!(
                    "session: device {id} broke protocol ({} instead of uplink); dropping",
                    kind.name()
                );
                self.drop_device(id);
            }
            Err(e) if is_timeout(&e) => {
                eprintln!(
                    "session: device {id} missed the {deadline:.0?} straggler deadline; \
                     treating as dropout"
                );
                self.stats.stragglers += 1;
                self.drop_device(id);
            }
            Err(e) => {
                eprintln!("session: device {id} connection lost ({e:#}); treating as dropout");
                self.drop_device(id);
            }
        }
        Ok(())
    }

    /// End the run: tell every live device we're done and fold the
    /// remaining byte counters into the stats.
    pub fn finish(&mut self) -> Result<()> {
        for id in 0..self.devices.len() {
            self.send_to(id, FrameKind::Done, &[]);
        }
        for id in 0..self.devices.len() {
            self.drop_device(id);
        }
        Ok(())
    }
}

/// `Round` frame payload: `[u32 plan_len][plan][downlink envelope]`.
fn round_payload(plan: &RoundPlan, msg: &DownlinkMsg) -> Vec<u8> {
    let plan_bytes = plan.to_bytes();
    let dl_bytes = msg.to_bytes();
    let mut out = Vec::with_capacity(4 + plan_bytes.len() + dl_bytes.len());
    out.extend_from_slice(&(plan_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&plan_bytes);
    out.extend_from_slice(&dl_bytes);
    out
}

/// Parse a `Round` frame payload back into its typed halves, validating
/// every recorded length (the envelope re-validates itself).
pub fn parse_round(payload: &[u8]) -> Result<(RoundPlan, DownlinkMsg)> {
    ensure!(payload.len() >= 4, "round payload truncated");
    let plan_len = u32::from_le_bytes(payload[..4].try_into()?) as usize;
    ensure!(
        payload.len() > 4 + plan_len,
        "round payload records {plan_len} plan bytes but carries {}",
        payload.len() - 4
    );
    let plan = RoundPlan::from_bytes(&payload[4..4 + plan_len]).context("round plan")?;
    let msg = DownlinkMsg::from_bytes(&payload[4 + plan_len..]).context("round downlink")?;
    Ok((plan, msg))
}

/// Device-side runtime knobs (the CLI flags of `fedsrn device`).
#[derive(Debug, Clone)]
pub struct DeviceOpts {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// This device's client id in `[0, clients)`.
    pub device_id: usize,
    /// Total budget for (re)connect attempts.
    pub connect_timeout: Duration,
}

/// What one device run did (printed by `fedsrn device`).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceReport {
    /// Rounds this device received a broadcast for.
    pub rounds_seen: usize,
    /// Rounds it was in the cohort and ran local training.
    pub trained: usize,
    /// Trained rounds whose uplink the failure model suppressed.
    pub dropped: usize,
    /// Times the connection was lost and re-established.
    pub reconnects: usize,
    pub tx_bytes: u64,
    pub rx_bytes: u64,
}

/// Keep trying to connect until `budget` runs out (the server may still
/// be binding, or be mid-restart).
fn connect_with_retry(addr: &str, budget: Duration) -> Result<Conn> {
    let start = Instant::now();
    let mut wait = Duration::from_millis(50);
    loop {
        match Conn::connect(addr) {
            Ok(conn) => return Ok(conn),
            Err(_) if start.elapsed() + wait < budget => {
                std::thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_secs(2));
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("no server at {addr} after {:.0?}", start.elapsed())
                })
            }
        }
    }
}

/// Run one device against a remote server: derive the local shard and
/// seeds from the shared config, register over the handshake, then
/// answer `Round` frames until `Done`. Connection loss triggers a
/// reconnect with the in-memory reconstruction state carried over (and
/// a server-side `Sync` when `qdelta` chain links were missed).
pub fn run_device(cfg: &ExperimentConfig, opts: &DeviceOpts) -> Result<DeviceReport> {
    cfg.validate()?;
    ensure!(
        opts.device_id < cfg.clients,
        "--id {} out of range for a {}-device federation",
        opts.device_id,
        cfg.clients
    );
    let rt = ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.model)
        .with_context(|| format!("loading model '{}'", cfg.model))?;
    let (train, _test) =
        load_experiment_data(cfg, rt.manifest.input_dim, rt.manifest.n_classes)?;
    let shard = partition_fleet(cfg, &train)
        .into_iter()
        .find(|s| s.client_id == opts.device_id)
        .context("partition did not produce this device's shard")?;
    let mut client = Client::new(shard, derive_client_seed(cfg.seed, opts.device_id));
    // The pure device half of the strategy; the throwaway server object
    // only exists to hand it out.
    let task = build_server(cfg, rt.manifest.n_params, rt.weights()).client_task();
    let participation = Participation::new(cfg.participation, cfg.dropout);
    let fingerprint = run_fingerprint(cfg, &rt.manifest);

    let mut report = DeviceReport::default();
    let mut prev_state: Option<Vec<f32>> = None;
    let mut rounds_done = 0usize;
    'connection: loop {
        let mut conn = connect_with_retry(&opts.addr, opts.connect_timeout)?;
        let hello = Hello {
            version: TRANSPORT_VERSION,
            fingerprint,
            device_id: opts.device_id as u64,
            resume_round: rounds_done as u64,
        };
        conn.send(FrameKind::Hello, &hello.to_bytes())?;
        // A mid-run reconnect is only welcomed at the server's next
        // round barrier, which can be a full round away — so wait out
        // the silence in ONE read on THIS connection (re-dialing would
        // queue stale Hellos the server would later mis-count as
        // reconnects, and resuming a framed stream after a mid-frame
        // timeout would desync it). The connect budget bounds the wait;
        // a typed rejection (Error frame) or a dead socket is fatal.
        conn.set_read_timeout(Some(opts.connect_timeout.max(HANDSHAKE_TIMEOUT)))?;
        let welcome_bytes = conn.recv_expect(FrameKind::Welcome).map_err(|e| {
            if is_timeout(&e) {
                e.context(format!("no welcome from {} within the connect budget", opts.addr))
            } else {
                e
            }
        })?;
        let welcome = Welcome::from_bytes(&welcome_bytes)?;
        ensure!(
            welcome.fingerprint == fingerprint,
            "server fingerprint {:#018x} != ours {:#018x}",
            welcome.fingerprint,
            fingerprint
        );
        ensure!(
            welcome.n_clients == cfg.clients as u64,
            "server runs a {}-device federation, our config says {}",
            welcome.n_clients,
            cfg.clients
        );
        // Rounds are server-paced: block until the next frame arrives.
        conn.set_read_timeout(None)?;
        loop {
            match conn.recv() {
                Ok((FrameKind::Sync, bytes)) => {
                    let msg = DownlinkMsg::from_bytes(&bytes).context("sync frame")?;
                    prev_state = Some(msg.decode_state(None)?);
                }
                Ok((FrameKind::Round, bytes)) => {
                    let (plan, dl) = parse_round(&bytes)?;
                    let cohort =
                        participation.sample_round(cfg.clients, plan.seed, plan.round);
                    let mut sent = Ok(());
                    if let Some(pos) =
                        cohort.iter().position(|&c| c == opts.device_id)
                    {
                        let up = task
                            .run(&rt, &train, &mut client, &dl, prev_state.as_deref(), &plan)?;
                        report.trained += 1;
                        sent = if participation.drops(pos, plan.seed, plan.round, opts.device_id)
                        {
                            report.dropped += 1;
                            conn.send(FrameKind::Dropped, &[])
                        } else {
                            conn.send(FrameKind::Uplink, &up.to_bytes())
                        };
                    }
                    // The broadcast itself landed: advance the local
                    // reconstruction even if the reply could not be sent.
                    prev_state = Some(dl.decode_state(prev_state.as_deref())?);
                    rounds_done = plan.round;
                    report.rounds_seen += 1;
                    if let Err(e) = sent {
                        // e.g. the server already dropped us as a
                        // straggler and closed the socket: reconnect,
                        // same as a recv-side connection loss.
                        eprintln!(
                            "device {}: uplink send failed ({e:#}); reconnecting",
                            opts.device_id
                        );
                        report.tx_bytes += conn.tx_bytes;
                        report.rx_bytes += conn.rx_bytes;
                        report.reconnects += 1;
                        continue 'connection;
                    }
                }
                Ok((FrameKind::Done, _)) => {
                    report.tx_bytes += conn.tx_bytes;
                    report.rx_bytes += conn.rx_bytes;
                    return Ok(report);
                }
                Ok((FrameKind::Error, bytes)) => {
                    bail!("server error: {}", String::from_utf8_lossy(&bytes));
                }
                Ok((kind, _)) => bail!("unexpected {} frame from server", kind.name()),
                Err(e) => {
                    eprintln!(
                        "device {}: connection lost ({e:#}); reconnecting",
                        opts.device_id
                    );
                    report.tx_bytes += conn.tx_bytes;
                    report.rx_bytes += conn.rx_bytes;
                    report.reconnects += 1;
                    continue 'connection;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{MaskMode, MaskStrategy};
    use crate::compress;
    use crate::fl::protocol::UplinkPayload;
    use crate::util::BitVec;
    use std::thread;

    const N_PARAMS: usize = 64;

    fn test_session(expected: usize, deadline_ms: u64) -> (Session, String) {
        let cfg = SessionConfig {
            expected,
            fingerprint: 0xFEED,
            rounds: 1,
            deadline: Duration::from_millis(deadline_ms),
            wave: 0,
            needs_state_sync: false,
        };
        let session = Session::bind("127.0.0.1:0", cfg).unwrap();
        let addr = session.local_addr().unwrap().to_string();
        (session, addr)
    }

    fn fake_handshake(addr: &str, fingerprint: u64, id: u64, resume: u64) -> Conn {
        let mut conn = Conn::connect(addr).unwrap();
        // fakes never block forever: a missing server reply fails the
        // test instead of hanging it
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let hello = Hello {
            version: TRANSPORT_VERSION,
            fingerprint,
            device_id: id,
            resume_round: resume,
        };
        conn.send(FrameKind::Hello, &hello.to_bytes()).unwrap();
        conn
    }

    fn plan() -> RoundPlan {
        RoundPlan {
            round: 1,
            seed: 7,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.001,
            adam: true,
        }
    }

    fn mask_uplink(weight: f64) -> Vec<u8> {
        let mask = BitVec::from_iter_len((0..N_PARAMS).map(|i| i % 3 == 0), N_PARAMS);
        UplinkMsg {
            weight,
            train_loss: 0.5,
            payload: UplinkPayload::CodedMask(compress::encode(&mask)),
        }
        .to_bytes()
    }

    #[test]
    fn straggler_deadline_converts_to_dropout() {
        let (mut session, addr) = test_session(2, 500);
        // device 0 answers promptly; device 1 sleeps past the deadline
        let a0 = addr.clone();
        let t0 = thread::spawn(move || {
            let mut conn = fake_handshake(&a0, 0xFEED, 0, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let (kind, payload) = conn.recv().unwrap();
            assert_eq!(kind, FrameKind::Round);
            parse_round(&payload).unwrap();
            conn.send(FrameKind::Uplink, &mask_uplink(10.0)).unwrap();
            // stay alive until the server is done with the round
            let _ = conn.recv();
        });
        let a1 = addr.clone();
        let t1 = thread::spawn(move || {
            let mut conn = fake_handshake(&a1, 0xFEED, 1, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let _ = conn.recv(); // the Round frame
            thread::sleep(Duration::from_millis(2500)); // blow the deadline
        });
        session.wait_for_fleet(Duration::from_secs(5)).unwrap();
        let mut server = MaskStrategy::new(N_PARAMS, 1, MaskMode::Stochastic);
        let mut fleet_state = None;
        let mut comm = RoundComm::new(N_PARAMS);
        let stats = session
            .run_round(
                &mut server,
                &mut fleet_state,
                Participation::default(),
                &plan(),
                &mut comm,
            )
            .unwrap();
        // one uplink folded, one straggler converted into dropout
        assert_eq!(comm.clients, 1);
        assert_eq!(comm.broadcasts, 2);
        assert_eq!(session.stats.stragglers, 1);
        assert_eq!(session.connected(), 1);
        assert!(stats.train_loss > 0.0);
        session.finish().unwrap();
        t0.join().unwrap();
        t1.join().unwrap();
    }

    #[test]
    fn handshake_rejects_fingerprint_mismatch_and_bad_id() {
        let (mut session, addr) = test_session(1, 1000);
        let t = thread::spawn(move || {
            let mut conn = fake_handshake(&addr, 0xBAD, 0, 0);
            let err = conn.recv_expect(FrameKind::Welcome).unwrap_err();
            assert!(err.to_string().contains("fingerprint"), "{err}");
            let mut conn = fake_handshake(&addr, 0xFEED, 9, 0);
            let err = conn.recv_expect(FrameKind::Welcome).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{err}");
        });
        let err = session.wait_for_fleet(Duration::from_millis(900)).unwrap_err();
        assert!(err.to_string().contains("missing ids [0]"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn reconnect_reregisters_and_gets_state_sync() {
        let (mut session, addr) = test_session(1, 1000);
        session.cfg.needs_state_sync = true;
        session.rounds_completed = 3;
        let state = vec![0.25f32; 8];
        let fleet_state = Some(state.clone());
        let t = thread::spawn(move || {
            // first registration: resume_round = 0 < 3 -> expect a Sync
            let mut conn = fake_handshake(&addr, 0xFEED, 0, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let sync = conn.recv_expect(FrameKind::Sync).unwrap();
            let msg = DownlinkMsg::from_bytes(&sync).unwrap();
            assert_eq!(msg.decode_state(None).unwrap(), vec![0.25f32; 8]);
            drop(conn);
            // reconnect already in sync: no Sync frame follows Welcome
            let mut conn = fake_handshake(&addr, 0xFEED, 0, 3);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            conn.send(FrameKind::Dropped, &[]).unwrap();
        });
        let start = Instant::now();
        while session.connected() == 0 && start.elapsed() < Duration::from_secs(5) {
            session.accept_pending(&fleet_state).unwrap();
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(session.stats.syncs, 1);
        // wait for the re-registration to land
        let start = Instant::now();
        while session.stats.reconnects == 0 && start.elapsed() < Duration::from_secs(5) {
            session.accept_pending(&fleet_state).unwrap();
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(session.stats.reconnects, 1);
        assert_eq!(session.stats.syncs, 1, "in-sync reconnect must not resync");
        t.join().unwrap();
    }

    #[test]
    fn round_payload_parses_and_validates() {
        let msg = DownlinkMsg::Theta(vec![0.5f32; 16]);
        let payload = round_payload(&plan(), &msg);
        let (p, m) = parse_round(&payload).unwrap();
        assert_eq!(p, plan());
        assert_eq!(m.n(), 16);
        assert!(parse_round(&payload[..3]).is_err());
        assert!(parse_round(&payload[..payload.len() - 1]).is_err());
        let mut bad = payload.clone();
        bad[0] = 99; // plan_len corrupted
        assert!(parse_round(&bad).is_err());
    }
}
