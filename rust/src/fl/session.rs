//! The networked federation runtime: server-side device sessions and the
//! device-side run loop.
//!
//! `fedsrn serve` drives the same [`crate::algos::ServerLogic`] round
//! (`begin_round -> fold_uplink* -> end_round`) as the in-process
//! [`crate::coordinator::RoundEngine`], but every hop crosses a real
//! [`crate::fl::transport`] socket:
//!
//! * **Readiness loop** — [`Session`] owns one non-blocking framed
//!   connection per device id plus a pending-handshake list, and drives
//!   them all from a single thread: each [`Session::sweep`] drains the
//!   accept queue, pumps every socket's reads and writes as far as the
//!   kernel allows, and parses completed frames into per-device inboxes
//!   (incremental [`crate::fl::transport::FrameBuf`] decoding). No
//!   thread-per-connection, no blocking reads, no fixed-cadence polling:
//!   the loop naps (500µs, counted in [`SessionStats::idle_naps`]) only
//!   on sweeps that provably made no progress, so one server multiplexes
//!   thousands of device sockets.
//! * **Registry** — devices register with the
//!   [`crate::fl::transport::Hello`] handshake (version + run
//!   fingerprint validated, mismatches get a typed error frame back); a
//!   reconnecting device replaces its stale connection. Each connection
//!   carries a generation tag so a mid-round reconnect can never be
//!   mistaken for the connection a broadcast went out on.
//! * **Pipelined round barrier** — [`Session::run_round`] mirrors the
//!   engine's schedule exactly: sample the cohort, queue the `Round`
//!   frame (chain links go to the whole fleet, stateless broadcasts only
//!   to the cohort), then slide a bounded window of ~2x the worker count
//!   over the cohort — broadcasting ahead of the fold frontier while
//!   late uplinks drain — and fold every envelope **in cohort order**,
//!   so coordinator memory stays O(wave × n_params) and the aggregate is
//!   bit-identical to the in-process path. A device that missed `qdelta`
//!   chain links is resynced with a full-state `Sync` frame queued
//!   immediately before its next `Round` frame.
//! * **Straggler deadline** — every in-flight uplink carries a
//!   wall-clock deadline; a device that blows it is converted into the
//!   existing dropout path ("trained, but the uplink never lands"), its
//!   connection is dropped, and the round continues. Injected dropout
//!   (the `dropout` config key) is decided device-side from the same
//!   seeded [`Participation::drops`] the engine uses, shipped as a tiny
//!   `Dropped` frame so accounting matches the simulation bit-for-bit.
//!   A device may also carry a [`DelayProfile`]: it then decides
//!   deterministically — in virtual ticks, no wall clock — whether it
//!   would have blown the deadline and self-reports `Dropped`, so the
//!   deadline→dropout path is testable without sleep calibration.
//!   An uplink that fully arrived before its connection died still
//!   counts: dead connections park their parsed inbox as dead letters
//!   for the round to collect.
//! * **Buffered-async mode** — with `aggregation=buffered<K>`
//!   (DESIGN.md §Fleet) the round barrier closes after `K` folds
//!   instead of the whole cohort. A straggler is not dropped: its
//!   position is parked, and its uplink — v2 envelopes carry the round
//!   they trained against — folds at a later round's start,
//!   staleness-discounted via [`ServerLogic::fold_uplink_stale`],
//!   counting toward that round's `K`. In sync mode a stale envelope on
//!   a live connection is a protocol error and is discarded.
//! * **Edge tier** — with `edges=N` the cohort's fresh uplinks fold
//!   into cohort-local [`EdgeAggregator`]s; each reporting edge ships
//!   one merged [`AggregateMsg`] envelope upstream (serialized and
//!   re-validated), bit-identical to the flat fold for the
//!   grouping-exact accumulators all three strategies use.
//! * **Accounting** — [`crate::fl::RoundComm`] records the serialized
//!   envelope bytes exactly as the in-process engine does (the envelope
//!   is byte-identical on the socket); [`SessionStats`] additionally
//!   reports the transport-level totals (frame headers, checksums,
//!   handshakes) actually moved, plus the degraded-path counters.
//!
//! The device half, [`run_device`], derives its shard, seeds, cohort
//! membership, and dropout decisions from the shared config — pure
//! functions of `(seed, round, id)` — so a fleet of independent
//! processes reproduces the simulated federation exactly. For fault
//! testing it can wrap its socket in a [`crate::fl::chaos::ChaosStream`]
//! ([`DeviceOpts::chaos`]), which injects seeded delays, split writes,
//! corrupted frames, and disconnects *after* a clean handshake.
//!
//! audit: panic-free

use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::algos::{build_server, RoundStats, ServerLogic};
use crate::compress::DownlinkMode;
use crate::config::{Aggregation, ExperimentConfig};
use crate::coordinator::RoundEngine;
use crate::data::{load_experiment_data, partition_fleet};
use crate::fl::aggregator::{AggregateMsg, EdgeAggregator};
use crate::fl::chaos::{ChaosSpec, ChaosStream};
use crate::fl::client::derive_client_seed;
use crate::fl::fleet::DelayProfile;
use crate::fl::protocol::{DownlinkMsg, RoundPlan};
use crate::fl::transport::{
    is_timeout, run_fingerprint, write_frame, Conn, FrameBuf, FrameKind, Hello, Welcome,
    MAX_FRAME_BYTES, TRANSPORT_VERSION,
};
use crate::fl::{Client, Participation, RoundComm, UplinkMsg};
use crate::runtime::ModelRuntime;

/// How long a registering device may take to complete its handshake.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Nap length for sweeps that made no progress (the only sleep in the
/// readiness loop; counted in [`SessionStats::idle_naps`]).
const NAP: Duration = Duration::from_micros(500);
/// How long [`Session::finish`] keeps flushing queued `Done` frames.
const FINISH_FLUSH: Duration = Duration::from_secs(5);

/// Server-session knobs (the CLI flags of `fedsrn serve`).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Devices the federation expects (= the config's `clients`).
    pub expected: usize,
    /// [`run_fingerprint`] every device must present.
    pub fingerprint: u64,
    /// Total rounds (echoed in the handshake for operator sanity).
    pub rounds: usize,
    /// Straggler deadline per in-flight uplink.
    pub deadline: Duration,
    /// Broadcast window size; 0 = the round engine's wave sizing.
    pub wave: usize,
    /// `downlink=qdelta`: a reconnecting device that missed chain links
    /// needs a full-state `Sync` frame before its next round.
    pub needs_state_sync: bool,
    /// `aggregation=sync|buffered<K>`: sync waits out the whole cohort;
    /// buffered closes the round after `K` folds and carries the
    /// stragglers' uplinks forward (staleness-discounted).
    pub aggregation: Aggregation,
    /// Staleness discount exponent for carried uplinks.
    pub staleness_beta: f64,
    /// Edge aggregators per round (`edges` config key; 0 = flat folds).
    pub edges: usize,
}

impl SessionConfig {
    /// Derive the session parameters a config implies.
    pub fn from_experiment(
        cfg: &ExperimentConfig,
        fingerprint: u64,
        deadline: Duration,
        wave: usize,
    ) -> Self {
        Self {
            expected: cfg.clients,
            fingerprint,
            rounds: cfg.rounds,
            deadline,
            wave,
            needs_state_sync: matches!(cfg.downlink, DownlinkMode::QDelta { .. }),
            aggregation: cfg.aggregation,
            staleness_beta: cfg.staleness_beta,
            edges: cfg.edges,
        }
    }
}

/// Transport-level telemetry for one serve run.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionStats {
    /// Bytes actually written to sockets (frames, headers, checksums).
    pub tx_bytes: u64,
    /// Bytes actually read from sockets.
    pub rx_bytes: u64,
    /// Uplinks that blew the straggler deadline (-> dropout path).
    pub stragglers: usize,
    /// Cohort members with no live connection when their turn came.
    pub missing: usize,
    /// Devices that re-registered after a drop.
    pub reconnects: usize,
    /// Full-state resync frames sent to reconnecting devices.
    pub syncs: usize,
    /// Corrupt frames / protocol violations that cost a connection.
    pub protocol_errors: usize,
    /// Carried uplinks folded staleness-discounted into a later round
    /// (buffered-async mode only).
    pub late_folds: usize,
    /// Zero-progress sweeps that slept one [`NAP`]. The readiness loop's
    /// only sleep — a busy fleet keeps this near zero.
    pub idle_naps: u64,
}

/// One registered device in the readiness loop: the non-blocking
/// connection plus its partial-frame read buffer, queued writes, parsed
/// inbox, and the generation tag that distinguishes this connection
/// from any earlier one under the same device id.
struct DeviceConn {
    conn: Conn,
    rbuf: FrameBuf,
    wbuf: Vec<u8>,
    wpos: usize,
    inbox: VecDeque<(FrameKind, Vec<u8>)>,
    gen: u64,
    /// Last round whose broadcast this connection is known (or queued)
    /// to have decoded — drives lazy `Sync` scheduling for `qdelta`.
    state_round: usize,
}

/// An accepted connection that has not completed its `Hello` yet.
struct Pending {
    conn: Conn,
    rbuf: FrameBuf,
    since: Instant,
}

/// The server side of the networked runtime: listener + device registry
/// + the single-threaded readiness loop that drives the round barrier.
pub struct Session {
    listener: TcpListener,
    devices: Vec<Option<DeviceConn>>,
    pending: Vec<Pending>,
    /// Parsed-but-unconsumed frames from connections that died, keyed by
    /// device id and tagged with the dead connection's generation: an
    /// uplink that fully arrived before the disconnect still counts.
    dead_letters: Vec<Option<(u64, VecDeque<(FrameKind, Vec<u8>)>)>>,
    /// Which ids have ever registered (re-registration = reconnect).
    seen: Vec<bool>,
    /// Buffered mode: uplinks still owed from rounds that closed over
    /// them, keyed by (device id, the generation their broadcast went
    /// out on). Collected at the start of every later round.
    stale_pending: Vec<(usize, u64)>,
    /// Buffered mode: fully-arrived carried uplinks awaiting their
    /// staleness-discounted fold at the next round's start.
    stale_buf: Vec<(usize, UplinkMsg)>,
    next_gen: u64,
    cfg: SessionConfig,
    rounds_completed: usize,
    pub stats: SessionStats,
}

/// Drain one socket's readable bytes into its frame buffer. Returns
/// `(bytes_read, dead)`; EOF and non-retryable errors mean dead.
fn pump_reads(conn: &mut Conn, rbuf: &mut FrameBuf, scratch: &mut [u8]) -> (usize, bool) {
    let mut total = 0;
    loop {
        match conn.read_some(scratch) {
            Ok(0) => return (total, true),
            Ok(n) => {
                rbuf.extend(&scratch[..n]);
                total += n;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return (total, false),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return (total, true),
        }
    }
}

/// Flush as much of a device's queued writes as the kernel accepts.
/// Returns `(bytes_written, dead)`.
fn pump_writes(dc: &mut DeviceConn) -> (usize, bool) {
    let mut total = 0;
    while dc.wpos < dc.wbuf.len() {
        match dc.conn.write_some(&dc.wbuf[dc.wpos..]) {
            Ok(0) => return (total, true),
            Ok(n) => {
                dc.wpos += n;
                total += n;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return (total, false),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return (total, true),
        }
    }
    dc.wbuf.clear();
    dc.wpos = 0;
    (total, false)
}

impl Session {
    /// Bind the coordinator socket (`addr` may use port 0; see
    /// [`Session::local_addr`]).
    pub fn bind(addr: &str, cfg: SessionConfig) -> Result<Self> {
        ensure!(cfg.expected > 0, "a session needs at least one device");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        let devices = (0..cfg.expected).map(|_| None).collect();
        let dead_letters = (0..cfg.expected).map(|_| None).collect();
        let seen = vec![false; cfg.expected];
        Ok(Self {
            listener,
            devices,
            pending: Vec::new(),
            dead_letters,
            seen,
            stale_pending: Vec::new(),
            stale_buf: Vec::new(),
            next_gen: 0,
            cfg,
            rounds_completed: 0,
            stats: SessionStats::default(),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading listener address")
    }

    /// Registered devices with a live connection.
    pub fn connected(&self) -> usize {
        self.devices.iter().filter(|d| d.is_some()).count()
    }

    /// One pass of the readiness loop: accept new connections, advance
    /// pending handshakes, and pump every registered socket's reads and
    /// writes, parsing completed frames into the per-device inboxes.
    /// Returns whether anything moved (a byte, a frame, a registration);
    /// callers nap only when it did not.
    fn sweep(&mut self) -> Result<bool> {
        let mut progress = false;
        let mut scratch = [0u8; 16 * 1024];
        // 1) accept queue -> pending handshakes
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let conn = Conn::new(stream)?;
                    conn.set_nonblocking(true)?;
                    self.pending.push(Pending {
                        conn,
                        rbuf: FrameBuf::new(),
                        since: Instant::now(),
                    });
                    progress = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                // A peer that connected and reset before we got to it is
                // its problem, not the federation's: skip it.
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::ConnectionAborted
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => return Err(e).context("accepting device connection"),
            }
        }
        // 2) pending handshakes: read until one whole frame is in
        let mut i = 0;
        while i < self.pending.len() {
            let (n, dead) = {
                let p = &mut self.pending[i];
                pump_reads(&mut p.conn, &mut p.rbuf, &mut scratch)
            };
            progress |= n > 0;
            let frame = self.pending[i].rbuf.next_frame(MAX_FRAME_BYTES);
            let expired = self.pending[i].since.elapsed() > HANDSHAKE_TIMEOUT;
            match frame {
                Ok(Some((FrameKind::Hello, payload))) => {
                    let p = self.pending.swap_remove(i);
                    progress = true;
                    match self.finish_handshake(p.conn, p.rbuf, &payload) {
                        Ok(id) => eprintln!("session: device {id} registered"),
                        Err(e) => eprintln!("session: handshake rejected: {e:#}"),
                    }
                }
                Ok(Some((kind, _))) => {
                    let p = self.pending.swap_remove(i);
                    eprintln!(
                        "session: pending connection sent {} before Hello; dropping",
                        kind.name()
                    );
                    self.stats.protocol_errors += 1;
                    self.retire(p.conn);
                }
                Err(e) => {
                    let p = self.pending.swap_remove(i);
                    eprintln!("session: pending connection sent a corrupt frame ({e:#}); dropping");
                    self.stats.protocol_errors += 1;
                    self.retire(p.conn);
                }
                Ok(None) if dead || expired => {
                    let p = self.pending.swap_remove(i);
                    self.retire(p.conn);
                }
                Ok(None) => i += 1,
            }
        }
        // 3) registered devices: flush writes, drain reads, parse frames
        for id in 0..self.devices.len() {
            let mut dead = false;
            let mut corrupt = false;
            if let Some(dc) = &mut self.devices[id] {
                let (wn, wdead) = pump_writes(dc);
                let (rn, rdead) = pump_reads(&mut dc.conn, &mut dc.rbuf, &mut scratch);
                progress |= wn > 0 || rn > 0;
                dead = wdead || rdead;
                // Parse everything delivered, even from a dying
                // connection: an uplink that fully arrived before the
                // EOF still counts (dead-letter path).
                loop {
                    match dc.rbuf.next_frame(MAX_FRAME_BYTES) {
                        Ok(Some(frame)) => {
                            dc.inbox.push_back(frame);
                            progress = true;
                        }
                        Ok(None) => break,
                        Err(_) => {
                            dead = true;
                            corrupt = true;
                            break;
                        }
                    }
                }
            } else {
                continue;
            }
            if corrupt {
                self.stats.protocol_errors += 1;
            }
            if dead {
                let reason = if corrupt { "corrupt frame" } else { "peer closed or reset" };
                eprintln!("session: device {id} connection lost ({reason}); dropping connection");
                self.drop_device(id);
            }
        }
        Ok(progress)
    }

    /// Sweep until `done` holds or `timeout` passes, napping only on
    /// zero-progress sweeps. Returns whether `done` was reached.
    fn poll_until(
        &mut self,
        timeout: Duration,
        mut done: impl FnMut(&Self) -> bool,
    ) -> Result<bool> {
        let start = Instant::now();
        loop {
            let progress = self.sweep()?;
            if done(self) {
                return Ok(true);
            }
            if start.elapsed() > timeout {
                return Ok(false);
            }
            if !progress {
                self.stats.idle_naps += 1;
                std::thread::sleep(NAP);
            }
        }
    }

    /// Run the readiness loop until every expected device has
    /// registered, or fail after `timeout` naming the ids still missing.
    pub fn wait_for_fleet(&mut self, timeout: Duration) -> Result<()> {
        let expected = self.cfg.expected;
        if self.poll_until(timeout, |s| s.connected() >= expected)? {
            return Ok(());
        }
        let missing: Vec<usize> = self
            .devices
            .iter()
            .enumerate()
            .filter_map(|(i, d)| d.is_none().then_some(i))
            .collect();
        bail!(
            "{}/{} devices registered after {:.0?}; missing ids {missing:?}",
            self.connected(),
            expected,
            timeout
        );
    }

    /// Validate a completed `Hello`, queue the `Welcome` (or send a
    /// typed error frame), and register the connection under a fresh
    /// generation tag.
    fn finish_handshake(&mut self, conn: Conn, rbuf: FrameBuf, payload: &[u8]) -> Result<usize> {
        let hello = match Hello::from_bytes(payload) {
            Ok(h) => h,
            Err(e) => {
                self.reject(conn, &format!("{e:#}"));
                return Err(e);
            }
        };
        let reject = if hello.fingerprint != self.cfg.fingerprint {
            Some(format!(
                "run fingerprint {:#018x} != server's {:#018x} \
                 (different config/model on the two sides?)",
                hello.fingerprint, self.cfg.fingerprint
            ))
        } else if hello.device_id >= self.cfg.expected as u64 {
            Some(format!(
                "device id {} out of range for a {}-device federation",
                hello.device_id, self.cfg.expected
            ))
        } else {
            None
        };
        if let Some(msg) = reject {
            self.reject(conn, &msg);
            bail!("device {} rejected: {msg}", hello.device_id);
        }
        let id = hello.device_id as usize;
        let welcome = Welcome {
            version: TRANSPORT_VERSION,
            fingerprint: self.cfg.fingerprint,
            n_clients: self.cfg.expected as u64,
            rounds: self.cfg.rounds as u64,
        };
        self.next_gen += 1;
        let mut dc = DeviceConn {
            conn,
            rbuf,
            wbuf: Vec::new(),
            wpos: 0,
            inbox: VecDeque::new(),
            gen: self.next_gen,
            state_round: hello.resume_round as usize,
        };
        write_frame(&mut dc.wbuf, FrameKind::Welcome, &welcome.to_bytes())?;
        // a replaced connection's undelivered inbox survives as dead
        // letters — an uplink that landed before the re-registration
        // still counts
        self.drop_device(id);
        if self.seen[id] {
            self.stats.reconnects += 1;
        } else {
            self.seen[id] = true;
        }
        self.devices[id] = Some(dc);
        Ok(id)
    }

    /// Turn a bad handshake away with a typed error frame. The frame is
    /// tiny and the socket buffer fresh, so a blocking send completes
    /// immediately (or fails — the peer is gone anyway).
    fn reject(&mut self, mut conn: Conn, msg: &str) {
        let _ = conn.set_nonblocking(false);
        let _ = conn.send(FrameKind::Error, msg.as_bytes());
        self.retire(conn);
    }

    /// Fold a dead or replaced connection's byte counters into the
    /// session totals before dropping it.
    fn retire(&mut self, conn: Conn) {
        self.stats.tx_bytes += conn.tx_bytes;
        self.stats.rx_bytes += conn.rx_bytes;
    }

    /// Drop a device's connection, parking any parsed-but-unconsumed
    /// frames as dead letters for the current round to collect.
    fn drop_device(&mut self, id: usize) {
        if let Some(dc) = self.devices[id].take() {
            let DeviceConn { conn, inbox, gen, .. } = dc;
            if !inbox.is_empty() {
                self.dead_letters[id] = Some((gen, inbox));
            }
            self.retire(conn);
        }
    }

    /// Queue one round broadcast to a device — preceded by a full-state
    /// `Sync` when the connection's known state is too old to decode a
    /// `qdelta` chain link. Returns the connection generation the frame
    /// went out on, or `None` if the device has no live connection.
    fn queue_round(
        &mut self,
        id: usize,
        round: usize,
        payload: &[u8],
        prev: Option<&[f32]>,
    ) -> Result<Option<u64>> {
        let needs_sync = self.cfg.needs_state_sync;
        let mut synced = false;
        let gen = {
            let Some(dc) = &mut self.devices[id] else {
                return Ok(None);
            };
            if needs_sync && dc.state_round + 1 < round {
                if let Some(state) = prev {
                    let sync = DownlinkMsg::RawF32(state.to_vec()).to_bytes();
                    write_frame(&mut dc.wbuf, FrameKind::Sync, &sync)?;
                    synced = true;
                }
            }
            write_frame(&mut dc.wbuf, FrameKind::Round, payload)?;
            // Optimistic: if the connection dies before this drains, the
            // device reconnects and re-reports its true resume round.
            dc.state_round = round;
            dc.gen
        };
        if synced {
            self.stats.syncs += 1;
        }
        Ok(Some(gen))
    }

    /// Pop the next reply frame for `(id, gen)` — from the live
    /// connection if it is still the one the broadcast went out on,
    /// else from its dead letters.
    fn take_reply(&mut self, id: usize, gen: u64) -> Option<(FrameKind, Vec<u8>)> {
        if let Some(dc) = &mut self.devices[id] {
            if dc.gen == gen {
                return dc.inbox.pop_front();
            }
        }
        if let Some((dgen, letters)) = &mut self.dead_letters[id] {
            if *dgen == gen {
                let frame = letters.pop_front();
                if letters.is_empty() {
                    self.dead_letters[id] = None;
                }
                return frame;
            }
        }
        None
    }

    /// Can a reply for `(id, gen)` still arrive or be waiting?
    fn reply_possible(&self, id: usize, gen: u64) -> bool {
        if let Some(dc) = &self.devices[id] {
            if dc.gen == gen {
                return true;
            }
        }
        matches!(&self.dead_letters[id], Some((dgen, _)) if *dgen == gen)
    }

    /// Wave size: the engine's sizing unless overridden.
    fn wave(&self) -> usize {
        if self.cfg.wave > 0 {
            self.cfg.wave
        } else {
            RoundEngine::new(0).wave_size()
        }
    }

    /// Drive one full round over the connected fleet — the socket twin
    /// of [`RoundEngine::run_round`], same schedule, same accounting,
    /// same fold order. Broadcasts are pipelined a bounded window ahead
    /// of the ordered streaming fold frontier.
    pub fn run_round(
        &mut self,
        server: &mut dyn ServerLogic,
        fleet_state: &mut Option<Vec<f32>>,
        participation: Participation,
        plan: &RoundPlan,
        comm: &mut RoundComm,
    ) -> Result<RoundStats> {
        let n = self.cfg.expected;
        let beta = self.cfg.staleness_beta;
        let buffered_k = match self.cfg.aggregation {
            Aggregation::Buffered { k } => Some(k.max(1)),
            Aggregation::Sync => None,
        };
        let cohort = participation.sample_round(n, plan.seed, plan.round);
        let msg = server.begin_round(plan)?;
        let payload = round_payload(plan, &msg);
        let prev = fleet_state.take();
        // Pick up reconnects — and, in buffered mode, carried uplinks —
        // that arrived between rounds, BEFORE voiding dead letters (a
        // parked straggler's envelope may be waiting there).
        self.sweep()?;
        let mut folds = 0usize;
        if buffered_k.is_some() {
            let pending = std::mem::take(&mut self.stale_pending);
            for (id, gen) in pending {
                match self.take_reply(id, gen) {
                    Some((kind, bytes)) => {
                        if let Some(up) = self.classify_reply(id, kind, &bytes) {
                            self.stale_buf.push((id, up));
                        }
                    }
                    None if self.reply_possible(id, gen) => self.stale_pending.push((id, gen)),
                    None => {} // connection gone before the uplink landed
                }
            }
            // Carried uplinks fold first — oldest training round first,
            // then device id — staleness-discounted; they count toward
            // this round's K.
            let mut late = std::mem::take(&mut self.stale_buf);
            late.sort_by(|a, b| (a.1.trained_round, a.0).cmp(&(b.1.trained_round, b.0)));
            for (_, up) in &late {
                server.fold_uplink_stale(up, plan, beta, comm)?;
                self.stats.late_folds += 1;
                folds += 1;
            }
        }
        // Remaining stale frames from a previous round's disconnects
        // answer an older broadcast; in sync mode they never fold.
        for slot in &mut self.dead_letters {
            *slot = None;
        }
        // A frame chain link must reach every device (one missed link
        // and the chain is undecodable); stateless broadcasts only the
        // cohort. Mirrors the engine's receiver accounting exactly.
        if matches!(msg, DownlinkMsg::Frame(_)) {
            for id in 0..n {
                if cohort.binary_search(&id).is_err()
                    && self.queue_round(id, plan.round, &payload, prev.as_deref())?.is_some()
                {
                    comm.add_downlink_msg(&msg);
                }
            }
        }
        let wave = self.wave().max(1);
        let m = cohort.len();
        // resolved[pos]: None = in flight; Some(None) = dropout/missing;
        // Some(Some(up)) = an envelope awaiting its in-order fold turn.
        let mut resolved: Vec<Option<Option<UplinkMsg>>> = (0..m).map(|_| None).collect();
        let mut deadlines = vec![Instant::now(); m];
        let mut gens = vec![0u64; m];
        let mut sent = 0usize;
        let mut frontier = 0usize;
        // Hierarchical aggregation: fresh folds route through
        // cohort-local edge accumulators (DESIGN.md §Fleet).
        let n_edges = self.cfg.edges.min(m);
        let mut edge_tier: Vec<EdgeAggregator> = (0..n_edges)
            .map(|_| EdgeAggregator::new(server.agg_kind(), comm.n_params))
            .collect();
        'round: while frontier < m {
            // (a) broadcast up to `wave` positions ahead of the frontier
            while sent < m && sent < frontier + wave {
                let id = cohort[sent];
                match self.queue_round(id, plan.round, &payload, prev.as_deref())? {
                    Some(gen) => {
                        comm.add_downlink_msg(&msg);
                        gens[sent] = gen;
                        deadlines[sent] = Instant::now() + self.cfg.deadline;
                    }
                    None => {
                        self.stats.missing += 1;
                        resolved[sent] = Some(None);
                    }
                }
                sent += 1;
            }
            // (b) one readiness sweep moves every socket forward
            let progress = self.sweep()?;
            // (c) classify the in-flight positions
            let mut advanced = false;
            for pos in frontier..sent {
                if resolved[pos].is_some() {
                    continue;
                }
                let id = cohort[pos];
                if let Some((kind, bytes)) = self.take_reply(id, gens[pos]) {
                    advanced = true;
                    match self.classify_reply(id, kind, &bytes) {
                        Some(up) if up.trained_round < plan.round as u64 => {
                            // An uplink owed from an earlier round,
                            // surfacing on the same connection ahead of
                            // this round's reply. The position itself
                            // stays in flight.
                            if buffered_k.is_some() {
                                server.fold_uplink_stale(&up, plan, beta, comm)?;
                                self.stats.late_folds += 1;
                                folds += 1;
                                self.stale_pending.retain(|&(p, g)| (p, g) != (id, gens[pos]));
                            } else {
                                eprintln!(
                                    "session: device {id} sent a round-{} uplink into \
                                     round {}; discarding (sync mode)",
                                    up.trained_round, plan.round
                                );
                                self.stats.protocol_errors += 1;
                            }
                        }
                        outcome => resolved[pos] = Some(outcome),
                    }
                } else if !self.reply_possible(id, gens[pos]) {
                    eprintln!(
                        "session: device {id} connection lost mid-round; treating as dropout"
                    );
                    resolved[pos] = Some(None);
                    advanced = true;
                } else if Instant::now() > deadlines[pos] {
                    if buffered_k.is_some() {
                        // Buffered mode never voids a straggler: stop
                        // waiting, let the uplink carry forward.
                        self.stale_pending.push((id, gens[pos]));
                        resolved[pos] = Some(None);
                        advanced = true;
                        continue;
                    }
                    eprintln!(
                        "session: device {id} missed the {:.0?} straggler deadline; \
                         treating as dropout",
                        self.cfg.deadline
                    );
                    self.stats.stragglers += 1;
                    self.drop_device(id);
                    // a straggler's late bytes are void, not dead letters
                    self.dead_letters[id] = None;
                    resolved[pos] = Some(None);
                    advanced = true;
                }
            }
            // (d) ordered streaming fold: envelopes fold strictly in
            // cohort order, so the aggregate is bit-identical to the
            // in-process engine (which routes through the same edge
            // tier when `edges` is set).
            while frontier < m && resolved[frontier].is_some() {
                if buffered_k.is_some_and(|k| folds >= k) {
                    break; // quota hit mid-drain: the surplus carries
                }
                if let Some(Some(up)) = resolved[frontier].take() {
                    if n_edges > 0 {
                        let e = frontier * n_edges / m;
                        edge_tier[e].fold(&up, plan.round, beta)?;
                    } else {
                        server.fold_uplink(&up, comm)?;
                    }
                    folds += 1;
                }
                frontier += 1;
                advanced = true;
            }
            // Buffered round quota: exactly K folds close the round.
            // Arrived-but-unfolded envelopes carry as already-late work;
            // still-in-flight positions carry as owed replies.
            if let Some(k) = buffered_k {
                if folds >= k {
                    for pos in frontier..sent {
                        match resolved[pos].take() {
                            Some(Some(up)) => self.stale_buf.push((cohort[pos], up)),
                            Some(None) => {}
                            None => self.stale_pending.push((cohort[pos], gens[pos])),
                        }
                    }
                    break 'round;
                }
            }
            if !progress && !advanced && frontier < m {
                self.stats.idle_naps += 1;
                std::thread::sleep(NAP);
            }
        }
        // Each reporting edge ships one merged envelope upstream —
        // serialized and re-validated exactly as a remote edge would be.
        for edge in &edge_tier {
            if edge.reporters() == 0 {
                continue;
            }
            let agg = AggregateMsg::from_bytes(&edge.finish().to_bytes())?;
            server.fold_aggregate(&agg, comm)?;
        }
        *fleet_state = Some(msg.decode_state(prev.as_deref())?);
        self.rounds_completed = plan.round;
        server.end_round(plan)
    }

    /// Turn one reply frame into its fold decision. Corrupt envelopes
    /// and protocol violations become the dropout path (typed, logged,
    /// connection dropped); `Dropped` is the injected failure model.
    fn classify_reply(&mut self, id: usize, kind: FrameKind, bytes: &[u8]) -> Option<UplinkMsg> {
        match kind {
            FrameKind::Uplink => match UplinkMsg::from_bytes(bytes) {
                Ok(up) => {
                    debug_assert_eq!(up.wire_bytes(), bytes.len());
                    Some(up)
                }
                Err(e) => {
                    eprintln!("session: device {id} sent a corrupt envelope ({e:#}); dropping");
                    self.stats.protocol_errors += 1;
                    self.drop_device(id);
                    None
                }
            },
            FrameKind::Dropped => None,
            other => {
                eprintln!(
                    "session: device {id} broke protocol ({} instead of uplink); dropping",
                    other.name()
                );
                self.stats.protocol_errors += 1;
                self.drop_device(id);
                None
            }
        }
    }

    /// End the run: queue `Done` to every live device, flush for up to
    /// [`FINISH_FLUSH`], and fold the remaining byte counters into the
    /// stats.
    pub fn finish(&mut self) -> Result<()> {
        for dc in self.devices.iter_mut().flatten() {
            write_frame(&mut dc.wbuf, FrameKind::Done, &[])?;
        }
        let deadline = Instant::now() + FINISH_FLUSH;
        loop {
            let progress = self.sweep()?;
            let unflushed =
                self.devices.iter().flatten().any(|dc| dc.wpos < dc.wbuf.len());
            if !unflushed || Instant::now() > deadline {
                break;
            }
            if !progress {
                self.stats.idle_naps += 1;
                std::thread::sleep(NAP);
            }
        }
        for id in 0..self.devices.len() {
            self.drop_device(id);
        }
        while let Some(p) = self.pending.pop() {
            self.retire(p.conn);
        }
        Ok(())
    }
}

/// `Round` frame payload: `[u32 plan_len][plan][downlink envelope]`.
fn round_payload(plan: &RoundPlan, msg: &DownlinkMsg) -> Vec<u8> {
    let plan_bytes = plan.to_bytes();
    let dl_bytes = msg.to_bytes();
    let mut out = Vec::with_capacity(4 + plan_bytes.len() + dl_bytes.len());
    out.extend_from_slice(&(plan_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&plan_bytes);
    out.extend_from_slice(&dl_bytes);
    out
}

/// Parse a `Round` frame payload back into its typed halves, validating
/// every recorded length (the envelope re-validates itself).
// audit:wire-decode-begin
pub fn parse_round(payload: &[u8]) -> Result<(RoundPlan, DownlinkMsg)> {
    ensure!(payload.len() >= 4, "round payload truncated");
    let plan_len = u32::from_le_bytes(payload[..4].try_into()?) as usize;
    ensure!(
        payload.len() > 4 + plan_len,
        "round payload records {plan_len} plan bytes but carries {}",
        payload.len() - 4
    );
    // audit:checked(the ensure above bounds 4 + plan_len by payload.len())
    let plan = RoundPlan::from_bytes(&payload[4..4 + plan_len]).context("round plan")?;
    // audit:checked(the ensure above bounds 4 + plan_len by payload.len())
    let msg = DownlinkMsg::from_bytes(&payload[4 + plan_len..]).context("round downlink")?;
    Ok((plan, msg))
}
// audit:wire-decode-end

/// Device-side runtime knobs (the CLI flags of `fedsrn device`).
#[derive(Debug, Clone)]
pub struct DeviceOpts {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// This device's client id in `[0, clients)`.
    pub device_id: usize,
    /// Total budget for (re)connect attempts.
    pub connect_timeout: Duration,
    /// Wrap the socket in a seeded fault injector (armed only after a
    /// clean handshake). `None` = a plain TCP stream.
    pub chaos: Option<ChaosSpec>,
    /// Simulated compute-latency profile: when set, the device decides
    /// deterministically — pure virtual ticks, no wall clock or sleeps
    /// — whether it would have blown the server's straggler deadline
    /// and self-reports `Dropped` for that round instead of an uplink.
    pub delay: Option<DelayProfile>,
    /// Virtual-tick deadline paired with [`DeviceOpts::delay`].
    pub deadline_ticks: u64,
}

/// What one device run did (printed by `fedsrn device`).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceReport {
    /// Rounds this device received a broadcast for.
    pub rounds_seen: usize,
    /// Rounds it was in the cohort and ran local training.
    pub trained: usize,
    /// Trained rounds whose uplink the failure model suppressed.
    pub dropped: usize,
    /// Times the connection was lost and re-established.
    pub reconnects: usize,
    pub tx_bytes: u64,
    pub rx_bytes: u64,
}

/// Keep trying to connect until `budget` runs out (the server may still
/// be binding, or be mid-restart).
fn connect_with_retry(addr: &str, budget: Duration) -> Result<TcpStream> {
    let start = Instant::now();
    let mut wait = Duration::from_millis(50);
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(_) if start.elapsed() + wait < budget => {
                std::thread::sleep(wait);
                wait = (wait * 2).min(Duration::from_secs(2));
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("no server at {addr} after {:.0?}", start.elapsed())
                })
            }
        }
    }
}

/// Run one device against a remote server: derive the local shard and
/// seeds from the shared config, register over the handshake, then
/// answer `Round` frames until `Done`. Connection loss triggers a
/// reconnect with the in-memory reconstruction state carried over (and
/// a server-side `Sync` when `qdelta` chain links were missed). With
/// [`DeviceOpts::chaos`] set, every connection attempt gets its own
/// deterministic fault schedule (seeded by `(chaos seed, id, attempt)`),
/// armed only after the handshake validates.
pub fn run_device(cfg: &ExperimentConfig, opts: &DeviceOpts) -> Result<DeviceReport> {
    cfg.validate()?;
    ensure!(
        opts.device_id < cfg.clients,
        "--id {} out of range for a {}-device federation",
        opts.device_id,
        cfg.clients
    );
    let mut rt = ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.model)
        .with_context(|| format!("loading model '{}'", cfg.model))?;
    rt.set_compute(cfg.compute);
    let (train, _test) =
        load_experiment_data(cfg, rt.manifest.input_dim, rt.manifest.n_classes)?;
    let shard = partition_fleet(cfg, &train)
        .into_iter()
        .find(|s| s.client_id == opts.device_id)
        .context("partition did not produce this device's shard")?;
    let mut client = Client::new(shard, derive_client_seed(cfg.seed, opts.device_id));
    // The pure device half of the strategy; the throwaway server object
    // only exists to hand it out.
    let task = build_server(cfg, rt.manifest.n_params, rt.weights(), &rt.manifest.layers)
        .client_task();
    let participation = Participation::new(cfg.participation, cfg.dropout);
    let fingerprint = run_fingerprint(cfg, &rt.manifest);

    let mut report = DeviceReport::default();
    let mut prev_state: Option<Vec<f32>> = None;
    let mut rounds_done = 0usize;
    let mut attempt = 0u64;
    'connection: loop {
        let stream = connect_with_retry(&opts.addr, opts.connect_timeout)?;
        let (mut conn, switch) = match &opts.chaos {
            Some(spec) => {
                stream.set_nonblocking(false).context("clearing O_NONBLOCK")?;
                stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                let rng = spec.rng_for(opts.device_id, attempt);
                let (wire, switch, _events) = ChaosStream::wrap(stream, *spec, rng);
                (Conn::from_wire(Box::new(wire)), Some(switch))
            }
            None => (Conn::new(stream)?, None),
        };
        attempt += 1;
        let hello = Hello {
            version: TRANSPORT_VERSION,
            fingerprint,
            device_id: opts.device_id as u64,
            resume_round: rounds_done as u64,
        };
        conn.send(FrameKind::Hello, &hello.to_bytes())?;
        // A mid-run reconnect is only welcomed at the server's next
        // sweep; wait out the silence in ONE read on THIS connection
        // (re-dialing would queue stale Hellos the server would later
        // mis-count as reconnects, and resuming a framed stream after a
        // mid-frame timeout would desync it). The connect budget bounds
        // the wait; a typed rejection (Error frame) or a dead socket is
        // fatal.
        conn.set_read_timeout(Some(opts.connect_timeout.max(HANDSHAKE_TIMEOUT)))?;
        let welcome_bytes = conn.recv_expect(FrameKind::Welcome).map_err(|e| {
            if is_timeout(&e) {
                e.context(format!("no welcome from {} within the connect budget", opts.addr))
            } else {
                e
            }
        })?;
        let welcome = Welcome::from_bytes(&welcome_bytes)?;
        ensure!(
            welcome.fingerprint == fingerprint,
            "server fingerprint {:#018x} != ours {:#018x}",
            welcome.fingerprint,
            fingerprint
        );
        ensure!(
            welcome.n_clients == cfg.clients as u64,
            "server runs a {}-device federation, our config says {}",
            welcome.n_clients,
            cfg.clients
        );
        // Chaos arms only after a clean handshake: the fault schedule
        // targets rounds, not registration (a fleet that can never
        // assemble tests nothing).
        if let Some(switch) = &switch {
            switch.arm();
        }
        // Rounds are server-paced: block until the next frame arrives.
        conn.set_read_timeout(None)?;
        loop {
            match conn.recv() {
                Ok((FrameKind::Sync, bytes)) => {
                    let msg = DownlinkMsg::from_bytes(&bytes).context("sync frame")?;
                    prev_state = Some(msg.decode_state(None)?);
                }
                Ok((FrameKind::Round, bytes)) => {
                    let (plan, dl) = parse_round(&bytes)?;
                    let cohort =
                        participation.sample_round(cfg.clients, plan.seed, plan.round);
                    let mut sent = Ok(());
                    if let Some(pos) =
                        cohort.iter().position(|&c| c == opts.device_id)
                    {
                        let up = task
                            .run(&rt, &train, &mut client, &dl, prev_state.as_deref(), &plan)?;
                        report.trained += 1;
                        // The device trained, but its uplink never
                        // lands: the seeded failure model, or — with a
                        // delay profile — a deterministic self-reported
                        // straggler (compute ticks exceed the deadline).
                        let late = opts.delay.is_some_and(|p| {
                            p.delay_ticks(cfg.seed, opts.device_id as u64, plan.round as u64)
                                > opts.deadline_ticks
                        });
                        sent = if participation.drops(pos, plan.seed, plan.round, opts.device_id)
                            || late
                        {
                            report.dropped += 1;
                            conn.send(FrameKind::Dropped, &[])
                        } else {
                            conn.send(FrameKind::Uplink, &up.to_bytes())
                        };
                    }
                    // The broadcast itself landed: advance the local
                    // reconstruction even if the reply could not be sent.
                    prev_state = Some(dl.decode_state(prev_state.as_deref())?);
                    rounds_done = plan.round;
                    report.rounds_seen += 1;
                    if let Err(e) = sent {
                        // e.g. the server already dropped us as a
                        // straggler and closed the socket: reconnect,
                        // same as a recv-side connection loss.
                        eprintln!(
                            "device {}: uplink send failed ({e:#}); reconnecting",
                            opts.device_id
                        );
                        report.tx_bytes += conn.tx_bytes;
                        report.rx_bytes += conn.rx_bytes;
                        report.reconnects += 1;
                        continue 'connection;
                    }
                }
                Ok((FrameKind::Done, _)) => {
                    report.tx_bytes += conn.tx_bytes;
                    report.rx_bytes += conn.rx_bytes;
                    return Ok(report);
                }
                Ok((FrameKind::Error, bytes)) => {
                    bail!("server error: {}", String::from_utf8_lossy(&bytes));
                }
                Ok((kind, _)) => bail!("unexpected {} frame from server", kind.name()),
                Err(e) => {
                    eprintln!(
                        "device {}: connection lost ({e:#}); reconnecting",
                        opts.device_id
                    );
                    report.tx_bytes += conn.tx_bytes;
                    report.rx_bytes += conn.rx_bytes;
                    report.reconnects += 1;
                    continue 'connection;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{MaskMode, MaskStrategy};
    use crate::compress;
    use crate::fl::protocol::UplinkPayload;
    use crate::util::BitVec;
    use std::sync::mpsc;
    use std::thread;

    const N_PARAMS: usize = 64;

    fn test_session(expected: usize, deadline_ms: u64) -> (Session, String) {
        let cfg = SessionConfig {
            expected,
            fingerprint: 0xFEED,
            rounds: 1,
            deadline: Duration::from_millis(deadline_ms),
            wave: 0,
            needs_state_sync: false,
            aggregation: Aggregation::Sync,
            staleness_beta: 1.0,
            edges: 0,
        };
        let session = Session::bind("127.0.0.1:0", cfg).unwrap();
        let addr = session.local_addr().unwrap().to_string();
        (session, addr)
    }

    fn fake_handshake(addr: &str, fingerprint: u64, id: u64, resume: u64) -> Conn {
        let mut conn = Conn::connect(addr).unwrap();
        // fakes never block forever: a missing server reply fails the
        // test instead of hanging it
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let hello = Hello {
            version: TRANSPORT_VERSION,
            fingerprint,
            device_id: id,
            resume_round: resume,
        };
        conn.send(FrameKind::Hello, &hello.to_bytes()).unwrap();
        conn
    }

    fn plan() -> RoundPlan {
        RoundPlan {
            round: 1,
            seed: 7,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.001,
            adam: true,
        }
    }

    fn mask_uplink(weight: f64, trained_round: usize) -> Vec<u8> {
        let mask = BitVec::from_iter_len((0..N_PARAMS).map(|i| i % 3 == 0), N_PARAMS);
        UplinkMsg {
            weight,
            train_loss: 0.5,
            trained_round: trained_round as u64,
            payload: UplinkPayload::CodedMask(compress::encode(&mask)),
        }
        .to_bytes()
    }

    /// Uplink with a per-device mask and integer weight, so edge-tier
    /// grouping tests exercise distinct exact contributions.
    fn device_uplink(id: usize, trained_round: usize) -> Vec<u8> {
        let mask = BitVec::from_iter_len((0..N_PARAMS).map(|i| (i + id) % 3 == 0), N_PARAMS);
        UplinkMsg {
            weight: id as f64 + 1.0,
            train_loss: 0.5,
            trained_round: trained_round as u64,
            payload: UplinkPayload::CodedMask(compress::encode(&mask)),
        }
        .to_bytes()
    }

    #[test]
    fn straggler_deadline_converts_to_dropout() {
        let (mut session, addr) = test_session(2, 500);
        // device 0 answers promptly
        let a0 = addr.clone();
        let t0 = thread::spawn(move || {
            let mut conn = fake_handshake(&a0, 0xFEED, 0, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let (kind, payload) = conn.recv().unwrap();
            assert_eq!(kind, FrameKind::Round);
            parse_round(&payload).unwrap();
            conn.send(FrameKind::Uplink, &mask_uplink(10.0, 1)).unwrap();
            // stay alive until the server is done with the round
            let _ = conn.recv();
        });
        // device 1 never answers its Round frame: it parks on a channel
        // (released only after the round's asserts ran) so the straggler
        // deadline alone — not test timing — converts it into a dropout
        let (release, park) = mpsc::channel::<()>();
        let a1 = addr.clone();
        let t1 = thread::spawn(move || {
            let mut conn = fake_handshake(&a1, 0xFEED, 1, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let _ = conn.recv(); // the Round frame
            let _ = park.recv(); // hold the socket open, silently
        });
        session.wait_for_fleet(Duration::from_secs(5)).unwrap();
        let mut server = MaskStrategy::new(N_PARAMS, 1, MaskMode::Stochastic);
        let mut fleet_state = None;
        let mut comm = RoundComm::new(N_PARAMS);
        let stats = session
            .run_round(
                &mut server,
                &mut fleet_state,
                Participation::default(),
                &plan(),
                &mut comm,
            )
            .unwrap();
        // one uplink folded, one straggler converted into dropout
        assert_eq!(comm.clients, 1);
        assert_eq!(comm.broadcasts, 2);
        assert_eq!(session.stats.stragglers, 1);
        assert_eq!(session.connected(), 1);
        assert!(stats.train_loss > 0.0);
        drop(release);
        session.finish().unwrap();
        t0.join().unwrap();
        t1.join().unwrap();
    }

    #[test]
    fn buffered_round_closes_at_quota_and_folds_the_straggler_stale() {
        let (mut session, addr) = test_session(2, 60_000);
        session.cfg.aggregation = Aggregation::Buffered { k: 1 };
        // device 0 answers both rounds promptly
        let a0 = addr.clone();
        let t0 = thread::spawn(move || {
            let mut conn = fake_handshake(&a0, 0xFEED, 0, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            for _ in 0..2 {
                let (kind, payload) = conn.recv().unwrap();
                assert_eq!(kind, FrameKind::Round);
                let (p, _) = parse_round(&payload).unwrap();
                conn.send(FrameKind::Uplink, &mask_uplink(10.0, p.round)).unwrap();
            }
            let _ = conn.recv(); // Done
        });
        // device 1 holds its round-1 uplink until that round has closed,
        // then delivers it late — buffered mode must carry it, not drop it
        let (release, park) = mpsc::channel::<()>();
        let a1 = addr.clone();
        let t1 = thread::spawn(move || {
            let mut conn = fake_handshake(&a1, 0xFEED, 1, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let (kind, _) = conn.recv().unwrap(); // the round-1 broadcast
            assert_eq!(kind, FrameKind::Round);
            let _ = park.recv(); // parked past the round-1 close
            conn.send(FrameKind::Uplink, &mask_uplink(10.0, 1)).unwrap();
            loop {
                match conn.recv() {
                    Ok((FrameKind::Round, payload)) => {
                        let (p, _) = parse_round(&payload).unwrap();
                        conn.send(FrameKind::Uplink, &mask_uplink(10.0, p.round)).unwrap();
                    }
                    _ => return, // Done (or server close)
                }
            }
        });
        session.wait_for_fleet(Duration::from_secs(5)).unwrap();
        let mut server = MaskStrategy::new(N_PARAMS, 1, MaskMode::Stochastic);
        let mut fleet_state = None;
        // round 1, quota K=1: device 0 folds, device 1 is parked — with a
        // 60s deadline the round still closes immediately at the quota
        let mut comm = RoundComm::new(N_PARAMS);
        let mut p = plan();
        session
            .run_round(&mut server, &mut fleet_state, Participation::default(), &p, &mut comm)
            .unwrap();
        assert_eq!(comm.clients, 1, "quota of 1 closes the round after one fold");
        assert_eq!(session.stats.stragglers, 0, "buffered mode never drops a straggler");
        assert_eq!(session.stats.late_folds, 0);
        assert_eq!(session.connected(), 2, "the parked device keeps its connection");
        // release the straggler; its round-1 uplink folds into round 2
        // staleness-discounted and counts toward that round's quota
        drop(release);
        session.cfg.aggregation = Aggregation::Buffered { k: 2 };
        p.round = 2;
        let mut comm = RoundComm::new(N_PARAMS);
        session
            .run_round(&mut server, &mut fleet_state, Participation::default(), &p, &mut comm)
            .unwrap();
        assert_eq!(session.stats.late_folds, 1, "the carried uplink folds stale");
        assert_eq!(comm.clients, 2, "round 2 = one stale + one fresh fold");
        assert_eq!(session.stats.stragglers, 0);
        session.finish().unwrap();
        t0.join().unwrap();
        t1.join().unwrap();
    }

    #[test]
    fn edge_tier_folds_bit_identical_to_flat() {
        // the same four distinct weighted uplinks, folded flat vs through
        // a two-edge tier, must produce bit-identical round statistics
        // (integer weights x 0/1 bits: the partial sums are exact)
        let run = |edges: usize| {
            let (mut session, addr) = test_session(4, 5_000);
            session.cfg.edges = edges;
            let handles: Vec<_> = (0..4usize)
                .map(|id| {
                    let addr = addr.clone();
                    thread::spawn(move || {
                        let mut conn = fake_handshake(&addr, 0xFEED, id as u64, 0);
                        conn.recv_expect(FrameKind::Welcome).unwrap();
                        let (kind, _) = conn.recv().unwrap();
                        assert_eq!(kind, FrameKind::Round);
                        conn.send(FrameKind::Uplink, &device_uplink(id, 1)).unwrap();
                        let _ = conn.recv(); // Done
                    })
                })
                .collect();
            session.wait_for_fleet(Duration::from_secs(5)).unwrap();
            let mut server = MaskStrategy::new(N_PARAMS, 4, MaskMode::Stochastic);
            let mut fleet_state = None;
            let mut comm = RoundComm::new(N_PARAMS);
            let stats = session
                .run_round(
                    &mut server,
                    &mut fleet_state,
                    Participation::default(),
                    &plan(),
                    &mut comm,
                )
                .unwrap();
            session.finish().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            (stats, comm)
        };
        let (flat, flat_comm) = run(0);
        let (edged, edged_comm) = run(2);
        assert_eq!(flat_comm.clients, 4);
        assert_eq!(edged_comm.clients, 4, "edge tier credits every constituent uplink");
        assert_eq!(flat_comm.ul_bits, edged_comm.ul_bits);
        assert_eq!(flat.mean_theta.to_bits(), edged.mean_theta.to_bits());
        assert_eq!(flat.mask_density.to_bits(), edged.mask_density.to_bits());
        assert_eq!(flat.train_loss.to_bits(), edged.train_loss.to_bits());
    }

    #[test]
    fn handshake_rejects_fingerprint_mismatch_and_bad_id() {
        let (mut session, addr) = test_session(1, 1000);
        let t = thread::spawn(move || {
            let mut conn = fake_handshake(&addr, 0xBAD, 0, 0);
            let err = conn.recv_expect(FrameKind::Welcome).unwrap_err();
            assert!(err.to_string().contains("fingerprint"), "{err}");
            let mut conn = fake_handshake(&addr, 0xFEED, 9, 0);
            let err = conn.recv_expect(FrameKind::Welcome).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{err}");
        });
        let err = session.wait_for_fleet(Duration::from_millis(900)).unwrap_err();
        assert!(err.to_string().contains("missing ids [0]"), "{err}");
        t.join().unwrap();
    }

    #[test]
    fn reconnect_resyncs_before_next_chain_round() {
        let (mut session, addr) = test_session(1, 2000);
        session.cfg.needs_state_sync = true;
        session.rounds_completed = 3;
        let state = vec![0.25f32; N_PARAMS];
        let mut fleet_state = Some(state.clone());
        let t = thread::spawn(move || {
            // registration with resume_round = 0: three completed rounds
            // were missed, so the round-4 broadcast is preceded by Sync
            let mut conn = fake_handshake(&addr, 0xFEED, 0, 0);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let sync = conn.recv_expect(FrameKind::Sync).unwrap();
            let msg = DownlinkMsg::from_bytes(&sync).unwrap();
            assert_eq!(msg.decode_state(None).unwrap(), vec![0.25f32; N_PARAMS]);
            let (kind, payload) = conn.recv().unwrap();
            assert_eq!(kind, FrameKind::Round);
            assert_eq!(parse_round(&payload).unwrap().0.round, 4);
            conn.send(FrameKind::Uplink, &mask_uplink(10.0, 4)).unwrap();
            drop(conn);
            // reconnect already in sync with round 4: Welcome, then the
            // round-5 broadcast with NO Sync in between
            let mut conn = fake_handshake(&addr, 0xFEED, 0, 4);
            conn.recv_expect(FrameKind::Welcome).unwrap();
            let (kind, payload) = conn.recv().unwrap();
            assert_eq!(kind, FrameKind::Round);
            assert_eq!(parse_round(&payload).unwrap().0.round, 5);
            conn.send(FrameKind::Uplink, &mask_uplink(10.0, 5)).unwrap();
            let _ = conn.recv(); // Done
        });
        session.wait_for_fleet(Duration::from_secs(5)).unwrap();
        let mut server = MaskStrategy::new(N_PARAMS, 1, MaskMode::Stochastic);
        let mut p4 = plan();
        p4.round = 4;
        let mut comm = RoundComm::new(N_PARAMS);
        session
            .run_round(&mut server, &mut fleet_state, Participation::default(), &p4, &mut comm)
            .unwrap();
        assert_eq!(session.stats.syncs, 1, "stale reconnect gets exactly one Sync");
        assert_eq!(comm.clients, 1, "the round-4 uplink folds despite the disconnect");
        // handshake barrier, no timing sleeps: sweep until the
        // re-registration lands
        assert!(
            session
                .poll_until(Duration::from_secs(5), |s| s.stats.reconnects == 1)
                .unwrap(),
            "re-registration never landed"
        );
        let mut p5 = plan();
        p5.round = 5;
        let mut comm = RoundComm::new(N_PARAMS);
        session
            .run_round(&mut server, &mut fleet_state, Participation::default(), &p5, &mut comm)
            .unwrap();
        assert_eq!(session.stats.syncs, 1, "in-sync reconnect must not resync");
        session.finish().unwrap();
        t.join().unwrap();
    }

    #[test]
    fn fleet_of_64_assembles_and_completes_without_hot_path_naps() {
        const FLEET: usize = 64;
        let (mut session, addr) = test_session(FLEET, 5_000);
        let handles: Vec<_> = (0..FLEET)
            .map(|id| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut conn = fake_handshake(&addr, 0xFEED, id as u64, 0);
                    conn.recv_expect(FrameKind::Welcome).unwrap();
                    let (kind, payload) = conn.recv().unwrap();
                    assert_eq!(kind, FrameKind::Round);
                    parse_round(&payload).unwrap();
                    conn.send(FrameKind::Uplink, &mask_uplink(1.0, 1)).unwrap();
                    conn.recv_expect(FrameKind::Done).unwrap();
                })
            })
            .collect();
        session.wait_for_fleet(Duration::from_secs(10)).unwrap();
        assert_eq!(session.connected(), FLEET);
        let mut server = MaskStrategy::new(N_PARAMS, FLEET, MaskMode::Stochastic);
        let mut fleet_state = None;
        let mut comm = RoundComm::new(N_PARAMS);
        session
            .run_round(
                &mut server,
                &mut fleet_state,
                Participation::default(),
                &plan(),
                &mut comm,
            )
            .unwrap();
        assert_eq!(comm.clients, FLEET, "all 64 uplinks folded");
        assert_eq!(session.stats.missing, 0);
        assert_eq!(session.stats.stragglers, 0);
        // The readiness loop may nap (500µs) only on provably idle
        // sweeps. With the old 10ms ACCEPT_POLL cadence this fleet spent
        // whole seconds asleep; the bound below caps total sleeping at
        // <2s even on a fully serialized single-core scheduler, i.e.
        // there is no fixed-cadence polling left on the hot path.
        assert!(
            session.stats.idle_naps < 4_000,
            "hot path is polling-sleep-bound: {} naps",
            session.stats.idle_naps
        );
        session.finish().unwrap();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn round_payload_parses_and_validates() {
        let msg = DownlinkMsg::Theta(vec![0.5f32; 16]);
        let payload = round_payload(&plan(), &msg);
        let (p, m) = parse_round(&payload).unwrap();
        assert_eq!(p, plan());
        assert_eq!(m.n(), 16);
        assert!(parse_round(&payload[..3]).is_err());
        assert!(parse_round(&payload[..payload.len() - 1]).is_err());
        let mut bad = payload.clone();
        bad[0] = 99; // plan_len corrupted
        assert!(parse_round(&bad).is_err());
    }
}
