//! Hierarchical edge-tier aggregation (DESIGN.md §Fleet).
//!
//! At fleet scale a single server folding every uplink is a fan-in
//! bottleneck. Every strategy's round state is an associative sum —
//! eq. 8 weighted mask sums (FedPM family and FedMRN's noise masks),
//! MV-SignSGD sign tallies, FedAvg weighted averages, SpaFL's weighted
//! per-filter threshold sums — so a cohort can be split across edge aggregators that each
//! fold their slice into one O(n_params) accumulator and ship a single
//! merged [`AggregateMsg`] envelope upstream. The top-tier fold of those
//! partial sums is bit-identical to the flat ordered fold whenever the
//! constituent terms form grouping-exact f64 sums: integer |D_i| weights
//! times {0,1} mask bits or ±1 signs are exact unconditionally; FedAvg's
//! weight×f32 products are exact, and their sums regroup exactly on a
//! shared dyadic grid with headroom below 2^53 (the §Fleet associativity
//! argument in DESIGN.md).
//!
//! The same module owns the staleness discount used by buffered-async
//! aggregation ([`staleness_scale`]) so the edge tier and the flat
//! server path scale weights with the identical expression.
//!
//! audit: wire-decode, deterministic

use anyhow::{bail, ensure, Result};

use crate::compress;
use crate::mask::empirical_bpp;

use super::protocol::{UplinkMsg, UplinkPayload, PROTOCOL_VERSION, PROTOCOL_VERSION_MIN};

const AGG_MASK_SUM: u8 = 0;
const AGG_SIGN_TALLY: u8 = 1;
const AGG_DENSE_SUM: u8 = 2;
const AGG_NOISE_MASK_SUM: u8 = 3;
const AGG_THRESHOLD_SUM: u8 = 4;

/// Aggregate envelope header: version + kind bytes, u32 sum count, then
/// f64 weight_sum, f64 loss_sum, u64 reporters, u64 ul_bits and
/// f64 est_bpp_sum — 46 bytes before the packed f64 sums.
const AGG_HEAD: usize = 2 + 4 + 8 + 8 + 8 + 8 + 8;

/// The associative accumulator shape an edge tier folds for a strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggKind {
    /// eq. 8 numerator: per-parameter sum of |D_i| × mask bit.
    MaskSum,
    /// MV-SignSGD: per-parameter sum of ±|D_i| (the majority tally).
    SignTally,
    /// FedAvg: per-parameter sum of |D_i| × local weight.
    DenseSum,
    /// FedMRN: per-parameter sum of |D_i| × noise-mask bit (v2 wire
    /// kind; identical arithmetic to `MaskSum`, distinct payload).
    NoiseMaskSum,
    /// SpaFL: per-FILTER sum of |D_i| × threshold — the accumulator is
    /// O(n_filters), not O(n_params), sized lazily from the first fold.
    ThresholdSum,
}

impl AggKind {
    fn wire_kind(self) -> u8 {
        match self {
            AggKind::MaskSum => AGG_MASK_SUM,
            AggKind::SignTally => AGG_SIGN_TALLY,
            AggKind::DenseSum => AGG_DENSE_SUM,
            AggKind::NoiseMaskSum => AGG_NOISE_MASK_SUM,
            AggKind::ThresholdSum => AGG_THRESHOLD_SUM,
        }
    }
}

/// The staleness discount of buffered-async aggregation (DESIGN.md
/// §Fleet): an uplink trained `gap` rounds before the round it lands in
/// folds with its weight scaled by `1/(1+gap)^beta`. `gap = 0` returns
/// exactly 1.0 (a fresh uplink folds unchanged in every rounding mode);
/// `beta = 0` disables discounting.
pub fn staleness_scale(gap: u64, beta: f64) -> f64 {
    if gap == 0 {
        return 1.0;
    }
    1.0 / (1.0 + gap as f64).powf(beta)
}

/// One edge tier's merged upstream envelope: the cohort-local partial
/// sums plus every scalar the server needs to keep its round stats and
/// communication accounting identical to the flat path.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregateMsg {
    pub kind: AggKind,
    /// Per-parameter partial sums (meaning depends on `kind`).
    pub acc: Vec<f64>,
    /// Sum of the folded uplinks' (discounted) aggregation weights.
    pub weight_sum: f64,
    /// Sum of the folded uplinks' train losses (mergeable round mean).
    pub loss_sum: f64,
    /// Number of constituent uplinks.
    pub reporters: u64,
    /// Summed serialized wire bits of the constituent uplink envelopes.
    pub ul_bits: u64,
    /// Summed per-uplink estimated source Bpp (eq. 13 terms).
    pub est_bpp_sum: f64,
}

impl AggregateMsg {
    /// Exact serialized envelope size in bytes.
    pub fn wire_bytes(&self) -> usize {
        AGG_HEAD + 8 * self.acc.len()
    }

    pub fn wire_bits(&self) -> u64 {
        self.wire_bytes() as u64 * 8
    }

    /// Serialize to the flat little-endian wire layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.push(PROTOCOL_VERSION);
        out.push(self.kind.wire_kind());
        // audit:checked(n_params is far below 2^32 by model geometry)
        out.extend_from_slice(&(self.acc.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.weight_sum.to_le_bytes());
        out.extend_from_slice(&self.loss_sum.to_le_bytes());
        out.extend_from_slice(&self.reporters.to_le_bytes());
        out.extend_from_slice(&self.ul_bits.to_le_bytes());
        out.extend_from_slice(&self.est_bpp_sum.to_le_bytes());
        for a in &self.acc {
            out.extend_from_slice(&a.to_le_bytes());
        }
        out
    }

    /// Parse and validate an aggregate envelope: version window, known
    /// kind, a recorded sum count matching the bytes present, at least
    /// one constituent uplink, and finite scalars/sums throughout —
    /// truncated or corrupt envelopes never decode.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(
            bytes.len() >= AGG_HEAD,
            "aggregate envelope truncated ({} bytes)",
            bytes.len()
        );
        ensure!(
            (PROTOCOL_VERSION_MIN..=PROTOCOL_VERSION).contains(&bytes[0]),
            "aggregate protocol version {} outside supported \
             {PROTOCOL_VERSION_MIN}..={PROTOCOL_VERSION}",
            bytes[0]
        );
        let kind = match bytes[1] {
            AGG_MASK_SUM => AggKind::MaskSum,
            AGG_SIGN_TALLY => AggKind::SignTally,
            AGG_DENSE_SUM => AggKind::DenseSum,
            AGG_NOISE_MASK_SUM => AggKind::NoiseMaskSum,
            AGG_THRESHOLD_SUM => AggKind::ThresholdSum,
            other => bail!("unknown aggregate kind {other}"),
        };
        ensure!(
            bytes[0] >= 2 || bytes[1] < AGG_NOISE_MASK_SUM,
            "aggregate kind {} requires protocol v2, envelope is v{}",
            bytes[1],
            bytes[0]
        );
        let n = u32::from_le_bytes(bytes[2..6].try_into()?) as usize;
        ensure!(
            bytes.len() == AGG_HEAD + 8 * n,
            "aggregate records {n} sums but carries {} payload bytes",
            bytes.len() - AGG_HEAD
        );
        let weight_sum = f64::from_le_bytes(bytes[6..14].try_into()?);
        let loss_sum = f64::from_le_bytes(bytes[14..22].try_into()?);
        let reporters = u64::from_le_bytes(bytes[22..30].try_into()?);
        let ul_bits = u64::from_le_bytes(bytes[30..38].try_into()?);
        let est_bpp_sum = f64::from_le_bytes(bytes[38..46].try_into()?);
        ensure!(reporters > 0, "aggregate envelope carries no uplinks");
        ensure!(
            weight_sum.is_finite() && weight_sum > 0.0,
            "aggregate weight sum {weight_sum} must be a positive finite total"
        );
        ensure!(loss_sum.is_finite(), "aggregate loss sum {loss_sum} not finite");
        ensure!(
            est_bpp_sum.is_finite() && est_bpp_sum >= 0.0,
            "aggregate est-Bpp sum {est_bpp_sum} must be non-negative and finite"
        );
        let mut acc = Vec::with_capacity(n);
        for chunk in bytes[AGG_HEAD..].chunks_exact(8) {
            let v = f64::from_le_bytes(chunk.try_into()?);
            ensure!(v.is_finite(), "aggregate partial sum {v} not finite");
            acc.push(v);
        }
        Ok(Self { kind, acc, weight_sum, loss_sum, reporters, ul_bits, est_bpp_sum })
    }
}

/// One edge-tier instance: folds its slice of the cohort's uplinks into
/// the strategy's associative accumulator and ships one merged envelope
/// upstream via [`EdgeAggregator::finish`]. The per-uplink arithmetic is
/// exactly the flat fold's step over the same decoded payloads, so the
/// partial sums regroup without changing any term.
#[derive(Debug, Clone)]
pub struct EdgeAggregator {
    kind: AggKind,
    acc: Vec<f64>,
    /// Model parameter count (Bpp denominator; for `ThresholdSum` this
    /// differs from the accumulator length).
    n_params: usize,
    weight_sum: f64,
    loss_sum: f64,
    reporters: u64,
    ul_bits: u64,
    est_bpp_sum: f64,
}

impl EdgeAggregator {
    pub fn new(kind: AggKind, n_params: usize) -> Self {
        // A ThresholdSum edge folds O(n_filters) sums, a count only the
        // strategy knows — size the accumulator lazily from the first
        // folded payload instead of from n_params.
        let acc = if kind == AggKind::ThresholdSum { Vec::new() } else { vec![0.0; n_params] };
        Self {
            kind,
            acc,
            n_params,
            weight_sum: 0.0,
            loss_sum: 0.0,
            reporters: 0,
            ul_bits: 0,
            est_bpp_sum: 0.0,
        }
    }

    /// Constituent uplinks folded so far (0 = nothing to ship upstream).
    pub fn reporters(&self) -> u64 {
        self.reporters
    }

    /// Fold one uplink envelope: decode its payload, discount its weight
    /// by the staleness gap against `round` (a fresh or v1 envelope
    /// scales by exactly 1.0), and accumulate. Also records the scalars
    /// the upstream fold needs for stats and communication accounting.
    pub fn fold(&mut self, msg: &UplinkMsg, round: usize, beta: f64) -> Result<()> {
        let gap = (round as u64).saturating_sub(msg.trained_round);
        let w = msg.weight * staleness_scale(gap, beta);
        let n = self.acc.len();
        match (self.kind, &msg.payload) {
            (AggKind::MaskSum, UplinkPayload::CodedMask(enc)) => {
                let mask = compress::decode(enc, n)?;
                self.est_bpp_sum += empirical_bpp(&mask);
                for (a, bit) in self.acc.iter_mut().zip(mask.iter()) {
                    if bit {
                        *a += w;
                    }
                }
            }
            (AggKind::SignTally, UplinkPayload::SignVector(enc)) => {
                let signs = compress::decode(enc, n)?;
                self.est_bpp_sum += empirical_bpp(&signs);
                for (a, bit) in self.acc.iter_mut().zip(signs.iter()) {
                    *a += if bit { w } else { -w };
                }
            }
            (AggKind::DenseSum, UplinkPayload::DenseDelta(v)) => {
                ensure!(
                    v.len() == n,
                    "dense uplink carries {} params, edge expects {n}",
                    v.len()
                );
                for (a, &x) in self.acc.iter_mut().zip(v) {
                    *a += w * x as f64;
                }
                self.est_bpp_sum += 32.0;
            }
            (AggKind::NoiseMaskSum, UplinkPayload::NoiseMask(enc)) => {
                let mask = compress::decode(enc, n)?;
                self.est_bpp_sum += empirical_bpp(&mask);
                for (a, bit) in self.acc.iter_mut().zip(mask.iter()) {
                    if bit {
                        *a += w;
                    }
                }
            }
            (AggKind::ThresholdSum, UplinkPayload::Thresholds(v)) => {
                if self.acc.is_empty() && self.reporters == 0 {
                    self.acc = vec![0.0; v.len()];
                }
                ensure!(
                    v.len() == self.acc.len(),
                    "thresholds uplink carries {} filters, edge expects {}",
                    v.len(),
                    self.acc.len()
                );
                for (a, &t) in self.acc.iter_mut().zip(v) {
                    *a += w * t as f64;
                }
                // Same expression as the flat fold's estimate, so the
                // upstream est-Bpp totals match bit for bit.
                self.est_bpp_sum += 32.0 * v.len() as f64 / self.n_params.max(1) as f64;
            }
            (kind, payload) => bail!(
                "edge aggregator for {kind:?} cannot fold a {} uplink",
                payload.kind_name()
            ),
        }
        self.weight_sum += w;
        self.loss_sum += msg.train_loss as f64;
        self.reporters += 1;
        self.ul_bits += msg.wire_bits();
        Ok(())
    }

    /// Close this edge's round slice into one upstream envelope.
    pub fn finish(&self) -> AggregateMsg {
        AggregateMsg {
            kind: self.kind,
            acc: self.acc.clone(),
            weight_sum: self.weight_sum,
            loss_sum: self.loss_sum,
            reporters: self.reporters,
            ul_bits: self.ul_bits,
            est_bpp_sum: self.est_bpp_sum,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::BitVec;

    fn mask_uplink(bits: &[u8], weight: f64, trained_round: u64) -> UplinkMsg {
        let m = BitVec::from_iter_len(bits.iter().map(|&b| b == 1), bits.len());
        UplinkMsg {
            weight,
            train_loss: 0.5,
            trained_round,
            payload: UplinkPayload::CodedMask(compress::encode(&m)),
        }
    }

    #[test]
    fn staleness_scale_contract() {
        assert_eq!(staleness_scale(0, 1.0), 1.0);
        assert_eq!(staleness_scale(0, 0.0), 1.0);
        assert_eq!(staleness_scale(3, 0.0), 1.0);
        assert!((staleness_scale(1, 1.0) - 0.5).abs() < 1e-15);
        assert!((staleness_scale(3, 2.0) - 1.0 / 16.0).abs() < 1e-15);
        // monotone in the gap for beta > 0
        assert!(staleness_scale(2, 1.0) < staleness_scale(1, 1.0));
    }

    #[test]
    fn envelope_roundtrip() {
        let mut edge = EdgeAggregator::new(AggKind::MaskSum, 4);
        edge.fold(&mask_uplink(&[1, 1, 0, 0], 3.0, UplinkMsg::FRESH), 5, 1.0).unwrap();
        edge.fold(&mask_uplink(&[1, 0, 1, 0], 2.0, UplinkMsg::FRESH), 5, 1.0).unwrap();
        let msg = edge.finish();
        let back = AggregateMsg::from_bytes(&msg.to_bytes()).unwrap();
        assert_eq!(back, msg);
        assert_eq!(back.reporters, 2);
        assert_eq!(back.acc, vec![5.0, 3.0, 2.0, 0.0]);
        assert_eq!(back.weight_sum, 5.0);
        assert!((back.loss_sum - 1.0).abs() < 1e-6);
        assert!(back.ul_bits > 0);
    }

    #[test]
    fn envelope_rejects_corruption() {
        let mut edge = EdgeAggregator::new(AggKind::SignTally, 8);
        let m = BitVec::from_iter_len((0..8).map(|i| i % 2 == 0), 8);
        let up = UplinkMsg {
            weight: 2.0,
            train_loss: 0.1,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::SignVector(compress::encode(&m)),
        };
        edge.fold(&up, 1, 1.0).unwrap();
        let bytes = edge.finish().to_bytes();
        // truncation at every prefix length must error, never panic
        for cut in 0..bytes.len() {
            assert!(AggregateMsg::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // bad version / unknown kind
        let mut bad = bytes.clone();
        bad[0] = 9;
        assert!(AggregateMsg::from_bytes(&bad).is_err());
        let mut bad = bytes.clone();
        bad[1] = 7;
        assert!(AggregateMsg::from_bytes(&bad).is_err());
        // zero reporters
        let mut bad = bytes.clone();
        bad[22..30].copy_from_slice(&0u64.to_le_bytes());
        assert!(AggregateMsg::from_bytes(&bad).is_err());
        // non-finite partial sum
        let mut bad = bytes;
        let tail = bad.len() - 8;
        bad[tail..].copy_from_slice(&f64::NAN.to_le_bytes());
        assert!(AggregateMsg::from_bytes(&bad).is_err());
    }

    #[test]
    fn fold_rejects_payload_kind_mismatch() {
        let mut edge = EdgeAggregator::new(AggKind::MaskSum, 4);
        let up = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; 4]),
        };
        assert!(edge.fold(&up, 1, 1.0).is_err());
        assert_eq!(edge.reporters(), 0, "rejected uplinks must not be accounted");
    }

    #[test]
    fn noise_mask_edge_folds_like_mask_sum() {
        let mut edge = EdgeAggregator::new(AggKind::NoiseMaskSum, 4);
        let m = BitVec::from_bools(&[true, true, false, false]);
        let up = UplinkMsg {
            weight: 3.0,
            train_loss: 0.5,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::NoiseMask(compress::encode(&m)),
        };
        edge.fold(&up, 1, 1.0).unwrap();
        let msg = edge.finish();
        assert_eq!(msg.kind, AggKind::NoiseMaskSum);
        assert_eq!(msg.acc, vec![3.0, 3.0, 0.0, 0.0]);
        let back = AggregateMsg::from_bytes(&msg.to_bytes()).unwrap();
        assert_eq!(back, msg);
        // a coded-mask uplink must not fold into a noise-mask edge
        let wrong = UplinkMsg {
            payload: UplinkPayload::CodedMask(compress::encode(&m)),
            ..up.clone()
        };
        assert!(edge.fold(&wrong, 1, 1.0).is_err());
    }

    #[test]
    fn threshold_edge_sizes_lazily_and_roundtrips() {
        // n_params = 100, but the strategy folds 3 per-filter sums
        let mut edge = EdgeAggregator::new(AggKind::ThresholdSum, 100);
        let up = |tau: Vec<f32>, w: f64| UplinkMsg {
            weight: w,
            train_loss: 0.5,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::Thresholds(tau),
        };
        edge.fold(&up(vec![0.5, 0.25, 0.0], 2.0), 1, 1.0).unwrap();
        edge.fold(&up(vec![0.25, 0.5, 1.0], 2.0), 1, 1.0).unwrap();
        // a filter-count mismatch after sizing must be rejected
        assert!(edge.fold(&up(vec![0.5; 4], 1.0), 1, 1.0).is_err());
        let msg = edge.finish();
        assert_eq!(msg.kind, AggKind::ThresholdSum);
        assert_eq!(msg.acc, vec![1.5, 1.5, 2.0]);
        assert_eq!(msg.reporters, 2);
        // est Bpp carries the n_params denominator, not n_filters
        assert!((msg.est_bpp_sum - 2.0 * 32.0 * 3.0 / 100.0).abs() < 1e-15);
        let back = AggregateMsg::from_bytes(&msg.to_bytes()).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn v2_only_aggregate_kinds_reject_a_v1_stamp() {
        let mut edge = EdgeAggregator::new(AggKind::ThresholdSum, 10);
        let up = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::Thresholds(vec![0.5]),
        };
        edge.fold(&up, 1, 1.0).unwrap();
        let mut bytes = edge.finish().to_bytes();
        assert!(AggregateMsg::from_bytes(&bytes).is_ok());
        bytes[0] = 1;
        assert!(
            AggregateMsg::from_bytes(&bytes).is_err(),
            "a v1 envelope cannot carry a v2-only aggregate kind"
        );
    }

    #[test]
    fn stale_uplink_folds_discounted() {
        let mut edge = EdgeAggregator::new(AggKind::MaskSum, 2);
        // trained at round 3, lands in round 5: gap 2, beta 1 -> w/3
        edge.fold(&mask_uplink(&[1, 0], 3.0, 3), 5, 1.0).unwrap();
        let msg = edge.finish();
        assert!((msg.acc[0] - 1.0).abs() < 1e-15);
        assert_eq!(msg.acc[1], 0.0);
        assert!((msg.weight_sum - 1.0).abs() < 1e-15);
    }
}
