//! Client participation & failure model.
//!
//! Real federations never see every device every round: devices are
//! sampled (participation fraction) and some of the sampled ones drop
//! mid-round (stragglers, battery, network). The paper assumes full
//! participation; this module generalizes the round loop so the same
//! code runs the paper's setting (fraction = 1, dropout = 0) and the
//! robustness ablations in `coordinator::ablation`.
//!
//! audit: deterministic

use crate::util::Xoshiro256;

/// Per-round participation policy.
#[derive(Debug, Clone, Copy)]
pub struct Participation {
    /// Fraction of devices sampled each round (0, 1].
    pub fraction: f64,
    /// Probability a sampled device fails to report its uplink.
    pub dropout: f64,
}

impl Default for Participation {
    fn default() -> Self {
        Self { fraction: 1.0, dropout: 0.0 }
    }
}

impl Participation {
    pub fn new(fraction: f64, dropout: f64) -> Self {
        assert!(fraction > 0.0 && fraction <= 1.0, "fraction in (0,1]");
        assert!((0.0..1.0).contains(&dropout), "dropout in [0,1)");
        Self { fraction, dropout }
    }

    /// Is this the paper's full-participation setting?
    pub fn is_full(&self) -> bool {
        self.fraction >= 1.0 && self.dropout == 0.0
    }

    /// Sample the participating client ids for `round`.
    ///
    /// At least one client always participates (a federation round with
    /// zero uplinks cannot aggregate); sampling is deterministic in
    /// (seed, round).
    pub fn sample_round(&self, n_clients: usize, seed: u64, round: usize) -> Vec<usize> {
        let mut rng = Xoshiro256::new(seed ^ 0x9A47 ^ ((round as u64) << 16));
        let k = ((n_clients as f64 * self.fraction).round() as usize).clamp(1, n_clients);
        let mut ids: Vec<usize> = (0..n_clients).collect();
        rng.shuffle(&mut ids);
        ids.truncate(k);
        ids.sort_unstable();
        ids
    }

    /// Does this sampled client drop out before its uplink lands?
    /// Guarantees at least one survivor among `participants` by never
    /// dropping the first one.
    pub fn drops(&self, position_in_round: usize, seed: u64, round: usize, client: usize) -> bool {
        if self.dropout == 0.0 || position_in_round == 0 {
            return false;
        }
        let mut rng =
            Xoshiro256::new(seed ^ 0xD209 ^ ((round as u64) << 20) ^ (client as u64));
        rng.next_f64() < self.dropout
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_participation_selects_everyone() {
        let p = Participation::default();
        assert!(p.is_full());
        assert_eq!(p.sample_round(7, 1, 0), vec![0, 1, 2, 3, 4, 5, 6]);
        assert!(!p.drops(3, 1, 0, 3));
    }

    #[test]
    fn fraction_selects_expected_count() {
        let p = Participation::new(0.3, 0.0);
        for round in 0..20 {
            let ids = p.sample_round(30, 5, round);
            assert_eq!(ids.len(), 9);
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "sorted unique");
            assert!(ids.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn sampling_varies_by_round_but_is_deterministic() {
        let p = Participation::new(0.5, 0.0);
        let a = p.sample_round(20, 9, 1);
        let b = p.sample_round(20, 9, 1);
        let c = p.sample_round(20, 9, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn at_least_one_client_even_at_tiny_fraction() {
        let p = Participation::new(0.01, 0.0);
        assert_eq!(p.sample_round(5, 3, 0).len(), 1);
    }

    #[test]
    fn dropout_rate_roughly_matches() {
        let p = Participation::new(1.0, 0.3);
        let mut dropped = 0;
        let total = 3000;
        for round in 0..total {
            if p.drops(1, 7, round, 1) {
                dropped += 1;
            }
        }
        let rate = dropped as f64 / total as f64;
        assert!((rate - 0.3).abs() < 0.05, "rate {rate}");
    }

    #[test]
    fn first_participant_never_drops() {
        let p = Participation::new(1.0, 0.99);
        assert!(!p.drops(0, 1, 5, 17));
    }

    #[test]
    #[should_panic(expected = "fraction")]
    fn zero_fraction_rejected() {
        Participation::new(0.0, 0.0);
    }
}
