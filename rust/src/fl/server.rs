//! Parameter server: global probability-mask state + round bookkeeping.
//!
//! Owns theta(t), performs eq. 8 aggregation of the decoded uplink
//! masks, and produces the evaluation masks. The server never sees raw
//! client data — only coded masks — mirroring the paper's privacy
//! setting.
//!
//! Audit policy: intentionally unannotated. Untrusted bytes are decoded
//! and validated one layer down (`fl/protocol.rs`, `compress/`, both
//! under `wire-decode`); by the time this module runs, every input is a
//! typed, validated value. Determinism is enforced structurally — the
//! only collections here are `Vec`s folded in arrival order — and
//! proven end-to-end by `tests/engine_determinism.rs`. Protocol role:
//! the mask-family server state behind [`crate::algos::MaskStrategy`].

use anyhow::{bail, ensure, Result};

use crate::compress::{self, Encoded};
use crate::mask::{empirical_bpp, sample_mask, BetaAggregator, MaskAggregator, ProbMask};
use crate::util::BitVec;

use super::aggregator::{AggKind, AggregateMsg};
use super::comm::RoundComm;
use super::protocol::{UplinkMsg, UplinkPayload};

/// How uplink masks combine into the next global mask.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggMode {
    /// eq. 8: dataset-size-weighted mean of the masks.
    Mean,
    /// Beta-posterior mean with symmetric prior strength `prior`
    /// (FedPM's Bayesian aggregation; -> Mean as prior -> 0).
    Bayes { prior: f64 },
}

enum Agg {
    Mean(MaskAggregator),
    Bayes(BetaAggregator),
}

/// The FedPM-family parameter server.
pub struct Server {
    theta: ProbMask,
    agg: Agg,
    n_params: usize,
    /// Root seed for server-side sampling (eval masks etc.).
    seed: u64,
}

impl Server {
    /// Fresh server with theta ~ U[0,1) (paper footnote 2), eq. 8 mean.
    pub fn new(n_params: usize, seed: u64) -> Self {
        Self::with_agg(n_params, seed, AggMode::Mean)
    }

    /// Server with an explicit aggregation mode.
    pub fn with_agg(n_params: usize, seed: u64, mode: AggMode) -> Self {
        let agg = match mode {
            AggMode::Mean => Agg::Mean(MaskAggregator::new(n_params)),
            AggMode::Bayes { prior } => Agg::Bayes(BetaAggregator::new(n_params, prior)),
        };
        Self {
            theta: ProbMask::uniform_random(n_params, seed ^ 0x7E7A),
            agg,
            n_params,
            seed,
        }
    }

    pub fn theta(&self) -> &ProbMask {
        &self.theta
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// Ingest one client's uplink envelope as it lands: decode, verify,
    /// accumulate (eq. 8) — streaming, so server memory stays O(n_params)
    /// however large the cohort. The codec validates the wire header
    /// (recorded bit-length and one-count) and rejects truncated or
    /// corrupt payloads; a non-mask payload kind is a protocol error.
    pub fn receive_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()> {
        let UplinkPayload::CodedMask(enc) = &msg.payload else {
            bail!(
                "mask server expects a coded-mask uplink, got {}",
                msg.payload.kind_name()
            );
        };
        let mask = compress::decode(enc, self.n_params)?;
        comm.add_uplink(msg.wire_bits(), empirical_bpp(&mask));
        match &mut self.agg {
            Agg::Mean(a) => a.add_mask(&mask, msg.weight),
            Agg::Bayes(a) => a.add_mask(&mask, msg.weight),
        }
        Ok(())
    }

    /// Ingest one edge tier's merged partial sums (hierarchical
    /// aggregation, DESIGN.md §Fleet): elementwise-add the cohort-local
    /// eq. 8 numerators into the round accumulator and credit the
    /// constituent uplinks' communication accounting. Bit-identical to
    /// receiving those uplinks directly in order for integer |D_i|
    /// weights (grouping-exact f64 sums).
    pub fn receive_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()> {
        ensure!(
            msg.kind == AggKind::MaskSum,
            "mask server expects a mask-sum aggregate, got {:?}",
            msg.kind
        );
        ensure!(
            msg.acc.len() == self.n_params,
            "aggregate covers {} params, server has {}",
            msg.acc.len(),
            self.n_params
        );
        comm.add_uplinks(msg.ul_bits, msg.est_bpp_sum, msg.reporters as usize);
        match &mut self.agg {
            Agg::Mean(a) => a.merge_sums(&msg.acc, msg.weight_sum, msg.reporters as usize),
            Agg::Bayes(a) => a.merge_sums(&msg.acc, msg.weight_sum, msg.reporters as usize),
        }
        Ok(())
    }

    /// Close the round: theta(t+1) from the configured aggregator.
    pub fn finish_round(&mut self) -> Result<()> {
        let n = match &self.agg {
            Agg::Mean(a) => a.n_clients(),
            Agg::Bayes(a) => a.n_clients(),
        };
        ensure!(n > 0, "no uplinks received this round");
        self.theta = match &self.agg {
            Agg::Mean(a) => a.finalize(),
            Agg::Bayes(a) => a.finalize(),
        };
        match &mut self.agg {
            Agg::Mean(a) => a.reset(),
            Agg::Bayes(a) => a.reset(),
        }
        Ok(())
    }

    /// Evaluation mask sampled from the current global theta (FedPM
    /// evaluates sampled sub-networks; seed varies per round).
    pub fn eval_mask_sampled(&self, round: usize) -> BitVec {
        self.eval_mask_sampled_from(&self.theta, round)
    }

    /// Sample an evaluation mask from an arbitrary theta with this
    /// server's per-round eval seed stream — used to evaluate the theta
    /// the clients actually received when the downlink is lossy
    /// (DESIGN.md §Downlink), with the same draws as [`Self::eval_mask_sampled`].
    pub fn eval_mask_sampled_from(&self, theta: &ProbMask, round: usize) -> BitVec {
        sample_mask(theta, self.seed ^ 0xE7A1 ^ ((round as u64) << 32))
    }

    /// Deterministic low-variance evaluation mask: 1[theta > 0.5].
    pub fn eval_mask_threshold(&self) -> BitVec {
        self.theta.threshold()
    }

    /// Final-model checkpoint payload: the coded thresholded mask (the
    /// "seed + binary mask" storage story of the paper's conclusion).
    pub fn checkpoint_mask(&self) -> Encoded {
        compress::encode(&self.theta.threshold())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask_enc(n: usize, p: f64, seed: u64) -> (BitVec, Encoded) {
        let pm = ProbMask::constant(n, p as f32);
        let m = sample_mask(&pm, seed);
        let e = compress::encode(&m);
        (m, e)
    }

    fn uplink(enc: Encoded, weight: f64) -> UplinkMsg {
        UplinkMsg {
            weight,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::CodedMask(enc),
        }
    }

    #[test]
    fn round_trip_aggregation() {
        let n = 1000;
        let mut srv = Server::new(n, 7);
        let mut comm = RoundComm::new(n);
        let (m1, e1) = mask_enc(n, 1.0, 1); // all ones
        let (m2, e2) = mask_enc(n, 0.0, 2); // all zeros
        assert_eq!(m1.count_ones(), n);
        assert_eq!(m2.count_ones(), 0);
        srv.receive_uplink(&uplink(e1, 1.0), &mut comm).unwrap();
        srv.receive_uplink(&uplink(e2, 1.0), &mut comm).unwrap();
        srv.finish_round().unwrap();
        // equal weights: theta = 0.5 everywhere
        assert!(srv.theta().theta().iter().all(|&t| (t - 0.5).abs() < 1e-6));
        assert_eq!(comm.clients, 2);
        assert!(comm.ul_bits > 0);
    }

    #[test]
    fn weighted_aggregation_follows_eq8() {
        let n = 64;
        let mut srv = Server::new(n, 3);
        let mut comm = RoundComm::new(n);
        let (_, ones) = mask_enc(n, 1.0, 1);
        let (_, zeros) = mask_enc(n, 0.0, 2);
        srv.receive_uplink(&uplink(ones, 30.0), &mut comm).unwrap();
        srv.receive_uplink(&uplink(zeros, 10.0), &mut comm).unwrap();
        srv.finish_round().unwrap();
        assert!(srv.theta().theta().iter().all(|&t| (t - 0.75).abs() < 1e-6));
    }

    #[test]
    fn non_mask_payload_rejected() {
        let mut srv = Server::new(16, 1);
        let mut comm = RoundComm::new(16);
        let msg = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; 16]),
        };
        assert!(srv.receive_uplink(&msg, &mut comm).is_err());
        assert_eq!(comm.clients, 0, "rejected uplinks must not be accounted");
    }

    #[test]
    fn finish_without_uplinks_errors() {
        let mut srv = Server::new(10, 1);
        assert!(srv.finish_round().is_err());
    }

    #[test]
    fn eval_masks() {
        let srv = Server::new(5000, 9);
        let a = srv.eval_mask_sampled(1);
        let b = srv.eval_mask_sampled(1);
        let c = srv.eval_mask_sampled(2);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // theta ~ U[0,1) -> threshold density ~0.5
        let t = srv.eval_mask_threshold();
        assert!((t.density() - 0.5).abs() < 0.05);
    }

    #[test]
    fn corrupted_one_count_rejected() {
        let n = 100;
        let mut srv = Server::new(n, 1);
        let mut comm = RoundComm::new(n);
        let (_, mut enc) = mask_enc(n, 0.5, 3);
        enc.ones += 1;
        assert!(srv.receive_uplink(&uplink(enc, 1.0), &mut comm).is_err());
    }

    #[test]
    fn checkpoint_is_decodable() {
        let srv = Server::new(2000, 11);
        let ck = srv.checkpoint_mask();
        let decoded = compress::decode(&ck, 2000).unwrap();
        assert_eq!(decoded, srv.eval_mask_threshold());
    }
}
