//! Fleet-scale federation simulator: 100k-device rounds on one thread.
//!
//! `fedsrn fleet --devices 100000` answers the question the networked
//! runtime cannot at laptop scale: what do staleness-discounted
//! buffered aggregation (`aggregation=buffered<K>`), hierarchical edge
//! folds (`edges=N`), churn, and heterogeneous device latency do to a
//! federation round — without 100k OS threads or sockets. Devices are
//! not processes here; each is a pure function of `(seed, id, round)`:
//!
//! * **Virtual clock** — time is a `u64` tick counter. A device sampled
//!   into a round finishes `DelayProfile::delay_ticks` after the
//!   broadcast; arrivals are ordered by `(tick, id)`. No wall clock
//!   anywhere in this module (the CLI measures real rounds/sec around
//!   it), so a schedule replays bit-for-bit.
//! * **Churn** — each sampled device flips a seeded coin to go dark for
//!   the round (position 0 is exempt, mirroring the dropout model's
//!   guaranteed survivor, so a round can always aggregate).
//! * **Sync mode** — arrivals after `deadline_ticks` are the engine's
//!   straggler-dropout path: their uplinks are void. If *every* arrival
//!   blows the deadline the earliest one folds anyway (a round with
//!   zero uplinks cannot aggregate).
//! * **Buffered mode** — nothing is dropped: every uplink carried from
//!   an earlier round folds first, sorted by `(trained_round, id)` and
//!   staleness-discounted via [`ServerLogic::fold_uplink_stale`]; fresh
//!   arrivals then fold in `(tick, id)` order until `K` total folds,
//!   and the rest carry to the next round tagged with the round they
//!   trained against. The carry buffer is bounded by one cohort.
//! * **Edge tier** — with `edges=N`, fresh arrivals route through
//!   cohort-local [`EdgeAggregator`]s whose merged [`AggregateMsg`]
//!   envelopes cross the (simulated) uplink — the same
//!   serialize/validate/fold path the engine and session use.
//!
//! Uplinks are synthesized, not trained: integer `|D_i|` weights and
//! 0/1 / ±1 / dyadic-grid payloads keep every fold grouping-exact (see
//! DESIGN.md §Fleet), so the simulator doubles as the determinism and
//! hierarchy-equivalence test bed for every strategy family.
//!
//! audit: deterministic

use anyhow::{ensure, Result};

use crate::algos::spafl::filters_from_layers;
use crate::algos::{
    EvalModel, FedAvg, FedMrn, MaskMode, MaskStrategy, ServerLogic, SignSgd, SpaFl,
};
use crate::compress::{self, DownlinkMode};
use crate::config::{Aggregation, Algorithm};
use crate::fl::aggregator::{AggKind, AggregateMsg, EdgeAggregator};
use crate::fl::protocol::{RoundPlan, UplinkMsg, UplinkPayload};
use crate::fl::{Participation, RoundComm};
use crate::mask::{LayerSlice, LayerSpec};
use crate::util::{BitVec, SeedSequence, Xoshiro256};

/// Per-device compute latency in **virtual ticks**: a device sampled
/// into a round finishes local training `base + seeded jitter` ticks
/// after the broadcast. Shared with [`crate::fl::session::DeviceOpts`],
/// where it drives the deterministic self-straggler path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DelayProfile {
    /// Deterministic floor of the device's compute latency.
    pub base: u64,
    /// Upper bound on the seeded per-round jitter added to `base`.
    pub jitter: u64,
}

impl DelayProfile {
    /// Derive a device's profile from the fleet seed: a seeded speed
    /// class scales the fleet-wide `base`/`jitter` by 1/2/4/8, giving
    /// the heavy-tailed straggler mix real fleets show.
    pub fn for_device(seed: u64, device: u64, base: u64, jitter: u64) -> Self {
        let s = SeedSequence::new(seed).child(0xDE7A).child(device).seed();
        let mult = 1u64 << Xoshiro256::new(s).below(4);
        Self { base: base * mult, jitter: jitter * mult }
    }

    /// Ticks from broadcast to uplink for (`device`, `round`) — a pure
    /// function of the seed path, so every schedule replays exactly.
    pub fn delay_ticks(&self, seed: u64, device: u64, round: u64) -> u64 {
        if self.jitter == 0 {
            return self.base;
        }
        let s = SeedSequence::new(seed).child(0xD11A).child(device).child(round).seed();
        self.base + Xoshiro256::new(s).below(self.jitter + 1)
    }
}

/// Everything one simulated fleet run depends on. Identical opts
/// produce an identical [`FleetReport`], bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetOpts {
    pub devices: usize,
    pub rounds: usize,
    /// Simulated model size (the real model is irrelevant here; small
    /// keeps 100k-device rounds fast while exercising every fold path).
    pub n_params: usize,
    pub algorithm: Algorithm,
    pub aggregation: Aggregation,
    pub staleness_beta: f64,
    /// Edge aggregators per round; 0 = flat folds.
    pub edges: usize,
    pub participation: f64,
    /// Per-round probability a sampled device churns offline (cohort
    /// position 0 is exempt so a round always has an arrival).
    pub churn: f64,
    /// Sync mode: arrivals later than this many ticks after the
    /// broadcast are dropouts (buffered mode carries them instead).
    pub deadline_ticks: u64,
    /// Fleet-wide latency floor before the per-device speed class.
    pub delay_base: u64,
    /// Fleet-wide jitter bound before the per-device speed class.
    pub delay_jitter: u64,
    pub seed: u64,
}

impl FleetOpts {
    /// Defaults sized so the slowest seeded speed class (8x) straddles
    /// the sync deadline: sync runs show real straggler dropouts,
    /// buffered runs show real carried folds.
    pub fn new(devices: usize, rounds: usize) -> Self {
        Self {
            devices,
            rounds,
            n_params: 256,
            algorithm: Algorithm::FedPMReg,
            aggregation: Aggregation::Sync,
            staleness_beta: 1.0,
            edges: 0,
            participation: 1.0,
            churn: 0.01,
            deadline_ticks: 150,
            delay_base: 10,
            delay_jitter: 20,
            seed: 42,
        }
    }
}

/// What one simulated fleet run did. `PartialEq` makes determinism a
/// one-line assertion.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub rounds_completed: usize,
    /// Fresh (same-round) uplink folds, including edge-tier routing.
    pub folds: usize,
    /// Staleness-discounted folds of carried uplinks (buffered mode).
    pub stale_folds: usize,
    /// Sync-mode arrivals that blew the virtual deadline.
    pub dropouts: usize,
    /// Sampled devices that churned offline before training.
    pub churned: usize,
    /// Uplinks still buffered when the run ended.
    pub carried: usize,
    /// Final virtual clock value.
    pub ticks: u64,
    /// FNV-1a digest over the final model's evaluation-view f32 bits.
    pub model_digest: u64,
    /// Last round's mean train loss.
    pub final_loss: f64,
}

/// The server under simulation, constructed directly (no model
/// artifacts): the simulator exercises aggregation semantics, not
/// gradients. Dense baselines start from seeded dyadic-grid weights.
fn build_sim_server(opts: &FleetOpts) -> Box<dyn ServerLogic> {
    let n = opts.n_params;
    match opts.algorithm {
        Algorithm::SignSGD => {
            Box::new(SignSgd::new(sim_dense(n, opts.seed), DownlinkMode::Float32))
        }
        Algorithm::FedAvg => Box::new(FedAvg::new(sim_dense(n, opts.seed), DownlinkMode::Float32)),
        Algorithm::FedMask => Box::new(MaskStrategy::new(n, opts.seed, MaskMode::Deterministic)),
        Algorithm::TopK => Box::new(MaskStrategy::new(n, opts.seed, MaskMode::TopK { frac: 0.3 })),
        Algorithm::FedMRN => Box::new(FedMrn::new(n, opts.seed)),
        Algorithm::SpaFL => Box::new(SpaFl::new(
            sim_dense(n, opts.seed),
            &sim_layers(n),
            DownlinkMode::Float32,
        )),
        Algorithm::FedPMReg | Algorithm::FedPM => {
            Box::new(MaskStrategy::new(n, opts.seed, MaskMode::Stochastic))
        }
    }
}

/// The simulated model's layer telemetry: one Dense block so SpaFL has
/// real column filters (8 strided columns when `n` divides; one
/// whole-row column otherwise). Shared by the sim server and
/// [`synth_uplink`] so the filter counts always agree.
fn sim_layers(n: usize) -> Vec<LayerSlice> {
    let spec = if n >= 8 && n % 8 == 0 {
        LayerSpec::Dense { k: n / 8, n: 8 }
    } else {
        LayerSpec::Dense { k: 1, n }
    };
    vec![LayerSlice { index: 0, spec, offset: 0 }]
}

/// Seeded dyadic-grid floats in [-1, 1): exactly representable, so
/// weighted f64 sums over them are grouping-exact (DESIGN.md §Fleet).
fn sim_dense(n: usize, seed: u64) -> Vec<f32> {
    let s = SeedSequence::new(seed).child(0x57A7).seed();
    let mut rng = Xoshiro256::new(s);
    (0..n).map(|_| (rng.below(2048) as f32 - 1024.0) / 1024.0).collect()
}

/// One device's round product: a wire-faithful [`UplinkMsg`] that is a
/// pure function of `(seed, device, round)` — integer `|D_i|` weight in
/// `1..=16`, payload matched to the strategy's [`AggKind`].
fn synth_uplink(kind: AggKind, n: usize, seed: u64, device: u64, round: usize) -> UplinkMsg {
    let s = SeedSequence::new(seed).child(0x0731).child(device).child(round as u64).seed();
    let mut rng = Xoshiro256::new(s);
    let weight = (1 + rng.below(16)) as f64;
    let train_loss = 0.1 + rng.next_f32() * 0.9;
    let payload = match kind {
        AggKind::MaskSum => {
            let m = BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < 0.3), n);
            UplinkPayload::CodedMask(compress::encode(&m))
        }
        AggKind::SignTally => {
            let m = BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < 0.5), n);
            UplinkPayload::SignVector(compress::encode(&m))
        }
        AggKind::DenseSum => {
            let w = (0..n).map(|_| (rng.below(2048) as f32 - 1024.0) / 1024.0).collect();
            UplinkPayload::DenseDelta(w)
        }
        AggKind::NoiseMaskSum => {
            // density 1/2 keeps the folded theta straddling the 0.5 eval
            // threshold, so the final mask (and digest) stays seed-rich
            let m = BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < 0.5), n);
            UplinkPayload::NoiseMask(compress::encode(&m))
        }
        AggKind::ThresholdSum => {
            // one non-negative dyadic threshold per simulated filter —
            // exact under weighted f64 folds, like every other payload
            let n_filters = filters_from_layers(&sim_layers(n), n).len();
            let tau = (0..n_filters).map(|_| rng.below(1024) as f32 / 1024.0).collect();
            UplinkPayload::Thresholds(tau)
        }
    };
    UplinkMsg { weight, train_loss, trained_round: round as u64, payload }
}

/// Fold one round's fresh arrivals — flat, or through a cohort-local
/// edge tier whose merged envelopes cross the (simulated) uplink wire.
fn fold_fresh(
    server: &mut dyn ServerLogic,
    arrivals: &[(u64, u64, UplinkMsg)],
    plan: &RoundPlan,
    opts: &FleetOpts,
    comm: &mut RoundComm,
) -> Result<()> {
    let n_edges = opts.edges.min(arrivals.len());
    if n_edges == 0 {
        for (_, _, up) in arrivals {
            server.fold_uplink(up, comm)?;
        }
        return Ok(());
    }
    let mut tier: Vec<EdgeAggregator> = (0..n_edges)
        .map(|_| EdgeAggregator::new(server.agg_kind(), opts.n_params))
        .collect();
    for (pos, (_, _, up)) in arrivals.iter().enumerate() {
        let e = pos * n_edges / arrivals.len();
        tier[e].fold(up, plan.round, opts.staleness_beta)?;
    }
    for edge in &tier {
        if edge.reporters() == 0 {
            continue;
        }
        let agg = AggregateMsg::from_bytes(&edge.finish().to_bytes())?;
        server.fold_aggregate(&agg, comm)?;
    }
    Ok(())
}

/// Run one simulated fleet to completion.
pub fn run_fleet(opts: &FleetOpts) -> Result<FleetReport> {
    ensure!(opts.devices > 0, "fleet needs at least one device");
    ensure!(opts.rounds > 0, "fleet needs at least one round");
    ensure!(opts.n_params > 0, "fleet needs a non-empty model");
    let mut server = build_sim_server(opts);
    let kind = server.agg_kind();
    let participation = Participation::new(opts.participation, 0.0);
    let profiles: Vec<DelayProfile> = (0..opts.devices)
        .map(|d| DelayProfile::for_device(opts.seed, d as u64, opts.delay_base, opts.delay_jitter))
        .collect();
    let buffered_k = match opts.aggregation {
        Aggregation::Buffered { k } => Some(k.max(1)),
        Aggregation::Sync => None,
    };
    let mut report = FleetReport {
        rounds_completed: 0,
        folds: 0,
        stale_folds: 0,
        dropouts: 0,
        churned: 0,
        carried: 0,
        ticks: 0,
        model_digest: 0,
        final_loss: 0.0,
    };
    // Uplinks trained in an earlier round, awaiting their buffered fold.
    let mut stale_buf: Vec<(u64, UplinkMsg)> = Vec::new();
    let mut now = 0u64;
    for round in 1..=opts.rounds {
        let plan = RoundPlan {
            round,
            seed: opts.seed,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.1,
            adam: false,
        };
        let mut comm = RoundComm::new(opts.n_params);
        let _broadcast = server.begin_round(&plan)?;
        let cohort = participation.sample_round(opts.devices, opts.seed, round);
        let churn_seed = SeedSequence::new(opts.seed).child(0xC4E1).child(round as u64).seed();
        let mut churn_rng = Xoshiro256::new(churn_seed);
        let mut arrivals: Vec<(u64, u64, UplinkMsg)> = Vec::with_capacity(cohort.len());
        for (pos, &dev) in cohort.iter().enumerate() {
            if churn_rng.next_f64() < opts.churn && pos != 0 {
                report.churned += 1;
                continue;
            }
            let delay = profiles[dev].delay_ticks(opts.seed, dev as u64, round as u64);
            let up = synth_uplink(kind, opts.n_params, opts.seed, dev as u64, round);
            arrivals.push((now + delay, dev as u64, up));
        }
        arrivals.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let round_end;
        let fresh = if let Some(k) = buffered_k {
            // (1) Every carried uplink folds first, oldest rounds first,
            // staleness-discounted; they count toward this round's K.
            stale_buf.sort_by(|a, b| (a.1.trained_round, a.0).cmp(&(b.1.trained_round, b.0)));
            let mut folded = 0usize;
            for (_, up) in stale_buf.drain(..) {
                server.fold_uplink_stale(&up, &plan, opts.staleness_beta, &mut comm)?;
                report.stale_folds += 1;
                folded += 1;
            }
            // (2) Fresh arrivals fold in (tick, id) order until K total
            // folds; the rest carry, tagged with their training round.
            let take = k.saturating_sub(folded).min(arrivals.len());
            let mut fresh = arrivals;
            let rest = fresh.split_off(take);
            round_end = fresh.last().map_or(now, |a| a.0).max(now + 1);
            for (_, dev, up) in rest {
                stale_buf.push((dev, up));
            }
            fresh
        } else {
            // Sync barrier: the engine's straggler-deadline semantics.
            let deadline = now + opts.deadline_ticks;
            let (on_time, mut late): (Vec<_>, Vec<_>) =
                arrivals.into_iter().partition(|a| a.0 <= deadline);
            let on_time = if on_time.is_empty() {
                // A round with zero uplinks cannot aggregate: the
                // earliest straggler folds anyway, deterministically.
                vec![late.remove(0)]
            } else {
                on_time
            };
            report.dropouts += late.len();
            round_end = if late.is_empty() {
                on_time.last().map_or(now, |a| a.0).max(now + 1)
            } else {
                deadline
            };
            on_time
        };
        fold_fresh(&mut *server, &fresh, &plan, opts, &mut comm)?;
        report.folds += fresh.len();
        let stats = server.end_round(&plan)?;
        report.final_loss = stats.train_loss;
        report.rounds_completed = round;
        now = round_end;
    }
    report.carried = stale_buf.len();
    report.ticks = now;
    report.model_digest = match server.eval_model(opts.rounds) {
        EvalModel::Masked(w) | EvalModel::Dense(w) => fnv1a_f32(&w),
    };
    Ok(report)
}

/// FNV-1a over the little-endian bit patterns of an f32 slice.
fn fnv1a_f32(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(algorithm: Algorithm) -> FleetOpts {
        FleetOpts { n_params: 64, algorithm, churn: 0.05, ..FleetOpts::new(200, 4) }
    }

    #[test]
    fn same_opts_same_report_bit_for_bit() {
        for algo in [
            Algorithm::FedPMReg,
            Algorithm::SignSGD,
            Algorithm::FedAvg,
            Algorithm::FedMRN,
            Algorithm::SpaFL,
        ] {
            for agg in [Aggregation::Sync, Aggregation::Buffered { k: 64 }] {
                let mut o = opts(algo);
                o.aggregation = agg;
                let a = run_fleet(&o).unwrap();
                let b = run_fleet(&o).unwrap();
                assert_eq!(a, b, "{algo:?}/{agg:?} must replay bit-for-bit");
                assert_eq!(a.rounds_completed, 4);
                let mut reseeded = o.clone();
                reseeded.seed ^= 1;
                let c = run_fleet(&reseeded).unwrap();
                assert_ne!(a.model_digest, c.model_digest, "the seed must matter");
            }
        }
    }

    #[test]
    fn buffered_mode_carries_stragglers_sync_drops_them() {
        let mut o = opts(Algorithm::FedPMReg);
        o.churn = 0.0;
        o.deadline_ticks = 30; // slower speed classes always blow this
        let sync = run_fleet(&o).unwrap();
        assert!(sync.dropouts > 0, "tight deadline must produce sync dropouts");
        assert_eq!(sync.stale_folds, 0);
        assert_eq!(sync.carried, 0);
        o.aggregation = Aggregation::Buffered { k: 150 };
        let buf = run_fleet(&o).unwrap();
        assert_eq!(buf.dropouts, 0, "buffered mode never voids an uplink");
        assert!(buf.stale_folds > 0, "carried uplinks must fold in later rounds");
        assert!(
            buf.folds + buf.stale_folds + buf.carried > sync.folds,
            "buffered mode must recover contributions sync dropped"
        );
    }

    #[test]
    fn edge_tier_is_bit_identical_to_flat_folds() {
        for algo in [
            Algorithm::FedPMReg,
            Algorithm::SignSGD,
            Algorithm::FedAvg,
            Algorithm::FedMRN,
            Algorithm::SpaFL,
        ] {
            let flat = opts(algo);
            let mut edged = flat.clone();
            edged.edges = 7;
            let a = run_fleet(&flat).unwrap();
            let b = run_fleet(&edged).unwrap();
            assert_eq!(a.model_digest, b.model_digest, "{algo:?}: edge fold changed the model");
            assert_eq!(a.folds, b.folds);
            // loss is a plain f64 sum: merging per-edge partial sums may
            // differ in the last ulp, never more
            assert!((a.final_loss - b.final_loss).abs() < 1e-9);
        }
    }

    #[test]
    fn delay_profiles_are_heterogeneous_and_pure() {
        let p = DelayProfile::for_device(7, 0, 10, 20);
        assert_eq!(p, DelayProfile::for_device(7, 0, 10, 20));
        let classes: std::collections::BTreeSet<u64> =
            (0..64).map(|d| DelayProfile::for_device(7, d, 10, 20).base).collect();
        assert!(classes.len() > 1, "a fleet must mix speed classes");
        let t = p.delay_ticks(7, 0, 3);
        assert_eq!(t, p.delay_ticks(7, 0, 3), "delay is pure in (seed, id, round)");
        assert!(t >= p.base && t <= p.base + p.jitter);
        let flat = DelayProfile { base: 5, jitter: 0 };
        assert_eq!(flat.delay_ticks(7, 1, 1), 5);
    }
}
