//! The federation wire protocol: typed, versioned server/client messages.
//!
//! The paper's whole contribution is a wire format — ≤ 1 bit-per-parameter
//! coded masks instead of floats — so the protocol is first-class: a round
//! is an exchange of [`DownlinkMsg`] (server -> fleet) and [`UplinkMsg`]
//! (device -> server) envelopes, each with a versioned, self-describing
//! byte layout (`to_bytes` / `from_bytes`) that validates every recorded
//! length and value range before trusting a payload — exactly like
//! [`crate::compress::decode`] does for the mask codec. Nothing else ever
//! needs to cross a network boundary:
//!
//! * **Downlink** — one broadcast per round: raw f32 weights (the dense
//!   baselines), a coded delta frame (`downlink=qdelta`, a link in the
//!   stateful chain of DESIGN.md §Downlink), a theta broadcast (the
//!   mask family's global probability mask), or a noise-theta broadcast
//!   (FedMRN: theta plus the frozen-noise seed the device expands
//!   locally — the noise tensor itself never crosses the wire).
//! * **Uplink** — one envelope per device: an entropy-coded binary mask
//!   (FedPM family), a coded sign vector (MV-SignSGD), a dense f32
//!   delta (FedAvg), a coded mask over frozen noise (FedMRN), or a
//!   per-filter pruning-threshold vector (SpaFL, orders of magnitude
//!   below 1 Bpp), plus the |D_i| aggregation weight and the local
//!   train loss the server folds into its round stats.
//! * **[`RoundPlan`]** — the typed per-round hyperparameter set the
//!   server side owns (replaces the old `RoundCtx` grab-bag); it is
//!   serializable too so a transport can ship it next to the broadcast.
//!
//! The server never materializes a cohort of uplinks: the strategies'
//! `fold_uplink` (see [`crate::algos`]) consumes envelopes one at a time
//! as they land, keeping server memory O(n_params) — the streaming-fold
//! contract described in DESIGN.md §Protocol.
//!
//! audit: wire-decode, deterministic

use anyhow::{bail, ensure, Context, Result};

use crate::compress::{DownlinkEncoder, DownlinkFrame, DownlinkMode, Encoded};

/// Wire-format version stamped on every envelope. Encoders always write
/// the current version; decoders accept the back-compat window
/// [`PROTOCOL_VERSION_MIN`]..=[`PROTOCOL_VERSION`] — anything outside it
/// is a hard decode error, never a silent reinterpretation. v2 added the
/// uplink `trained_round` staleness tag (buffered-async aggregation); a
/// v1 uplink decodes with [`UplinkMsg::FRESH`].
pub const PROTOCOL_VERSION: u8 = 2;
/// Oldest wire-format version decoders still accept.
pub const PROTOCOL_VERSION_MIN: u8 = 1;

const DL_RAW_F32: u8 = 0;
const DL_FRAME: u8 = 1;
const DL_THETA: u8 = 2;
/// v2-only: theta + frozen-noise seed (FedMRN).
const DL_NOISE_THETA: u8 = 3;

const UL_CODED_MASK: u8 = 0;
const UL_SIGN_VECTOR: u8 = 1;
const UL_DENSE_DELTA: u8 = 2;
/// v2-only: coded mask over frozen noise (FedMRN).
const UL_NOISE_MASK: u8 = 3;
/// v2-only: per-filter pruning thresholds (SpaFL).
const UL_THRESHOLDS: u8 = 4;

/// Envelope header size shared by both directions: version + kind bytes.
const ENVELOPE_HEAD: usize = 2;
/// v1 uplink header: envelope head + f64 weight + f32 train loss.
const UPLINK_HEAD_V1: usize = ENVELOPE_HEAD + 8 + 4;
/// v2 uplink header: v1 head + u64 trained_round staleness tag.
const UPLINK_HEAD: usize = UPLINK_HEAD_V1 + 8;

fn put_f32s(out: &mut Vec<u8>, values: &[f32]) {
    // audit:checked(a weight/state vector is far below 2^32 entries by model geometry)
    out.extend_from_slice(&(values.len() as u32).to_le_bytes());
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Read a `u32 n` + `n` f32 payload occupying the whole remainder.
fn take_f32s(bytes: &[u8], what: &str) -> Result<Vec<f32>> {
    ensure!(bytes.len() >= 4, "{what} length field truncated");
    let n = u32::from_le_bytes(bytes[..4].try_into()?) as usize;
    ensure!(
        bytes.len() == 4 + 4 * n,
        "{what} records {n} values but carries {} payload bytes",
        bytes.len() - 4
    );
    let mut values = Vec::with_capacity(n);
    for chunk in bytes[4..].chunks_exact(4) {
        values.push(f32::from_le_bytes(chunk.try_into()?));
    }
    Ok(values)
}

fn check_header(bytes: &[u8], what: &str) -> Result<u8> {
    ensure!(bytes.len() >= ENVELOPE_HEAD, "{what} envelope truncated ({} bytes)", bytes.len());
    ensure!(
        (PROTOCOL_VERSION_MIN..=PROTOCOL_VERSION).contains(&bytes[0]),
        "{what} protocol version {} outside supported {PROTOCOL_VERSION_MIN}..={PROTOCOL_VERSION}",
        bytes[0]
    );
    Ok(bytes[1])
}

/// One server -> fleet broadcast as it travels on the wire.
#[derive(Debug, Clone)]
pub enum DownlinkMsg {
    /// Raw f32 global weights (dense baselines, `downlink=float32`).
    RawF32(Vec<f32>),
    /// A coded downlink frame: a link in the `downlink=qdelta` chain
    /// (or its dense bootstrap). Decoding needs the state the device
    /// reconstructed from the previous frame.
    Frame(DownlinkFrame),
    /// The mask family's global probability mask theta in [0,1]^n
    /// (`downlink=float32`).
    Theta(Vec<f32>),
    /// FedMRN's broadcast (v2-only): the global mask probabilities plus
    /// the seed of the frozen noise tensor the mask selects from. The
    /// reconstruction contract differs from [`DownlinkMsg::Theta`]: the
    /// device expands `noise_seed` into the full noise tensor locally
    /// (`algos::fedmrn::noise_from_seed`), so the n-element noise vector
    /// never crosses the wire — only its 8-byte seed does.
    NoiseTheta {
        /// Seed of the frozen noise tensor shared by server and fleet.
        noise_seed: u64,
        /// Global mask probabilities in [0,1]^n.
        theta: Vec<f32>,
    },
}

impl DownlinkMsg {
    /// Encode the next broadcast of `state` through `dl`, the one place
    /// wire mode maps to message kind: stateless raw values under
    /// `Float32` ([`DownlinkMsg::Theta`] when `probability_mask`,
    /// [`DownlinkMsg::RawF32`] otherwise), a coded chain link under
    /// `QDelta` (advancing the fleet-side reconstruction `dl` tracks).
    pub fn broadcast(dl: &mut DownlinkEncoder, state: &[f32], probability_mask: bool) -> Self {
        match dl.mode() {
            DownlinkMode::Float32 if probability_mask => DownlinkMsg::Theta(state.to_vec()),
            DownlinkMode::Float32 => DownlinkMsg::RawF32(state.to_vec()),
            DownlinkMode::QDelta { .. } => DownlinkMsg::Frame(dl.encode_frame(state)),
        }
    }

    pub fn kind_name(&self) -> &'static str {
        match self {
            DownlinkMsg::RawF32(_) => "raw_f32",
            DownlinkMsg::Frame(_) => "frame",
            DownlinkMsg::Theta(_) => "theta",
            DownlinkMsg::NoiseTheta { .. } => "noise_theta",
        }
    }

    /// Parameter count this broadcast covers.
    pub fn n(&self) -> usize {
        match self {
            DownlinkMsg::RawF32(v) | DownlinkMsg::Theta(v) => v.len(),
            DownlinkMsg::Frame(f) => f.n(),
            DownlinkMsg::NoiseTheta { theta, .. } => theta.len(),
        }
    }

    /// Exact serialized envelope size in bytes — what the communication
    /// accounting records per receiving device.
    pub fn wire_bytes(&self) -> usize {
        match self {
            DownlinkMsg::RawF32(v) | DownlinkMsg::Theta(v) => ENVELOPE_HEAD + 4 + 4 * v.len(),
            DownlinkMsg::Frame(f) => ENVELOPE_HEAD + 4 + f.wire_bytes(),
            DownlinkMsg::NoiseTheta { theta, .. } => ENVELOPE_HEAD + 8 + 4 + 4 * theta.len(),
        }
    }

    pub fn wire_bits(&self) -> u64 {
        self.wire_bytes() as u64 * 8
    }

    /// Serialize to the flat little-endian wire layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.push(PROTOCOL_VERSION);
        match self {
            DownlinkMsg::RawF32(v) => {
                out.push(DL_RAW_F32);
                put_f32s(&mut out, v);
            }
            DownlinkMsg::Frame(f) => {
                out.push(DL_FRAME);
                let fb = f.to_bytes();
                // audit:checked(a downlink frame is capped well below 2^32 wire bytes)
                out.extend_from_slice(&(fb.len() as u32).to_le_bytes());
                out.extend_from_slice(&fb);
            }
            DownlinkMsg::Theta(v) => {
                out.push(DL_THETA);
                put_f32s(&mut out, v);
            }
            DownlinkMsg::NoiseTheta { noise_seed, theta } => {
                out.push(DL_NOISE_THETA);
                out.extend_from_slice(&noise_seed.to_le_bytes());
                put_f32s(&mut out, theta);
            }
        }
        out
    }

    /// Parse and validate a broadcast. Every recorded length is checked
    /// against the bytes actually present, values must be finite (theta
    /// additionally in [0,1]), and an unknown kind or version mismatch
    /// is an error — truncated or corrupt envelopes never decode.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let kind = check_header(bytes, "downlink")?;
        // Kinds introduced with v2 never decode from a v1-stamped
        // envelope — a v1 peer cannot have produced them, so the stamp
        // is corruption, not back-compat.
        ensure!(
            bytes[0] >= 2 || kind < DL_NOISE_THETA,
            "downlink kind {kind} requires protocol v2, envelope is v{}",
            bytes[0]
        );
        let body = &bytes[ENVELOPE_HEAD..];
        match kind {
            DL_RAW_F32 => {
                let values = take_f32s(body, "raw-f32 downlink")?;
                ensure!(
                    values.iter().all(|v| v.is_finite()),
                    "raw-f32 downlink carries non-finite weights"
                );
                Ok(DownlinkMsg::RawF32(values))
            }
            DL_THETA => {
                let theta = take_f32s(body, "theta downlink")?;
                ensure!(
                    theta.iter().all(|t| t.is_finite() && (0.0..=1.0).contains(t)),
                    "theta downlink carries values outside [0,1]"
                );
                Ok(DownlinkMsg::Theta(theta))
            }
            DL_FRAME => {
                ensure!(body.len() >= 4, "frame downlink length field truncated");
                let flen = u32::from_le_bytes(body[..4].try_into()?) as usize;
                ensure!(
                    body.len() == 4 + flen,
                    "frame downlink records {flen} frame bytes but carries {}",
                    body.len() - 4
                );
                let frame =
                    DownlinkFrame::from_bytes(&body[4..]).context("downlink frame body")?;
                Ok(DownlinkMsg::Frame(frame))
            }
            DL_NOISE_THETA => {
                ensure!(body.len() >= 8, "noise-theta downlink seed field truncated");
                let noise_seed = u64::from_le_bytes(bytes[2..10].try_into()?);
                let theta = take_f32s(&body[8..], "noise-theta downlink")?;
                ensure!(
                    theta.iter().all(|t| t.is_finite() && (0.0..=1.0).contains(t)),
                    "noise-theta downlink carries values outside [0,1]"
                );
                Ok(DownlinkMsg::NoiseTheta { noise_seed, theta })
            }
            other => bail!("unknown downlink message kind {other}"),
        }
    }

    /// Decode the broadcast into the state a device now holds. Delta
    /// frames need `prev` — the state this device reconstructed from the
    /// previous broadcast; stateless kinds only check it for shape.
    pub fn decode_state(&self, prev: Option<&[f32]>) -> Result<Vec<f32>> {
        match self {
            DownlinkMsg::RawF32(v)
            | DownlinkMsg::Theta(v)
            | DownlinkMsg::NoiseTheta { theta: v, .. } => {
                if let Some(p) = prev {
                    ensure!(
                        p.len() == v.len(),
                        "broadcast for {} params, device holds {}",
                        v.len(),
                        p.len()
                    );
                }
                Ok(v.clone())
            }
            DownlinkMsg::Frame(f) => f.decode(prev),
        }
    }
}

/// What one device's uplink envelope carries.
#[derive(Debug, Clone)]
pub enum UplinkPayload {
    /// Entropy-coded binary mask (the FedPM family — the paper's wire).
    CodedMask(Encoded),
    /// Coded gradient-sign vector (MV-SignSGD, ~1 Bpp).
    SignVector(Encoded),
    /// Dense f32 local model (FedAvg, the 32 Bpp reference point).
    DenseDelta(Vec<f32>),
    /// Entropy-coded binary mask over the frozen noise tensor (FedMRN,
    /// v2-only). Same coded layout as [`UplinkPayload::CodedMask`] but a
    /// distinct kind: the bits select noise entries, not magnitudes, and
    /// only a [`DownlinkMsg::NoiseTheta`]-speaking server may fold it.
    NoiseMask(Encoded),
    /// Per-filter pruning thresholds (SpaFL, v2-only): one finite
    /// non-negative f32 per filter of the layer graph — orders of
    /// magnitude fewer entries than the model has parameters.
    Thresholds(Vec<f32>),
}

impl UplinkPayload {
    pub fn kind_name(&self) -> &'static str {
        match self {
            UplinkPayload::CodedMask(_) => "coded_mask",
            UplinkPayload::SignVector(_) => "sign_vector",
            UplinkPayload::DenseDelta(_) => "dense_delta",
            UplinkPayload::NoiseMask(_) => "noise_mask",
            UplinkPayload::Thresholds(_) => "thresholds",
        }
    }
}

/// One device -> server uplink as it travels on the wire.
#[derive(Debug, Clone)]
pub struct UplinkMsg {
    /// |D_i| aggregation weight (eq. 8 numerator).
    pub weight: f64,
    /// Mean local train loss — rides the envelope so the server's round
    /// stats need no side channel.
    pub train_loss: f32,
    /// The round this uplink trained against (v2 staleness tag). Under
    /// buffered-async aggregation the server folds envelopes whose tag
    /// trails the current round with a discounted weight instead of
    /// dropping them. [`UplinkMsg::FRESH`] marks an always-fresh uplink
    /// (and every decoded v1 envelope): `round.saturating_sub(FRESH)`
    /// is 0, so the discount path is a no-op.
    pub trained_round: u64,
    pub payload: UplinkPayload,
}

impl UplinkMsg {
    /// `trained_round` sentinel meaning "never stale" — the value every
    /// v1 envelope decodes with.
    pub const FRESH: u64 = u64::MAX;

    /// Exact serialized envelope size in bytes — what the communication
    /// accounting records per received uplink.
    pub fn wire_bytes(&self) -> usize {
        UPLINK_HEAD
            + match &self.payload {
                UplinkPayload::CodedMask(e)
                | UplinkPayload::SignVector(e)
                | UplinkPayload::NoiseMask(e) => 4 + e.wire_bytes(),
                UplinkPayload::DenseDelta(v) | UplinkPayload::Thresholds(v) => 4 + 4 * v.len(),
            }
    }

    pub fn wire_bits(&self) -> u64 {
        self.wire_bytes() as u64 * 8
    }

    /// Serialize to the flat little-endian wire layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.push(PROTOCOL_VERSION);
        let kind = match &self.payload {
            UplinkPayload::CodedMask(_) => UL_CODED_MASK,
            UplinkPayload::SignVector(_) => UL_SIGN_VECTOR,
            UplinkPayload::DenseDelta(_) => UL_DENSE_DELTA,
            UplinkPayload::NoiseMask(_) => UL_NOISE_MASK,
            UplinkPayload::Thresholds(_) => UL_THRESHOLDS,
        };
        out.push(kind);
        out.extend_from_slice(&self.weight.to_le_bytes());
        out.extend_from_slice(&self.train_loss.to_le_bytes());
        out.extend_from_slice(&self.trained_round.to_le_bytes());
        match &self.payload {
            UplinkPayload::CodedMask(e)
            | UplinkPayload::SignVector(e)
            | UplinkPayload::NoiseMask(e) => {
                let eb = e.to_bytes();
                // audit:checked(a coded mask is at most ~n/8 bytes, far below 2^32)
                out.extend_from_slice(&(eb.len() as u32).to_le_bytes());
                out.extend_from_slice(&eb);
            }
            UplinkPayload::DenseDelta(v) | UplinkPayload::Thresholds(v) => {
                put_f32s(&mut out, v)
            }
        }
        out
    }

    /// Parse and validate an uplink envelope: version, kind, a positive
    /// finite weight, a finite train loss, and a payload whose recorded
    /// lengths match the bytes present (coded payloads re-validate their
    /// own headers through [`Encoded::from_bytes`]).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let kind = check_header(bytes, "uplink")?;
        // v2-introduced kinds (noise mask, thresholds) never decode from
        // a v1-stamped envelope: no v1 peer could have produced them.
        ensure!(
            bytes[0] >= 2 || kind < UL_NOISE_MASK,
            "uplink kind {kind} requires protocol v2, envelope is v{}",
            bytes[0]
        );
        let head = if bytes[0] >= 2 { UPLINK_HEAD } else { UPLINK_HEAD_V1 };
        ensure!(bytes.len() >= head, "uplink header truncated ({} bytes)", bytes.len());
        let weight = f64::from_le_bytes(bytes[2..10].try_into()?);
        ensure!(
            weight.is_finite() && weight > 0.0,
            "uplink aggregation weight {weight} must be a positive finite |D_i|"
        );
        let train_loss = f32::from_le_bytes(bytes[10..14].try_into()?);
        ensure!(train_loss.is_finite(), "uplink train loss {train_loss} not finite");
        let (trained_round, body) = if bytes[0] >= 2 {
            (u64::from_le_bytes(bytes[14..22].try_into()?), &bytes[UPLINK_HEAD..])
        } else {
            // v1 envelopes predate the staleness tag: always fresh.
            (Self::FRESH, &bytes[UPLINK_HEAD_V1..])
        };
        let payload = match kind {
            UL_CODED_MASK | UL_SIGN_VECTOR | UL_NOISE_MASK => {
                ensure!(body.len() >= 4, "uplink payload length field truncated");
                let elen = u32::from_le_bytes(body[..4].try_into()?) as usize;
                ensure!(
                    body.len() == 4 + elen,
                    "uplink records {elen} coded bytes but carries {}",
                    body.len() - 4
                );
                let enc = Encoded::from_bytes(&body[4..]).context("uplink coded payload")?;
                match kind {
                    UL_CODED_MASK => UplinkPayload::CodedMask(enc),
                    UL_SIGN_VECTOR => UplinkPayload::SignVector(enc),
                    _ => UplinkPayload::NoiseMask(enc),
                }
            }
            UL_DENSE_DELTA => {
                let values = take_f32s(body, "dense uplink")?;
                ensure!(
                    values.iter().all(|v| v.is_finite()),
                    "dense uplink carries non-finite values"
                );
                UplinkPayload::DenseDelta(values)
            }
            UL_THRESHOLDS => {
                let values = take_f32s(body, "thresholds uplink")?;
                ensure!(
                    values.iter().all(|v| v.is_finite() && *v >= 0.0),
                    "thresholds uplink carries negative or non-finite values"
                );
                UplinkPayload::Thresholds(values)
            }
            other => bail!("unknown uplink message kind {other}"),
        };
        Ok(Self { weight, train_loss, trained_round, payload })
    }
}

/// Typed per-round hyperparameters, owned by the server side and handed
/// to every [`crate::algos::ClientTask`] next to the broadcast. This is
/// the protocol's replacement for the old in-process `RoundCtx` field
/// grab-bag: plain data, no runtime references, serializable so a real
/// transport can ship it with the downlink.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundPlan {
    /// 1-based communication round index.
    pub round: usize,
    /// Root experiment seed (participation sampling, mask streams).
    pub seed: u64,
    /// Regularizer strength lambda (eq. 12).
    pub lambda: f32,
    /// Local score-SGD learning rate.
    pub lr: f32,
    /// Local epochs per round.
    pub local_epochs: usize,
    /// Top-k keep fraction (TopK uplink mode).
    pub topk_frac: f64,
    /// Server / dense-baseline step size.
    pub server_lr: f32,
    /// Optimize local scores with Adam (vs plain SGD).
    pub adam: bool,
}

/// Serialized [`RoundPlan`] size: version + round + seed + lambda + lr +
/// local_epochs + topk_frac + server_lr + adam.
const PLAN_BYTES: usize = 1 + 8 + 8 + 4 + 4 + 4 + 8 + 4 + 1;

impl RoundPlan {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(PLAN_BYTES);
        out.push(PROTOCOL_VERSION);
        out.extend_from_slice(&(self.round as u64).to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.lambda.to_le_bytes());
        out.extend_from_slice(&self.lr.to_le_bytes());
        // audit:checked(local_epochs is a config knob validated to a small count)
        out.extend_from_slice(&(self.local_epochs as u32).to_le_bytes());
        out.extend_from_slice(&self.topk_frac.to_le_bytes());
        out.extend_from_slice(&self.server_lr.to_le_bytes());
        // audit:checked(a bool narrows losslessly into u8)
        out.push(self.adam as u8);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(
            bytes.len() == PLAN_BYTES,
            "round plan must be exactly {PLAN_BYTES} bytes, got {}",
            bytes.len()
        );
        ensure!(
            (PROTOCOL_VERSION_MIN..=PROTOCOL_VERSION).contains(&bytes[0]),
            "round plan protocol version {} outside supported \
             {PROTOCOL_VERSION_MIN}..={PROTOCOL_VERSION}",
            bytes[0]
        );
        let round = u64::from_le_bytes(bytes[1..9].try_into()?) as usize;
        let seed = u64::from_le_bytes(bytes[9..17].try_into()?);
        let lambda = f32::from_le_bytes(bytes[17..21].try_into()?);
        let lr = f32::from_le_bytes(bytes[21..25].try_into()?);
        let local_epochs = u32::from_le_bytes(bytes[25..29].try_into()?) as usize;
        let topk_frac = f64::from_le_bytes(bytes[29..37].try_into()?);
        let server_lr = f32::from_le_bytes(bytes[37..41].try_into()?);
        let adam = match bytes[41] {
            0 => false,
            1 => true,
            other => bail!("round plan adam flag must be 0|1, got {other}"),
        };
        ensure!(lambda.is_finite() && lambda >= 0.0, "round plan lambda {lambda} invalid");
        ensure!(lr.is_finite(), "round plan lr {lr} not finite");
        ensure!(local_epochs >= 1, "round plan local_epochs must be >= 1");
        ensure!(
            topk_frac.is_finite() && (0.0..=1.0).contains(&topk_frac),
            "round plan topk_frac {topk_frac} outside [0,1]"
        );
        ensure!(server_lr.is_finite(), "round plan server_lr {server_lr} not finite");
        Ok(Self { round, seed, lambda, lr, local_epochs, topk_frac, server_lr, adam })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{self, DownlinkEncoder, DownlinkMode};
    use crate::util::{BitVec, Xoshiro256};

    fn uniform(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..n).map(|_| rng.next_f32()).collect()
    }

    fn bits_of(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn delta_frame(n: usize, seed: u64) -> (DownlinkFrame, Vec<f32>) {
        let a = uniform(n, seed);
        let b: Vec<f32> = a.iter().map(|&v| v + 0.03).collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        enc.encode_frame(&a);
        let frame = enc.encode_frame(&b);
        assert!(!frame.is_dense());
        (frame, a)
    }

    #[test]
    fn downlink_kinds_roundtrip_bit_identically() {
        let theta = uniform(777, 1);
        let weights: Vec<f32> = uniform(500, 2).iter().map(|v| v * 4.0 - 2.0).collect();
        let (frame, prev) = delta_frame(600, 3);
        for msg in [
            DownlinkMsg::Theta(theta.clone()),
            DownlinkMsg::RawF32(weights.clone()),
            DownlinkMsg::Frame(frame.clone()),
            DownlinkMsg::NoiseTheta { noise_seed: 0xDEAD_BEEF, theta: theta.clone() },
        ] {
            let bytes = msg.to_bytes();
            assert_eq!(bytes.len(), msg.wire_bytes(), "{}", msg.kind_name());
            let back = DownlinkMsg::from_bytes(&bytes).unwrap();
            assert_eq!(back.kind_name(), msg.kind_name());
            assert_eq!(back.n(), msg.n());
            let prev_ref = match msg {
                DownlinkMsg::Frame(_) => Some(&prev[..]),
                _ => None,
            };
            assert_eq!(
                bits_of(&back.decode_state(prev_ref).unwrap()),
                bits_of(&msg.decode_state(prev_ref).unwrap()),
                "{} state must survive the wire bit-for-bit",
                msg.kind_name()
            );
        }
    }

    #[test]
    fn uplink_kinds_roundtrip_bit_identically() {
        let mask = BitVec::from_iter_len((0..900).map(|i| i % 7 == 0), 900);
        let enc = compress::encode(&mask);
        let dense: Vec<f32> = uniform(300, 5).iter().map(|v| v - 0.5).collect();
        let thresholds: Vec<f32> = uniform(24, 6);
        for payload in [
            UplinkPayload::CodedMask(enc.clone()),
            UplinkPayload::SignVector(enc.clone()),
            UplinkPayload::DenseDelta(dense.clone()),
            UplinkPayload::NoiseMask(enc.clone()),
            UplinkPayload::Thresholds(thresholds.clone()),
        ] {
            let msg = UplinkMsg { weight: 37.0, train_loss: 1.25, trained_round: 12, payload };
            let bytes = msg.to_bytes();
            assert_eq!(bytes.len(), msg.wire_bytes(), "{}", msg.payload.kind_name());
            let back = UplinkMsg::from_bytes(&bytes).unwrap();
            assert_eq!(back.weight.to_bits(), msg.weight.to_bits());
            assert_eq!(back.train_loss.to_bits(), msg.train_loss.to_bits());
            assert_eq!(back.trained_round, 12);
            assert_eq!(back.payload.kind_name(), msg.payload.kind_name());
            match (&back.payload, &msg.payload) {
                (UplinkPayload::CodedMask(a), UplinkPayload::CodedMask(b))
                | (UplinkPayload::SignVector(a), UplinkPayload::SignVector(b))
                | (UplinkPayload::NoiseMask(a), UplinkPayload::NoiseMask(b)) => {
                    assert_eq!(a.to_bytes(), b.to_bytes());
                    assert_eq!(compress::decode(a, mask.len()).unwrap(), mask);
                }
                (UplinkPayload::DenseDelta(a), UplinkPayload::DenseDelta(b))
                | (UplinkPayload::Thresholds(a), UplinkPayload::Thresholds(b)) => {
                    assert_eq!(bits_of(a), bits_of(b));
                }
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut dl = DownlinkMsg::Theta(uniform(10, 7)).to_bytes();
        dl[0] = PROTOCOL_VERSION + 1;
        assert!(DownlinkMsg::from_bytes(&dl).is_err());
        let msg = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; 4]),
        };
        let mut ul = msg.to_bytes();
        ul[0] = 0;
        assert!(UplinkMsg::from_bytes(&ul).is_err());
        let mut plan = plan_fixture().to_bytes();
        plan[0] = 9;
        assert!(RoundPlan::from_bytes(&plan).is_err());
    }

    #[test]
    fn unknown_kinds_and_truncation_rejected() {
        let dl = DownlinkMsg::Theta(uniform(50, 8)).to_bytes();
        let mut bad = dl.clone();
        bad[1] = 9;
        assert!(DownlinkMsg::from_bytes(&bad).is_err());
        for cut in [0, 1, 3, dl.len() - 1] {
            assert!(DownlinkMsg::from_bytes(&dl[..cut]).is_err(), "cut={cut}");
        }
        let ul = UplinkMsg {
            weight: 3.0,
            train_loss: 0.5,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::CodedMask(compress::encode(&BitVec::zeros(64))),
        }
        .to_bytes();
        let mut bad = ul.clone();
        bad[1] = 7;
        assert!(UplinkMsg::from_bytes(&bad).is_err());
        for cut in [0, 5, 13, ul.len() - 1] {
            assert!(UplinkMsg::from_bytes(&ul[..cut]).is_err(), "cut={cut}");
        }
        // trailing bytes are as corrupt as missing ones
        let mut padded = ul;
        padded.push(0);
        assert!(UplinkMsg::from_bytes(&padded).is_err());
    }

    #[test]
    fn value_range_validation() {
        // theta outside [0,1]
        let mut msg = DownlinkMsg::Theta(vec![0.5; 8]);
        if let DownlinkMsg::Theta(t) = &mut msg {
            t[3] = 1.5;
        }
        assert!(DownlinkMsg::from_bytes(&msg.to_bytes()).is_err());
        // non-finite weights
        let raw = DownlinkMsg::RawF32(vec![0.0, f32::NAN]);
        assert!(DownlinkMsg::from_bytes(&raw.to_bytes()).is_err());
        // non-positive / non-finite uplink weight
        for weight in [0.0, -1.0, f64::INFINITY] {
            let msg = UplinkMsg {
                weight,
                train_loss: 0.0,
                trained_round: UplinkMsg::FRESH,
                payload: UplinkPayload::DenseDelta(vec![0.0; 2]),
            };
            assert!(UplinkMsg::from_bytes(&msg.to_bytes()).is_err(), "weight={weight}");
        }
        // thresholds must be finite and non-negative
        for bad in [-0.5f32, f32::NAN] {
            let msg = UplinkMsg {
                weight: 1.0,
                train_loss: 0.0,
                trained_round: UplinkMsg::FRESH,
                payload: UplinkPayload::Thresholds(vec![0.25, bad]),
            };
            assert!(UplinkMsg::from_bytes(&msg.to_bytes()).is_err(), "threshold={bad}");
        }
        // noise-theta values obey the theta range contract
        let bad = DownlinkMsg::NoiseTheta { noise_seed: 1, theta: vec![0.5, 2.0] };
        assert!(DownlinkMsg::from_bytes(&bad.to_bytes()).is_err());
    }

    #[test]
    fn noise_theta_carries_the_seed_across_the_wire() {
        let msg = DownlinkMsg::NoiseTheta { noise_seed: 0x5EED_CAFE, theta: uniform(33, 9) };
        match DownlinkMsg::from_bytes(&msg.to_bytes()).unwrap() {
            DownlinkMsg::NoiseTheta { noise_seed, theta } => {
                assert_eq!(noise_seed, 0x5EED_CAFE);
                assert_eq!(theta.len(), 33);
                // decode_state yields theta and shape-checks prev
                let state = msg.decode_state(Some(&[0.0; 33])).unwrap();
                assert_eq!(bits_of(&state), bits_of(&theta));
                assert!(msg.decode_state(Some(&[0.0; 32])).is_err());
            }
            other => panic!("wrong kind {}", other.kind_name()),
        }
    }

    #[test]
    fn v2_only_kinds_reject_a_v1_stamp() {
        // A v1 peer cannot emit noise-theta / noise-mask / thresholds:
        // a v1-stamped envelope of those kinds must be a decode error,
        // never a silent reinterpretation under the v1 head layout.
        let mut dl =
            DownlinkMsg::NoiseTheta { noise_seed: 3, theta: vec![0.5; 4] }.to_bytes();
        dl[0] = 1;
        assert!(DownlinkMsg::from_bytes(&dl).is_err());
        for payload in [
            UplinkPayload::NoiseMask(compress::encode(&BitVec::zeros(64))),
            UplinkPayload::Thresholds(vec![0.1, 0.2]),
        ] {
            let v2 = UplinkMsg {
                weight: 2.0,
                train_loss: 0.25,
                trained_round: 7,
                payload,
            }
            .to_bytes();
            // v1 splice: drop the trained_round tag, restamp the version
            let mut v1 = Vec::with_capacity(v2.len() - 8);
            v1.extend_from_slice(&v2[..14]);
            v1.extend_from_slice(&v2[22..]);
            v1[0] = 1;
            assert!(UplinkMsg::from_bytes(&v1).is_err());
            // a bare restamp (v2 length, v1 version byte) errors too
            let mut restamped = v2.clone();
            restamped[0] = 1;
            assert!(UplinkMsg::from_bytes(&restamped).is_err());
        }
    }

    #[test]
    fn v1_uplink_decodes_as_fresh() {
        // A v1 envelope has no trained_round field: build one by hand
        // (v2 bytes minus the 8 tag bytes, version byte rewritten) and
        // check it decodes with the FRESH sentinel — the back-compat
        // contract of the v2 bump.
        let msg = UplinkMsg {
            weight: 5.0,
            train_loss: 0.75,
            trained_round: 9,
            payload: UplinkPayload::DenseDelta(vec![0.25, -0.5]),
        };
        let v2 = msg.to_bytes();
        let mut v1 = Vec::with_capacity(v2.len() - 8);
        v1.extend_from_slice(&v2[..14]);
        v1.extend_from_slice(&v2[22..]);
        v1[0] = 1;
        let back = UplinkMsg::from_bytes(&v1).unwrap();
        assert_eq!(back.weight.to_bits(), msg.weight.to_bits());
        assert_eq!(back.train_loss.to_bits(), msg.train_loss.to_bits());
        assert_eq!(back.trained_round, UplinkMsg::FRESH);
        match back.payload {
            UplinkPayload::DenseDelta(v) => assert_eq!(bits_of(&v), bits_of(&[0.25, -0.5])),
            other => panic!("wrong payload kind {}", other.kind_name()),
        }
        // and a truncated v1 head still errors
        assert!(UplinkMsg::from_bytes(&v1[..13]).is_err());
    }

    fn plan_fixture() -> RoundPlan {
        RoundPlan {
            round: 12,
            seed: 2023,
            lambda: 1.5,
            lr: 0.2,
            local_epochs: 3,
            topk_frac: 0.3,
            server_lr: 0.001,
            adam: true,
        }
    }

    #[test]
    fn round_plan_roundtrip_and_validation() {
        let plan = plan_fixture();
        let bytes = plan.to_bytes();
        assert_eq!(bytes.len(), PLAN_BYTES);
        assert_eq!(RoundPlan::from_bytes(&bytes).unwrap(), plan);
        assert!(RoundPlan::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut bad = bytes.clone();
        bad[41] = 2; // adam flag
        assert!(RoundPlan::from_bytes(&bad).is_err());
        let bad_plan = RoundPlan { topk_frac: 1.5, ..plan };
        assert!(RoundPlan::from_bytes(&bad_plan.to_bytes()).is_err());
    }

    #[test]
    fn frame_chain_survives_the_wire() {
        // Two qdelta links shipped as bytes must reproduce the server's
        // reconstruction exactly (the DESIGN.md §Downlink contract, now
        // through the protocol envelope).
        let n = 2000;
        let a = uniform(n, 11);
        let b: Vec<f32> = a.iter().map(|&v| v + 0.01).collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        let m0 = DownlinkMsg::Frame(enc.encode_frame(&a));
        let m1 = DownlinkMsg::Frame(enc.encode_frame(&b));
        let c0 = DownlinkMsg::from_bytes(&m0.to_bytes())
            .unwrap()
            .decode_state(None)
            .unwrap();
        let c1 = DownlinkMsg::from_bytes(&m1.to_bytes())
            .unwrap()
            .decode_state(Some(&c0))
            .unwrap();
        assert_eq!(bits_of(&c1), bits_of(enc.recon()));
    }
}
