//! Simulated edge device: local data shard + local training loop.
//!
//! A client receives the global score vector, runs `local_epochs` of
//! STE-SGD through the PJRT `local_train` program (one call per S
//! minibatches — the scan lives inside the HLO, so the FFI boundary is
//! crossed once per S steps, not per step), and hands back its updated
//! local scores plus train metrics.
//!
//! audit: deterministic

use anyhow::Result;

use crate::data::{BatchSampler, Dataset, Shard};
use crate::runtime::{ModelRuntime, TrainMetrics};
use crate::util::SeedSequence;

/// Per-client seed derivation shared by the in-process experiment and
/// the networked device runtime ([`crate::fl::session::run_device`]): a
/// client's randomness is a pure function of (root experiment seed,
/// client id), which is what lets a remote device process reproduce the
/// simulated fleet bit-for-bit.
pub fn derive_client_seed(root_seed: u64, client_id: usize) -> u64 {
    SeedSequence::new(root_seed).child(0xC11E).child(client_id as u64).seed()
}

/// Per-device state living across rounds.
pub struct Client {
    pub id: usize,
    pub shard: Shard,
    sampler: BatchSampler,
    /// Distinct seed stream per (client, round, call).
    seed_base: u64,
}

impl Client {
    pub fn new(shard: Shard, seed: u64) -> Self {
        let sampler = BatchSampler::new(shard.indices.clone(), seed ^ 0xC11E27);
        let seed_base = seed;
        Self { id: shard.client_id, shard, sampler, seed_base }
    }

    /// |D_i| aggregation weight.
    pub fn weight(&self) -> f64 {
        self.shard.weight()
    }

    /// Steps of SGD in one round: ceil(|D_i| / B) * local_epochs.
    pub fn steps_per_round(&self, batch: usize, local_epochs: usize) -> usize {
        self.shard.len().div_ceil(batch) * local_epochs
    }

    /// Run one local phase. Returns (updated scores, averaged metrics).
    ///
    /// The exported program consumes a fixed `steps` batches per call;
    /// we issue ceil(total_steps / steps) calls, threading the score
    /// vector through (mirrors eq. 6's h-indexed local iterations).
    #[allow(clippy::too_many_arguments)]
    pub fn local_phase(
        &mut self,
        rt: &ModelRuntime,
        data: &Dataset,
        mut scores: Vec<f32>,
        round: usize,
        lambda: f32,
        lr: f32,
        local_epochs: usize,
        deterministic: bool,
        adam: bool,
    ) -> Result<(Vec<f32>, TrainMetrics)> {
        let man = &rt.manifest;
        let total_steps = self.steps_per_round(man.batch, local_epochs).max(1);
        let calls = total_steps.div_ceil(man.steps);

        let mut agg = TrainMetrics { mean_loss: 0.0, correct: 0.0, sum_sigma: 0.0, active: 0.0 };
        for call in 0..calls {
            let (xs, ys) = self.gather_call_batches(data, man.steps, man.batch);
            let seed = self.call_seed(round, call);
            let (s_new, met) =
                rt.local_train(&scores, &xs, &ys, seed, lambda, lr, deterministic, adam)?;
            scores = s_new;
            agg.mean_loss += (met.mean_loss - agg.mean_loss) / (call + 1) as f32;
            agg.correct += met.correct;
            agg.sum_sigma = met.sum_sigma; // final state, not a mean
            agg.active = met.active;
        }
        Ok((scores, agg))
    }

    /// Collect `steps` minibatches of `batch` rows into contiguous
    /// buffers shaped (steps, batch, dim) / (steps, batch).
    pub fn gather_call_batches(
        &mut self,
        data: &Dataset,
        steps: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(steps * batch * data.dim);
        let mut ys = Vec::with_capacity(steps * batch);
        for _ in 0..steps {
            let idx = self.sampler.next_batch(batch);
            let (x, y) = data.gather(&idx);
            xs.extend_from_slice(&x);
            ys.extend_from_slice(&y);
        }
        (xs, ys)
    }

    /// Deterministic, collision-free seed per (client, round, call),
    /// truncated to the i32 the HLO scalar input takes.
    pub fn call_seed(&self, round: usize, call: usize) -> i32 {
        let mut z = self
            .seed_base
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((round as u64) << 20)
            .wrapping_add(call as u64);
        // splitmix finalizer
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        (z ^ (z >> 31)) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_iid, SynthSpec, Synthetic};

    fn setup() -> (Dataset, Client) {
        let data = Synthetic::new(SynthSpec::tiny(), 3).generate(130, 1);
        let shards = partition_iid(&data, 4, 7);
        let client = Client::new(shards[0].clone(), 42);
        (data, client)
    }

    #[test]
    fn steps_per_round_math() {
        let (_, c) = setup();
        // 130/4 -> 33 samples (client 0 gets extra); ceil(33/8)*3 = 15
        assert_eq!(c.shard.len(), 33);
        assert_eq!(c.steps_per_round(8, 3), 15);
        assert_eq!(c.steps_per_round(64, 1), 1);
    }

    #[test]
    fn gather_shapes() {
        let (data, mut c) = setup();
        let (xs, ys) = c.gather_call_batches(&data, 3, 8);
        assert_eq!(xs.len(), 3 * 8 * data.dim);
        assert_eq!(ys.len(), 24);
        // all labels valid
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn gather_draws_only_from_own_shard() {
        let (data, mut c) = setup();
        let own: std::collections::HashSet<usize> = c.shard.indices.iter().copied().collect();
        // label multiset check: every gathered row must match some row in
        // the shard (cheap necessary condition without row identity)
        let (xs, _) = c.gather_call_batches(&data, 2, 8);
        for row in xs.chunks(data.dim) {
            let found = own.iter().any(|&i| data.row(i) == row);
            assert!(found, "gathered row not from shard");
        }
    }

    #[test]
    fn call_seeds_unique_across_rounds_and_calls() {
        let (_, c) = setup();
        let mut seen = std::collections::HashSet::new();
        for round in 0..50 {
            for call in 0..4 {
                assert!(seen.insert(c.call_seed(round, call)));
            }
        }
    }

    #[test]
    fn different_clients_different_seeds() {
        let data = Synthetic::new(SynthSpec::tiny(), 3).generate(100, 1);
        let shards = partition_iid(&data, 2, 7);
        let a = Client::new(shards[0].clone(), 1);
        let b = Client::new(shards[1].clone(), 2);
        assert_ne!(a.call_seed(0, 0), b.call_seed(0, 0));
    }
}
