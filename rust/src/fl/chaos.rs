//! Deterministic network-fault injection for the loopback harness.
//!
//! [`ChaosStream`] sits between `fl::transport` framing and the socket —
//! it implements [`Wire`], so a [`crate::fl::transport::Conn`] built on
//! it frames bytes exactly as usual while the wrapper mangles the I/O
//! underneath. Every decision is drawn from a [`Xoshiro256`] stream
//! derived via [`SeedSequence`] (`util/rng.rs`), so a *chaos schedule* —
//! which writes stall, which frames are split or corrupted, when the
//! connection dies — is a pure function of `(chaos seed, device id,
//! connection attempt)` and replays identically across runs.
//!
//! The injected faults are the four real-network failure classes the
//! session layer must absorb:
//!
//! * **Delays** — bounded sleeps before an op (always ≪ the session's
//!   straggler deadline, so a delay alone never changes the outcome);
//! * **Split/short writes** — a write accepts only a prefix, forcing
//!   the peer's incremental `FrameBuf` to see partial frames;
//! * **Corrupted frames** — one byte of a read or write is flipped,
//!   which the FNV-1a frame checksum must catch;
//! * **Mid-round disconnects** — the socket is shut down and every
//!   later op fails with `ConnectionReset`, driving the peer into the
//!   typed dropout/reconnect path.
//!
//! The chaos RNG starts **disarmed** so the Hello/Welcome handshake
//! always completes cleanly (fleet assembly is not the failure model
//! under test); the device loop arms it via the [`ChaosSwitch`] right
//! after `Welcome` validates. The whole-session invariant this enables
//! (`tests/transport_e2e.rs`): every schedule ends in a bit-identical
//! run summary or a typed dropout/reconnect/error — never a hang, a
//! panic, or a silently wrong aggregate.
//!
//! Audit policy: intentionally unannotated — this is the fault
//! *injector*, test-harness-only code that deliberately corrupts I/O;
//! it parses nothing and contributes nothing to any aggregate. The
//! modules it attacks (`fl/transport.rs`, `fl/session.rs`) carry the
//! real `wire-decode` policies.

use std::io::{Error, ErrorKind, Read, Result, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::fl::transport::Wire;
use crate::util::rng::{SeedSequence, Xoshiro256};

/// Domain tag separating chaos streams from every other consumer of the
/// experiment seed tree.
const CHAOS_TAG: u64 = 0xC4A0_5EED;

/// Per-op fault probabilities + delay bound: one *chaos schedule* when
/// combined with a seed. Probabilities apply independently per
/// `read`/`write` call on the wrapped socket.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosSpec {
    pub seed: u64,
    /// P(sleep before an op).
    pub p_delay: f64,
    /// Upper bound on one injected sleep.
    pub max_delay: Duration,
    /// P(a write accepts only a random prefix).
    pub p_split: f64,
    /// P(one byte of an op's buffer is flipped).
    pub p_corrupt: f64,
    /// P(the connection dies at this op, permanently).
    pub p_disconnect: f64,
}

impl ChaosSpec {
    /// A schedule whose intensities are themselves drawn from the seed:
    /// each probability lands uniformly in `[0, max]`, so a sweep over
    /// seeds covers everything from near-clean runs (which must stay
    /// bit-identical) to heavily degraded ones (which must end typed).
    pub fn from_seed(seed: u64) -> Self {
        let mut rng = SeedSequence::new(seed).child(CHAOS_TAG).xoshiro();
        Self {
            seed,
            p_delay: 0.25 * rng.next_f64(),
            max_delay: Duration::from_micros(rng.below(5_000)),
            p_split: 0.5 * rng.next_f64(),
            p_corrupt: 0.12 * rng.next_f64(),
            p_disconnect: 0.08 * rng.next_f64(),
        }
    }

    /// A fixed high-intensity schedule for smoke jobs: frequent splits
    /// and delays plus enough corruption/disconnection that a short
    /// multi-device run is all but guaranteed to exercise the typed
    /// degraded paths (used by `fedsrn device --chaos-seed`).
    pub fn aggressive(seed: u64) -> Self {
        Self {
            seed,
            p_delay: 0.15,
            max_delay: Duration::from_millis(5),
            p_split: 0.35,
            p_corrupt: 0.06,
            p_disconnect: 0.03,
        }
    }

    /// The decision stream for one connection: distinct per device and
    /// per reconnect attempt, pure in all three inputs.
    pub fn rng_for(&self, device_id: usize, attempt: u64) -> Xoshiro256 {
        SeedSequence::new(self.seed)
            .child(CHAOS_TAG)
            .child(device_id as u64)
            .child(attempt)
            .xoshiro()
    }
}

/// Handle to arm a [`ChaosStream`] after the handshake completes.
#[derive(Clone)]
pub struct ChaosSwitch(Arc<AtomicBool>);

impl ChaosSwitch {
    pub fn arm(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn armed(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Counters of what the schedule actually injected (shared, so tests
/// can assert determinism and harnesses can report degradation).
#[derive(Debug, Default)]
pub struct ChaosEvents {
    pub delays: std::sync::atomic::AtomicU64,
    pub splits: std::sync::atomic::AtomicU64,
    pub corruptions: std::sync::atomic::AtomicU64,
    pub disconnects: std::sync::atomic::AtomicU64,
}

impl ChaosEvents {
    pub fn total_faults(&self) -> u64 {
        self.corruptions.load(Ordering::Relaxed) + self.disconnects.load(Ordering::Relaxed)
    }
}

/// A [`Wire`] that forwards to an inner wire while injecting the
/// seeded fault schedule. Generic so tests can drive it over in-memory
/// wires; the device loop uses `ChaosStream<TcpStream>`.
pub struct ChaosStream<S: Wire> {
    inner: S,
    rng: Xoshiro256,
    spec: ChaosSpec,
    armed: Arc<AtomicBool>,
    events: Arc<ChaosEvents>,
    /// Once the schedule kills the connection, every op fails.
    dead: bool,
}

impl<S: Wire> ChaosStream<S> {
    /// Wrap `inner` with the schedule `spec`, drawing decisions from
    /// `rng` (see [`ChaosSpec::rng_for`]). Starts disarmed.
    pub fn wrap(
        inner: S,
        spec: ChaosSpec,
        rng: Xoshiro256,
    ) -> (Self, ChaosSwitch, Arc<ChaosEvents>) {
        let armed = Arc::new(AtomicBool::new(false));
        let events = Arc::new(ChaosEvents::default());
        let stream = Self {
            inner,
            rng,
            spec,
            armed: Arc::clone(&armed),
            events: Arc::clone(&events),
            dead: false,
        };
        (stream, ChaosSwitch(armed), events)
    }

    fn active(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// Pre-op faults shared by reads and writes. Returns `Err` when the
    /// schedule disconnects here.
    fn pre_op(&mut self) -> Result<()> {
        if self.dead {
            return Err(Error::new(ErrorKind::ConnectionReset, "chaos: connection dead"));
        }
        if self.rng.next_f64() < self.spec.p_delay {
            let us = self.spec.max_delay.as_micros() as u64;
            if us > 0 {
                let sleep = self.rng.below(us);
                self.events.delays.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_micros(sleep));
            }
        }
        if self.rng.next_f64() < self.spec.p_disconnect {
            self.dead = true;
            self.events.disconnects.fetch_add(1, Ordering::Relaxed);
            self.inner.shutdown();
            return Err(Error::new(ErrorKind::ConnectionReset, "chaos: injected disconnect"));
        }
        Ok(())
    }
}

impl<S: Wire> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> Result<usize> {
        if !self.active() {
            return self.inner.read(buf);
        }
        self.pre_op()?;
        let n = self.inner.read(buf)?;
        if n > 0 && self.rng.next_f64() < self.spec.p_corrupt {
            let i = self.rng.below(n as u64) as usize;
            buf[i] ^= 1 << self.rng.below(8);
            self.events.corruptions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(n)
    }
}

impl<S: Wire> Write for ChaosStream<S> {
    fn write(&mut self, buf: &[u8]) -> Result<usize> {
        if !self.active() || buf.is_empty() {
            return self.inner.write(buf);
        }
        self.pre_op()?;
        // short write: hand the kernel only a prefix; the caller's
        // `write_all` (or the session's write queue) retries the rest,
        // so the peer observes a partial frame in between
        let len = if buf.len() > 1 && self.rng.next_f64() < self.spec.p_split {
            self.events.splits.fetch_add(1, Ordering::Relaxed);
            1 + self.rng.below(buf.len() as u64 - 1) as usize
        } else {
            buf.len()
        };
        if self.rng.next_f64() < self.spec.p_corrupt {
            let mut mangled = buf[..len].to_vec();
            let i = self.rng.below(len as u64) as usize;
            mangled[i] ^= 1 << self.rng.below(8);
            self.events.corruptions.fetch_add(1, Ordering::Relaxed);
            self.inner.write(&mangled)
        } else {
            self.inner.write(&buf[..len])
        }
    }

    fn flush(&mut self) -> Result<()> {
        self.inner.flush()
    }
}

impl<S: Wire> Wire for ChaosStream<S> {
    fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        self.inner.set_read_timeout(d)
    }

    fn set_nonblocking(&self, on: bool) -> Result<()> {
        self.inner.set_nonblocking(on)
    }

    fn shutdown(&self) {
        self.inner.shutdown();
    }

    fn peer_desc(&self) -> String {
        format!("{} (chaos seed {})", self.inner.peer_desc(), self.spec.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::transport::{write_frame, FrameBuf, FrameKind, MAX_FRAME_BYTES};

    /// In-memory wire: reads from a script, records writes.
    struct MemWire {
        input: std::io::Cursor<Vec<u8>>,
        output: Vec<u8>,
    }

    impl MemWire {
        fn new(input: Vec<u8>) -> Self {
            Self { input: std::io::Cursor::new(input), output: Vec::new() }
        }
    }

    impl Read for MemWire {
        fn read(&mut self, buf: &mut [u8]) -> Result<usize> {
            self.input.read(buf)
        }
    }

    impl Write for MemWire {
        fn write(&mut self, buf: &[u8]) -> Result<usize> {
            self.output.write(buf)
        }

        fn flush(&mut self) -> Result<()> {
            Ok(())
        }
    }

    impl Wire for MemWire {
        fn set_read_timeout(&self, _d: Option<Duration>) -> Result<()> {
            Ok(())
        }

        fn set_nonblocking(&self, _on: bool) -> Result<()> {
            Ok(())
        }

        fn shutdown(&self) {}

        fn peer_desc(&self) -> String {
            "mem".into()
        }
    }

    fn spec_hot() -> ChaosSpec {
        ChaosSpec {
            seed: 11,
            p_delay: 0.0, // keep unit tests instant
            max_delay: Duration::ZERO,
            p_split: 0.6,
            p_corrupt: 0.3,
            p_disconnect: 0.05,
        }
    }

    /// Drive `frames` through a fresh chaos stream; return the mangled
    /// bytes that reached the wire and the event counts.
    fn run_schedule(spec: &ChaosSpec, attempt: u64) -> (Vec<u8>, u64, u64, u64) {
        let (mut chaos, switch, events) =
            ChaosStream::wrap(MemWire::new(Vec::new()), *spec, spec.rng_for(0, attempt));
        switch.arm();
        for i in 0..40u8 {
            let _ = write_frame(&mut chaos, FrameKind::Uplink, &[i; 50]);
        }
        (
            chaos.inner.output,
            events.splits.load(Ordering::Relaxed),
            events.corruptions.load(Ordering::Relaxed),
            events.disconnects.load(Ordering::Relaxed),
        )
    }

    #[test]
    fn schedule_is_deterministic_in_seed_and_attempt() {
        let spec = spec_hot();
        let a = run_schedule(&spec, 0);
        let b = run_schedule(&spec, 0);
        assert_eq!(a, b, "same (seed, device, attempt) => same mangling");
        let c = run_schedule(&spec, 1);
        assert_ne!(a.0, c.0, "a reconnect draws a fresh stream");
    }

    #[test]
    fn disarmed_stream_is_transparent() {
        let spec = spec_hot();
        let (mut chaos, _switch, events) =
            ChaosStream::wrap(MemWire::new(Vec::new()), spec, spec.rng_for(0, 0));
        let mut clean = Vec::new();
        for i in 0..10u8 {
            write_frame(&mut chaos, FrameKind::Round, &[i; 30]).unwrap();
            write_frame(&mut clean, FrameKind::Round, &[i; 30]).unwrap();
        }
        assert_eq!(chaos.inner.output, clean, "disarmed chaos must not touch bytes");
        assert_eq!(events.total_faults(), 0);
    }

    #[test]
    fn corrupted_writes_fail_frame_validation_never_decode_wrong() {
        // whatever chaos does to framed bytes, the receiving FrameBuf
        // yields either intact frames or a typed error — the transport
        // guarantee the session invariant is built on
        for seed in 0..32u64 {
            let spec = ChaosSpec { seed, ..spec_hot() };
            let (wire_bytes, _s, corruptions, disconnects) = run_schedule(&spec, 0);
            let mut fb = FrameBuf::new();
            fb.extend(&wire_bytes);
            let mut intact = 0u64;
            loop {
                match fb.next_frame(MAX_FRAME_BYTES) {
                    Ok(Some((kind, payload))) => {
                        // a yielded frame is bitwise what the sender
                        // framed — chaos may lose frames (typed error or
                        // truncation) but can never hand back wrong data
                        assert_eq!(kind, FrameKind::Uplink);
                        assert_eq!(payload.len(), 50);
                        let fill = payload[0];
                        assert!(payload.iter().all(|&b| b == fill), "mangled frame decoded");
                        intact += 1;
                    }
                    Ok(None) | Err(_) => break,
                }
            }
            if corruptions + disconnects > 0 {
                assert!(intact < 40, "seed {seed}: a faulted frame cannot arrive intact");
            } else {
                // splits and delays alone reorder nothing and lose nothing
                assert_eq!(intact, 40, "seed {seed}");
            }
        }
    }

    #[test]
    fn disconnect_is_permanent() {
        let spec = ChaosSpec { p_disconnect: 1.0, ..spec_hot() };
        let (mut chaos, switch, events) =
            ChaosStream::wrap(MemWire::new(Vec::new()), spec, spec.rng_for(3, 0));
        switch.arm();
        assert!(write_frame(&mut chaos, FrameKind::Uplink, b"x").is_err());
        assert!(write_frame(&mut chaos, FrameKind::Uplink, b"x").is_err());
        let mut buf = [0u8; 4];
        assert!(chaos.read(&mut buf).is_err());
        assert_eq!(events.disconnects.load(Ordering::Relaxed), 1, "dies once, stays dead");
    }

    #[test]
    fn from_seed_spans_mild_to_wild() {
        let specs: Vec<ChaosSpec> = (0..64).map(ChaosSpec::from_seed).collect();
        assert!(specs.iter().any(|s| s.p_corrupt < 0.06), "some schedules are near-clean");
        assert!(specs.iter().any(|s| s.p_corrupt > 0.06), "some schedules corrupt hard");
        assert!(specs.iter().all(|s| s.max_delay < Duration::from_millis(10)));
        assert_eq!(ChaosSpec::from_seed(5), ChaosSpec::from_seed(5));
    }
}
