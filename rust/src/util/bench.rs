//! Shared micro-benchmark timing + the machine-readable perf trajectory.
//!
//! One timing loop ([`time`] / [`time_pair`]) serves every consumer —
//! the `cargo bench` harnesses (`benches/common/mod.rs`) and the
//! `fedsrn codec-bench` CLI — so the JSON trajectory emitter
//! ([`BenchJson`]) has a single source of truth for what "ns/iter"
//! means. CI runs the bench binaries, which write
//! `BENCH_components.json` / `BENCH_figures.json` (see
//! `$BENCH_JSON_DIR`), validates the files, and uploads them as
//! artifacts — the repo's perf history is data, not log text.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

/// One measured timing: wall-clock over repeated runs with warmup.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl Timing {
    pub fn ns_per_iter(&self) -> f64 {
        self.mean_s * 1e9
    }
}

/// Run `f` repeatedly: 2 warmup iterations, then timed iterations until
/// ~`budget_s` seconds or `max_iters`, whichever first — always at
/// least one timed iteration.
pub fn time(budget_s: f64, max_iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..2 {
        f();
    }
    let max_iters = max_iters.max(1);
    let mut times = Vec::new();
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() >= budget_s || times.len() >= max_iters {
            break;
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Timing {
        iters: times.len(),
        mean_s: mean,
        p50_s: times[times.len() / 2],
        p95_s: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
    }
}

/// An A/B pair measured under the same budget (candidate vs baseline).
#[derive(Debug, Clone, Copy)]
pub struct PairTiming {
    pub a: Timing,
    pub b: Timing,
}

impl PairTiming {
    /// How many times faster `a` is than `b` (> 1 means `a` wins).
    pub fn speedup_a_over_b(&self) -> f64 {
        self.b.mean_s / self.a.mean_s
    }
}

/// Time a candidate/baseline pair back to back with the same budget.
pub fn time_pair(
    budget_s: f64,
    max_iters: usize,
    fa: impl FnMut(),
    fb: impl FnMut(),
) -> PairTiming {
    PairTiming { a: time(budget_s, max_iters, fa), b: time(budget_s, max_iters, fb) }
}

struct BenchEntry {
    name: String,
    iters: usize,
    ns_per_iter: f64,
    baseline: Option<String>,
}

/// Collects bench results and emits one machine-readable JSON array:
/// `[{"name", "iters", "ns_per_iter", "baseline", "ratio_vs_baseline"}]`
/// where `ratio_vs_baseline` = baseline ns / own ns (> 1 ⇒ faster than
/// the named baseline), resolved at write time against the entries
/// actually recorded (`null` when the baseline didn't run).
#[derive(Default)]
pub struct BenchJson {
    entries: Vec<BenchEntry>,
}

impl BenchJson {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, t: &Timing, baseline: Option<&str>) {
        self.record_raw(name, t.iters, t.ns_per_iter(), baseline);
    }

    /// Record an externally-measured result (e.g. secs/round from a
    /// figure harness) in the same schema.
    pub fn record_raw(
        &mut self,
        name: &str,
        iters: usize,
        ns_per_iter: f64,
        baseline: Option<&str>,
    ) {
        self.entries.push(BenchEntry {
            name: name.to_string(),
            iters,
            ns_per_iter,
            baseline: baseline.map(str::to_string),
        });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn ns_of(&self, name: &str) -> Option<f64> {
        self.entries.iter().find(|e| e.name == name).map(|e| e.ns_per_iter)
    }

    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, e) in self.entries.iter().enumerate() {
            let ratio = e
                .baseline
                .as_deref()
                .and_then(|b| self.ns_of(b))
                .map(|base_ns| base_ns / e.ns_per_iter);
            let _ = write!(
                s,
                "  {{\"name\":\"{}\",\"iters\":{},\"ns_per_iter\":{:.1},\"baseline\":{},\
                 \"ratio_vs_baseline\":{}}}",
                escape(&e.name),
                e.iters,
                e.ns_per_iter,
                match &e.baseline {
                    Some(b) => format!("\"{}\"", escape(b)),
                    None => "null".to_string(),
                },
                match ratio {
                    Some(r) if r.is_finite() => format!("{r:.4}"),
                    _ => "null".to_string(),
                },
            );
            s.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
        }
        s.push_str("]\n");
        s
    }

    pub fn write_file(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing bench JSON {path:?}"))
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_always_produces_a_sample() {
        let t = time(0.0, 0, || std::hint::black_box(2u64.pow(10)));
        assert_eq!(t.iters, 1);
        assert!(t.mean_s >= 0.0 && t.p50_s >= 0.0 && t.p95_s >= 0.0);
        assert!(t.ns_per_iter() >= 0.0);
    }

    #[test]
    fn time_respects_iteration_cap() {
        let mut calls = 0usize;
        let t = time(10.0, 5, || calls += 1);
        assert_eq!(t.iters, 5);
        assert_eq!(calls, 5 + 2); // warmup included
    }

    #[test]
    fn pair_speedup_orientation() {
        let p = time_pair(
            0.01,
            20,
            || std::hint::black_box(1 + 1),
            || std::thread::sleep(std::time::Duration::from_micros(200)),
        );
        assert!(p.speedup_a_over_b() > 1.0, "{}", p.speedup_a_over_b());
    }

    #[test]
    fn json_schema_and_baseline_ratio() {
        let mut j = BenchJson::new();
        j.record_raw("fast", 10, 100.0, Some("slow"));
        j.record_raw("slow", 10, 400.0, None);
        j.record_raw("orphan", 3, 50.0, Some("not-recorded"));
        let out = j.to_json();
        assert!(out.starts_with('[') && out.trim_end().ends_with(']'));
        assert!(out.contains("\"name\":\"fast\""));
        assert!(out.contains("\"baseline\":\"slow\""));
        assert!(out.contains("\"ratio_vs_baseline\":4.0000"), "{out}");
        assert!(out.contains("\"baseline\":null"));
        // unknown baseline resolves to null, not a crash
        assert!(out.contains("\"baseline\":\"not-recorded\",\"ratio_vs_baseline\":null"));
        assert_eq!(j.len(), 3);
        assert!(!j.is_empty());
    }

    #[test]
    fn json_writes_to_disk() {
        let path =
            std::env::temp_dir().join(format!("fedsrn_bench_{}.json", std::process::id()));
        let mut j = BenchJson::new();
        j.record_raw("x", 1, 1.0, None);
        j.write_file(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"name\":\"x\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn names_are_escaped() {
        let mut j = BenchJson::new();
        j.record_raw("weird\"name", 1, 1.0, None);
        assert!(j.to_json().contains("weird\\\"name"));
    }
}
