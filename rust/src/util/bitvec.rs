//! Packed bit vector — the wire representation of a binary mask.
//!
//! The paper's headline claim is "at most 1 bit per parameter": a mask
//! over `n` parameters occupies `ceil(n/64)` words here, and the entropy
//! coder in [`crate::compress`] pushes the *actual* uplink below that
//! whenever the mask is sparse.
//!
//! Invariant: the slack bits of the last word (positions `len..` when
//! `len % 64 != 0`) are always zero. `zeros` allocates zeroed words and
//! `set` bounds-checks `i < len` with a hard assert, so no constructor
//! or mutation can raise a slack bit. Consumers — `count_ones`,
//! `iter_ones`, and the packed compute tier
//! ([`crate::runtime::packed`]) — rely on this to scan whole words
//! without re-masking the tail.
//!
//! audit: deterministic

/// A fixed-length packed bit vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl BitVec {
    /// All-zeros vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        Self { len, words: vec![0; len.div_ceil(64)] }
    }

    /// Build from a boolean slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    /// Build from any iterator of bools with a known length.
    pub fn from_iter_len(iter: impl Iterator<Item = bool>, len: usize) -> Self {
        let mut v = Self::zeros(len);
        let mut n = 0usize;
        for (i, b) in iter.enumerate() {
            assert!(i < len, "iterator longer than declared len {len}");
            if b {
                v.set(i, true);
            }
            n = i + 1;
        }
        assert_eq!(n, len, "iterator shorter than declared len");
        v
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i`. Hard-asserts `i < len` even in release builds: an
    /// out-of-range set could raise a slack bit of the last word and
    /// silently break every whole-word consumer (see module invariant).
    #[inline]
    pub fn set(&mut self, i: usize, b: bool) {
        assert!(i < self.len, "bit index {i} out of range for BitVec of len {}", self.len);
        let (w, s) = (i / 64, i % 64);
        if b {
            self.words[w] |= 1 << s;
        } else {
            self.words[w] &= !(1 << s);
        }
    }

    /// Number of ones (hardware popcount per word).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction of ones, in [0, 1]. Empty vectors report 0.
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.count_ones() as f64 / self.len as f64
        }
    }

    /// Iterate bits in order.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// Iterate the indices of set bits via word scanning — O(words +
    /// popcount) instead of O(n), the hot-loop form for sparse masks.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(move |(wi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    return None;
                }
                let b = w.trailing_zeros() as usize;
                w &= w - 1;
                Some(wi * 64 + b)
            })
        })
    }

    /// Expand to f32 {0.0, 1.0} — the layout the PJRT eval program takes.
    pub fn to_f32(&self) -> Vec<f32> {
        self.iter().map(|b| if b { 1.0 } else { 0.0 }).collect()
    }

    /// Build from an f32 vector by `v > 0.5` (inverse of `to_f32`).
    pub fn from_f32_threshold(v: &[f32]) -> Self {
        Self::from_iter_len(v.iter().map(|&x| x > 0.5), v.len())
    }

    /// Raw words (little-endian bit order within each word).
    ///
    /// Contract: slack bits of the last word are zero (module
    /// invariant), so callers may `count_ones()` / AND / scan whole
    /// words — including the last — without masking off the tail.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Uncompressed wire size in bytes (the 1 Bpp upper bound).
    pub fn raw_bytes(&self) -> usize {
        self.len.div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in (0..130).step_by(3) {
            v.set(i, true);
        }
        for i in 0..130 {
            assert_eq!(v.get(i), i % 3 == 0, "bit {i}");
        }
    }

    #[test]
    fn count_density() {
        let mut v = BitVec::zeros(1000);
        for i in 0..250 {
            v.set(i * 4, true);
        }
        assert_eq!(v.count_ones(), 250);
        assert!((v.density() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn from_bools_and_iter() {
        let bits: Vec<bool> = (0..77).map(|i| i % 5 == 0).collect();
        let v = BitVec::from_bools(&bits);
        assert_eq!(v.iter().collect::<Vec<_>>(), bits);
    }

    #[test]
    fn f32_roundtrip() {
        let bits: Vec<bool> = (0..64).map(|i| i % 2 == 0).collect();
        let v = BitVec::from_bools(&bits);
        let f = v.to_f32();
        assert_eq!(BitVec::from_f32_threshold(&f), v);
    }

    #[test]
    fn empty() {
        let v = BitVec::zeros(0);
        assert!(v.is_empty());
        assert_eq!(v.count_ones(), 0);
        assert_eq!(v.density(), 0.0);
        assert_eq!(v.raw_bytes(), 0);
    }

    #[test]
    fn raw_bytes_bound() {
        assert_eq!(BitVec::zeros(8).raw_bytes(), 1);
        assert_eq!(BitVec::zeros(9).raw_bytes(), 2);
        assert_eq!(BitVec::zeros(268_800).raw_bytes(), 33_600);
    }

    #[test]
    fn iter_ones_matches_iter() {
        let bits: Vec<bool> = (0..300).map(|i| (i * 13) % 7 == 0).collect();
        let v = BitVec::from_bools(&bits);
        let ones: Vec<usize> = v.iter_ones().collect();
        let want: Vec<usize> =
            (0..300).filter(|&i| bits[i]).collect();
        assert_eq!(ones, want);
        assert_eq!(ones.len(), v.count_ones());
    }

    #[test]
    fn clear_bit() {
        let mut v = BitVec::zeros(10);
        v.set(5, true);
        assert!(v.get(5));
        v.set(5, false);
        assert!(!v.get(5));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn set_out_of_range_panics_in_release_too() {
        // index 70 lands inside the second allocated word of a len-70
        // vector... but 70 >= len, so it MUST panic: allowing it would
        // raise a slack bit and break the whole-word contract.
        let mut v = BitVec::zeros(70);
        v.set(70, true);
    }

    #[test]
    fn slack_bits_stay_zero_around_word_boundaries() {
        // every constructor, at lengths straddling the 64-bit boundary
        for len in [1usize, 63, 64, 65, 127, 128, 129, 191] {
            let all = BitVec::from_iter_len((0..len).map(|_| true), len);
            let thr: Vec<f32> = (0..len).map(|i| if i % 2 == 0 { 1.0 } else { 0.2 }).collect();
            let v2 = BitVec::from_f32_threshold(&thr);
            let mut v3 = BitVec::zeros(len);
            for i in (0..len).rev() {
                v3.set(i, true);
            }
            for v in [&all, &v2, &v3] {
                let rem = len % 64;
                if rem != 0 {
                    let last = *v.words().last().unwrap();
                    assert_eq!(last & !((1u64 << rem) - 1), 0, "len={len} slack dirty");
                }
            }
            assert_eq!(all.count_ones(), len);
            assert_eq!(v2.count_ones(), len.div_ceil(2));
            assert_eq!(v3.count_ones(), len);
        }
    }
}
