//! Minimal flat-JSON parsing for our own JSONL metric records.
//!
//! The metrics sink only ever emits `{"key":number|null,...}` objects,
//! so this parser handles exactly that grammar (plus string values for
//! forward compatibility) and rejects nesting loudly. Not a general
//! JSON parser by design.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A flat record: key -> number (null becomes NaN) or string.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Num(f64),
    Str(String),
}

impl JsonValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(v) => Some(*v),
            JsonValue::Str(_) => None,
        }
    }
}

/// Parse one flat JSON object line.
pub fn parse_flat_json(line: &str) -> Result<BTreeMap<String, JsonValue>> {
    let s = line.trim();
    let Some(inner) = s.strip_prefix('{').and_then(|t| t.strip_suffix('}')) else {
        bail!("expected a flat JSON object, got '{s}'");
    };
    let mut out = BTreeMap::new();
    let mut rest = inner.trim();
    while !rest.is_empty() {
        // key
        let Some(r) = rest.strip_prefix('"') else {
            bail!("expected quoted key at '{rest}'");
        };
        let Some(endq) = r.find('"') else { bail!("unterminated key") };
        let key = &r[..endq];
        let r = r[endq + 1..].trim_start();
        let Some(r) = r.strip_prefix(':') else { bail!("missing ':' after key {key}") };
        let r = r.trim_start();
        // value: string | number | null
        let (value, after) = if let Some(v) = r.strip_prefix('"') {
            let Some(endq) = v.find('"') else { bail!("unterminated string value") };
            (JsonValue::Str(v[..endq].to_string()), &v[endq + 1..])
        } else if let Some(after) = r.strip_prefix("null") {
            (JsonValue::Num(f64::NAN), after)
        } else {
            let end = r
                .find(|c: char| c == ',' || c == '}' || c.is_whitespace())
                .unwrap_or(r.len());
            let tok = &r[..end];
            if tok.starts_with('{') || tok.starts_with('[') {
                bail!("nested JSON not supported by this parser");
            }
            let num: f64 = tok.parse().map_err(|e| anyhow::anyhow!("bad number '{tok}': {e}"))?;
            (JsonValue::Num(num), &r[end..])
        };
        out.insert(key.to_string(), value);
        rest = after.trim_start();
        if let Some(r) = rest.strip_prefix(',') {
            rest = r.trim_start();
        } else if !rest.is_empty() {
            bail!("trailing garbage '{rest}'");
        }
    }
    Ok(out)
}

/// Read a JSONL file of flat records.
pub fn read_jsonl(path: &std::path::Path) -> Result<Vec<BTreeMap<String, JsonValue>>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse_flat_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_metric_record() {
        let rec = parse_flat_json(
            r#"{"round":3,"accuracy":0.925000,"loss":null,"tag":"x"}"#,
        )
        .unwrap();
        assert_eq!(rec["round"].as_f64(), Some(3.0));
        assert_eq!(rec["accuracy"].as_f64(), Some(0.925));
        assert!(rec["loss"].as_f64().unwrap().is_nan());
        assert_eq!(rec["tag"], JsonValue::Str("x".into()));
    }

    #[test]
    fn round_trips_sink_output() {
        use crate::fl::RoundRecord;
        let r = RoundRecord { round: 7, accuracy: 0.5, est_bpp: 0.25, ..Default::default() };
        let rec = parse_flat_json(&r.to_json()).unwrap();
        assert_eq!(rec["round"].as_f64(), Some(7.0));
        assert_eq!(rec["est_bpp"].as_f64(), Some(0.25));
    }

    #[test]
    fn rejects_nesting_and_garbage() {
        assert!(parse_flat_json(r#"{"a":{"b":1}}"#).is_err());
        assert!(parse_flat_json("not json").is_err());
        assert!(parse_flat_json(r#"{"a":1 "b":2}"#).is_err());
    }

    #[test]
    fn empty_object_ok() {
        assert!(parse_flat_json("{}").unwrap().is_empty());
    }
}
