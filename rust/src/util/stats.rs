//! Small statistics helpers for metric aggregation and reporting.

/// Mean of a slice; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Unbiased sample standard deviation; 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// 95% normal-approximation confidence half-width around the mean.
pub fn ci95(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Exponential moving average accumulator.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Online mean/min/max/count accumulator (Welford variance).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(ci95(&[1.0]), 0.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn running_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.5, -2.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.variance().sqrt() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), -2.0);
        assert_eq!(r.max(), 5.5);
        assert_eq!(r.count(), 6);
    }
}
