//! Shared substrate: PRNGs, bit vectors, statistics, timers, bench
//! timing.

pub mod bench;
pub mod bitvec;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use bitvec::BitVec;
pub use json::{parse_flat_json, read_jsonl, JsonValue};
pub use rng::{Philox4x32, SeedSequence, SplitMix64, Xoshiro256};
pub use stats::{ci95, mean, std_dev, Ema, Running};
pub use timer::{ShardedTimers, Timers};

/// Numerically-stable logistic function, mirroring `jax.nn.sigmoid`.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Logit (inverse sigmoid); clamps away from {0, 1} for stability.
#[inline]
pub fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-7, 1.0 - 1e-7);
    (p / (1.0 - p)).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_logit_inverse() {
        for &p in &[0.01f32, 0.2, 0.5, 0.9, 0.999] {
            assert!((sigmoid(logit(p)) - p).abs() < 1e-5, "p={p}");
        }
    }

    #[test]
    fn sigmoid_extremes() {
        assert!(sigmoid(100.0) > 0.999_999);
        assert!(sigmoid(-100.0) < 1e-6);
        assert_eq!(sigmoid(0.0), 0.5);
    }
}
