//! Lightweight scoped timers used by the metrics sink and the perf pass.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A named stopwatch accumulating durations per label; cheap enough to
/// leave in the round loop permanently (one `Instant::now` per section).
#[derive(Debug, Default)]
pub struct Timers {
    acc: BTreeMap<String, (Duration, u64)>,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `label`.
    pub fn time<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed());
        out
    }

    /// Record an externally-measured duration.
    pub fn add(&mut self, label: &str, d: Duration) {
        let e = self.acc.entry(label.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// (total seconds, call count) per label.
    pub fn summary(&self) -> Vec<(String, f64, u64)> {
        self.acc
            .iter()
            .map(|(k, (d, n))| (k.clone(), d.as_secs_f64(), *n))
            .collect()
    }

    /// Total seconds across all labels.
    pub fn total_secs(&self) -> f64 {
        self.acc.values().map(|(d, _)| d.as_secs_f64()).sum()
    }

    pub fn clear(&mut self) {
        self.acc.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_labels() {
        let mut t = Timers::new();
        let x = t.time("a", || 21 * 2);
        assert_eq!(x, 42);
        t.time("a", || ());
        t.time("b", || ());
        let s = t.summary();
        assert_eq!(s.len(), 2);
        let a = s.iter().find(|(k, _, _)| k == "a").unwrap();
        assert_eq!(a.2, 2);
        assert!(t.total_secs() >= 0.0);
    }

    #[test]
    fn clear_resets() {
        let mut t = Timers::new();
        t.time("x", || ());
        t.clear();
        assert!(t.summary().is_empty());
    }
}
