//! Lightweight scoped timers used by the metrics sink and the perf pass.
//!
//! [`Timers`] is the single-threaded accumulator; [`ShardedTimers`]
//! spreads `add` calls over per-thread shards so the parallel round
//! engine's workers never serialize on telemetry, merging on read.

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A named stopwatch accumulating durations per label; cheap enough to
/// leave in the round loop permanently (one `Instant::now` per section).
#[derive(Debug, Default)]
pub struct Timers {
    acc: BTreeMap<String, (Duration, u64)>,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `label`.
    pub fn time<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed());
        out
    }

    /// Record an externally-measured duration.
    pub fn add(&mut self, label: &str, d: Duration) {
        let e = self.acc.entry(label.to_string()).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// (total seconds, call count) per label.
    pub fn summary(&self) -> Vec<(String, f64, u64)> {
        self.acc
            .iter()
            .map(|(k, (d, n))| (k.clone(), d.as_secs_f64(), *n))
            .collect()
    }

    /// Total seconds across all labels.
    pub fn total_secs(&self) -> f64 {
        self.acc.values().map(|(d, _)| d.as_secs_f64()).sum()
    }

    pub fn clear(&mut self) {
        self.acc.clear();
    }

    /// Fold another accumulator into this one (label-wise sums).
    pub fn merge(&mut self, other: &Timers) {
        for (label, (d, n)) in &other.acc {
            let e = self.acc.entry(label.clone()).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *n;
        }
    }
}

/// Shard count: enough that concurrent workers land on distinct locks
/// with high probability at typical core counts.
const TIMER_SHARDS: usize = 16;

/// Thread-sharded timer accumulation, merged on read.
///
/// `add` hashes the calling thread's id to one of 16
/// independently-locked [`Timers`]; concurrent workers therefore take
/// uncontended locks instead of serializing on one global mutex (the
/// seed's `Mutex<Timers>` made every runtime call a rendezvous point
/// for the parallel round engine). Reads (`snapshot`) merge all shards
/// into one `Timers` — telemetry only, so a racing `add` landing just
/// after a snapshot is fine.
#[derive(Debug, Default)]
pub struct ShardedTimers {
    shards: [Mutex<Timers>; TIMER_SHARDS],
}

impl ShardedTimers {
    pub fn new() -> Self {
        Self::default()
    }

    fn shard(&self) -> &Mutex<Timers> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        &self.shards[(h.finish() as usize) % TIMER_SHARDS]
    }

    /// Record an externally-measured duration on this thread's shard.
    pub fn add(&self, label: &str, d: Duration) {
        self.shard().lock().unwrap().add(label, d);
    }

    /// Merge every shard into one accumulator.
    pub fn snapshot(&self) -> Timers {
        let mut out = Timers::new();
        for s in &self.shards {
            out.merge(&s.lock().unwrap());
        }
        out
    }

    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_labels() {
        let mut t = Timers::new();
        let x = t.time("a", || 21 * 2);
        assert_eq!(x, 42);
        t.time("a", || ());
        t.time("b", || ());
        let s = t.summary();
        assert_eq!(s.len(), 2);
        let a = s.iter().find(|(k, _, _)| k == "a").unwrap();
        assert_eq!(a.2, 2);
        assert!(t.total_secs() >= 0.0);
    }

    #[test]
    fn clear_resets() {
        let mut t = Timers::new();
        t.time("x", || ());
        t.clear();
        assert!(t.summary().is_empty());
    }

    #[test]
    fn merge_sums_labels() {
        let mut a = Timers::new();
        a.add("x", Duration::from_millis(2));
        let mut b = Timers::new();
        b.add("x", Duration::from_millis(3));
        b.add("y", Duration::from_millis(1));
        a.merge(&b);
        let s = a.summary();
        let x = s.iter().find(|(k, _, _)| k == "x").unwrap();
        assert_eq!(x.2, 2);
        assert!((x.1 - 0.005).abs() < 1e-9);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn sharded_accumulates_across_threads() {
        let st = ShardedTimers::new();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..10 {
                        st.add("work", Duration::from_micros(5));
                    }
                });
            }
        });
        st.add("main", Duration::from_micros(1));
        let snap = st.snapshot();
        let s = snap.summary();
        let work = s.iter().find(|(k, _, _)| k == "work").unwrap();
        assert_eq!(work.2, 80, "all worker adds must survive the merge");
        assert!(s.iter().any(|(k, _, _)| k == "main"));
        st.clear();
        assert!(st.snapshot().summary().is_empty());
    }
}
