//! Deterministic PRNG substrate.
//!
//! Everything stochastic on the Rust side (dataset synthesis, non-IID
//! partitioning, server-side mask sampling, per-client seed derivation)
//! flows through these generators so that every experiment is exactly
//! reproducible from a single root seed — mirroring the paper's setting
//! where the server broadcasts a seed and every party reconstructs the
//! same randomness.
//!
//! * [`SplitMix64`] — seed expander (also used to seed the others).
//! * [`Xoshiro256`] — xoshiro256++, the general-purpose stream.
//! * [`Philox4x32`] — counter-based; used where random access by index
//!   matters (per-parameter Bernoulli draws without storing a stream).
//!
//! audit: deterministic

/// SplitMix64: tiny, passes BigCrush, standard seed expander.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna). Fast, 256-bit state.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased
    /// enough for simulation workloads; n is tiny relative to 2^64).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached spare is intentionally not
    /// kept: call sites batch anyway and statelessness keeps replay easy).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child stream (for per-client randomness).
    pub fn fork(&mut self, tag: u64) -> Xoshiro256 {
        let mut sm = SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15));
        Xoshiro256::new(sm.next_u64())
    }
}

/// A splittable seed tree (DESIGN.md §Parallel round engine).
///
/// `SeedSequence` derives child streams by *hashing*, never by drawing
/// from a shared stateful generator, so the seed a client receives is a
/// pure function of the path `(root, round, client, ...)` — independent
/// of which worker thread derives it and in which order. This is the
/// determinism contract the parallel round engine relies on: the same
/// config seed yields bit-identical per-client randomness at any thread
/// count.
///
/// Derivation is a SplitMix64-style finalizer over `key ^ mix(tag)`,
/// which keeps children well-separated even for adjacent tags (0, 1, 2,
/// ... are the common case: round indices and client ids).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeedSequence {
    key: u64,
}

impl SeedSequence {
    const GAMMA: u64 = 0x9E3779B97F4A7C15;

    pub fn new(root: u64) -> Self {
        Self { key: Self::finalize(root ^ 0x5EED_7143_A11E_57A2) }
    }

    #[inline]
    fn finalize(mut z: u64) -> u64 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Derive an independent child stream for `tag`. Pure: the same
    /// (self, tag) always yields the same child, in any call order.
    #[inline]
    pub fn child(&self, tag: u64) -> SeedSequence {
        SeedSequence { key: Self::finalize(self.key ^ tag.wrapping_mul(Self::GAMMA)) }
    }

    /// The raw 64-bit seed of this node (for APIs that take a `u64`).
    #[inline]
    pub fn seed(&self) -> u64 {
        self.key
    }

    /// A sequential generator seeded from this node.
    pub fn xoshiro(&self) -> Xoshiro256 {
        Xoshiro256::new(self.key)
    }

    /// A counter-based generator keyed from this node.
    pub fn philox(&self) -> Philox4x32 {
        Philox4x32::new(self.key)
    }
}

/// Philox-4x32-10 counter-based generator (Salmon et al., SC'11).
///
/// `at(counter)` returns the same 4 words for the same (key, counter) no
/// matter the call order — random access without storing streams, used
/// for per-parameter Bernoulli draws during server-side mask sampling.
#[derive(Debug, Clone, Copy)]
pub struct Philox4x32 {
    key: [u32; 2],
}

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9;
const PHILOX_W1: u32 = 0xBB67_AE85;

impl Philox4x32 {
    pub fn new(seed: u64) -> Self {
        Self { key: [seed as u32, (seed >> 32) as u32] }
    }

    #[inline]
    fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
        let p0 = (PHILOX_M0 as u64) * (ctr[0] as u64);
        let p1 = (PHILOX_M1 as u64) * (ctr[2] as u64);
        [
            ((p1 >> 32) as u32) ^ ctr[1] ^ key[0],
            p1 as u32,
            ((p0 >> 32) as u32) ^ ctr[3] ^ key[1],
            p0 as u32,
        ]
    }

    /// The 10-round Philox block function at a 128-bit counter.
    pub fn at(&self, counter: u128) -> [u32; 4] {
        let mut ctr = [
            counter as u32,
            (counter >> 32) as u32,
            (counter >> 64) as u32,
            (counter >> 96) as u32,
        ];
        let mut key = self.key;
        for _ in 0..10 {
            ctr = Self::round(ctr, key);
            key[0] = key[0].wrapping_add(PHILOX_W0);
            key[1] = key[1].wrapping_add(PHILOX_W1);
        }
        ctr
    }

    /// Uniform f32 in [0, 1) for a scalar index. Consistent with
    /// `fill_uniform`: index i lives in word i%4 of block i/4.
    #[inline]
    pub fn uniform_at(&self, index: u64) -> f32 {
        let w = self.at((index / 4) as u128)[(index % 4) as usize];
        (w >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Fill `out` with uniforms for indices [start, start + out.len()).
    /// Consumes all 4 words per block: ~4x fewer block functions than
    /// `uniform_at` in a loop.
    pub fn fill_uniform(&self, start: u64, out: &mut [f32]) {
        const SCALE: f32 = 1.0 / (1u32 << 24) as f32;
        let mut i = 0usize;
        let mut block = start / 4;
        // align to the block containing `start`
        let mut words = self.at(block as u128);
        let mut off = (start % 4) as usize;
        while i < out.len() {
            if off == 4 {
                block += 1;
                words = self.at(block as u128);
                off = 0;
            }
            out[i] = (words[off] >> 8) as f32 * SCALE;
            off += 1;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values for seed 0 (from the canonical C impl).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn xoshiro_uniformity_rough() {
        let mut r = Xoshiro256::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn xoshiro_f32_in_range() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256::new(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Xoshiro256::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn seed_sequence_is_pure_and_order_free() {
        let root = SeedSequence::new(2023);
        // same path, derived twice, in different orders
        let a1 = root.child(4).child(17);
        let b = root.child(9).child(3); // unrelated derivation in between
        let a2 = root.child(4).child(17);
        assert_eq!(a1, a2, "child derivation must be pure");
        assert_ne!(a1, b);
    }

    #[test]
    fn seed_sequence_children_are_well_separated() {
        let root = SeedSequence::new(7);
        let mut seen = std::collections::HashSet::new();
        for client in 0..100u64 {
            for round in 0..100u64 {
                assert!(seen.insert(root.child(round).child(client).seed()));
            }
        }
    }

    #[test]
    fn seed_sequence_streams_differ_between_siblings() {
        let root = SeedSequence::new(1);
        let mut a = root.child(0).xoshiro();
        let mut b = root.child(1).xoshiro();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
        assert_ne!(root.child(0).philox().at(0), root.child(1).philox().at(0));
    }

    #[test]
    fn philox_random_access_matches_stream() {
        let p = Philox4x32::new(0xDEADBEEF);
        let mut buf = vec![0.0f32; 1000];
        p.fill_uniform(123, &mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, p.uniform_at(123 + i as u64), "i={i}");
        }
    }

    #[test]
    fn philox_key_sensitivity() {
        let a = Philox4x32::new(1);
        let b = Philox4x32::new(2);
        assert_ne!(a.at(0), b.at(0));
        assert_ne!(a.at(0), a.at(1));
    }

    #[test]
    fn philox_uniform_range_and_mean() {
        let p = Philox4x32::new(77);
        let mut buf = vec![0.0f32; 100_000];
        p.fill_uniform(0, &mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        assert!(buf.iter().all(|&x| (0.0..1.0).contains(&x)));
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
