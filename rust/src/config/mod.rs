//! Experiment configuration: typed configs + a dependency-free TOML
//! subset parser.
//!
//! Configs drive the launcher exactly like Megatron/MaxText-style config
//! files drive theirs: `fedsrn train --config experiments/fig1.toml`
//! with CLI overrides on top. The parser supports the subset we use:
//! `[section]` headers, `key = value` with string / int / float / bool,
//! and `#` comments — and rejects anything else loudly rather than
//! guessing.

pub mod parse;

pub use parse::{parse_toml, TomlValue};

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::compress::DownlinkMode;
use crate::runtime::Compute;

/// Which algorithm drives the federation (paper + baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// FedPM with the paper's entropy-proxy regularizer (lambda > 0).
    FedPMReg,
    /// Original FedPM (consistent objective, lambda = 0).
    FedPM,
    /// FedMask-style deterministic masking (threshold, biased updates).
    FedMask,
    /// Top-k score masking (Fig. 2 baseline).
    TopK,
    /// Majority-vote SignSGD (Fig. 2 baseline; dense weights).
    SignSGD,
    /// Dense FedAvg (float uplink reference point).
    FedAvg,
    /// Masked random noise (arxiv 2408.03220): binary mask over a
    /// seeded frozen noise tensor, seed rides the downlink envelope.
    FedMRN,
    /// SpaFL (arxiv 2406.00431): per-filter trainable pruning
    /// thresholds are the only uplink payload.
    SpaFL,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fedpm_reg" | "fedpmreg" | "ours" => Algorithm::FedPMReg,
            "fedpm" => Algorithm::FedPM,
            "fedmask" => Algorithm::FedMask,
            "topk" | "top-k" => Algorithm::TopK,
            "signsgd" | "mv-signsgd" | "mv_signsgd" => Algorithm::SignSGD,
            "fedavg" => Algorithm::FedAvg,
            "fedmrn" | "mrn" => Algorithm::FedMRN,
            "spafl" => Algorithm::SpaFL,
            other => bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedPMReg => "fedpm_reg",
            Algorithm::FedPM => "fedpm",
            Algorithm::FedMask => "fedmask",
            Algorithm::TopK => "topk",
            Algorithm::SignSGD => "signsgd",
            Algorithm::FedAvg => "fedavg",
            Algorithm::FedMRN => "fedmrn",
            Algorithm::SpaFL => "spafl",
        }
    }

    /// Does this algorithm ship binary payloads (vs float vectors)
    /// uplink? FedAvg uploads dense weights and SpaFL uploads per-filter
    /// float thresholds; everything else codes bits.
    pub fn uplink_is_binary(&self) -> bool {
        !matches!(self, Algorithm::FedAvg | Algorithm::SpaFL)
    }
}

/// Data distribution across devices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    Iid,
    /// Non-IID with `c` classes per device.
    NonIid { c: usize },
    /// Non-IID with per-class Dirichlet(alpha) client proportions — the
    /// standard heterogeneity benchmark axis (SparsyFed/SpaFL). Small
    /// alpha concentrates each class on few devices; large alpha
    /// approaches IID.
    Dirichlet { alpha: f64 },
}

/// How the server closes a round over the fleet's uplinks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregation {
    /// Synchronous barrier: every sampled device either reports within
    /// the deadline or maps to dropout (the pre-fleet behaviour).
    Sync,
    /// Buffered-async: the round closes once `k` uplinks have folded;
    /// later envelopes are not dropped but carried into the next round
    /// and folded with a staleness-discounted weight (their v2
    /// `trained_round` tag dates them).
    Buffered { k: usize },
}

/// Full experiment description (one figure line = one config).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Exported model name (see python/compile/model.py registry).
    pub model: String,
    /// Dataset name: mnist | cifar10 | cifar100 | tiny.
    pub dataset: String,
    pub algorithm: Algorithm,
    pub partition: Partition,
    /// Number of federated devices K.
    pub clients: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Local epochs per round (paper: 3).
    pub local_epochs: usize,
    /// Regularization strength lambda (eq. 12); 0 recovers FedPM.
    pub lambda: f32,
    /// Local SGD learning rate eta.
    pub lr: f32,
    /// Top-k keep fraction (TopK algorithm only).
    pub topk_frac: f64,
    /// SignSGD server step size.
    pub server_lr: f32,
    /// Training samples synthesized (or subsampled) per experiment.
    pub train_samples: usize,
    /// Held-out evaluation samples.
    pub test_samples: usize,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    /// Optimize local scores with Adam (FedPM practice) vs plain SGD.
    pub adam: bool,
    /// Fraction of devices sampled per round (paper: 1.0).
    pub participation: f64,
    /// Probability a sampled device drops before its uplink lands.
    pub dropout: f64,
    /// Server aggregation: eq. 8 mean, or Beta-posterior damping.
    pub bayes_prior: f64,
    /// Round-close policy: synchronous barrier or buffered-async
    /// (`aggregation = sync | buffered<K>`).
    pub aggregation: Aggregation,
    /// Staleness discount exponent beta: a fold that trained `gap`
    /// rounds ago contributes with weight scaled by `1/(1+gap)^beta`
    /// (0 = no discount; only the buffered path ever sees gap > 0).
    pub staleness_beta: f64,
    /// Hierarchical aggregation: number of edge-tier aggregators the
    /// cohort is split across (0 = flat single-tier fold). Edge folds
    /// are proven bit-identical to the flat ordered fold, so this is a
    /// topology knob, not a semantics knob.
    pub edges: usize,
    /// Downlink wire format: raw f32 (the paper's implicit 32 Bpp) or
    /// quantized sparse deltas with residual feedback (`qdelta<bits>`,
    /// DESIGN.md §Downlink). Clients train on exactly what this ships.
    pub downlink: DownlinkMode,
    /// Masked-eval forward implementation (`compute = blocked |
    /// packed`). `packed` runs evaluation through the bit-packed
    /// sign-select tier (falling back to blocked whenever the mask /
    /// weights pair is not packable); training always runs the blocked
    /// f32 path, so this is an eval-throughput knob, not a semantics
    /// knob (results agree within f32 reassociation tolerance).
    pub compute: Compute,
    /// Worker threads for the parallel round engine (0 = all cores,
    /// 1 = sequential reference path). Results are bit-identical at any
    /// value — this is a throughput knob, not a semantics knob.
    pub threads: usize,
    /// Root seed for everything.
    pub seed: u64,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Output metrics file (JSONL); empty = stdout summary only.
    pub out: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: "mlp_mnist".into(),
            dataset: "mnist".into(),
            algorithm: Algorithm::FedPMReg,
            partition: Partition::Iid,
            clients: 10,
            rounds: 30,
            local_epochs: 3,
            lambda: 1.0,
            lr: 0.2,
            topk_frac: 0.3,
            server_lr: 0.001,
            train_samples: 2000,
            test_samples: 512,
            eval_every: 1,
            adam: true,
            participation: 1.0,
            dropout: 0.0,
            bayes_prior: 0.0,
            aggregation: Aggregation::Sync,
            staleness_beta: 1.0,
            edges: 0,
            downlink: DownlinkMode::Float32,
            compute: Compute::Blocked,
            threads: 0,
            seed: 2023,
            artifacts_dir: "artifacts".into(),
            out: String::new(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML file ([experiment] section) + defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self::default();
        let flat = |doc: &BTreeMap<String, BTreeMap<String, TomlValue>>,
                    sect: &str|
         -> BTreeMap<String, TomlValue> {
            doc.get(sect).cloned().unwrap_or_default()
        };
        let mut kv = flat(&doc, "");
        kv.extend(flat(&doc, "experiment"));
        for (k, v) in kv {
            cfg.apply(&k, &v.to_string_raw())?;
        }
        Ok(cfg)
    }

    /// Apply one key=value override (CLI and TOML share this path).
    pub fn apply(&mut self, key: &str, val: &str) -> Result<()> {
        match key {
            "model" => self.model = val.to_string(),
            "dataset" => self.dataset = val.to_string(),
            "algorithm" => self.algorithm = Algorithm::parse(val)?,
            "partition" => {
                self.partition = match val {
                    "iid" => Partition::Iid,
                    other => {
                        if let Some(c) = other.strip_prefix("noniid") {
                            let c = c.trim_matches(|ch| ch == '_' || ch == '-');
                            Partition::NonIid { c: c.parse().context("noniid_<c>")? }
                        } else if let Some(a) = other.strip_prefix("dirichlet") {
                            let a = a.trim_matches(|ch| ch == ':' || ch == '_' || ch == '-');
                            Partition::Dirichlet {
                                alpha: a.parse().context("dirichlet:<alpha>")?,
                            }
                        } else {
                            bail!("partition must be iid | noniid_<c> | dirichlet:<alpha>")
                        }
                    }
                }
            }
            "clients" => self.clients = val.parse()?,
            "rounds" => self.rounds = val.parse()?,
            "local_epochs" => self.local_epochs = val.parse()?,
            "lambda" => self.lambda = val.parse()?,
            "lr" => self.lr = val.parse()?,
            "topk_frac" => self.topk_frac = val.parse()?,
            "server_lr" => self.server_lr = val.parse()?,
            "train_samples" => self.train_samples = val.parse()?,
            "test_samples" => self.test_samples = val.parse()?,
            "eval_every" => self.eval_every = val.parse()?,
            "adam" => self.adam = val.parse()?,
            "participation" => self.participation = val.parse()?,
            "dropout" => self.dropout = val.parse()?,
            "bayes_prior" => self.bayes_prior = val.parse()?,
            "aggregation" => {
                self.aggregation = match val {
                    "sync" => Aggregation::Sync,
                    other => {
                        if let Some(k) = other.strip_prefix("buffered") {
                            let k = k.trim_matches(|ch| {
                                ch == ':' || ch == '_' || ch == '-' || ch == '<' || ch == '>'
                            });
                            Aggregation::Buffered { k: k.parse().context("buffered<K>")? }
                        } else {
                            bail!("aggregation must be sync | buffered<K>")
                        }
                    }
                }
            }
            "staleness_beta" => self.staleness_beta = val.parse()?,
            "edges" => self.edges = val.parse()?,
            "downlink" => self.downlink = DownlinkMode::parse(val)?,
            "compute" => self.compute = Compute::parse(val)?,
            "optimizer" => {
                self.adam = match val {
                    "adam" => true,
                    "sgd" => false,
                    other => bail!("optimizer must be adam|sgd, got '{other}'"),
                }
            }
            "threads" => self.threads = val.parse()?,
            "seed" => self.seed = val.parse()?,
            "artifacts_dir" => self.artifacts_dir = val.to_string(),
            "out" => self.out = val.to_string(),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Sanity-check cross-field constraints before launch.
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("clients must be > 0");
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if self.local_epochs == 0 {
            bail!("local_epochs must be > 0");
        }
        if !(0.0..=1.0).contains(&self.topk_frac) {
            bail!("topk_frac must be in [0,1]");
        }
        if self.lambda < 0.0 {
            bail!("lambda must be >= 0");
        }
        if self.train_samples < self.clients {
            bail!("need at least one sample per client");
        }
        if let Partition::NonIid { c } = self.partition {
            if c == 0 {
                bail!("noniid c must be >= 1");
            }
        }
        if let Partition::Dirichlet { alpha } = self.partition {
            if !(alpha.is_finite() && alpha > 0.0) {
                bail!("dirichlet alpha must be a positive finite value");
            }
        }
        if let Aggregation::Buffered { k } = self.aggregation {
            if k == 0 {
                bail!("buffered aggregation needs K >= 1 folds per round");
            }
        }
        if !(self.staleness_beta.is_finite() && self.staleness_beta >= 0.0) {
            bail!("staleness_beta must be >= 0");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be > 0");
        }
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            bail!("participation must be in (0,1]");
        }
        if !(0.0..1.0).contains(&self.dropout) {
            bail!("dropout must be in [0,1)");
        }
        if self.bayes_prior < 0.0 {
            bail!("bayes_prior must be >= 0");
        }
        if self.algorithm == Algorithm::FedMRN && self.downlink != DownlinkMode::Float32 {
            // The noise seed rides every noise-theta envelope; a qdelta
            // frame chain has nowhere to carry it.
            bail!("fedmrn requires downlink=float32 (the noise seed rides the broadcast)");
        }
        Ok(())
    }

    /// FedPM is exactly FedPMReg with lambda = 0; normalize so the algos
    /// layer only needs one implementation.
    pub fn effective_lambda(&self) -> f32 {
        match self.algorithm {
            Algorithm::FedPM => 0.0,
            _ => self.lambda,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_round_trip() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            # figure 2a, lambda sweep point
            [experiment]
            model = "mlp_mnist"
            dataset = "mnist"
            algorithm = "fedpm_reg"
            partition = "noniid_2"
            clients = 30
            rounds = 100
            lambda = 0.1
            lr = 0.25
            seed = 7
            "#,
        )
        .unwrap();
        assert_eq!(cfg.clients, 30);
        assert_eq!(cfg.partition, Partition::NonIid { c: 2 });
        assert_eq!(cfg.algorithm, Algorithm::FedPMReg);
        assert!((cfg.lambda - 0.1).abs() < 1e-6);
        assert_eq!(cfg.seed, 7);
        cfg.validate().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml_str("typo_key = 3").is_err());
    }

    #[test]
    fn algorithm_parse_aliases() {
        assert_eq!(Algorithm::parse("ours").unwrap(), Algorithm::FedPMReg);
        assert_eq!(Algorithm::parse("MV-SignSGD").unwrap(), Algorithm::SignSGD);
        assert_eq!(Algorithm::parse("fedmrn").unwrap(), Algorithm::FedMRN);
        assert_eq!(Algorithm::parse("SpaFL").unwrap(), Algorithm::SpaFL);
        assert!(Algorithm::parse("sgd").is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = ExperimentConfig::default();
        cfg.clients = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.topk_frac = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.partition = Partition::NonIid { c: 0 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn fedpm_lambda_normalized_to_zero() {
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = Algorithm::FedPM;
        cfg.lambda = 5.0;
        assert_eq!(cfg.effective_lambda(), 0.0);
        cfg.algorithm = Algorithm::FedPMReg;
        assert_eq!(cfg.effective_lambda(), 5.0);
    }

    #[test]
    fn uplink_kind() {
        assert!(Algorithm::FedPMReg.uplink_is_binary());
        assert!(Algorithm::FedMRN.uplink_is_binary());
        assert!(!Algorithm::FedAvg.uplink_is_binary());
        assert!(!Algorithm::SpaFL.uplink_is_binary());
    }

    #[test]
    fn fedmrn_rejects_qdelta_downlink() {
        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = Algorithm::FedMRN;
        cfg.validate().unwrap();
        cfg.apply("downlink", "qdelta8").unwrap();
        assert!(cfg.validate().is_err(), "the seed cannot ride a delta chain");
        cfg.algorithm = Algorithm::SpaFL;
        cfg.validate().unwrap();
    }

    #[test]
    fn dirichlet_partition_parses_and_validates() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply("partition", "dirichlet:0.5").unwrap();
        assert_eq!(cfg.partition, Partition::Dirichlet { alpha: 0.5 });
        cfg.validate().unwrap();
        cfg.apply("partition", "dirichlet_2").unwrap();
        assert_eq!(cfg.partition, Partition::Dirichlet { alpha: 2.0 });
        assert!(cfg.apply("partition", "dirichlet:x").is_err());
        cfg.partition = Partition::Dirichlet { alpha: 0.0 };
        assert!(cfg.validate().is_err());
        cfg.partition = Partition::Dirichlet { alpha: f64::NAN };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn aggregation_key_parses_and_validates() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.aggregation, Aggregation::Sync);
        for spelling in ["buffered16", "buffered:16", "buffered_16", "buffered<16>"] {
            cfg.apply("aggregation", spelling).unwrap();
            assert_eq!(cfg.aggregation, Aggregation::Buffered { k: 16 }, "{spelling}");
        }
        cfg.validate().unwrap();
        cfg.apply("aggregation", "sync").unwrap();
        assert_eq!(cfg.aggregation, Aggregation::Sync);
        assert!(cfg.apply("aggregation", "async").is_err());
        cfg.aggregation = Aggregation::Buffered { k: 0 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn fleet_keys_parse_and_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply("staleness_beta", "0.5").unwrap();
        cfg.apply("edges", "4").unwrap();
        assert_eq!(cfg.staleness_beta, 0.5);
        assert_eq!(cfg.edges, 4);
        cfg.validate().unwrap();
        cfg.staleness_beta = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn compute_key_parses_and_defaults_to_blocked() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.compute, Compute::Blocked);
        cfg.apply("compute", "packed").unwrap();
        assert_eq!(cfg.compute, Compute::Packed);
        cfg.validate().unwrap();
        assert!(cfg.apply("compute", "fast").is_err());
        let cfg = ExperimentConfig::from_toml_str("[experiment]\ncompute = \"packed\"\n").unwrap();
        assert_eq!(cfg.compute, Compute::Packed);
    }

    #[test]
    fn downlink_key_parses_and_defaults_to_float32() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.downlink, DownlinkMode::Float32);
        cfg.apply("downlink", "qdelta8").unwrap();
        assert_eq!(cfg.downlink, DownlinkMode::QDelta { bits: 8 });
        cfg.validate().unwrap();
        assert!(cfg.apply("downlink", "qdelta99").is_err());
        let cfg = ExperimentConfig::from_toml_str(
            "[experiment]\ndownlink = \"qdelta4\"\n",
        )
        .unwrap();
        assert_eq!(cfg.downlink, DownlinkMode::QDelta { bits: 4 });
    }
}
