//! Minimal TOML-subset parser (no external dependencies).
//!
//! Supported: `[section]` headers, `key = value` pairs where value is a
//! quoted string, integer, float, or bool; full-line and trailing `#`
//! comments; blank lines. Arrays/tables/multiline strings are NOT
//! supported and produce an error — experiment configs never need them
//! and silent misparses are worse than a loud failure.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    /// The raw string form used by `ExperimentConfig::apply`.
    pub fn to_string_raw(&self) -> String {
        match self {
            TomlValue::Str(s) => s.clone(),
            TomlValue::Int(i) => i.to_string(),
            TomlValue::Float(f) => f.to_string(),
            TomlValue::Bool(b) => b.to_string(),
        }
    }
}

/// Parse a TOML-subset document into section -> key -> value.
/// Keys before any `[section]` land in the "" section.
pub fn parse_toml(
    text: &str,
) -> Result<BTreeMap<String, BTreeMap<String, TomlValue>>> {
    let mut doc: BTreeMap<String, BTreeMap<String, TomlValue>> = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected key = value, got '{line}'", lineno + 1);
        };
        let key = k.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(v.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone()).or_default().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if v.is_empty() {
        bail!("missing value");
    }
    if let Some(stripped) = v.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            bail!("unterminated string literal");
        };
        if inner.contains('"') {
            bail!("embedded quotes not supported");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if v.starts_with('[') {
        bail!("arrays not supported by this parser");
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{v}' (quote strings)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
            top = 1
            [a]
            s = "hello"   # trailing comment
            i = -42
            f = 3.5
            b = true
            [b]
            x = 0.1
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Int(1));
        assert_eq!(doc["a"]["s"], TomlValue::Str("hello".into()));
        assert_eq!(doc["a"]["i"], TomlValue::Int(-42));
        assert_eq!(doc["a"]["f"], TomlValue::Float(3.5));
        assert_eq!(doc["a"]["b"], TomlValue::Bool(true));
        assert_eq!(doc["b"]["x"], TomlValue::Float(0.1));
    }

    #[test]
    fn hash_inside_string_survives() {
        let doc = parse_toml(r##"k = "a#b""##).unwrap();
        assert_eq!(doc[""]["k"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("novalue =").is_err());
        assert!(parse_toml("= 3").is_err());
        assert!(parse_toml("k = [1, 2]").is_err());
        assert!(parse_toml("k = \"open").is_err());
        assert!(parse_toml("just a line").is_err());
    }

    #[test]
    fn raw_strings() {
        assert_eq!(TomlValue::Int(7).to_string_raw(), "7");
        assert_eq!(TomlValue::Bool(false).to_string_raw(), "false");
        assert_eq!(TomlValue::Str("x".into()).to_string_raw(), "x");
    }
}
