//! Mask codec: the uplink wire format.
//!
//! Races the adaptive arithmetic coder against Golomb-Rice and the raw
//! 1-bit-per-parameter packing, and ships whichever is smallest. The
//! header (method byte + u32 one-count + u32 payload bit-length) keeps
//! the format self-describing (the decoder needs `len` from the session
//! context, like any FL round does), and decoding *validates* it: the
//! recorded bit-length must match the bytes actually present and the
//! decoded mask must reproduce the recorded one-count — a truncated or
//! corrupt payload is an error, never silent garbage.
//!
//! This is what turns the paper's "≤ 1 Bpp" bound into actually-measured
//! uplink bytes in the experiment logs.
//!
//! audit: deterministic, panic-free

use anyhow::{bail, ensure, Result};

use super::{arithmetic, golomb};
use crate::util::BitVec;

/// Codec id in the wire header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Raw = 0,
    Arithmetic = 1,
    Golomb = 2,
}

impl Method {
    fn from_u8(b: u8) -> Option<Self> {
        match b {
            0 => Some(Method::Raw),
            1 => Some(Method::Arithmetic),
            2 => Some(Method::Golomb),
            _ => None,
        }
    }
}

/// An encoded mask as it would travel on the uplink.
#[derive(Debug, Clone)]
pub struct Encoded {
    pub method: Method,
    pub ones: u32,
    /// Recorded payload length in bits (byte-aligned by every coder
    /// here); `decode` checks it against the bytes actually present so
    /// truncation in transit is detected instead of decoded as garbage.
    pub bit_len: u32,
    pub payload: Vec<u8>,
}

impl Encoded {
    fn new(method: Method, ones: u32, payload: Vec<u8>) -> Self {
        let bit_len = payload.len() as u32 * 8;
        Self { method, ones, bit_len, payload }
    }

    /// Total wire bytes: header (1 method + 4 ones + 4 bit-length) +
    /// payload.
    pub fn wire_bytes(&self) -> usize {
        1 + 4 + 4 + self.payload.len()
    }

    /// Wire bits per mask parameter.
    pub fn bpp(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.wire_bytes() as f64 * 8.0 / n as f64
        }
    }

    /// Serialize to a flat byte vector.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.push(self.method as u8);
        out.extend_from_slice(&self.ones.to_le_bytes());
        out.extend_from_slice(&self.bit_len.to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse from a flat byte vector, validating the recorded payload
    /// bit-length against the bytes actually present.
    // audit:wire-decode-begin
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 9, "uplink header truncated ({} bytes)", bytes.len());
        let Some(method) = Method::from_u8(bytes[0]) else {
            bail!("unknown codec id {}", bytes[0]);
        };
        let ones = u32::from_le_bytes(bytes[1..5].try_into()?);
        let bit_len = u32::from_le_bytes(bytes[5..9].try_into()?);
        let payload = bytes[9..].to_vec();
        ensure!(
            (bit_len as usize).div_ceil(8) == payload.len(),
            "recorded bit-length {bit_len} does not match {} payload bytes",
            payload.len()
        );
        Ok(Self { method, ones, bit_len, payload })
    }
    // audit:wire-decode-end
}

fn pack_raw(mask: &BitVec) -> Vec<u8> {
    let mut out = vec![0u8; mask.raw_bytes()];
    for (i, bit) in mask.iter().enumerate() {
        if bit {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

fn unpack_raw(bytes: &[u8], len: usize) -> BitVec {
    BitVec::from_iter_len(
        (0..len).map(|i| (bytes[i / 8] >> (i % 8)) & 1 == 1),
        len,
    )
}

/// Encode with whichever method is smallest for this mask.
pub fn encode(mask: &BitVec) -> Encoded {
    let ones = mask.count_ones() as u32;
    let raw = pack_raw(mask);
    let arith = arithmetic::encode(mask);
    let gol = golomb::encode(mask);
    let (method, payload) =
        if arith.len() <= gol.len() && arith.len() <= raw.len() {
            (Method::Arithmetic, arith)
        } else if gol.len() <= raw.len() {
            (Method::Golomb, gol)
        } else {
            (Method::Raw, raw)
        };
    Encoded::new(method, ones, payload)
}

/// Encode with a forced method (for benchmarking individual coders).
pub fn encode_with(mask: &BitVec, method: Method) -> Encoded {
    let ones = mask.count_ones() as u32;
    let payload = match method {
        Method::Raw => pack_raw(mask),
        Method::Arithmetic => arithmetic::encode(mask),
        Method::Golomb => golomb::encode(mask),
    };
    Encoded::new(method, ones, payload)
}

/// Decode an uplink mask of `len` parameters.
///
/// Validates everything the wire header records before trusting the
/// payload: the one-count must fit in `len`, the recorded bit-length
/// must match the payload bytes present, raw/Rice payloads must have
/// exactly the size the mask demands, and the decoded mask must
/// reproduce the recorded one-count.
// audit:wire-decode-begin
pub fn decode(enc: &Encoded, len: usize) -> Result<BitVec> {
    ensure!(
        enc.ones as usize <= len,
        "one-count {} exceeds mask length {len}",
        enc.ones
    );
    ensure!(
        (enc.bit_len as usize).div_ceil(8) == enc.payload.len(),
        "recorded bit-length {} does not match {} payload bytes",
        enc.bit_len,
        enc.payload.len()
    );
    let mask = match enc.method {
        Method::Raw => {
            ensure!(
                enc.payload.len() == len.div_ceil(8),
                "raw payload is {} bytes, a {len}-bit mask needs {}",
                enc.payload.len(),
                len.div_ceil(8)
            );
            unpack_raw(&enc.payload, len)
        }
        Method::Arithmetic => arithmetic::decode(&enc.payload, len),
        Method::Golomb => golomb::decode(&enc.payload, len, enc.ones as usize)?,
    };
    ensure!(
        mask.count_ones() == enc.ones as usize,
        "decoded one-count {} does not match recorded {} (corrupt payload)",
        mask.count_ones(),
        enc.ones
    );
    Ok(mask)
}
// audit:wire-decode-end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_mask(n: usize, p: f64, seed: u64) -> BitVec {
        let mut rng = Xoshiro256::new(seed);
        BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
    }

    #[test]
    fn roundtrip_all_densities() {
        for &p in &[0.0, 0.005, 0.05, 0.3, 0.5, 0.8, 1.0] {
            let m = random_mask(30_000, p, 21);
            let enc = encode(&m);
            assert_eq!(decode(&enc, m.len()).unwrap(), m, "p={p} method={:?}", enc.method);
        }
    }

    #[test]
    fn never_worse_than_raw_plus_header() {
        for &p in &[0.01, 0.5, 0.99] {
            let m = random_mask(10_000, p, 4);
            let enc = encode(&m);
            assert!(enc.payload.len() <= m.raw_bytes(), "p={p}");
        }
    }

    #[test]
    fn picks_entropy_coder_for_sparse() {
        let m = random_mask(50_000, 0.02, 6);
        let enc = encode(&m);
        assert_ne!(enc.method, Method::Raw);
        assert!(enc.bpp(m.len()) < 0.25, "bpp={}", enc.bpp(m.len()));
    }

    #[test]
    fn serialization_roundtrip() {
        let m = random_mask(5_000, 0.1, 8);
        let enc = encode(&m);
        let parsed = Encoded::from_bytes(&enc.to_bytes()).unwrap();
        assert_eq!(parsed.method, enc.method);
        assert_eq!(parsed.ones, enc.ones);
        assert_eq!(parsed.bit_len, enc.bit_len);
        assert_eq!(decode(&parsed, m.len()).unwrap(), m);
    }

    #[test]
    fn forced_methods_all_roundtrip() {
        let m = random_mask(8_000, 0.07, 10);
        for method in [Method::Raw, Method::Arithmetic, Method::Golomb] {
            let enc = encode_with(&m, method);
            assert_eq!(decode(&enc, m.len()).unwrap(), m, "{method:?}");
        }
    }

    #[test]
    fn from_bytes_rejects_garbage() {
        assert!(Encoded::from_bytes(&[]).is_err());
        assert!(Encoded::from_bytes(&[9, 0, 0, 0, 0, 0, 0, 0, 0, 1]).is_err());
        // valid header shape but recorded bit-length disagrees with bytes
        let m = random_mask(1000, 0.2, 11);
        let mut bytes = encode(&m).to_bytes();
        bytes.push(0); // payload longer than the header records
        assert!(Encoded::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        for method in [Method::Raw, Method::Arithmetic, Method::Golomb] {
            let m = random_mask(4_000, 0.1, 13);
            let enc = encode_with(&m, method);
            let bytes = enc.to_bytes();
            // chop wire bytes: either the header parse or the decode must fail
            let chopped = &bytes[..bytes.len() - 2];
            let outcome = Encoded::from_bytes(chopped).and_then(|e| decode(&e, m.len()));
            assert!(outcome.is_err(), "{method:?}: truncated payload must not decode");
        }
    }

    #[test]
    fn length_mismatched_header_rejected() {
        let m = random_mask(4_000, 0.1, 14);
        let mut enc = encode(&m);
        enc.bit_len += 8; // header claims one more payload byte than present
        assert!(decode(&enc, m.len()).is_err());
        let mut enc = encode(&m);
        enc.ones = enc.ones.wrapping_add(1); // one-count corrupted in transit
        assert!(decode(&enc, m.len()).is_err());
        // raw payloads also validate against the session's mask length
        let enc = encode_with(&m, Method::Raw);
        assert!(decode(&enc, m.len() + 64).is_err(), "wrong session length must not decode");
    }

    #[test]
    fn oversized_one_count_rejected() {
        let m = random_mask(100, 0.5, 15);
        let mut enc = encode(&m);
        enc.ones = 101;
        assert!(decode(&enc, 100).is_err());
    }
}
