//! Bit-granular I/O used by every entropy coder in this module.
//!
//! Bits are written MSB-first within each byte; the writer tracks the
//! exact bit count so communication accounting can report fractional
//! bytes honestly.
//!
//! audit: deterministic, panic-free

/// MSB-first bit writer over a growable byte buffer.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Bits already used in the final byte (0..8).
    bit_pos: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        if self.bit_pos == 0 {
            self.bytes.push(0);
        }
        if bit {
            let last = self.bytes.len() - 1;
            self.bytes[last] |= 1 << (7 - self.bit_pos);
        }
        self.bit_pos = (self.bit_pos + 1) % 8;
    }

    /// Write the low `n` bits of `v`, most significant first.
    pub fn put_bits(&mut self, v: u64, n: u8) {
        debug_assert!(n <= 64);
        for i in (0..n).rev() {
            self.put_bit((v >> i) & 1 == 1);
        }
    }

    /// `q` one-bits followed by a zero (unary code).
    pub fn put_unary(&mut self, q: u64) {
        for _ in 0..q {
            self.put_bit(true);
        }
        self.put_bit(false);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        if self.bit_pos == 0 {
            self.bytes.len() * 8
        } else {
            (self.bytes.len() - 1) * 8 + self.bit_pos as usize
        }
    }

    /// Finish and return the padded byte buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// MSB-first bit reader over a byte slice.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize, // bit cursor
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// Next bit; reads 0 past the end (coders carry explicit lengths, so
    /// trailing-zero padding is never ambiguous).
    #[inline]
    pub fn get_bit(&mut self) -> bool {
        let byte = self.pos / 8;
        let bit = if byte < self.bytes.len() {
            (self.bytes[byte] >> (7 - (self.pos % 8))) & 1 == 1
        } else {
            false
        };
        self.pos += 1;
        bit
    }

    /// Read `n` bits MSB-first into the low bits of the result.
    pub fn get_bits(&mut self, n: u8) -> u64 {
        debug_assert!(n <= 64);
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.get_bit() as u64;
        }
        v
    }

    /// Count ones until the terminating zero (unary decode).
    pub fn get_unary(&mut self) -> u64 {
        let mut q = 0;
        while self.get_bit() {
            q += 1;
            debug_assert!(q < 1 << 40, "runaway unary decode");
        }
        q
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let bits: Vec<bool> = (0..100).map(|i| (i * 7) % 3 == 0).collect();
        let mut w = BitWriter::new();
        for &b in &bits {
            w.put_bit(b);
        }
        assert_eq!(w.bit_len(), 100);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(r.get_bit(), b, "bit {i}");
        }
    }

    #[test]
    fn bits_roundtrip() {
        let mut w = BitWriter::new();
        w.put_bits(0b101101, 6);
        w.put_bits(0xFFFF_FFFF_FFFF, 48);
        w.put_bits(0, 1);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get_bits(6), 0b101101);
        assert_eq!(r.get_bits(48), 0xFFFF_FFFF_FFFF);
        assert_eq!(r.get_bits(1), 0);
    }

    #[test]
    fn unary_roundtrip() {
        let vals = [0u64, 1, 2, 7, 31, 100];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.put_unary(v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(r.get_unary(), v);
        }
    }

    #[test]
    fn read_past_end_is_zero() {
        let mut r = BitReader::new(&[0xFF]);
        assert_eq!(r.get_bits(8), 0xFF);
        assert_eq!(r.get_bits(8), 0);
    }

    #[test]
    fn bit_len_partial_byte() {
        let mut w = BitWriter::new();
        w.put_bits(0b101, 3);
        assert_eq!(w.bit_len(), 3);
        assert_eq!(w.as_bytes().len(), 1);
    }
}
