//! Adaptive binary arithmetic coder.
//!
//! The uplink masks are Bernoulli(p) sources with p drifting over rounds
//! (that is the whole point of the regularizer); an adaptive binary
//! arithmetic coder tracks p online and compresses to within a few
//! hundredths of a bit of the empirical entropy H(p) — so "measured
//! uplink bits / n" in the experiment logs is an *achieved* rate, not an
//! estimate (paper eq. 13 is logged alongside).
//!
//! Classic Witten-Neal-Cleary construction over 32-bit registers with an
//! adaptive zero/one counter model.
//!
//! audit: deterministic, panic-free

use super::bitstream::{BitReader, BitWriter};
use crate::util::BitVec;

const TOP: u32 = 0xFFFF_FFFF;
const QTR: u32 = 0x4000_0000;
const HALF: u32 = 0x8000_0000;
const THREE_QTR: u32 = 0xC000_0000;

/// Adaptive zero/one frequency model with +1 smoothing and periodic
/// halving (so it tracks non-stationary p as training sparsifies masks).
#[derive(Debug, Clone)]
struct Adaptive {
    c0: u32,
    c1: u32,
}

impl Adaptive {
    fn new() -> Self {
        Self { c0: 1, c1: 1 }
    }

    #[inline]
    fn total(&self) -> u64 {
        self.c0 as u64 + self.c1 as u64
    }

    #[inline]
    fn update(&mut self, bit: bool) {
        if bit {
            self.c1 += 1;
        } else {
            self.c0 += 1;
        }
        // Rescale keeps the model responsive to drift and the range
        // arithmetic inside 32 bits.
        if self.total() >= 1 << 16 {
            self.c0 = (self.c0 >> 1).max(1);
            self.c1 = (self.c1 >> 1).max(1);
        }
    }
}

/// Encode a bit vector; returns the coded bytes.
pub fn encode(mask: &BitVec) -> Vec<u8> {
    let mut model = Adaptive::new();
    let mut w = BitWriter::new();
    let mut low: u32 = 0;
    let mut high: u32 = TOP;
    let mut pending: u32 = 0;

    let emit = |w: &mut BitWriter, bit: bool, pending: &mut u32| {
        w.put_bit(bit);
        while *pending > 0 {
            w.put_bit(!bit);
            *pending -= 1;
        }
    };

    for bit in mask.iter() {
        let range = (high - low) as u64 + 1;
        let split = low + ((range * model.c0 as u64 / model.total()) as u32) - 1;
        if bit {
            low = split + 1;
        } else {
            high = split;
        }
        loop {
            if high < HALF {
                emit(&mut w, false, &mut pending);
            } else if low >= HALF {
                emit(&mut w, true, &mut pending);
                low -= HALF;
                high -= HALF;
            } else if low >= QTR && high < THREE_QTR {
                pending += 1;
                low -= QTR;
                high -= QTR;
            } else {
                break;
            }
            low <<= 1;
            high = (high << 1) | 1;
        }
        model.update(bit);
    }
    // Flush: two disambiguating bits.
    pending += 1;
    if low < QTR {
        emit(&mut w, false, &mut pending);
    } else {
        emit(&mut w, true, &mut pending);
    }
    w.into_bytes()
}

/// Decode `len` bits from `bytes` (must be the output of [`encode`]).
// audit:wire-decode-begin
pub fn decode(bytes: &[u8], len: usize) -> BitVec {
    let mut model = Adaptive::new();
    let mut r = BitReader::new(bytes);
    let mut low: u32 = 0;
    let mut high: u32 = TOP;
    // audit:checked(get_bits(32) reads exactly 32 bits, so the value fits u32)
    let mut code: u32 = r.get_bits(32) as u32;
    let mut out = BitVec::zeros(len);

    for i in 0..len {
        let range = (high - low) as u64 + 1;
        // audit:checked(range <= 2^32 and c0/total < 1, so the product stays below 2^32)
        let split = low + ((range * model.c0 as u64 / model.total()) as u32) - 1;
        let bit = code > split;
        if bit {
            low = split + 1;
        } else {
            high = split;
        }
        if bit {
            out.set(i, true);
        }
        loop {
            if high < HALF {
                // nothing
            } else if low >= HALF {
                low -= HALF;
                high -= HALF;
                code -= HALF;
            } else if low >= QTR && high < THREE_QTR {
                low -= QTR;
                high -= QTR;
                code -= QTR;
            } else {
                break;
            }
            low <<= 1;
            high = (high << 1) | 1;
            // audit:checked(a bool widens losslessly into u32)
            code = (code << 1) | r.get_bit() as u32;
        }
        model.update(bit);
    }
    out
}
// audit:wire-decode-end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_mask(n: usize, p: f64, seed: u64) -> BitVec {
        let mut rng = Xoshiro256::new(seed);
        BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
    }

    #[test]
    fn roundtrip_various_densities() {
        for &p in &[0.0, 0.01, 0.1, 0.5, 0.9, 1.0] {
            let m = random_mask(10_000, p, 42);
            let coded = encode(&m);
            assert_eq!(decode(&coded, m.len()), m, "p={p}");
        }
    }

    #[test]
    fn roundtrip_small_lengths() {
        for n in 0..40 {
            let m = random_mask(n, 0.3, n as u64);
            assert_eq!(decode(&encode(&m), n), m, "n={n}");
        }
    }

    #[test]
    fn compresses_sparse_to_near_entropy() {
        let n = 100_000;
        let p = 0.03;
        let m = random_mask(n, p, 7);
        let bits = encode(&m).len() as f64 * 8.0;
        let h = -(p * p.log2() + (1.0 - p) * (1.0 - p).log2());
        let rate = bits / n as f64;
        // within 10% + a small constant of the source entropy
        assert!(rate < h * 1.10 + 0.01, "rate={rate:.4} H={h:.4}");
    }

    #[test]
    fn dense_mask_stays_near_one_bpp() {
        let n = 50_000;
        let m = random_mask(n, 0.5, 3);
        let rate = encode(&m).len() as f64 * 8.0 / n as f64;
        assert!(rate < 1.02, "rate={rate}");
        assert!(rate > 0.98, "suspiciously good rate for p=0.5: {rate}");
    }

    #[test]
    fn nonstationary_source_adapts() {
        // p drifts 0.5 -> 0.02 across the vector (what training does).
        let n = 60_000;
        let mut rng = Xoshiro256::new(11);
        let m = BitVec::from_iter_len(
            (0..n).map(|i| {
                let p = 0.5 - 0.48 * (i as f64 / n as f64);
                rng.next_f64() < p
            }),
            n,
        );
        let coded = encode(&m);
        assert_eq!(decode(&coded, n), m);
        let rate = coded.len() as f64 * 8.0 / n as f64;
        assert!(rate < 0.95, "adaptive model should beat 1 Bpp, got {rate}");
    }
}
