//! Compressed downlink: the server->client direction of the wire.
//!
//! The paper's evaluation axis is *uplink* bits per parameter, and its
//! own accounting (like ours before this module existed) shipped the
//! global state downlink as raw f32 — 32 Bpp every round, dominating
//! total traffic in the direction nobody was compressing. This module
//! closes that gap (DESIGN.md §Downlink):
//!
//! * The server broadcasts the global state (theta for the mask family,
//!   dense weights for the baselines) as **quantized sparse deltas**
//!   against the previous round's broadcast: a uniform b-bit quantizer
//!   over the changed coordinates, a changed-coordinate bitmap entropy-
//!   coded by the existing mask codec (adaptive arithmetic / Golomb),
//!   and a dense-f32 fallback whenever delta coding would not pay.
//! * **Residual feedback** is structural: deltas are always computed
//!   against the *reconstruction the clients hold* (`recon`), so every
//!   quantization error and every coordinate withheld by the per-round
//!   change cap stays in the next round's delta until it is sent. The
//!   reconstruction converges to the server state geometrically when
//!   the state stops moving (property-tested in `tests/properties.rs`).
//! * Clients must train against the reconstruction — the quantized
//!   state they actually received — never the server's exact vector;
//!   otherwise the simulation under-reports the scheme's accuracy cost.
//!   Strategies read `recon()` after `broadcast()` for exactly this.
//!
//! Framing is versionless but self-describing; [`DownlinkFrame`] is the
//! unit that would travel on the wire and `from_bytes`/`decode` validate
//! every recorded length against the bytes actually present.
//!
//! audit: deterministic, panic-free

use anyhow::{bail, ensure, Context, Result};

use super::bitstream::{BitReader, BitWriter};
use super::codec::{self, Encoded};
use crate::util::BitVec;

/// Downlink compression mode (config key `downlink`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DownlinkMode {
    /// Raw f32 broadcast, 32 Bpp — the paper's (implicit) setting and
    /// the backward-compatible default.
    Float32,
    /// Quantized sparse deltas against the previous broadcast with a
    /// uniform `bits`-bit quantizer (sign + magnitude per changed
    /// coordinate) and server-side residual feedback.
    QDelta { bits: u8 },
}

impl DownlinkMode {
    /// Parse a config value: `float32` | `qdelta` (8 bits) | `qdelta<b>`
    /// with b in 2..=16.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "float32" | "f32" | "dense" => Ok(DownlinkMode::Float32),
            "qdelta" => Ok(DownlinkMode::QDelta { bits: 8 }),
            other => {
                let Some(b) = other.strip_prefix("qdelta") else {
                    bail!("downlink must be float32 | qdelta<bits>, got '{other}'");
                };
                let bits: u8 = b.parse().with_context(|| format!("qdelta bits in '{other}'"))?;
                ensure!(
                    (2..=16).contains(&bits),
                    "qdelta bits must be in 2..=16, got {bits}"
                );
                Ok(DownlinkMode::QDelta { bits })
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            DownlinkMode::Float32 => "float32".to_string(),
            DownlinkMode::QDelta { bits } => format!("qdelta{bits}"),
        }
    }
}

/// At most this fraction of coordinates is shipped per delta frame; the
/// rest stays in the residual and rides a later round. This caps the
/// worst-case delta rate at roughly `frac*bits + H(frac)` Bpp (≈ 2.8 for
/// qdelta8) — without it, early rounds where every theta coordinate
/// moves would cost the full `bits` per parameter.
const MAX_CHANGED_FRAC_INV: usize = 4;

/// Frame kinds on the wire.
const KIND_DENSE: u8 = 0;
const KIND_DELTA: u8 = 1;

#[derive(Debug, Clone)]
enum Body {
    /// Raw f32 payload (first broadcast, or fallback when deltas are
    /// dense enough that delta framing would cost more than floats).
    Dense { values: Vec<f32> },
    /// Changed-coordinate bitmap (entropy-coded) + packed sign/magnitude
    /// quantizer indices, `bits` per changed coordinate.
    Delta { bits: u8, n: u32, step: f32, bitmap: Encoded, packed: Vec<u8> },
}

/// One downlink broadcast as it would travel on the wire.
#[derive(Debug, Clone)]
pub struct DownlinkFrame {
    body: Body,
}

impl DownlinkFrame {
    /// Parameter count this frame covers.
    pub fn n(&self) -> usize {
        match &self.body {
            Body::Dense { values } => values.len(),
            Body::Delta { n, .. } => *n as usize,
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self.body, Body::Dense { .. })
    }

    /// Total serialized size in bytes (what the accounting records).
    pub fn wire_bytes(&self) -> usize {
        match &self.body {
            Body::Dense { values } => 1 + 4 + 4 * values.len(),
            Body::Delta { bitmap, packed, .. } => {
                1 + 1 + 4 + 4 + 4 + bitmap.wire_bytes() + 4 + packed.len()
            }
        }
    }

    pub fn wire_bits(&self) -> u64 {
        self.wire_bytes() as u64 * 8
    }

    /// Serialize to a flat byte vector (little-endian).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        match &self.body {
            Body::Dense { values } => {
                out.push(KIND_DENSE);
                out.extend_from_slice(&(values.len() as u32).to_le_bytes());
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Body::Delta { bits, n, step, bitmap, packed } => {
                out.push(KIND_DELTA);
                out.push(*bits);
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(&step.to_le_bytes());
                let bm = bitmap.to_bytes();
                out.extend_from_slice(&(bm.len() as u32).to_le_bytes());
                out.extend_from_slice(&bm);
                out.extend_from_slice(&(packed.len() as u32).to_le_bytes());
                out.extend_from_slice(packed);
            }
        }
        out
    }

    /// Parse and validate a frame. Every recorded length is checked
    /// against the bytes actually present — a truncated or padded
    /// payload is an error, never silent garbage.
    // audit:wire-decode-begin
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, k: usize| -> Result<&[u8]> {
            ensure!(*pos + k <= bytes.len(), "downlink frame truncated");
            // audit:checked(the ensure above bounds pos + k by bytes.len())
            let s = &bytes[*pos..*pos + k];
            *pos += k;
            Ok(s)
        };
        let kind = take(&mut pos, 1)?[0];
        match kind {
            KIND_DENSE => {
                let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                ensure!(
                    bytes.len() == 5 + 4 * n,
                    "dense frame records {n} params but carries {} payload bytes",
                    bytes.len().saturating_sub(5)
                );
                let mut values = Vec::with_capacity(n);
                for _ in 0..n {
                    values.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into()?));
                }
                Ok(Self { body: Body::Dense { values } })
            }
            KIND_DELTA => {
                let bits = take(&mut pos, 1)?[0];
                ensure!((2..=16).contains(&bits), "delta frame bits {bits} out of range");
                let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
                let step = f32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
                ensure!(step.is_finite() && step >= 0.0, "delta frame step {step} invalid");
                let bm_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let bitmap = Encoded::from_bytes(take(&mut pos, bm_len)?)
                    .context("delta frame bitmap")?;
                ensure!(bitmap.ones <= n, "bitmap one-count {} exceeds n {n}", bitmap.ones);
                let packed_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let need = ((bitmap.ones as usize) * bits as usize).div_ceil(8);
                ensure!(
                    packed_len == need,
                    "delta frame carries {packed_len} value bytes, {} changed coords at \
                     {bits} bits need {need}",
                    bitmap.ones
                );
                let packed = take(&mut pos, packed_len)?.to_vec();
                ensure!(pos == bytes.len(), "trailing bytes after downlink frame");
                Ok(Self { body: Body::Delta { bits, n, step, bitmap, packed } })
            }
            other => bail!("unknown downlink frame kind {other}"),
        }
    }

    /// Reconstruct the broadcast state. Delta frames need `prev` — the
    /// reconstruction this client held after the previous round. The
    /// result is bit-identical to the server's own `recon` (both sides
    /// compute `prev + q*step` in the same f32 order).
    pub fn decode(&self, prev: Option<&[f32]>) -> Result<Vec<f32>> {
        // (still inside the wire-decode fence opened at from_bytes: both
        // functions parse what arrived off the wire.)
        match &self.body {
            Body::Dense { values } => {
                if let Some(p) = prev {
                    ensure!(
                        p.len() == values.len(),
                        "dense frame for {} params, client holds {}",
                        values.len(),
                        p.len()
                    );
                }
                Ok(values.clone())
            }
            Body::Delta { bits, n, step, bitmap, packed } => {
                let n = *n as usize;
                let prev = prev.context("delta frame needs the previous broadcast state")?;
                ensure!(
                    prev.len() == n,
                    "delta frame for {n} params, client holds {}",
                    prev.len()
                );
                let changed = codec::decode(bitmap, n).context("delta frame bitmap")?;
                let mut out = prev.to_vec();
                let mut r = BitReader::new(packed);
                for idx in changed.iter_ones() {
                    let neg = r.get_bit();
                    let mag = r.get_bits(*bits - 1);
                    ensure!(mag >= 1, "zero quantizer magnitude (corrupt delta payload)");
                    let q = if neg { -(mag as i64) } else { mag as i64 };
                    // audit:checked(the bitmap codec bounds idx by n == out.len())
                    out[idx] = prev[idx] + q as f32 * step;
                }
                // Truncation is impossible here: `from_bytes` already
                // enforced packed_len == ceil(ones*bits/8), and the loop
                // consumes exactly ones*bits bits.
                Ok(out)
            }
        }
    }
    // audit:wire-decode-end
}

/// Server-side downlink state: the mode plus the reconstruction every
/// client currently holds. Residual feedback is implicit — deltas are
/// computed against `recon`, so what a frame fails to deliver this round
/// (quantization error, capped coordinates) is still pending next round.
#[derive(Debug, Clone)]
pub struct DownlinkEncoder {
    mode: DownlinkMode,
    recon: Vec<f32>,
}

impl DownlinkEncoder {
    pub fn new(mode: DownlinkMode) -> Self {
        Self { mode, recon: Vec::new() }
    }

    pub fn mode(&self) -> DownlinkMode {
        self.mode
    }

    /// The state the clients hold after the last `broadcast` (equal to
    /// the broadcast state exactly under `Float32`, quantized under
    /// `QDelta`). Empty before the first broadcast.
    pub fn recon(&self) -> &[f32] {
        &self.recon
    }

    /// Broadcast `state` to the fleet: updates `recon` and returns the
    /// per-client wire bits the accounting should record.
    ///
    /// `Float32` is counted as raw floats (n * 32 bits, no framing) so
    /// the baseline matches the paper's accounting bit-for-bit.
    pub fn broadcast(&mut self, state: &[f32]) -> u64 {
        match self.mode {
            DownlinkMode::Float32 => {
                self.recon = state.to_vec();
                state.len() as u64 * 32
            }
            DownlinkMode::QDelta { .. } => self.encode_frame(state).wire_bits(),
        }
    }

    /// What the fleet would hold if `state` were broadcast right now,
    /// without committing anything to the stream — used to evaluate the
    /// model the way a device would actually see it.
    pub fn preview(&self, state: &[f32]) -> Vec<f32> {
        match self.mode {
            DownlinkMode::Float32 => state.to_vec(),
            DownlinkMode::QDelta { .. } => {
                let mut probe = self.clone();
                probe.broadcast(state);
                probe.recon
            }
        }
    }

    /// Encode the next broadcast of `state` as an explicit wire frame,
    /// advancing `recon` to what the clients will reconstruct from it.
    pub fn encode_frame(&mut self, state: &[f32]) -> DownlinkFrame {
        let bits = match self.mode {
            DownlinkMode::Float32 => {
                return self.dense_frame(state);
            }
            DownlinkMode::QDelta { bits } => bits,
        };
        if self.recon.len() != state.len() {
            // First broadcast (or a model swap): nothing to delta against.
            return self.dense_frame(state);
        }

        let n = state.len();
        let qmax = (1i64 << (bits - 1)) - 1;
        let deltas: Vec<f32> = state.iter().zip(&self.recon).map(|(&s, &r)| s - r).collect();
        let max_abs = deltas.iter().fold(0.0f32, |m, &d| m.max(d.abs()));
        if max_abs == 0.0 {
            // Nothing changed: an empty bitmap is the cheapest truth.
            let bitmap = codec::encode(&BitVec::zeros(n));
            return DownlinkFrame {
                body: Body::Delta { bits, n: n as u32, step: 0.0, bitmap, packed: Vec::new() },
            };
        }
        let step = max_abs / qmax as f32;
        let mut q: Vec<i64> = deltas
            .iter()
            .map(|&d| ((d / step).round() as i64).clamp(-qmax, qmax))
            .collect();

        // Per-round change cap: ship only the largest |delta| coordinates
        // when too many moved; the rest stays in the residual.
        let cap = (n / MAX_CHANGED_FRAC_INV).max(1);
        let mut changed: Vec<usize> = (0..n).filter(|&i| q[i] != 0).collect();
        if changed.len() > cap {
            changed.sort_unstable_by(|&a, &b| {
                deltas[b].abs().total_cmp(&deltas[a].abs()).then(a.cmp(&b))
            });
            for &i in &changed[cap..] {
                q[i] = 0;
            }
            changed.truncate(cap);
            changed.sort_unstable();
        }

        let bitmap_bits = BitVec::from_iter_len((0..n).map(|i| q[i] != 0), n);
        let bitmap = codec::encode(&bitmap_bits);
        let mut w = BitWriter::new();
        for &i in &changed {
            w.put_bit(q[i] < 0);
            w.put_bits(q[i].unsigned_abs(), bits - 1);
        }
        let packed = w.into_bytes();

        let frame = DownlinkFrame {
            body: Body::Delta { bits, n: n as u32, step, bitmap, packed },
        };
        if frame.wire_bytes() >= 1 + 4 + 4 * n {
            // Deltas so dense that raw floats are cheaper — fall back.
            return self.dense_frame(state);
        }
        for &i in &changed {
            self.recon[i] += q[i] as f32 * step;
        }
        frame
    }

    fn dense_frame(&mut self, state: &[f32]) -> DownlinkFrame {
        self.recon = state.to_vec();
        DownlinkFrame { body: Body::Dense { values: state.to_vec() } }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn uniform(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..n).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn parse_modes() {
        assert_eq!(DownlinkMode::parse("float32").unwrap(), DownlinkMode::Float32);
        assert_eq!(DownlinkMode::parse("qdelta").unwrap(), DownlinkMode::QDelta { bits: 8 });
        assert_eq!(DownlinkMode::parse("qdelta4").unwrap(), DownlinkMode::QDelta { bits: 4 });
        assert_eq!(DownlinkMode::parse("QDelta8").unwrap(), DownlinkMode::QDelta { bits: 8 });
        assert!(DownlinkMode::parse("qdelta1").is_err());
        assert!(DownlinkMode::parse("qdelta17").is_err());
        assert!(DownlinkMode::parse("huffman").is_err());
        assert_eq!(DownlinkMode::parse("qdelta8").unwrap().name(), "qdelta8");
    }

    #[test]
    fn float32_mode_is_exact_and_32bpp() {
        let state = uniform(1000, 1);
        let mut enc = DownlinkEncoder::new(DownlinkMode::Float32);
        let bits = enc.broadcast(&state);
        assert_eq!(bits, 32_000);
        assert_eq!(enc.recon(), &state[..]);
        assert_eq!(enc.preview(&state), state);
    }

    #[test]
    fn first_qdelta_broadcast_is_dense_and_exact() {
        let state = uniform(500, 2);
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        let frame = enc.encode_frame(&state);
        assert!(frame.is_dense());
        assert_eq!(enc.recon(), &state[..]);
        let decoded = DownlinkFrame::from_bytes(&frame.to_bytes()).unwrap();
        assert_eq!(decoded.decode(None).unwrap(), state);
    }

    #[test]
    fn delta_roundtrip_matches_server_recon_bit_for_bit() {
        let n = 4000;
        let a = uniform(n, 3);
        let mut rng = Xoshiro256::new(4);
        // ~30% of coordinates move
        let b: Vec<f32> = a
            .iter()
            .map(|&v| if rng.next_f64() < 0.3 { v + 0.2 * (rng.next_f32() - 0.5) } else { v })
            .collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        let f0 = enc.encode_frame(&a);
        let client0 = DownlinkFrame::from_bytes(&f0.to_bytes()).unwrap().decode(None).unwrap();
        assert_eq!(client0, enc.recon());
        let f1 = enc.encode_frame(&b);
        assert!(!f1.is_dense());
        let client1 = DownlinkFrame::from_bytes(&f1.to_bytes())
            .unwrap()
            .decode(Some(&client0))
            .unwrap();
        let server: Vec<u32> = enc.recon().iter().map(|v| v.to_bits()).collect();
        let client: Vec<u32> = client1.iter().map(|v| v.to_bits()).collect();
        assert_eq!(server, client, "client and server reconstructions diverged");
    }

    #[test]
    fn unchanged_state_costs_almost_nothing() {
        let state = uniform(10_000, 5);
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        enc.broadcast(&state);
        let bits = enc.broadcast(&state);
        assert!(bits < 2_000, "empty delta should be tiny, got {bits} bits");
        assert_eq!(enc.recon(), &state[..]);
    }

    #[test]
    fn change_cap_bounds_the_rate() {
        let n = 20_000;
        let a = uniform(n, 6);
        let b = uniform(n, 7); // every coordinate moves
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        enc.broadcast(&a);
        let bits = enc.broadcast(&b);
        let bpp = bits as f64 / n as f64;
        assert!(bpp < 4.0, "capped delta must stay under 4 Bpp, got {bpp:.3}");
    }

    #[test]
    fn residual_feedback_converges_to_target() {
        let n = 512;
        let a = uniform(n, 8);
        let b: Vec<f32> = a.iter().map(|&v| v + 0.5).collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        enc.broadcast(&a);
        for _ in 0..12 {
            enc.broadcast(&b);
        }
        let err = enc
            .recon()
            .iter()
            .zip(&b)
            .fold(0.0f32, |m, (&r, &t)| m.max((r - t).abs()));
        assert!(err < 1e-4, "residual feedback must converge, err={err}");
    }

    #[test]
    fn truncated_and_corrupt_frames_rejected() {
        let a = uniform(300, 9);
        let b: Vec<f32> = a.iter().map(|&v| v + 0.1).collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 6 });
        enc.encode_frame(&a);
        let frame = enc.encode_frame(&b);
        let bytes = frame.to_bytes();
        // truncation at any point must be caught
        assert!(DownlinkFrame::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(DownlinkFrame::from_bytes(&bytes[..3]).is_err());
        assert!(DownlinkFrame::from_bytes(&[]).is_err());
        // unknown kind
        let mut bad = bytes.clone();
        bad[0] = 9;
        assert!(DownlinkFrame::from_bytes(&bad).is_err());
        // delta frame without the previous state
        let parsed = DownlinkFrame::from_bytes(&bytes).unwrap();
        assert!(parsed.decode(None).is_err());
        // wrong prev length
        assert!(parsed.decode(Some(&a[..10])).is_err());
    }

    #[test]
    fn dense_fallback_when_deltas_do_not_pay() {
        // 16-bit deltas on a 4-float vector: delta framing (~30 B of
        // headers + bitmap + values) exceeds the 21-B dense frame, so
        // the encoder must fall back to exact floats.
        let a = uniform(4, 10);
        let b = uniform(4, 11);
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 16 });
        enc.encode_frame(&a);
        let frame = enc.encode_frame(&b);
        assert!(frame.is_dense());
        assert_eq!(enc.recon(), &b[..]);
    }

    #[test]
    fn preview_matches_committed_broadcast_without_advancing_state() {
        let n = 2000;
        let a = uniform(n, 12);
        let b: Vec<f32> = a.iter().map(|&v| v + 0.05).collect();
        let mut enc = DownlinkEncoder::new(DownlinkMode::QDelta { bits: 8 });
        enc.broadcast(&a);
        let before = enc.recon().to_vec();
        let previewed = enc.preview(&b);
        assert_eq!(enc.recon(), &before[..], "preview must not commit");
        enc.broadcast(&b);
        let committed: Vec<u32> = enc.recon().iter().map(|v| v.to_bits()).collect();
        let previewed: Vec<u32> = previewed.iter().map(|v| v.to_bits()).collect();
        assert_eq!(committed, previewed);
    }
}
