//! Bidirectional compression substrate: bitstreams + entropy coders +
//! the uplink mask codec + the downlink delta codec.
//!
//! `codec::encode` is the production uplink entry point (used by the FL
//! client to produce wire bytes); `downlink` is the server->client
//! direction (quantized sparse deltas, DESIGN.md §Downlink);
//! `arithmetic` / `golomb` are also public for the component benchmarks
//! and the codec ablation.

pub mod arithmetic;
pub mod bitstream;
pub mod codec;
pub mod downlink;
pub mod golomb;

pub use codec::{decode, encode, encode_with, Encoded, Method};
pub use downlink::{DownlinkEncoder, DownlinkFrame, DownlinkMode};
