//! Uplink compression substrate: bitstreams + entropy coders + codec.
//!
//! `codec::encode` is the production entry point (used by the FL client
//! to produce wire bytes); `arithmetic` / `golomb` are also public for
//! the component benchmarks and the codec ablation.

pub mod arithmetic;
pub mod bitstream;
pub mod codec;
pub mod golomb;

pub use codec::{decode, encode, encode_with, Encoded, Method};
