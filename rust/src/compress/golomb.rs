//! Golomb-Rice run-length coding for sparse masks.
//!
//! When the regularizer has pushed mask density to a few percent, the
//! gaps between ones are geometrically distributed — the regime Golomb
//! codes are optimal for. This coder writes the gap sequence with a Rice
//! parameter chosen from the observed density, and is the cheap
//! (single-pass, branch-light) alternative the MaskCodec races against
//! the arithmetic coder.
//!
//! audit: deterministic, panic-free

use anyhow::{ensure, Result};

use super::bitstream::{BitReader, BitWriter};
use crate::util::BitVec;

/// Optimal-ish Rice parameter for gap mean `1/p`: k = ceil(log2(mean)).
pub fn rice_param_for_density(p: f64) -> u8 {
    if p <= 0.0 {
        return 16;
    }
    if p >= 0.5 {
        return 0;
    }
    let mean_gap = 1.0 / p;
    (mean_gap.log2().ceil() as i32).clamp(0, 30) as u8
}

/// Encode the positions of ones as Rice-coded gaps.
/// Wire format: [k: 5 bits][gap codes...], caller carries `len`.
pub fn encode(mask: &BitVec) -> Vec<u8> {
    let k = rice_param_for_density(mask.density());
    let mut w = BitWriter::new();
    w.put_bits(k as u64, 5);
    let mut last: i64 = -1;
    for (i, bit) in mask.iter().enumerate() {
        if bit {
            let gap = (i as i64 - last - 1) as u64;
            w.put_unary(gap >> k);
            w.put_bits(gap & ((1 << k) - 1), k);
            last = i as i64;
        }
    }
    w.into_bytes()
}

/// Decode a Rice-coded mask of `len` bits with `ones` one-bits.
///
/// Validates the payload as it decodes: a gap that overruns the mask
/// length, a unary run longer than any legal gap, or a stream that
/// reads past the available bytes (truncation) is an error — never
/// silently-garbled positions.
// audit:wire-decode-begin
pub fn decode(bytes: &[u8], len: usize, ones: usize) -> Result<BitVec> {
    ensure!(ones <= len, "one-count {ones} exceeds mask length {len}");
    let mut r = BitReader::new(bytes);
    // audit:checked(get_bits(5) reads exactly 5 bits, so the value fits u8)
    let k = r.get_bits(5) as u8;
    let mut out = BitVec::zeros(len);
    let mut pos: u64 = 0; // next candidate position
    for i in 0..ones {
        let q = r.get_unary();
        ensure!(
            q <= len as u64,
            "corrupt Rice payload: unary run {q} exceeds mask length {len}"
        );
        let rem = r.get_bits(k);
        let gap = (q << k) | rem;
        let idx = pos + gap;
        ensure!(
            (idx as usize) < len,
            "Rice gap decode overran mask length (one #{i} at {idx} >= {len})"
        );
        out.set(idx as usize, true);
        pos = idx + 1;
    }
    ensure!(
        r.bit_pos() <= bytes.len() * 8,
        "Rice payload truncated: {} bits consumed, {} available",
        r.bit_pos(),
        bytes.len() * 8
    );
    Ok(out)
}
// audit:wire-decode-end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn random_mask(n: usize, p: f64, seed: u64) -> BitVec {
        let mut rng = Xoshiro256::new(seed);
        BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
    }

    #[test]
    fn roundtrip() {
        for &p in &[0.001, 0.01, 0.1, 0.5, 0.95] {
            let m = random_mask(20_000, p, 9);
            let coded = encode(&m);
            assert_eq!(decode(&coded, m.len(), m.count_ones()).unwrap(), m, "p={p}");
        }
    }

    #[test]
    fn empty_and_full() {
        let zero = BitVec::zeros(1000);
        assert_eq!(decode(&encode(&zero), 1000, 0).unwrap(), zero);
        let full = BitVec::from_iter_len((0..1000).map(|_| true), 1000);
        assert_eq!(decode(&encode(&full), 1000, 1000).unwrap(), full);
    }

    #[test]
    fn truncated_and_overrun_payloads_error() {
        let m = random_mask(20_000, 0.03, 17);
        let coded = encode(&m);
        let ones = m.count_ones();
        // chop half the payload: the decoder must notice, not guess
        assert!(decode(&coded[..coded.len() / 2], 20_000, ones).is_err());
        // claim more ones than the mask can hold
        assert!(decode(&coded, 100, ones).is_err());
        assert!(decode(&coded, 20_000, 20_001).is_err());
    }

    #[test]
    fn sparse_beats_raw() {
        let n = 100_000;
        let m = random_mask(n, 0.01, 5);
        let bits = encode(&m).len() * 8;
        assert!(bits < n / 2, "golomb on 1% density should be << raw: {bits}");
    }

    #[test]
    fn rice_param_monotone() {
        assert_eq!(rice_param_for_density(0.5), 0);
        assert!(rice_param_for_density(0.1) < rice_param_for_density(0.01));
        assert_eq!(rice_param_for_density(0.0), 16);
    }

    #[test]
    fn single_bit_positions() {
        for pos in [0usize, 1, 63, 64, 999] {
            let mut m = BitVec::zeros(1000);
            m.set(pos, true);
            assert_eq!(decode(&encode(&m), 1000, 1).unwrap(), m, "pos={pos}");
        }
    }
}
