//! Final-model checkpoints: the paper's "a SEED and a binary mask is the
//! whole model" storage story, as an actual on-disk format.
//!
//! Wire format (little-endian):
//!   magic "FSRN"  | version u16 | model-name len u16 + bytes |
//!   weight_seed u64 | n_params u64 | encoded-mask bytes len u32 + bytes
//!
//! `size_report` quantifies the claim against a dense float checkpoint.

use std::fs;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::compress::{self, Encoded};
use crate::util::BitVec;

const MAGIC: &[u8; 4] = b"FSRN";
// v2: the embedded `Encoded` mask grew a payload bit-length header
// field; v1 files are rejected with a clean version error instead of a
// confusing bit-length mismatch.
const VERSION: u16 = 2;

/// A strong-LTH model checkpoint: seed + coded mask.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model: String,
    pub weight_seed: u64,
    pub n_params: u64,
    pub mask: Encoded,
}

impl Checkpoint {
    pub fn new(model: &str, weight_seed: u64, n_params: usize, mask: &BitVec) -> Self {
        Self {
            model: model.to_string(),
            weight_seed,
            n_params: n_params as u64,
            mask: compress::encode(mask),
        }
    }

    /// Decode the stored mask, validating the coded payload (truncated
    /// or corrupt checkpoints error instead of yielding garbage masks).
    pub fn decode_mask(&self) -> Result<BitVec> {
        compress::decode(&self.mask, self.n_params as usize)
    }

    /// Serialized size in bytes.
    pub fn size_bytes(&self) -> usize {
        4 + 2 + 2 + self.model.len() + 8 + 8 + 4 + self.mask.to_bytes().len()
    }

    /// Dense float32 checkpoint size for the same model.
    pub fn dense_size_bytes(&self) -> usize {
        self.n_params as usize * 4
    }

    /// Compression factor vs dense storage (the paper's "memory
    /// efficiency" multiplier).
    pub fn compression_factor(&self) -> f64 {
        self.dense_size_bytes() as f64 / self.size_bytes() as f64
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut out = Vec::with_capacity(self.size_bytes());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let name = self.model.as_bytes();
        ensure!(name.len() <= u16::MAX as usize, "model name too long");
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
        out.extend_from_slice(&self.weight_seed.to_le_bytes());
        out.extend_from_slice(&self.n_params.to_le_bytes());
        let mask_bytes = self.mask.to_bytes();
        out.extend_from_slice(&(mask_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&mask_bytes);
        fs::write(path, out).with_context(|| format!("writing checkpoint {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let raw = fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            ensure!(*pos + n <= raw.len(), "checkpoint truncated");
            let s = &raw[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = u16::from_le_bytes(take(&mut pos, 2)?.try_into()?);
        ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let name_len = u16::from_le_bytes(take(&mut pos, 2)?.try_into()?) as usize;
        let model = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
        let weight_seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
        let n_params = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
        let mask_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mask = Encoded::from_bytes(take(&mut pos, mask_len)?)
            .context("corrupt mask payload")?;
        Ok(Self { model, weight_seed, n_params, mask })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn sparse_mask(n: usize, p: f64) -> BitVec {
        let mut rng = Xoshiro256::new(5);
        BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < p), n)
    }

    #[test]
    fn save_load_roundtrip() {
        let mask = sparse_mask(10_000, 0.05);
        let ck = Checkpoint::new("mlp_tiny", 2023, 10_000, &mask);
        let path = std::env::temp_dir().join(format!("fedsrn_ck_{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.model, "mlp_tiny");
        assert_eq!(back.weight_seed, 2023);
        assert_eq!(back.decode_mask().unwrap(), mask);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sparse_checkpoint_beats_dense_storage_by_a_lot() {
        let n = 100_000;
        let ck = Checkpoint::new("m", 0, n, &sparse_mask(n, 0.02));
        // dense = 400 KB; 2%-density coded mask ~ 1.8 KB
        assert!(ck.compression_factor() > 50.0, "{}", ck.compression_factor());
    }

    #[test]
    fn dense_mask_still_beats_floats_32x() {
        let n = 50_000;
        let ck = Checkpoint::new("m", 0, n, &sparse_mask(n, 0.5));
        assert!(ck.compression_factor() > 30.0, "{}", ck.compression_factor());
    }

    #[test]
    fn truncated_mask_payload_rejected() {
        let mask = sparse_mask(5_000, 0.1);
        let ck = Checkpoint::new("m", 1, 5_000, &mask);
        let mut enc = ck.mask.clone();
        enc.payload.pop(); // recorded bit-length no longer matches
        let bad = Checkpoint { mask: enc, ..ck };
        assert!(bad.decode_mask().is_err());
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = std::env::temp_dir().join(format!("fedsrn_bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
