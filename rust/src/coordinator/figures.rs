//! Figure harnesses: regenerate every table/figure series of the paper.
//!
//! * [`run_fig1`] — IID accuracy + Bpp vs rounds (Fig. 1): FedPM vs
//!   FedPM + regularizer (lambda = 1), per dataset.
//! * [`run_fig2`] — non-IID trade-off (Fig. 2): lambda sweep vs FedPM,
//!   Top-k and MV-SignSGD, per dataset, c in {2, 4}.
//! * [`run_compare`] — the five-strategy family (FedPM+reg, MV-SignSGD,
//!   FedAvg, FedMRN, SpaFL) at one matched communication budget: same
//!   model, cohort and round count for every strategy, accuracy plotted
//!   against the uplink Bpp each actually spent. Emits the fig-1-style
//!   table plus a machine-readable `compare.json`.
//! * [`summary_table`] — the sec. IV text numbers: Bpp saved vs FedPM
//!   and accuracy deltas for every run pair.
//!
//! Each harness prints the series the paper plots (round, accuracy,
//! estimated Bpp) in a plot-ready TSV block, plus the paper-vs-measured
//! comparison lines consumed by EXPERIMENTS.md.

use anyhow::Result;

use crate::config::{Algorithm, ExperimentConfig, Partition};
use crate::coordinator::experiment::{Experiment, RunSummary};
use crate::fl::MetricsSink;

/// One named run within a figure (a single curve).
pub struct Curve {
    pub label: String,
    pub summary: RunSummary,
    /// (round, accuracy, est_bpp, coded_bpp) samples.
    pub series: Vec<(usize, f64, f64, f64)>,
}

/// Run one config and capture its curve.
pub fn run_curve(label: &str, cfg: ExperimentConfig, out_dir: &str) -> Result<Curve> {
    let path = if out_dir.is_empty() {
        String::new()
    } else {
        std::fs::create_dir_all(out_dir)?;
        format!("{out_dir}/{label}.jsonl")
    };
    eprintln!("=== run {label}: algo={} lambda={} ===", cfg.algorithm.name(), cfg.lambda);
    let mut sink = MetricsSink::new(&path, 10)?;
    let mut exp = Experiment::build(cfg)?;
    let summary = exp.run(&mut sink)?;
    let series = sink
        .records()
        .iter()
        .map(|r| (r.round, r.accuracy, r.est_bpp, r.coded_bpp))
        .collect();
    Ok(Curve { label: label.to_string(), summary, series })
}

fn print_series(curves: &[Curve]) {
    println!("\n# series (tsv): round\t{}", curves.iter().map(|c| format!("{}_acc\t{}_bpp", c.label, c.label)).collect::<Vec<_>>().join("\t"));
    let rounds = curves.iter().map(|c| c.series.len()).max().unwrap_or(0);
    for i in 0..rounds {
        let mut row = String::new();
        let mut round = 0;
        for c in curves {
            if let Some(&(r, acc, bpp, _)) = c.series.get(i) {
                round = r;
                row.push_str(&format!("\t{acc:.4}\t{bpp:.4}"));
            } else {
                row.push_str("\t\t");
            }
        }
        println!("{round}{row}");
    }
}

fn print_summaries(title: &str, curves: &[Curve]) {
    println!("\n## {title}");
    println!(
        "{:<24} {:>9} {:>10} {:>11} {:>9} {:>9} {:>9} {:>12}",
        "curve", "final_acc", "avg_estBpp", "avg_codedBpp", "avg_DLBpp", "UL_MB", "DL_MB",
        "storage_bits"
    );
    for c in curves {
        println!(
            "{:<24} {:>9.4} {:>10.4} {:>11.4} {:>9.4} {:>9.3} {:>9.3} {:>12}",
            c.label,
            c.summary.final_accuracy,
            c.summary.avg_est_bpp,
            c.summary.avg_coded_bpp,
            c.summary.avg_dl_bpp,
            c.summary.total_ul_mb,
            c.summary.total_dl_mb,
            c.summary.storage_bits
        );
    }
    // paper-style deltas vs the FedPM curve when present
    if let Some(base) = curves.iter().find(|c| c.label.contains("fedpm") && !c.label.contains("reg")) {
        for c in curves {
            if std::ptr::eq(c, base) {
                continue;
            }
            println!(
                "   {} vs {}: Bpp saved = {:+.3}, accuracy delta = {:+.4}",
                c.label,
                base.label,
                base.summary.avg_est_bpp - c.summary.avg_est_bpp,
                c.summary.final_accuracy - base.summary.final_accuracy
            );
        }
    }
}

/// Base config shared by the figure harnesses.
fn base_cfg(model: &str, dataset: &str, rounds: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        model: model.to_string(),
        dataset: dataset.to_string(),
        rounds,
        seed,
        ..ExperimentConfig::default()
    }
}

/// Model paired with each dataset in the scaled-down default harness.
/// CIFAR-10 defaults to the native `conv4` stack — the model family the
/// paper's fig. 1/2 headline results use — now that the layer-graph
/// compute core runs conv models without artifacts (DESIGN.md
/// §Compute-core). `--model mlp_cifar10` restores the MLP stand-in;
/// cifar100 keeps its MLP (the built-in conv stacks are 10-class).
pub fn default_model_for(dataset: &str) -> &'static str {
    match dataset {
        "mnist" => "mlp_mnist",
        "cifar10" => "conv4",
        "cifar100" => "mlp_cifar100",
        _ => "mlp_tiny",
    }
}

/// Fig. 1: IID FedPM vs FedPM+reg(lambda=1) — accuracy & Bpp vs rounds.
pub fn run_fig1(
    dataset: &str,
    model: &str,
    rounds: usize,
    clients: usize,
    seed: u64,
    out_dir: &str,
) -> Result<Vec<Curve>> {
    let mk = |algo: Algorithm, lambda: f32| {
        let mut cfg = base_cfg(model, dataset, rounds, seed);
        cfg.algorithm = algo;
        cfg.lambda = lambda;
        cfg.clients = clients;
        cfg.partition = Partition::Iid;
        cfg
    };
    let curves = vec![
        run_curve("fedpm", mk(Algorithm::FedPM, 0.0), out_dir)?,
        run_curve("fedpm_reg_l1", mk(Algorithm::FedPMReg, 1.0), out_dir)?,
    ];
    print_summaries(&format!("Fig.1 ({dataset}, IID, {clients} devices)"), &curves);
    print_series(&curves);
    Ok(curves)
}

/// Fig. 2: non-IID trade-off — lambda sweep vs FedPM / Top-k / SignSGD.
#[allow(clippy::too_many_arguments)]
pub fn run_fig2(
    dataset: &str,
    model: &str,
    rounds: usize,
    clients: usize,
    c: usize,
    lambdas: &[f32],
    seed: u64,
    out_dir: &str,
) -> Result<Vec<Curve>> {
    let mk = |algo: Algorithm, lambda: f32| {
        let mut cfg = base_cfg(model, dataset, rounds, seed);
        cfg.algorithm = algo;
        cfg.lambda = lambda;
        cfg.clients = clients;
        cfg.partition = Partition::NonIid { c };
        cfg
    };
    let mut curves = vec![run_curve("fedpm", mk(Algorithm::FedPM, 0.0), out_dir)?];
    for &l in lambdas {
        let label = format!("fedpm_reg_l{l}");
        curves.push(run_curve(&label, mk(Algorithm::FedPMReg, l), out_dir)?);
    }
    // Top-k at the sparsity the regularized run reached (paper: "same
    // sparsity level as the sub-network obtained for lambda=0.5").
    let reg_density = curves
        .last()
        .map(|c| c.series.last().map(|s| s.2).unwrap_or(0.3))
        .unwrap_or(0.3)
        .clamp(0.05, 0.5);
    let mut topk_cfg = mk(Algorithm::TopK, 0.0);
    topk_cfg.topk_frac = reg_density;
    curves.push(run_curve("topk", topk_cfg, out_dir)?);
    curves.push(run_curve("mv_signsgd", mk(Algorithm::SignSGD, 0.0), out_dir)?);
    print_summaries(
        &format!("Fig.2 ({dataset}, non-IID c={c}, {clients} devices)"),
        &curves,
    );
    print_series(&curves);
    Ok(curves)
}

/// `figures --compare`: every strategy family the crate implements,
/// run at one matched communication budget (identical model, dataset,
/// cohort, round count and seed), so the table reads as the fig-1
/// accuracy-vs-Bpp trade-off across the whole family — from FedAvg's
/// 32 Bpp down through the ~1 Bpp mask families to SpaFL's per-filter
/// thresholds.
pub fn run_compare(
    dataset: &str,
    model: &str,
    rounds: usize,
    clients: usize,
    seed: u64,
    out_dir: &str,
) -> Result<Vec<Curve>> {
    let mk = |algo: Algorithm, lambda: f32| {
        let mut cfg = base_cfg(model, dataset, rounds, seed);
        cfg.algorithm = algo;
        cfg.lambda = lambda;
        cfg.clients = clients;
        cfg.partition = Partition::Iid;
        cfg
    };
    let curves = vec![
        run_curve("fedpm_reg_l1", mk(Algorithm::FedPMReg, 1.0), out_dir)?,
        run_curve("mv_signsgd", mk(Algorithm::SignSGD, 0.0), out_dir)?,
        run_curve("fedavg", mk(Algorithm::FedAvg, 0.0), out_dir)?,
        run_curve("fedmrn", mk(Algorithm::FedMRN, 0.0), out_dir)?,
        run_curve("spafl", mk(Algorithm::SpaFL, 0.0), out_dir)?,
    ];
    print_summaries(
        &format!("Strategy comparison ({dataset}, IID, {clients} devices, {rounds} rounds)"),
        &curves,
    );
    print_series(&curves);
    let json = compare_json(&curves);
    if out_dir.is_empty() {
        println!("\n# compare.json\n{json}");
    } else {
        let path = format!("{out_dir}/compare.json");
        std::fs::write(&path, &json)?;
        println!("\nwrote {path}");
    }
    Ok(curves)
}

/// Hand-rolled JSON for the comparison (anyhow is the crate's only
/// dependency — no serde): an array of per-strategy objects, each the
/// accuracy/Bpp/storage point that strategy reached under the shared
/// budget.
fn compare_json(curves: &[Curve]) -> String {
    let mut out = String::from("[\n");
    for (i, c) in curves.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"strategy\": \"{}\", \"final_accuracy\": {:.6}, \
             \"avg_est_bpp\": {:.6}, \"avg_coded_bpp\": {:.6}, \
             \"avg_dl_bpp\": {:.6}, \"total_ul_mb\": {:.6}, \
             \"total_dl_mb\": {:.6}, \"storage_bits\": {}, \"rounds\": {}}}{}\n",
            c.label,
            c.summary.final_accuracy,
            c.summary.avg_est_bpp,
            c.summary.avg_coded_bpp,
            c.summary.avg_dl_bpp,
            c.summary.total_ul_mb,
            c.summary.total_dl_mb,
            c.summary.storage_bits,
            c.summary.rounds,
            if i + 1 < curves.len() { "," } else { "" }
        ));
    }
    out.push(']');
    out
}

/// Sec. IV text numbers: per-dataset IID Bpp savings of reg vs FedPM.
pub fn summary_table(curves_by_dataset: &[(String, Vec<Curve>)]) {
    println!("\n## Paper-vs-measured summary (sec. IV text numbers)");
    println!(
        "{:<10} {:>14} {:>14} {:>12}",
        "dataset", "BppSaved(meas)", "BppSaved(est)", "accDelta"
    );
    for (name, curves) in curves_by_dataset {
        let Some(base) = curves.iter().find(|c| c.label == "fedpm") else { continue };
        let Some(reg) = curves.iter().find(|c| c.label.starts_with("fedpm_reg")) else { continue };
        println!(
            "{:<10} {:>14.3} {:>14.3} {:>12.4}",
            name,
            base.summary.avg_coded_bpp - reg.summary.avg_coded_bpp,
            base.summary.avg_est_bpp - reg.summary.avg_est_bpp,
            reg.summary.final_accuracy - base.summary.final_accuracy,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_json_is_well_formed() {
        let mk = |label: &str, acc: f64, bpp: f64| Curve {
            label: label.into(),
            summary: RunSummary {
                algorithm: label.into(),
                final_accuracy: acc,
                avg_est_bpp: bpp,
                avg_coded_bpp: bpp,
                avg_dl_bpp: 32.0,
                total_ul_mb: 1.0,
                total_dl_mb: 2.0,
                storage_bits: 64,
                rounds: 3,
            },
            series: vec![(1, acc, bpp, bpp)],
        };
        let json = compare_json(&[mk("fedavg", 0.9, 32.0), mk("spafl", 0.8, 0.005)]);
        assert!(json.starts_with('[') && json.ends_with(']'));
        assert_eq!(json.matches("\"strategy\"").count(), 2);
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches("},").count(), 1, "one separator between two objects");
        assert!(!json.contains(",\n]"), "no trailing comma before the closing bracket");
        assert!(json.contains("\"avg_est_bpp\": 0.005000"));
    }
}
