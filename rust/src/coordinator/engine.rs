//! Parallel federated round engine (DESIGN.md §Parallel round engine).
//!
//! Every strategy funnels its per-client work — local training, uplink
//! mask construction, entropy coding — through [`RoundEngine::run_cohort`],
//! which shards the sampled cohort across worker threads and returns the
//! per-client results **in cohort order**, whatever the execution
//! interleaving was.
//!
//! ## Determinism contract
//!
//! Parallel runs are bit-identical to the sequential path at any thread
//! count because the engine never lets scheduling reach the math:
//!
//! 1. **Seed-derived streams.** All client randomness is a pure function
//!    of a [`crate::util::SeedSequence`] path `(root, round, client, ...)`
//!    or of per-client state (`BatchSampler`) only ever touched by that
//!    client's own work item. No RNG is shared across work items.
//! 2. **Ordered reduction.** Worker threads only *produce* results; the
//!    engine stitches them back into cohort order, and all mutation of
//!    shared round state (aggregators, [`crate::fl::RoundComm`], running
//!    means) happens in that order on the calling thread. Mask
//!    aggregation itself is additionally order-independent for the
//!    integer dataset-size weights the federation uses (exact f64 sums —
//!    see the property tests), so even a future out-of-order merge
//!    cannot change theta.
//!
//! The engine intentionally uses `std::thread::scope` rather than an
//! external thread pool: cohorts are O(10-1000) coarse work items per
//! round, far past the point where work-stealing would matter, and it
//! keeps the dependency surface of the offline build at zero.
//!
//! audit: deterministic

use anyhow::{ensure, Result};

use crate::algos::{ClientTask as _, RoundStats, ServerLogic};
use crate::data::Dataset;
use crate::fl::aggregator::{AggregateMsg, EdgeAggregator};
use crate::fl::protocol::{DownlinkMsg, RoundPlan};
use crate::fl::{Client, Participation, RoundComm};
use crate::runtime::ModelRuntime;

/// Shards a round's cohort across worker threads.
#[derive(Debug, Clone, Copy)]
pub struct RoundEngine {
    threads: usize,
    /// Edge aggregator count for hierarchical folds (0 = flat fold).
    edges: usize,
    /// Staleness discount exponent the edge tier applies.
    staleness_beta: f64,
}

impl Default for RoundEngine {
    fn default() -> Self {
        Self::new(0)
    }
}

impl RoundEngine {
    /// `threads = 0` resolves to the machine's available parallelism;
    /// `threads = 1` is the sequential reference path (same code, same
    /// order, no spawns).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Self { threads, edges: 0, staleness_beta: 1.0 }
    }

    /// Configure the hierarchical edge tier (DESIGN.md §Fleet):
    /// `edges > 0` splits every cohort into that many contiguous slices,
    /// folds each slice through an [`EdgeAggregator`], and ships one
    /// serialized [`AggregateMsg`] envelope per edge to the server —
    /// bit-identical to the flat ordered fold (grouping-exact sums).
    /// `beta` is the staleness discount exponent the edges apply.
    pub fn with_edges(mut self, edges: usize, beta: f64) -> Self {
        self.edges = edges;
        self.staleness_beta = beta;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fan-out wave size: ~2x the worker count, so at most one wave of
    /// uplink envelopes is in flight at a time. Shared with the
    /// networked session ([`crate::fl::session::Session`]), which
    /// bounds its remote cohorts the same way.
    pub fn wave_size(&self) -> usize {
        self.threads.max(4) * 2
    }

    /// Run `work(pos, client)` once per cohort member, in parallel, and
    /// return the results in cohort order (`pos` = position within the
    /// cohort). `cohort` holds sorted, unique indices into `clients`.
    ///
    /// `work` must be a pure function of its arguments (plus shared
    /// `Sync` captures) for the determinism contract to hold.
    pub fn run_cohort<T, F>(
        &self,
        clients: &mut [Client],
        cohort: &[usize],
        work: F,
    ) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, &mut Client) -> Result<T> + Sync,
    {
        debug_assert!(cohort.windows(2).all(|w| w[0] < w[1]), "cohort sorted+unique");
        // Select disjoint `&mut Client` references in cohort order.
        let mut selected: Vec<(usize, &mut Client)> = Vec::with_capacity(cohort.len());
        {
            let mut next = 0usize;
            for (i, c) in clients.iter_mut().enumerate() {
                if next == cohort.len() {
                    break;
                }
                if cohort[next] == i {
                    selected.push((next, c));
                    next += 1;
                }
            }
            ensure!(next == cohort.len(), "cohort index out of range");
        }

        let workers = self.threads.min(selected.len()).max(1);
        if workers == 1 {
            // Sequential reference path: identical code path minus spawns.
            return selected.into_iter().map(|(pos, c)| work(pos, c)).collect();
        }

        // Stripe the cohort across workers; each worker returns
        // (pos, result) pairs that are stitched back into cohort order.
        let mut stripes: Vec<Vec<(usize, &mut Client)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (i, item) in selected.into_iter().enumerate() {
            stripes[i % workers].push(item);
        }
        let work = &work;
        let mut slots: Vec<Option<Result<T>>> =
            cohort.iter().map(|_| None).collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = stripes
                .into_iter()
                .map(|stripe| {
                    scope.spawn(move || {
                        stripe
                            .into_iter()
                            .map(|(pos, c)| (pos, work(pos, c)))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                for (pos, r) in h.join().expect("round-engine worker panicked") {
                    slots[pos] = Some(r);
                }
            }
        });
        // First error (in cohort order, not completion order) wins, so
        // failures are as reproducible as successes.
        slots
            .into_iter()
            .map(|s| s.expect("every cohort position must produce a result"))
            .collect()
    }

    /// Drive one full protocol round (DESIGN.md §Protocol):
    ///
    /// 1. Sample the cohort from the participation model.
    /// 2. `server.begin_round` -> one [`DownlinkMsg`]; a frame chain link
    ///    is accounted to **every** device (a device that missed one
    ///    could not decode the next), a stateless broadcast only to the
    ///    sampled cohort.
    /// 3. Run the strategy's [`crate::algos::ClientTask`] across the
    ///    cohort in **waves** of ~2x the worker count, so at most one
    ///    wave of uplink envelopes is resident at a time and the server
    ///    folds each wave the moment it completes — coordinator memory
    ///    is O(wave × n_params), server fold state O(n_params), at any
    ///    cohort size.
    /// 4. Apply the dropout failure model (the device trained, its
    ///    uplink never lands), fold surviving envelopes in cohort order,
    ///    and `server.end_round`.
    ///
    /// `fleet_state` is the state the fleet reconstructed from the
    /// previous broadcast (`None` before the first round); the engine
    /// advances it exactly like a device would, by decoding the message.
    #[allow(clippy::too_many_arguments)]
    pub fn run_round(
        &self,
        server: &mut dyn ServerLogic,
        rt: &ModelRuntime,
        data: &Dataset,
        clients: &mut [Client],
        fleet_state: &mut Option<Vec<f32>>,
        participation: Participation,
        plan: &RoundPlan,
        comm: &mut RoundComm,
    ) -> Result<RoundStats> {
        let cohort = participation.sample_round(clients.len(), plan.seed, plan.round);
        let msg = server.begin_round(plan)?;
        let receivers = match msg {
            DownlinkMsg::Frame(_) => clients.len(),
            DownlinkMsg::RawF32(_) | DownlinkMsg::Theta(_) | DownlinkMsg::NoiseTheta { .. } => {
                cohort.len()
            }
        };
        for _ in 0..receivers {
            comm.add_downlink_msg(&msg);
        }

        // Hierarchical mode: each cohort slice folds into its own edge
        // aggregator; the server only ever sees the merged envelopes.
        let n_edges = self.edges.min(cohort.len());
        let mut edge_tier: Vec<EdgeAggregator> = if n_edges > 0 {
            let kind = server.agg_kind();
            (0..n_edges)
                .map(|_| EdgeAggregator::new(kind, rt.manifest.n_params))
                .collect()
        } else {
            Vec::new()
        };

        let task = server.client_task();
        let prev = fleet_state.take();
        let prev_ref = prev.as_deref();
        let task_ref = task.as_ref();
        let wave = self.wave_size();
        let mut offset = 0usize;
        for ids in cohort.chunks(wave) {
            let uplinks = self.run_cohort(clients, ids, |pos, client| {
                let up = task_ref.run(rt, data, client, &msg, prev_ref, plan)?;
                // Failure injection: the device trained but its uplink
                // never arrives; the server must tolerate the gap.
                let dropped =
                    participation.drops(offset + pos, plan.seed, plan.round, client.id);
                Ok(if dropped { None } else { Some(up) })
            })?;
            // Ordered streaming fold: envelopes land in cohort order, so
            // the result is independent of worker scheduling. With edges
            // each envelope folds into its contiguous slice's aggregator
            // instead — the same terms in the same order, just grouped.
            for (pos, up) in uplinks.into_iter().enumerate() {
                let Some(up) = up else { continue };
                if n_edges > 0 {
                    let e = (offset + pos) * n_edges / cohort.len();
                    edge_tier[e].fold(&up, plan.round, self.staleness_beta)?;
                } else {
                    server.fold_uplink(&up, comm)?;
                }
            }
            offset += ids.len();
        }
        for edge in &edge_tier {
            if edge.reporters() == 0 {
                continue;
            }
            // Ship the merged envelope through its real wire layout so
            // the hierarchical path exercises encode+decode end to end.
            let agg = AggregateMsg::from_bytes(&edge.finish().to_bytes())?;
            server.fold_aggregate(&agg, comm)?;
        }

        *fleet_state = Some(msg.decode_state(prev_ref)?);
        server.end_round(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_iid, Dataset, SynthSpec, Synthetic};

    fn task(n_clients: usize) -> Dataset {
        Synthetic::new(SynthSpec::tiny(), 3).generate(40 * n_clients, 1)
    }

    fn fleet(data: &Dataset, n: usize) -> Vec<Client> {
        partition_iid(data, n, 7)
            .into_iter()
            .map(|s| {
                let seed = 100 + s.client_id as u64;
                Client::new(s, seed)
            })
            .collect()
    }

    /// A deterministic per-client computation exercising the client's
    /// own mutable state (the batch sampler) — exact-comparable output.
    fn probe(data: &Dataset, pos: usize, c: &mut Client) -> (usize, usize, Vec<i32>, u64) {
        let (xs, ys) = c.gather_call_batches(data, 2, 4);
        let sum: f64 = xs.iter().map(|&v| v as f64).sum();
        (pos, c.id, ys, sum.to_bits())
    }

    #[test]
    fn results_arrive_in_cohort_order_at_any_thread_count() {
        let data = task(8);
        let cohort: Vec<usize> = vec![0, 2, 3, 5, 6, 7];
        let reference = {
            let mut clients = fleet(&data, 8);
            RoundEngine::new(1)
                .run_cohort(&mut clients, &cohort, |pos, c| Ok(probe(&data, pos, c)))
                .unwrap()
        };
        for threads in [2, 3, 8, 16] {
            let mut clients = fleet(&data, 8);
            let got = RoundEngine::new(threads)
                .run_cohort(&mut clients, &cohort, |pos, c| Ok(probe(&data, pos, c)))
                .unwrap();
            assert_eq!(got, reference, "threads={threads}");
        }
        // positions are 0..cohort.len(), ids are the cohort's client ids
        for (pos, r) in reference.iter().enumerate() {
            assert_eq!(r.0, pos);
            assert_eq!(r.1, cohort[pos]);
        }
    }

    #[test]
    fn error_reporting_is_deterministic() {
        let data = task(6);
        let mut clients = fleet(&data, 6);
        let cohort: Vec<usize> = (0..6).collect();
        let failing = |pos: usize, _c: &mut Client| -> Result<usize> {
            if pos % 2 == 1 {
                anyhow::bail!("client at position {pos} failed");
            }
            Ok(pos)
        };
        for threads in [1, 4] {
            let err = RoundEngine::new(threads)
                .run_cohort(&mut clients, &cohort, failing)
                .unwrap_err();
            assert!(err.to_string().contains("position 1"), "threads={threads}: {err}");
        }
    }

    #[test]
    fn out_of_range_cohort_rejected() {
        let data = task(3);
        let mut clients = fleet(&data, 3);
        let err = RoundEngine::new(2)
            .run_cohort(&mut clients, &[0, 9], |pos, _c| Ok(pos))
            .unwrap_err();
        assert!(err.to_string().contains("out of range"));
    }

    #[test]
    fn zero_threads_resolves_to_hardware() {
        assert!(RoundEngine::new(0).threads() >= 1);
        assert_eq!(RoundEngine::new(5).threads(), 5);
    }

    #[test]
    fn empty_cohort_is_fine() {
        let data = task(2);
        let mut clients = fleet(&data, 2);
        let out = RoundEngine::new(4)
            .run_cohort(&mut clients, &[], |pos, _c| Ok(pos))
            .unwrap();
        assert!(out.is_empty());
    }
}
