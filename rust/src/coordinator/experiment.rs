//! Experiment runner: config -> data -> clients -> rounds -> metrics.
//!
//! This is the launcher core: everything an experiment needs is derived
//! deterministically from the [`ExperimentConfig`], so a config file (or
//! a figure harness that sweeps configs) fully specifies a run.

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::algos::{build_server, EvalModel, RoundStats, ServerLogic};
use crate::config::ExperimentConfig;
use crate::coordinator::RoundEngine;
use crate::data::{load_experiment_data, partition_fleet, Dataset};
use crate::fl::protocol::RoundPlan;
use crate::fl::session::Session;
use crate::fl::{
    derive_client_seed, Client, CommTotals, MetricsSink, Participation, RoundComm, RoundRecord,
};
use crate::runtime::{EvalMetrics, ModelRuntime};

/// Per-device evaluation view: which test rows match the device's
/// target distribution (all rows for IID; own-classes rows non-IID).
struct EvalShard {
    x: Vec<f32>,
    y: Vec<i32>,
}

/// A fully-materialized experiment ready to run.
pub struct Experiment {
    pub cfg: ExperimentConfig,
    rt: ModelRuntime,
    train: Dataset,
    clients: Vec<Client>,
    eval_shards: Vec<EvalShard>,
    server: Box<dyn ServerLogic>,
    engine: RoundEngine,
    /// The state the fleet reconstructed from the previous broadcast
    /// (what a device needs to decode the next `qdelta` frame).
    fleet_state: Option<Vec<f32>>,
    pub totals: CommTotals,
}

/// End-of-run summary the figure harnesses print.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub algorithm: String,
    pub final_accuracy: f64,
    /// Mean est. Bpp (eq. 13) over all rounds — the paper's reported
    /// "average bits per parameter required".
    pub avg_est_bpp: f64,
    pub avg_coded_bpp: f64,
    /// Mean measured downlink Bpp over all rounds (32.0 for raw floats;
    /// far less with `downlink=qdelta` — DESIGN.md §Downlink).
    pub avg_dl_bpp: f64,
    pub total_ul_mb: f64,
    pub total_dl_mb: f64,
    pub storage_bits: u64,
    pub rounds: usize,
}

impl Experiment {
    /// Build everything from a validated config.
    pub fn build(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let mut rt = ModelRuntime::load(Path::new(&cfg.artifacts_dir), &cfg.model)
            .with_context(|| format!("loading model '{}'", cfg.model))?;
        rt.set_compute(cfg.compute);

        // --- data: real if present, synthetic otherwise ----------------
        let (train, test) = Self::load_data(&cfg, rt.manifest.input_dim, rt.manifest.n_classes)?;
        ensure!(
            train.dim == rt.manifest.input_dim,
            "dataset dim {} != model input dim {} (wrong --model/--dataset pairing?)",
            train.dim,
            rt.manifest.input_dim
        );

        // --- partition + device fleet ----------------------------------
        // Per-client seeds come from a splittable seed tree, never from
        // a shared sequential stream: a client's randomness is a pure
        // function of (root seed, client id), which is what lets both
        // the parallel round engine and a remote device process replay
        // the sequential path bit-for-bit (fl::derive_client_seed).
        let clients: Vec<Client> = partition_fleet(&cfg, &train)
            .into_iter()
            .map(|s| {
                let seed = derive_client_seed(cfg.seed, s.client_id);
                Client::new(s, seed)
            })
            .collect();

        // --- per-device eval shards ------------------------------------
        let eval_shards = clients
            .iter()
            .map(|c| {
                let idx: Vec<usize> = (0..test.len())
                    .filter(|&i| c.shard.classes.contains(&(test.y[i] as usize)))
                    .collect();
                let (x, y) = test.gather(&idx);
                EvalShard { x, y }
            })
            .collect();

        let server =
            build_server(&cfg, rt.manifest.n_params, rt.weights(), &rt.manifest.layers);
        let engine =
            RoundEngine::new(cfg.threads).with_edges(cfg.edges, cfg.staleness_beta);
        Ok(Self {
            cfg,
            rt,
            train,
            clients,
            eval_shards,
            server,
            engine,
            fleet_state: None,
            totals: CommTotals::default(),
        })
    }

    /// The typed per-round hyperparameter plan the server side owns
    /// (protocol replacement for the old `RoundCtx` grab-bag).
    fn round_plan(&self, round: usize) -> RoundPlan {
        RoundPlan {
            round,
            seed: self.cfg.seed,
            lambda: self.cfg.effective_lambda(),
            lr: self.cfg.lr,
            local_epochs: self.cfg.local_epochs,
            topk_frac: self.cfg.topk_frac,
            server_lr: self.cfg.server_lr,
            adam: self.cfg.adam,
        }
    }

    fn load_data(cfg: &ExperimentConfig, dim: usize, n_classes: usize) -> Result<(Dataset, Dataset)> {
        // Shared with the networked device runtime: both ends of a
        // socket derive byte-identical data from the same config.
        load_experiment_data(cfg, dim, n_classes)
    }

    /// Evaluate the server's current global model over all device
    /// targets, weighting each device by its eval-shard sample count.
    fn evaluate(&self, round: usize) -> Result<(f64, f64)> {
        let model = self.server.eval_model(round);
        let ones = vec![1.0f32; self.rt.manifest.n_params];
        // IID shards all have the same class set; dedupe the work by
        // evaluating once and replicating when every shard is identical.
        let identical = self
            .clients
            .iter()
            .all(|c| c.shard.classes.len() == self.train.n_classes);
        let n_eval = if identical { 1 } else { self.eval_shards.len() };
        let mut per_shard = Vec::with_capacity(n_eval);
        for shard in self.eval_shards.iter().take(n_eval) {
            if shard.y.is_empty() {
                // A test split can miss a device's classes entirely (small
                // non-IID splits); an empty shard says nothing about the
                // model and must carry zero weight, not a 0.0 "accuracy".
                per_shard.push(EvalMetrics::default());
                continue;
            }
            let m = match &model {
                EvalModel::Masked(mask) => self.rt.eval_mask(mask, &shard.x, &shard.y)?,
                EvalModel::Dense(w) => {
                    self.rt.eval_with_weights(&ones, w, &shard.x, &shard.y)?
                }
            };
            per_shard.push(m);
        }
        Ok(weighted_eval(&per_shard))
    }

    /// Run all rounds through the in-process parallel round engine,
    /// logging one record per round into `sink`.
    pub fn run(&mut self, sink: &mut MetricsSink) -> Result<RunSummary> {
        let engine = self.engine;
        self.run_with(sink, |server, rt, data, clients, fleet_state, part, plan, comm| {
            engine.run_round(server, rt, data, clients, fleet_state, part, plan, comm)
        })
    }

    /// Run all rounds over a networked [`Session`] (`fedsrn serve`):
    /// identical lifecycle — same evaluation, metrics, and summaries —
    /// with the round itself driven by the session's single-threaded
    /// readiness loop across real device sockets instead of the
    /// in-process engine.
    pub fn run_served(
        &mut self,
        session: &mut Session,
        sink: &mut MetricsSink,
    ) -> Result<RunSummary> {
        self.run_with(sink, |server, _rt, _data, _clients, fleet_state, part, plan, comm| {
            session.run_round(server, fleet_state, part, plan, comm)
        })
    }

    /// Shared experiment lifecycle with a pluggable round driver: every
    /// round, `round_fn` receives the server logic, runtime, data, the
    /// (simulated) fleet, the fleet's broadcast reconstruction, the
    /// participation model, the round plan, and the communication
    /// accumulator, and returns the round's stats.
    #[allow(clippy::type_complexity)]
    pub fn run_with<F>(&mut self, sink: &mut MetricsSink, mut round_fn: F) -> Result<RunSummary>
    where
        F: FnMut(
            &mut dyn ServerLogic,
            &ModelRuntime,
            &Dataset,
            &mut [Client],
            &mut Option<Vec<f32>>,
            Participation,
            &RoundPlan,
            &mut RoundComm,
        ) -> Result<RoundStats>,
    {
        let mut last_acc = 0.0;
        let mut last_loss = 0.0;
        let mut est_bpp_sum = 0.0;
        let mut coded_bpp_sum = 0.0;
        let mut dl_bpp_sum = 0.0;
        let participation = Participation::new(self.cfg.participation, self.cfg.dropout);
        for round in 1..=self.cfg.rounds {
            let t0 = Instant::now();
            let mut comm = RoundComm::new(self.rt.manifest.n_params);
            let plan = self.round_plan(round);
            let stats = round_fn(
                self.server.as_mut(),
                &self.rt,
                &self.train,
                &mut self.clients,
                &mut self.fleet_state,
                participation,
                &plan,
                &mut comm,
            )
            // a failed round names itself: under fault injection the
            // serve log must show *which* round died and why (e.g. a
            // whole cohort lost -> "no uplinks received this round")
            .with_context(|| format!("round {round}/{} failed", self.cfg.rounds))?;
            self.totals.add_round(&comm);
            est_bpp_sum += comm.est_bpp();
            coded_bpp_sum += comm.measured_bpp();
            dl_bpp_sum += comm.measured_dl_bpp();

            if round % self.cfg.eval_every == 0 || round == self.cfg.rounds {
                let (a, l) = self.evaluate(round)?;
                last_acc = a;
                last_loss = l;
            }
            sink.push(RoundRecord {
                round,
                accuracy: last_acc,
                loss: last_loss,
                train_loss: stats.train_loss,
                est_bpp: comm.est_bpp(),
                coded_bpp: comm.measured_bpp(),
                dl_bpp: comm.measured_dl_bpp(),
                mean_theta: stats.mean_theta,
                mask_density: stats.mask_density,
                secs: t0.elapsed().as_secs_f64(),
            })?;
        }
        sink.flush()?;
        // Perf telemetry: per-program wall-clock breakdown (FEDSRN_TIMERS=1).
        if std::env::var("FEDSRN_TIMERS").is_ok() {
            eprintln!("--- runtime timer breakdown ---");
            for (label, secs, calls) in self.rt.timers.snapshot().summary() {
                eprintln!(
                    "{label:<24} {secs:>9.3}s over {calls:>6} calls ({:.2}ms/call)",
                    secs / calls.max(1) as f64 * 1e3
                );
            }
        }
        Ok(RunSummary {
            algorithm: self.cfg.algorithm.name().to_string(),
            final_accuracy: sink.tail_mean(3, |r| r.accuracy),
            avg_est_bpp: est_bpp_sum / self.cfg.rounds as f64,
            avg_coded_bpp: coded_bpp_sum / self.cfg.rounds as f64,
            avg_dl_bpp: dl_bpp_sum / self.cfg.rounds as f64,
            total_ul_mb: self.totals.ul_megabytes(),
            total_dl_mb: self.totals.dl_megabytes(),
            storage_bits: self.server.storage_bits(),
            rounds: self.cfg.rounds,
        })
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    /// The server's current global model (for checkpointing).
    pub fn global_model(&self) -> EvalModel {
        self.server.eval_model(self.cfg.rounds)
    }
}

/// Sample-weighted mean accuracy and loss over per-device eval shards.
///
/// Each device counts by its eval-shard sample count: accuracy is total
/// correct / total examples, loss is total loss / total examples. Empty
/// shards (examples == 0) contribute nothing — the seed's unweighted
/// mean let an empty non-IID shard inject a 0.0 accuracy / 0.0 loss
/// term and skew every reported number.
fn weighted_eval(per_shard: &[EvalMetrics]) -> (f64, f64) {
    let examples: usize = per_shard.iter().map(|m| m.examples).sum();
    if examples == 0 {
        return (0.0, 0.0);
    }
    let correct: f64 = per_shard.iter().map(|m| m.correct).sum();
    let loss: f64 = per_shard.iter().map(|m| m.loss_sum).sum();
    (correct / examples as f64, loss / examples as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Partition;

    fn metrics(correct: f64, loss_sum: f64, examples: usize) -> EvalMetrics {
        EvalMetrics { correct, loss_sum, examples }
    }

    #[test]
    fn weighted_eval_weights_by_sample_count() {
        // 90% on 100 samples + 50% on 10 samples: weighted 95/110, not
        // the unweighted (0.9 + 0.5)/2 = 0.7.
        let (acc, loss) =
            weighted_eval(&[metrics(90.0, 100.0, 100), metrics(5.0, 30.0, 10)]);
        assert!((acc - 95.0 / 110.0).abs() < 1e-12, "acc={acc}");
        assert!((loss - 130.0 / 110.0).abs() < 1e-12, "loss={loss}");
    }

    #[test]
    fn weighted_eval_skips_empty_shards() {
        // an empty shard must not drag the mean toward zero
        let full = [metrics(8.0, 4.0, 10)];
        let with_empty = [metrics(8.0, 4.0, 10), EvalMetrics::default()];
        assert_eq!(weighted_eval(&full), weighted_eval(&with_empty));
        assert_eq!(weighted_eval(&full).0, 0.8);
    }

    #[test]
    fn weighted_eval_all_empty_is_zero_not_nan() {
        let (acc, loss) = weighted_eval(&[EvalMetrics::default(); 3]);
        assert_eq!((acc, loss), (0.0, 0.0));
        let (acc, _) = weighted_eval(&[]);
        assert_eq!(acc, 0.0);
    }

    #[test]
    fn noniid_run_with_sparse_test_split_stays_finite() {
        // A single test sample covers one of 10 classes, so most of the
        // 10 two-class devices are guaranteed an empty eval shard; the
        // run must not skew or NaN (the seed averaged-in 0.0 accuracy
        // and 0.0 loss for every empty shard).
        let cfg = ExperimentConfig {
            model: "mlp_tiny".into(),
            dataset: "tiny".into(),
            clients: 10,
            rounds: 2,
            partition: Partition::NonIid { c: 2 },
            train_samples: 400,
            test_samples: 1,
            seed: 5,
            ..ExperimentConfig::default()
        };
        let mut sink = MetricsSink::new("", 1000).unwrap();
        let mut exp = Experiment::build(cfg).unwrap();
        let empty_shards =
            exp.eval_shards.iter().filter(|s| s.y.is_empty()).count();
        assert!(empty_shards > 0, "test split should leave some shards empty");
        let summary = exp.run(&mut sink).unwrap();
        assert!(summary.final_accuracy.is_finite());
        for r in sink.records() {
            assert!(r.accuracy.is_finite() && r.loss.is_finite());
        }
    }
}
