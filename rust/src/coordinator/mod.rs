//! Coordinator: experiment lifecycle, figure harnesses, checkpoints.

pub mod checkpoint;
pub mod experiment;
pub mod figures;

pub use checkpoint::Checkpoint;
pub use experiment::{Experiment, RunSummary};
