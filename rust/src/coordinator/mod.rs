//! Coordinator: experiment lifecycle, round engine, figure harnesses,
//! checkpoints.

pub mod checkpoint;
pub mod engine;
pub mod experiment;
pub mod figures;

pub use checkpoint::Checkpoint;
pub use engine::RoundEngine;
pub use experiment::{Experiment, RunSummary};
