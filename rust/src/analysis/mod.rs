//! `fedsrn audit` — a zero-dependency invariant linter for this crate.
//!
//! The test suite can only spot-check the two contracts the whole
//! reproduction rests on: aggregation must be bit-identical across
//! sequential/parallel/socket/chaos execution, and every byte arriving
//! off the wire must parse without panicking. This module *enforces*
//! them structurally: a tiny lexer ([`lexer`]) blanks comments, string
//! literals and `#[cfg(test)]` items out of each source file, and a
//! rule engine ([`rules`]) checks the remaining tokens against
//! policies the modules declare about themselves in comments.
//!
//! Run it as `fedsrn audit` (a required CI gate); rule families,
//! the annotation grammar and the waiver protocol are documented in
//! DESIGN.md §Static-analysis.

mod lexer;
mod rules;

pub use lexer::{sanitize, Comment, Sanitized};
pub use rules::{check_file, parse_directives, Directives, Finding, UNSAFE_BUDGET_FILES};

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Outcome of auditing a source tree.
#[derive(Debug)]
pub struct AuditReport {
    /// `.rs` files scanned.
    pub files: usize,
    /// Files that declared at least one policy or region.
    pub annotated: usize,
    /// All violations, in (file, line) order.
    pub findings: Vec<Finding>,
}

impl AuditReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one line per finding plus a summary.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "audit: {} files scanned, {} under policy, {} finding(s)\n",
            self.files,
            self.annotated,
            self.findings.len()
        ));
        out
    }
}

/// Audit a single file's contents. `rel` is the path relative to the
/// source root (it selects the `unsafe` budget); exposed for the
/// fixture tests.
pub fn audit_file(rel: &str, text: &str) -> Vec<Finding> {
    check_file(rel, &sanitize(text)).1
}

/// Audit every `.rs` file under `src_root` (sorted walk, so output and
/// exit status are deterministic).
pub fn audit_tree(src_root: &Path) -> Result<AuditReport> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)
        .with_context(|| format!("walking source tree {}", src_root.display()))?;
    files.sort();
    let mut report = AuditReport { files: 0, annotated: 0, findings: Vec::new() };
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let (directives, findings) = check_file(&rel, &sanitize(&text));
        report.files += 1;
        if directives.any_policy() {
            report.annotated += 1;
        }
        report.findings.extend(findings);
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
