//! Source sanitizer for the audit pass.
//!
//! Produces a *blanked* view of a Rust source file: the same length in
//! lines as the original, with comment bodies, string/char-literal
//! contents and `#[cfg(test)]` items replaced by spaces (newlines are
//! preserved, so line numbers survive). Rules then tokenize the blanked
//! text and never see a forbidden name that only occurs in prose, a log
//! message or a unit test.
//!
//! Line comments are additionally captured verbatim (with their line
//! numbers) because the policy grammar lives in comments — see
//! [`super::rules`] for the directives.
//!
//! This is a lexer, not a parser: it understands exactly as much Rust
//! as it needs to (nested block comments, escapes, raw strings, byte
//! literals, and the char-literal/lifetime ambiguity) and nothing more.

/// One `//`-style comment, captured before blanking.
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// `//!` inner doc comment (module policies live in these).
    pub inner: bool,
    /// Text after `//`, `//!` or `///`, untrimmed.
    pub text: String,
}

/// Result of sanitizing one file.
#[derive(Debug, Clone)]
pub struct Sanitized {
    /// Source with comments, literal contents and test items blanked.
    pub blanked: String,
    /// Line comments outside `#[cfg(test)]` items, in file order.
    pub comments: Vec<Comment>,
    /// 1-based inclusive line ranges of stripped `#[cfg(test)]` items.
    pub test_regions: Vec<(usize, usize)>,
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Blank comments and literals, capturing line comments on the way.
fn blank_pass(src: &str) -> (Vec<char>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let mut out: Vec<char> = Vec::with_capacity(chars.len());
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    // Push a blank for every consumed char, keeping newlines (and the
    // line counter) intact.
    macro_rules! blank_upto {
        ($j:expr) => {
            while i < $j {
                if chars[i] == '\n' {
                    line += 1;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
        };
    }

    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        match c {
            '\n' => {
                line += 1;
                out.push('\n');
                i += 1;
            }
            '/' if next == Some('/') => {
                let start_line = line;
                let mut j = i + 2;
                let inner = chars.get(j) == Some(&'!');
                if inner || chars.get(j) == Some(&'/') {
                    j += 1;
                }
                let text_start = j;
                while j < chars.len() && chars[j] != '\n' {
                    j += 1;
                }
                let text: String = chars[text_start..j].iter().collect();
                comments.push(Comment { line: start_line, inner, text });
                blank_upto!(j);
            }
            '/' if next == Some('*') => {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < chars.len() && depth > 0 {
                    if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                        depth += 1;
                        j += 2;
                    } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                blank_upto!(j);
            }
            '"' => {
                let j = end_of_string(&chars, i);
                blank_upto!(j);
            }
            'r' | 'b' if !prev_is_ident(&chars, i) => {
                if let Some(j) = end_of_prefixed_literal(&chars, i) {
                    blank_upto!(j);
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            '\'' => {
                if let Some(j) = end_of_char_literal(&chars, i) {
                    blank_upto!(j);
                } else {
                    // Lifetime: keep the tick, the ident follows normally.
                    out.push('\'');
                    i += 1;
                }
            }
            _ => {
                out.push(c);
                i += 1;
            }
        }
    }
    (out, comments)
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(chars[i - 1])
}

/// `chars[i]` is the opening `"`; return the index one past the close.
fn end_of_string(chars: &[char], i: usize) -> usize {
    let mut j = i + 1;
    while j < chars.len() {
        match chars[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            _ => j += 1,
        }
    }
    j
}

/// Handle `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#` and `b'…'` starting at
/// `i`. Returns one past the literal, or `None` if this is a plain
/// identifier after all.
fn end_of_prefixed_literal(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if chars[i] == 'b' {
        match chars.get(j) {
            Some('"') => return Some(end_of_string(chars, j)),
            Some('\'') => return end_of_char_literal(chars, j),
            Some('r') => j += 1,
            _ => return None,
        }
    }
    // Raw string: hashes then a quote.
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    while j < chars.len() {
        if chars[j] == '"' {
            let tail = &chars[j + 1..];
            if tail.len() >= hashes && tail[..hashes].iter().all(|&c| c == '#') {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(j)
}

/// `chars[i]` is a `'`. Returns one past the closing quote for a char
/// literal, or `None` for a lifetime.
fn end_of_char_literal(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            // Escape: skip the escaped char, then scan to the close.
            let mut j = i + 3;
            while j < chars.len() && chars[j] != '\'' && chars[j] != '\n' {
                j += 1;
            }
            Some(j + 1)
        }
        Some(&d) if is_ident_char(d) => {
            // 'a' is a char literal; 'a (no closing quote) a lifetime.
            if chars.get(i + 2) == Some(&'\'') {
                Some(i + 3)
            } else {
                None
            }
        }
        Some(_) => {
            if chars.get(i + 2) == Some(&'\'') {
                Some(i + 3)
            } else {
                None
            }
        }
        None => None,
    }
}

/// Blank every item annotated `#[cfg(test)]` in the already-blanked
/// text; returns the 1-based inclusive line ranges removed.
fn strip_test_items(blanked: &mut [char]) -> Vec<(usize, usize)> {
    let pat: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut regions = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i + pat.len() <= blanked.len() {
        if blanked[i] == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if blanked[i..i + pat.len()] != pat[..] {
            i += 1;
            continue;
        }
        // Attribute found: the item it governs ends at the matching
        // close brace of its body, or at a `;` for braceless items.
        let start_line = line;
        let mut j = i + pat.len();
        let mut depth = 0usize;
        let mut end_line = line;
        while j < blanked.len() {
            match blanked[j] {
                '\n' => end_line += 1,
                '{' => depth += 1,
                '}' => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        break;
                    }
                }
                ';' if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let end = j.min(blanked.len().saturating_sub(1));
        for slot in blanked.iter_mut().take(end + 1).skip(i) {
            if *slot != '\n' {
                *slot = ' ';
            }
        }
        regions.push((start_line, end_line));
        line = end_line;
        i = end + 1;
    }
    regions
}

/// Sanitize one file: blank comments/literals, then strip test items
/// (and any comments captured inside them).
pub fn sanitize(src: &str) -> Sanitized {
    let (mut blanked, mut comments) = blank_pass(src);
    let test_regions = strip_test_items(&mut blanked);
    comments.retain(|c| !test_regions.iter().any(|&(s, e)| c.line >= s && c.line <= e));
    Sanitized { blanked: blanked.into_iter().collect(), comments, test_regions }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_blanked_and_captured() {
        let s = sanitize("let x = 1; // trailing note\n//! audit: deterministic\n");
        assert!(!s.blanked.contains("trailing"));
        assert!(s.blanked.contains("let x = 1;"));
        assert_eq!(s.comments.len(), 2);
        assert_eq!(s.comments[0].line, 1);
        assert!(!s.comments[0].inner);
        assert_eq!(s.comments[0].text, " trailing note");
        assert!(s.comments[1].inner);
        assert_eq!(s.comments[1].text, " audit: deterministic");
    }

    #[test]
    fn strings_and_chars_are_blanked() {
        let src = "call(\"panic! inside\", 'x', '\\n', b\"bytes\", r#\"raw \" str\"#);";
        let s = sanitize(src);
        assert!(!s.blanked.contains("panic"));
        assert!(!s.blanked.contains("bytes"));
        assert!(!s.blanked.contains("raw"));
        assert!(s.blanked.contains("call("));
        assert_eq!(s.blanked.chars().count(), src.chars().count());
    }

    #[test]
    fn lifetimes_survive() {
        let s = sanitize("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(s.blanked.contains("'a str"));
    }

    #[test]
    fn multiline_and_nested_block_comments() {
        let s = sanitize("a /* one /* two */ still */ b\nc");
        assert!(s.blanked.contains('a'));
        assert!(s.blanked.contains('b'));
        assert!(!s.blanked.contains("still"));
        assert_eq!(s.blanked.lines().count(), 2);
    }

    #[test]
    fn test_items_are_stripped() {
        let src = "fn real() {}\n#[cfg(test)]\nmod tests {\n\
                   \x20   // audit:checked(bogus)\n    fn t() { x.unwrap(); }\n}\n\
                   fn after() {}\n";
        let s = sanitize(src);
        assert!(s.blanked.contains("fn real"));
        assert!(s.blanked.contains("fn after"));
        assert!(!s.blanked.contains("unwrap"));
        assert_eq!(s.test_regions, vec![(2, 6)]);
        assert!(s.comments.is_empty(), "comments inside test items are dropped");
    }

    #[test]
    fn escaped_quotes_do_not_derail() {
        let s = sanitize(r#"let a = "he said \"hi\""; let b = 2;"#);
        assert!(s.blanked.contains("let b = 2;"));
        assert!(!s.blanked.contains("hi"));
    }
}
