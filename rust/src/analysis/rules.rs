//! The audit rule engine: policy directives + token-level checks.
//!
//! Policies are declared in comments (see DESIGN.md §Static-analysis):
//!
//! * a module opts in with an inner doc line of the form
//!   `//! audit: wire-decode, deterministic` (valid policies:
//!   `wire-decode`, `panic-free`, `deterministic`);
//! * a region is marked with plain-comment fences, e.g.
//!   `// audit:no-alloc-begin` … `// audit:no-alloc-end` (also
//!   `wire-decode-begin`/`-end` for functions that parse untrusted
//!   bytes inside an otherwise-trusted module);
//! * a single statement is waived with `// audit:checked(<reason>)` on
//!   the same line or the line directly above — the reason is
//!   mandatory and should name the guard that makes the line safe.
//!
//! Rule families:
//!
//! * **wire-decode** — code that parses untrusted bytes must be
//!   panic-free: no `unwrap`/`expect`, no panicking macros, no
//!   dynamically-indexed slices (static literal/const indexes are
//!   fine), no unchecked `as` narrowing to sub-`usize` integers.
//! * **panic-free** — the panicking-call subset of wire-decode, for
//!   modules whose indexes are trusted but that must never take down
//!   the process (the server readiness loop, the entropy coders).
//! * **deterministic** — aggregate-affecting code must not consult
//!   wall clocks or iterate hash tables: `Instant`, `SystemTime`,
//!   `HashMap`, `HashSet`, `RandomState` are forbidden names.
//! * **no-alloc** (region-only) — hot-loop regions must not allocate:
//!   `vec![]`, `Vec::`/`String::`/`Box::` constructors, `.clone()`,
//!   `.to_vec()`, `.to_owned()`, `.collect()` are forbidden.
//! * **unsafe-budget** (always on, no annotation) — `unsafe` may only
//!   appear in the budgeted files (`runtime/pjrt.rs` for the PJRT FFI
//!   boundary, `runtime/packed.rs` for the `std::arch` SIMD
//!   intrinsics), and every occurrence there must have a `// SAFETY:`
//!   comment within the 8 preceding lines.

use super::lexer::{Comment, Sanitized};

/// One audit violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the scanned source root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule family that fired.
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Directives parsed from one file's comments.
#[derive(Debug, Default)]
pub struct Directives {
    pub wire_decode: bool,
    pub deterministic: bool,
    pub panic_free: bool,
    /// 1-based inclusive line ranges between region fences.
    pub no_alloc_regions: Vec<(usize, usize)>,
    pub wire_regions: Vec<(usize, usize)>,
    /// Lines covered by an `audit:checked(...)` waiver.
    pub waived: Vec<usize>,
    /// Malformed-directive findings (rule `audit-syntax`).
    pub errors: Vec<Finding>,
}

impl Directives {
    pub fn any_policy(&self) -> bool {
        self.wire_decode
            || self.deterministic
            || self.panic_free
            || !self.no_alloc_regions.is_empty()
            || !self.wire_regions.is_empty()
    }

    fn waived(&self, line: usize) -> bool {
        self.waived.contains(&line)
    }
}

fn in_regions(regions: &[(usize, usize)], line: usize) -> bool {
    regions.iter().any(|&(s, e)| line > s && line < e)
}

/// Parse every `audit:` directive out of a file's comments.
pub fn parse_directives(file: &str, comments: &[Comment]) -> Directives {
    let mut d = Directives::default();
    let mut no_alloc_open: Vec<usize> = Vec::new();
    let mut wire_open: Vec<usize> = Vec::new();
    let err = |line: usize, message: String| Finding {
        file: file.to_string(),
        line,
        rule: "audit-syntax",
        message,
    };
    for c in comments {
        let Some(rest) = c.text.trim().strip_prefix("audit:") else {
            continue;
        };
        let rest = rest.trim();
        if let Some(body) = rest.strip_prefix("checked(") {
            match body.strip_suffix(')') {
                Some(reason) if !reason.trim().is_empty() => {
                    d.waived.push(c.line);
                    d.waived.push(c.line + 1);
                }
                _ => d.errors.push(err(
                    c.line,
                    "audit:checked needs a non-empty reason: audit:checked(<why this is safe>)"
                        .to_string(),
                )),
            }
        } else if rest == "no-alloc-begin" {
            no_alloc_open.push(c.line);
        } else if rest == "no-alloc-end" {
            match no_alloc_open.pop() {
                Some(start) => d.no_alloc_regions.push((start, c.line)),
                None => d.errors.push(err(c.line, "no-alloc-end without a begin".to_string())),
            }
        } else if rest == "wire-decode-begin" {
            wire_open.push(c.line);
        } else if rest == "wire-decode-end" {
            match wire_open.pop() {
                Some(start) => d.wire_regions.push((start, c.line)),
                None => d.errors.push(err(c.line, "wire-decode-end without a begin".to_string())),
            }
        } else if c.inner {
            for policy in rest.split(',') {
                match policy.trim() {
                    "wire-decode" => d.wire_decode = true,
                    "deterministic" => d.deterministic = true,
                    "panic-free" => d.panic_free = true,
                    other => d.errors.push(err(
                        c.line,
                        format!(
                            "unknown module policy '{other}' \
                             (valid: wire-decode, deterministic, panic-free)"
                        ),
                    )),
                }
            }
        } else {
            d.errors.push(err(
                c.line,
                format!("unknown audit directive '{rest}'"),
            ));
        }
    }
    for line in no_alloc_open {
        d.errors.push(err(line, "no-alloc-begin without an end".to_string()));
    }
    for line in wire_open {
        d.errors.push(err(line, "wire-decode-begin without an end".to_string()));
    }
    d
}

/// A token of the blanked source: a word (identifier or number) or a
/// single punctuation char.
#[derive(Debug, Clone)]
struct Tok {
    line: usize,
    text: String,
    word: bool,
}

fn tokenize(blanked: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut word = String::new();
    let mut word_line = 1usize;
    for c in blanked.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            if word.is_empty() {
                word_line = line;
            }
            word.push(c);
            continue;
        }
        if !word.is_empty() {
            toks.push(Tok { line: word_line, text: std::mem::take(&mut word), word: true });
        }
        if c == '\n' {
            line += 1;
        } else if !c.is_whitespace() {
            toks.push(Tok { line, text: c.to_string(), word: false });
        }
    }
    if !word.is_empty() {
        toks.push(Tok { line: word_line, text: word, word: true });
    }
    toks
}

/// Macros that panic (the `debug_assert*` family is allowed: it
/// vanishes in release builds and documents invariants).
const PANIC_MACROS: [&str; 7] =
    ["panic", "assert", "assert_eq", "assert_ne", "unreachable", "todo", "unimplemented"];

/// Names forbidden under `deterministic`.
const NONDET_NAMES: [&str; 5] = ["Instant", "SystemTime", "HashMap", "HashSet", "RandomState"];

/// `as`-targets the wire-decode rule treats as unchecked narrowing.
const NARROW_TARGETS: [&str; 6] = ["u8", "u16", "u32", "i8", "i16", "i32"];

/// Method names forbidden inside `no-alloc` regions.
const ALLOC_METHODS: [&str; 4] = ["clone", "to_vec", "to_owned", "collect"];

/// Type names whose `::` constructors are forbidden in `no-alloc`.
const ALLOC_TYPES: [&str; 3] = ["Vec", "String", "Box"];

/// Keywords that can directly precede `[` without forming an index
/// expression (`&mut [f32]`, `return [0; 4]`, …).
const NON_INDEX_KEYWORDS: [&str; 12] = [
    "mut", "ref", "dyn", "in", "as", "return", "move", "else", "match", "if", "impl", "where",
];

fn is_numeric(text: &str) -> bool {
    text.chars().next().is_some_and(|c| c.is_ascii_digit())
}

fn is_const_name(text: &str) -> bool {
    text.chars().any(|c| c.is_ascii_uppercase())
        && text.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
}

/// The files `unsafe` is budgeted to: the PJRT FFI boundary and the
/// `std::arch` SIMD intrinsics of the packed compute tier.
pub const UNSAFE_BUDGET_FILES: [&str; 2] = ["runtime/pjrt.rs", "runtime/packed.rs"];

fn has_safety_comment(comments: &[Comment], line: usize) -> bool {
    comments
        .iter()
        .any(|c| c.line < line && c.line + 8 >= line && c.text.contains("SAFETY:"))
}

/// Run every rule family over one sanitized file. `file` is the path
/// relative to the source root (it selects the unsafe budget).
pub fn check_file(file: &str, san: &Sanitized) -> (Directives, Vec<Finding>) {
    let d = parse_directives(file, &san.comments);
    let toks = tokenize(&san.blanked);
    let mut out = d.errors.clone();
    let finding = |line: usize, rule: &'static str, message: String| Finding {
        file: file.to_string(),
        line,
        rule,
        message,
    };

    let panic_scope =
        |line: usize| d.wire_decode || d.panic_free || in_regions(&d.wire_regions, line);
    let strict_scope = |line: usize| d.wire_decode || in_regions(&d.wire_regions, line);

    for (i, t) in toks.iter().enumerate() {
        let line = t.line;
        let prev = i.checked_sub(1).map(|j| &toks[j]);
        let next = toks.get(i + 1);

        // unsafe-budget: always on, waivers do not apply.
        if t.word && t.text == "unsafe" {
            if !UNSAFE_BUDGET_FILES.contains(&file) {
                out.push(finding(
                    line,
                    "unsafe-budget",
                    format!("`unsafe` outside the budgeted {}", UNSAFE_BUDGET_FILES.join(" / ")),
                ));
            } else if !has_safety_comment(&san.comments, line) {
                out.push(finding(
                    line,
                    "unsafe-budget",
                    "`unsafe` without a `// SAFETY:` comment in the 8 lines above".to_string(),
                ));
            }
        }
        if d.waived(line) {
            continue;
        }

        // Panicking calls (wire-decode and panic-free scopes).
        if panic_scope(line) && t.word {
            let rule = if strict_scope(line) { "wire-decode" } else { "panic-free" };
            let dotted = prev.is_some_and(|p| !p.word && p.text == ".");
            if dotted && (t.text == "unwrap" || t.text == "expect") {
                out.push(finding(
                    line,
                    rule,
                    format!(".{}() can panic on untrusted input; return the error", t.text),
                ));
            }
            let banged = next.is_some_and(|n| !n.word && n.text == "!");
            if banged && PANIC_MACROS.contains(&t.text.as_str()) {
                out.push(finding(
                    line,
                    rule,
                    format!("{}! panics; use ensure!/bail! to surface a typed error", t.text),
                ));
            }
        }

        // Unchecked narrowing + dynamic indexing (wire-decode scope).
        if strict_scope(line) && t.word && t.text == "as" {
            if let Some(n) = next {
                if n.word && NARROW_TARGETS.contains(&n.text.as_str()) {
                    out.push(finding(
                        line,
                        "wire-decode",
                        format!(
                            "unchecked `as {}` narrowing; bound the value first and waive \
                             with audit:checked(<guard>)",
                            n.text
                        ),
                    ));
                }
            }
        }
        if strict_scope(line) && !t.word && t.text == "[" {
            let postfix = prev.is_some_and(|p| {
                if p.word {
                    !NON_INDEX_KEYWORDS.contains(&p.text.as_str())
                } else {
                    p.text == "]" || p.text == ")" || p.text == "?"
                }
            });
            if postfix && dynamic_index(&toks, i) {
                out.push(finding(
                    line,
                    "wire-decode",
                    "dynamically-indexed slice can panic on untrusted lengths; use get() \
                     or guard and waive with audit:checked(<guard>)"
                        .to_string(),
                ));
            }
        }

        // Determinism.
        if d.deterministic && t.word && NONDET_NAMES.contains(&t.text.as_str()) {
            out.push(finding(
                line,
                "deterministic",
                format!("{} is nondeterministic; aggregate-affecting code must not use it", t.text),
            ));
        }

        // Allocation inside marked hot loops.
        if in_regions(&d.no_alloc_regions, line) {
            let banged = next.is_some_and(|n| !n.word && n.text == "!");
            let dotted = prev.is_some_and(|p| !p.word && p.text == ".");
            let pathed = next.is_some_and(|n| !n.word && n.text == ":");
            if t.word && t.text == "vec" && banged {
                out.push(finding(line, "no-alloc", "vec![] allocates in a hot loop".to_string()));
            } else if t.word && dotted && ALLOC_METHODS.contains(&t.text.as_str()) {
                out.push(finding(
                    line,
                    "no-alloc",
                    format!(".{}() allocates in a hot loop; reuse workspace buffers", t.text),
                ));
            } else if t.word && pathed && ALLOC_TYPES.contains(&t.text.as_str()) {
                out.push(finding(
                    line,
                    "no-alloc",
                    format!("{}:: constructor allocates in a hot loop", t.text),
                ));
            }
        }
    }

    out.sort_by_key(|f| f.line);
    (d, out)
}

/// Does the bracket group opening at `toks[open]` index with anything
/// other than literals, `..` ranges and SCREAMING_CASE constants?
fn dynamic_index(toks: &[Tok], open: usize) -> bool {
    let mut depth = 1usize;
    let mut j = open + 1;
    while j < toks.len() && depth > 0 {
        let t = &toks[j];
        if !t.word {
            if t.text == "[" {
                depth += 1;
            } else if t.text == "]" {
                depth -= 1;
            }
        } else if !is_numeric(&t.text) && !is_const_name(&t.text) {
            return true;
        }
        j += 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::sanitize;

    fn run(file: &str, src: &str) -> Vec<Finding> {
        check_file(file, &sanitize(src)).1
    }

    fn rules(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unannotated_files_only_get_the_unsafe_rule() {
        let f = run("x.rs", "fn f(v: &[u8]) -> u8 { v.first().copied().unwrap_or(0) }");
        assert!(f.is_empty(), "{f:?}");
        let f = run("x.rs", "fn f() { unsafe { std::hint::unreachable_unchecked() } }");
        assert_eq!(rules(&f), ["unsafe-budget"]);
    }

    #[test]
    fn wire_decode_catches_the_four_shapes() {
        let src = "//! audit: wire-decode\n\
                   fn f(b: &[u8], n: usize) -> u16 {\n\
                   let x = b.first().unwrap();\n\
                   assert!(*x > 0);\n\
                   let y = b[n];\n\
                   (y as u16) + (*x as u16)\n\
                   }\n";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["wire-decode"; 5], "{f:?}");
        assert_eq!(f.iter().map(|f| f.line).collect::<Vec<_>>(), [3, 4, 5, 6, 6]);
    }

    #[test]
    fn static_indexes_and_widening_are_fine() {
        let src = "//! audit: wire-decode\n\
                   const HEAD: usize = 4;\n\
                   fn f(b: &[u8]) -> u64 {\n\
                   let arr = [0u8; 2];\n\
                   let n = b.len() as u64;\n\
                   (b[0] as u64) + (b[1..3].len() as u64) + (b[HEAD] as u64)\n\
                   + (arr[1] as u64) + n\n\
                   }\n";
        let f = run("x.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn waiver_covers_its_own_and_the_next_line() {
        let src = "//! audit: wire-decode\n\
                   fn f(b: &[u8], n: usize) -> u8 {\n\
                   // audit:checked(caller bounds n against b.len())\n\
                   b[n]\n\
                   }\n";
        assert!(run("x.rs", src).is_empty());
        let unreasoned = "//! audit: wire-decode\n\
                          fn f(b: &[u8], n: usize) -> u8 {\n\
                          // audit:checked()\n\
                          b[n]\n\
                          }\n";
        let f = run("x.rs", unreasoned);
        assert_eq!(rules(&f), ["audit-syntax", "wire-decode"], "{f:?}");
    }

    #[test]
    fn panic_free_skips_index_strictness() {
        let src = "//! audit: panic-free\n\
                   fn f(v: &[u32], i: usize) -> u8 { v[i] as u8 }\n\
                   fn g(v: &[u32]) { v.last().unwrap(); }\n";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["panic-free"]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn deterministic_bans_clocks_and_hashers() {
        let src = "//! audit: deterministic\n\
                   use std::collections::HashMap;\n\
                   fn f() { let _ = std::time::Instant::now(); }\n";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["deterministic", "deterministic"]);
    }

    #[test]
    fn no_alloc_region_bans_allocation_but_only_inside() {
        let src = "fn setup() -> Vec<f32> { vec![0.0; 8] }\n\
                   // audit:no-alloc-begin\n\
                   fn hot(a: &mut [f32], b: &[f32]) {\n\
                   for (x, y) in a.iter_mut().zip(b) { *x += *y; }\n\
                   }\n\
                   // audit:no-alloc-end\n\
                   fn teardown(v: &[f32]) -> Vec<f32> { v.to_vec() }\n";
        assert!(run("x.rs", src).is_empty());
        let bad = "// audit:no-alloc-begin\n\
                   fn hot(b: &[f32]) -> Vec<f32> {\n\
                   let v = vec![0.0f32; 4];\n\
                   let w = Vec::with_capacity(4);\n\
                   let _ = (v.clone(), w);\n\
                   b.to_vec()\n\
                   }\n\
                   // audit:no-alloc-end\n";
        let f = run("x.rs", bad);
        assert_eq!(rules(&f), ["no-alloc"; 4], "{f:?}");
    }

    #[test]
    fn unsafe_needs_a_safety_comment_even_in_budget() {
        for file in UNSAFE_BUDGET_FILES {
            let bare = "fn f() { unsafe { work() } }\n";
            assert_eq!(rules(&run(file, bare)), ["unsafe-budget"], "{file}");
            let documented = "// SAFETY: work() has no preconditions here.\n\
                              fn f() { unsafe { work() } }\n";
            assert!(run(file, documented).is_empty(), "{file}");
        }
    }

    #[test]
    fn region_fences_must_pair() {
        let src = "// audit:no-alloc-begin\nfn f() {}\n";
        assert_eq!(rules(&run("x.rs", src)), ["audit-syntax"]);
        let src = "fn f() {}\n// audit:wire-decode-end\n";
        assert_eq!(rules(&run("x.rs", src)), ["audit-syntax"]);
    }

    #[test]
    fn unknown_policies_and_directives_error() {
        assert_eq!(rules(&run("x.rs", "//! audit: wire-safety\n")), ["audit-syntax"]);
        assert_eq!(rules(&run("x.rs", "// audit:nonsense\n")), ["audit-syntax"]);
    }

    #[test]
    fn test_items_are_exempt() {
        let src = "//! audit: wire-decode, deterministic\n\
                   fn ok(b: &[u8]) -> u8 { b[0] }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   #[test]\n\
                   fn t() { let m = std::collections::HashMap::<u8, u8>::new(); \
                   assert!(m.get(&0).is_none()); }\n\
                   }\n";
        assert!(run("x.rs", src).is_empty());
    }
}
