//! fedsrn — launcher for the regularized sparse-random-network FL stack.
//!
//! Commands:
//!   train              one experiment from a config file / overrides
//!   serve              run the federation server over real TCP sessions
//!   device             run one remote device against a server
//!   fleet              simulate a 100k-device federation (no sockets)
//!   figure fig1|fig2|summary|compare   regenerate the paper's figures
//!                      (`figures --compare` = the five-strategy Bpp table)
//!   eval               evaluate a saved checkpoint
//!   analyze            summarize a run's JSONL metrics log
//!   inspect-artifacts  list AOT artifacts and their manifests
//!   codec-bench        entropy-coder throughput/rate sweep
//!   audit              invariant linter over the crate sources (CI gate)
//!   help

#![warn(clippy::disallowed_methods, clippy::disallowed_types)]

use std::path::Path;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use fedsrn::cli::Args;
use fedsrn::compress;
use fedsrn::config::ExperimentConfig;
use fedsrn::coordinator::{figures, Checkpoint, Experiment};
use fedsrn::fl::MetricsSink;
use fedsrn::mask::ProbMask;
use fedsrn::runtime::{available_models, Manifest, ModelRuntime};
use fedsrn::util::{BitVec, Xoshiro256};

const HELP: &str = "\
fedsrn — Communication-Efficient FL via Regularized Sparse Random Networks

USAGE:
  fedsrn train [--config FILE] [--set key=value]... [--checkpoint FILE]
  fedsrn serve [--config FILE] [--set key=value]... [--addr 127.0.0.1:7878]
               [--deadline-ms 30000] [--register-timeout-ms 120000] [--wave N]
  fedsrn device --id N [--addr 127.0.0.1:7878] [--config FILE]
               [--set key=value]... [--connect-timeout-ms 60000]
               [--chaos-seed S] [--delay-base B] [--delay-jitter J]
               [--deadline-ticks T]
  fedsrn fleet --devices N [--rounds R] [--config FILE] [--set key=value]...
               [--n-params P] [--churn F] [--deadline-ticks T]
               [--delay-base B] [--delay-jitter J]
  fedsrn figure fig1 [--dataset mnist|cifar10|cifar100] [--model M]
                     [--rounds N] [--clients K] [--seed S] [--out DIR]
  fedsrn figure fig2 [--dataset mnist|cifar10] [--model M] [--rounds N]
                     [--clients K] [--classes C] [--lambdas 0.1,1]
                     [--seed S] [--out DIR]
  fedsrn figure summary [--rounds N] [--out DIR]   # all IID datasets
  fedsrn figures --compare [--dataset D] [--model M] [--rounds N]
                 [--clients K] [--seed S] [--out DIR]
                 # all five strategies at one matched budget -> compare.json
  fedsrn eval --checkpoint FILE [--dataset D] [--samples N] [--seed S]
  fedsrn analyze --run FILE.jsonl [--tail 5]
  fedsrn inspect-artifacts [--dir artifacts]
  fedsrn codec-bench [--n 268800]
  fedsrn audit [--src rust/src]
  fedsrn help

Config keys for --set (see rust/src/config/mod.rs): model dataset
algorithm partition clients rounds local_epochs lambda lr topk_frac
server_lr train_samples test_samples eval_every optimizer adam
participation dropout bayes_prior downlink aggregation staleness_beta
edges threads seed artifacts_dir out

model names the built-in native registry entry or an exported artifact:
mlp_tiny | mlp_mnist | mlp_cifar10 | mlp_cifar100 (dense) and conv_tiny
| conv4 | conv6 (layer graphs; pair conv4/conv6 with dataset=cifar10,
conv_tiny with dataset=tiny). `fedsrn inspect-artifacts` lists both.

downlink selects the broadcast wire format: float32 (raw, 32 Bpp) or
qdelta<bits> (quantized sparse deltas with residual feedback, e.g.
qdelta8); clients train on exactly what the wire delivered.

threads controls the parallel round engine (0 = all cores, 1 =
sequential); results are bit-identical at any thread count.

serve/device run the same federation over real sockets: start `fedsrn
serve`, then one `fedsrn device --id I` process per client id with the
SAME config/--set values (a version/fingerprint handshake rejects
mismatches). The result is bit-identical to `fedsrn train`
(DESIGN.md §Transport).

--chaos-seed wraps the device's socket in a deterministic fault
injector (seeded delays, split writes, corrupted frames, mid-round
disconnects) armed after a clean handshake — for torture-testing the
server's readiness loop; every failure must surface as a typed
dropout/reconnect, never a hang or a wrong aggregate.

aggregation selects the round barrier: sync (wait out the whole
cohort) or buffered<K> (close after K folds; stragglers' uplinks
carry forward, discounted by 1/(1+staleness)^staleness_beta).
edges=N folds each cohort through N edge aggregators that each ship
one merged envelope upstream — bit-identical to the flat fold
(DESIGN.md §Fleet). partition=dirichlet:<alpha> draws per-client
class mixtures from a symmetric Dirichlet (smaller alpha = more
label skew).

fleet simulates a sync or buffered-async federation at fleet scale
(100k+ devices, no OS threads or sockets): seeded churn, per-device
compute-delay profiles, virtual-tick straggler deadlines. Prints
rounds/sec and peak RSS and writes both as fleet/* entries into
$BENCH_JSON_DIR/BENCH_components.json. --delay-base/--delay-jitter
on `device` give one real device the same deterministic
self-straggler behavior (DESIGN.md §Fleet).

audit lints the crate sources for the contracts the test suite can
only spot-check: wire-decode panic-freedom, aggregate determinism,
alloc-free hot loops and the unsafe budget (DESIGN.md
§Static-analysis). Any finding is a non-zero exit; CI runs it as a
required gate.
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() {
        println!("{HELP}");
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "device" => cmd_device(&args),
        "fleet" => cmd_fleet(&args),
        "figure" | "figures" => cmd_figure(&args),
        "eval" => cmd_eval(&args),
        "analyze" => cmd_analyze(&args),
        "inspect-artifacts" => cmd_inspect(&args),
        "codec-bench" => cmd_codec_bench(&args),
        "audit" => cmd_audit(&args),
        other => bail!("unknown command '{other}' (try `fedsrn help`)"),
    }
}

/// Run the invariant linter over the crate sources (the CI gate).
fn cmd_audit(args: &Args) -> Result<()> {
    args.ensure_known_flags(&["src"])?;
    let root = match args.flag("src") {
        Some(p) => std::path::PathBuf::from(p),
        None => ["rust/src", "src"]
            .into_iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_dir())
            .context("no rust/src or src here; pass --src DIR")?,
    };
    let report = fedsrn::analysis::audit_tree(&root)?;
    print!("{}", report.render());
    if !report.is_clean() {
        bail!("audit failed with {} finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    args.ensure_known_flags(&["config", "checkpoint"])?;
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    for (k, v) in &args.overrides {
        cfg.apply(k, v)?;
    }
    cfg.validate()?;
    eprintln!(
        "training: model={} dataset={} algo={} partition={:?} K={} T={} lambda={}",
        cfg.model, cfg.dataset, cfg.algorithm.name(), cfg.partition, cfg.clients,
        cfg.rounds, cfg.effective_lambda()
    );
    let out = cfg.out.clone();
    let mut sink = MetricsSink::new(&out, 1)?;
    let mut exp = Experiment::build(cfg)?;
    let summary = exp.run(&mut sink)?;
    print_summary(&summary);
    if let Some(ck_path) = args.flag("checkpoint") {
        save_checkpoint(&exp, ck_path)?;
    }
    Ok(())
}

/// Shared summary line (train + serve): the CI loopback job parses the
/// `avg_estBpp=` field (eq. 13, the paper's reported UL Bpp) to assert
/// the mask uplink stays <= 1 Bpp — keep the key=value format stable.
fn print_summary(summary: &fedsrn::coordinator::RunSummary) {
    println!(
        "final: acc={:.4} avg_estBpp={:.4} avg_codedBpp={:.4} avg_DLBpp={:.4} \
         UL={:.3}MB DL={:.3}MB storage={}bits",
        summary.final_accuracy,
        summary.avg_est_bpp,
        summary.avg_coded_bpp,
        summary.avg_dl_bpp,
        summary.total_ul_mb,
        summary.total_dl_mb,
        summary.storage_bits
    );
}

fn cmd_serve(args: &Args) -> Result<()> {
    use fedsrn::fl::{run_fingerprint, Session, SessionConfig};
    use std::time::Duration;
    args.ensure_known_flags(&["config", "addr", "deadline-ms", "register-timeout-ms", "wave"])?;
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    for (k, v) in &args.overrides {
        cfg.apply(k, v)?;
    }
    cfg.validate()?;
    let addr = args.flag_or("addr", "127.0.0.1:7878");
    let deadline = Duration::from_millis(args.flag_parse("deadline-ms", 30_000u64)?);
    let register_timeout =
        Duration::from_millis(args.flag_parse("register-timeout-ms", 120_000u64)?);
    let wave: usize = args.flag_parse("wave", 0usize)?;
    eprintln!(
        "serving: model={} dataset={} algo={} K={} T={} downlink={}",
        cfg.model,
        cfg.dataset,
        cfg.algorithm.name(),
        cfg.clients,
        cfg.rounds,
        cfg.downlink.name()
    );
    let out = cfg.out.clone();
    let mut sink = MetricsSink::new(&out, 1)?;
    let mut exp = Experiment::build(cfg)?;
    let fingerprint = run_fingerprint(&exp.cfg, &exp.runtime().manifest);
    let scfg = SessionConfig::from_experiment(&exp.cfg, fingerprint, deadline, wave);
    let mut session = Session::bind(&addr, scfg)?;
    eprintln!(
        "listening on {} (fingerprint {fingerprint:#018x}); waiting for {} devices",
        session.local_addr()?,
        exp.cfg.clients
    );
    session.wait_for_fleet(register_timeout)?;
    let summary = exp.run_served(&mut session, &mut sink)?;
    session.finish()?;
    print_summary(&summary);
    let stats = session.stats;
    println!(
        "transport: tx={:.3}MB rx={:.3}MB stragglers={} missing={} reconnects={} syncs={} \
         protocol_errors={} idle_naps={}",
        stats.tx_bytes as f64 / 1e6,
        stats.rx_bytes as f64 / 1e6,
        stats.stragglers,
        stats.missing,
        stats.reconnects,
        stats.syncs,
        stats.protocol_errors,
        stats.idle_naps
    );
    Ok(())
}

fn cmd_device(args: &Args) -> Result<()> {
    use fedsrn::fl::{run_device, ChaosSpec, DelayProfile, DeviceOpts};
    use std::time::Duration;
    args.ensure_known_flags(&[
        "config",
        "addr",
        "id",
        "connect-timeout-ms",
        "chaos-seed",
        "delay-base",
        "delay-jitter",
        "deadline-ticks",
    ])?;
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    for (k, v) in &args.overrides {
        cfg.apply(k, v)?;
    }
    cfg.validate()?;
    let id: usize = args
        .flag("id")
        .context("--id N required (this device's client id)")?
        .parse()
        .context("--id must be an integer")?;
    let chaos = match args.flag("chaos-seed") {
        Some(s) => {
            let seed: u64 = s.parse().context("--chaos-seed must be an integer")?;
            Some(ChaosSpec::aggressive(seed))
        }
        None => None,
    };
    // --delay-base opts this device into the deterministic virtual-tick
    // self-straggler path (DESIGN.md §Fleet): its per-device speed class
    // is derived from the shared experiment seed, so every process in
    // the fleet agrees on who the stragglers are.
    let delay = match args.flag("delay-base") {
        Some(_) => {
            let base = args.flag_parse("delay-base", 0u64)?;
            let jitter = args.flag_parse("delay-jitter", 0u64)?;
            Some(DelayProfile::for_device(cfg.seed, id as u64, base, jitter))
        }
        None => None,
    };
    let opts = DeviceOpts {
        addr: args.flag_or("addr", "127.0.0.1:7878"),
        device_id: id,
        connect_timeout: Duration::from_millis(
            args.flag_parse("connect-timeout-ms", 60_000u64)?,
        ),
        chaos,
        delay,
        deadline_ticks: args.flag_parse("deadline-ticks", 150u64)?,
    };
    match &opts.chaos {
        Some(spec) => eprintln!(
            "device {id}: connecting to {} (chaos seed {})",
            opts.addr, spec.seed
        ),
        None => eprintln!("device {id}: connecting to {}", opts.addr),
    }
    let report = run_device(&cfg, &opts)?;
    println!(
        "device {id}: done — rounds_seen={} trained={} dropped={} reconnects={} \
         tx={:.3}MB rx={:.3}MB",
        report.rounds_seen,
        report.trained,
        report.dropped,
        report.reconnects,
        report.tx_bytes as f64 / 1e6,
        report.rx_bytes as f64 / 1e6
    );
    Ok(())
}

/// Peak resident set size in MB from `/proc/self/status` (`VmHWM`, in
/// kB), or `None` off Linux / when unreadable.
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

/// Run the fleet-scale simulator and emit its trajectory metrics in the
/// same machine-readable schema as the bench harnesses.
fn cmd_fleet(args: &Args) -> Result<()> {
    use fedsrn::fl::{run_fleet, FleetOpts};
    use std::time::Instant;
    args.ensure_known_flags(&[
        "config",
        "devices",
        "rounds",
        "n-params",
        "churn",
        "deadline-ticks",
        "delay-base",
        "delay-jitter",
    ])?;
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    for (k, v) in &args.overrides {
        cfg.apply(k, v)?;
    }
    cfg.validate()?;
    let devices: usize = args.flag_parse("devices", 100_000usize)?;
    let rounds: usize = args.flag_parse("rounds", 3usize)?;
    let mut opts = FleetOpts::new(devices, rounds);
    opts.algorithm = cfg.algorithm;
    opts.aggregation = cfg.aggregation;
    opts.staleness_beta = cfg.staleness_beta;
    opts.edges = cfg.edges;
    opts.participation = cfg.participation;
    opts.seed = cfg.seed;
    opts.n_params = args.flag_parse("n-params", opts.n_params)?;
    opts.churn = args.flag_parse("churn", opts.churn)?;
    opts.deadline_ticks = args.flag_parse("deadline-ticks", opts.deadline_ticks)?;
    opts.delay_base = args.flag_parse("delay-base", opts.delay_base)?;
    opts.delay_jitter = args.flag_parse("delay-jitter", opts.delay_jitter)?;
    eprintln!(
        "fleet: {} devices x {} rounds, algo={} aggregation={:?} edges={} churn={}",
        opts.devices,
        opts.rounds,
        opts.algorithm.name(),
        opts.aggregation,
        opts.edges,
        opts.churn
    );
    let t0 = Instant::now();
    let report = run_fleet(&opts)?;
    let elapsed = t0.elapsed();
    println!(
        "fleet: rounds={} folds={} stale_folds={} dropouts={} churned={} carried={} \
         ticks={} digest={:#018x} loss={:.4}",
        report.rounds_completed,
        report.folds,
        report.stale_folds,
        report.dropouts,
        report.churned,
        report.carried,
        report.ticks,
        report.model_digest,
        report.final_loss
    );
    let rounds_per_sec = report.rounds_completed as f64 / elapsed.as_secs_f64();
    println!(
        "fleet: {:.2} rounds/sec ({} devices, {:.2}s wall)",
        rounds_per_sec,
        opts.devices,
        elapsed.as_secs_f64()
    );
    let mut json = fedsrn::util::bench::BenchJson::new();
    json.record_raw(
        "fleet/rounds_per_sec",
        report.rounds_completed,
        elapsed.as_nanos() as f64 / report.rounds_completed.max(1) as f64,
        None,
    );
    if let Some(rss_mb) = peak_rss_mb() {
        println!("fleet: peak RSS {rss_mb:.1} MB");
        json.record_raw("fleet/peak_rss_mb", 1, rss_mb, None);
    }
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::PathBuf::from(dir).join("BENCH_components.json");
    json.write_file(&path)?;
    println!("fleet: wrote {} trajectory entries -> {}", json.len(), path.display());
    Ok(())
}

fn save_checkpoint(exp: &Experiment, path: &str) -> Result<()> {
    use fedsrn::algos::EvalModel;
    let man = &exp.runtime().manifest;
    let mask = match exp.global_model() {
        EvalModel::Masked(m) => BitVec::from_f32_threshold(&m),
        EvalModel::Dense(_) => {
            bail!("--checkpoint is only meaningful for mask algorithms")
        }
    };
    let ck = Checkpoint::new(&man.model, man.weight_seed, man.n_params, &mask);
    ck.save(Path::new(path))?;
    println!(
        "checkpoint: {} bytes vs dense {} bytes ({:.1}x smaller) -> {path}",
        ck.size_bytes(),
        ck.dense_size_bytes(),
        ck.compression_factor()
    );
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    args.ensure_known_flags(&[
        "dataset", "model", "rounds", "clients", "classes", "lambdas", "seed", "out", "compare",
    ])?;
    // `fedsrn figures --compare` and `fedsrn figure compare` are the
    // same harness.
    let compare = "compare".to_string();
    let which = if args.has_flag("compare") {
        &compare
    } else {
        args.positional
            .first()
            .context("figure needs a name: fig1 | fig2 | summary | compare")?
    };
    let dataset = args.flag_or("dataset", "mnist");
    let model = args.flag_or("model", figures::default_model_for(&dataset));
    let seed: u64 = args.flag_parse("seed", 2023u64)?;
    let out = args.flag_or("out", "runs");
    match which.as_str() {
        "fig1" => {
            let rounds = args.flag_parse("rounds", 30usize)?;
            let clients = args.flag_parse("clients", 10usize)?;
            figures::run_fig1(&dataset, &model, rounds, clients, seed, &out)?;
        }
        "fig2" => {
            let rounds = args.flag_parse("rounds", 30usize)?;
            let clients = args.flag_parse("clients", 30usize)?;
            let c = args.flag_parse("classes", 2usize)?;
            let lambdas: Vec<f32> = args
                .flag_or("lambdas", "0.1,1")
                .split(',')
                .map(|s| s.trim().parse::<f32>().context("parsing --lambdas"))
                .collect::<Result<_>>()?;
            figures::run_fig2(&dataset, &model, rounds, clients, c, &lambdas, seed, &out)?;
        }
        "compare" => {
            let rounds = args.flag_parse("rounds", 20usize)?;
            let clients = args.flag_parse("clients", 10usize)?;
            figures::run_compare(&dataset, &model, rounds, clients, seed, &out)?;
        }
        "summary" => {
            let rounds = args.flag_parse("rounds", 30usize)?;
            let mut all = Vec::new();
            for ds in ["mnist", "cifar10", "cifar100"] {
                let model = figures::default_model_for(ds).to_string();
                if Manifest::load(Path::new("artifacts"), &model).is_err()
                    && Manifest::builtin(&model).is_none()
                {
                    eprintln!("skipping {ds}: no artifacts or built-in for {model}");
                    continue;
                }
                let curves = figures::run_fig1(ds, &model, rounds, 10, seed, &out)?;
                all.push((ds.to_string(), curves));
            }
            figures::summary_table(&all);
        }
        other => bail!("unknown figure '{other}'"),
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    args.ensure_known_flags(&["checkpoint", "dataset", "samples", "artifacts", "seed", "compute"])?;
    let ck_path = args.flag("checkpoint").context("--checkpoint FILE required")?;
    let ck = Checkpoint::load(Path::new(ck_path))?;
    let dir = args.flag_or("artifacts", "artifacts");
    let mut rt = ModelRuntime::load(Path::new(&dir), &ck.model)?;
    rt.set_compute(fedsrn::runtime::Compute::parse(&args.flag_or("compute", "blocked"))?);
    let dataset = args.flag_or("dataset", "tiny");
    let samples: usize = args.flag_parse("samples", 512usize)?;
    // Pass the experiment's seed to reproduce its exact test draw
    // (Experiment::load_data subsamples with cfg.seed ^ 1).
    let seed: u64 = args.flag_parse("seed", 2023u64)?;
    // Same data-resolution order as Experiment::load_data: the real test
    // split when the files are present, the synthetic generator otherwise
    // (the seed used to always evaluate on synthetic data, silently
    // ignoring a downloaded dataset).
    let data = match fedsrn::data::loader::try_load(&dataset, false) {
        Some(test) => {
            eprintln!("using real {dataset} test data ({} samples)", test.len());
            anyhow::ensure!(
                test.dim == rt.manifest.input_dim,
                "dataset '{dataset}' dim {} != model input {} (wrong --dataset pairing?)",
                test.dim,
                rt.manifest.input_dim
            );
            anyhow::ensure!(
                test.n_classes == rt.manifest.n_classes,
                "dataset '{dataset}' has {} classes, model expects {}",
                test.n_classes,
                rt.manifest.n_classes
            );
            fedsrn::data::subsample(test, samples, seed ^ 1)
        }
        None => {
            let mut spec =
                fedsrn::data::SynthSpec::by_name(&dataset).context("unknown dataset")?;
            spec.n_classes = rt.manifest.n_classes;
            fedsrn::data::Synthetic::new(spec, seed ^ 0xDA7A).generate(samples, 2)
        }
    };
    let mask_bits = ck.decode_mask().context("decoding checkpoint mask")?;
    let m = rt.eval_mask(&mask_bits.to_f32(), &data.x, &data.y)?;
    println!(
        "checkpoint {}: accuracy={:.4} loss={:.4} ({} examples, mask density {:.4})",
        ck_path,
        m.accuracy(),
        m.mean_loss(),
        m.examples,
        mask_bits.density()
    );
    if !rt.manifest.layers.is_empty() {
        let stats = fedsrn::mask::layer_stats(&mask_bits, &rt.manifest.layers);
        println!("\nper-layer sparsity (where the regularizer pruned):");
        print!("{}", fedsrn::mask::layers::format_table(&stats));
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    args.ensure_known_flags(&["run", "tail"])?;
    let path = args.flag("run").context("--run FILE.jsonl required")?;
    let tail: usize = args.flag_parse("tail", 5usize)?;
    let recs = fedsrn::util::read_jsonl(Path::new(path))?;
    anyhow::ensure!(!recs.is_empty(), "no records in {path}");
    let col = |k: &str| -> Vec<f64> {
        recs.iter().filter_map(|r| r.get(k).and_then(|v| v.as_f64())).collect()
    };
    let acc = col("accuracy");
    let est = col("est_bpp");
    let coded = col("coded_bpp");
    let dl = col("dl_bpp");
    let secs = col("secs");
    let last = |v: &[f64], k: usize| -> f64 {
        if v.is_empty() { return 0.0; }
        let take = k.min(v.len());
        v[v.len() - take..].iter().sum::<f64>() / take as f64
    };
    println!("run: {path} ({} rounds)", recs.len());
    println!("  final accuracy (tail {tail} mean): {:.4}", last(&acc, tail));
    println!("  est Bpp: first {:.4} -> last {:.4} (avg {:.4})",
        est.first().copied().unwrap_or(0.0), est.last().copied().unwrap_or(0.0),
        fedsrn::util::mean(&est));
    println!("  coded Bpp avg: {:.4}", fedsrn::util::mean(&coded));
    if !dl.is_empty() {
        println!("  DL Bpp avg: {:.4}", fedsrn::util::mean(&dl));
    }
    println!("  round time: mean {:.3}s (total {:.1}s)",
        fedsrn::util::mean(&secs), secs.iter().sum::<f64>());
    // Bpp savings vs the 1-bit bound over the whole run
    println!("  uplink saved vs 1 Bpp bound: {:.1}%",
        (1.0 - fedsrn::util::mean(&coded)).max(0.0) * 100.0);
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.ensure_known_flags(&["dir"])?;
    let dir = args.flag_or("dir", "artifacts");
    let header = format!(
        "{:<16} {:<9} {:>10} {:>8} {:>8} {:>6} {:>6} {:>7}",
        "model", "source", "n_params", "in_dim", "classes", "B", "S", "layers"
    );
    let row = |man: &Manifest, source: &str| {
        println!(
            "{:<16} {:<9} {:>10} {:>8} {:>8} {:>6} {:>6} {:>7}",
            man.model,
            source,
            man.n_params,
            man.input_dim,
            man.n_classes,
            man.batch,
            man.steps,
            man.layers.iter().filter(|l| !l.is_empty()).count()
        );
    };
    println!("{header}");
    let exported = available_models(Path::new(&dir));
    for m in &exported {
        row(&Manifest::load(Path::new(&dir), m)?, "artifact");
    }
    // The built-in native registry runs with no artifacts at all
    // (DESIGN.md §Substitutions); exported manifests shadow it.
    for m in Manifest::builtin_models() {
        if !exported.iter().any(|e| e == m) {
            row(&Manifest::builtin(m).unwrap(), "builtin");
        }
    }
    if exported.is_empty() {
        eprintln!("(no artifacts in '{dir}' — built-in native registry only)");
    }
    Ok(())
}

fn cmd_codec_bench(args: &Args) -> Result<()> {
    args.ensure_known_flags(&["n"])?;
    let n: usize = args.flag_parse("n", 268_800usize)?;
    println!("mask codec sweep over n={n} parameters:");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "density", "H(p) bits", "arith Bpp", "golomb Bpp", "winner", "arith MB/s", "golomb MB/s"
    );
    let mut rng = Xoshiro256::new(7);
    for &p in &[0.005, 0.01, 0.05, 0.1, 0.25, 0.5] {
        let theta = ProbMask::constant(n, p as f32);
        let mask = fedsrn::mask::sample_mask(&theta, rng.next_u64());
        let h = fedsrn::mask::entropy_bits(p);
        let arith = compress::encode_with(&mask, compress::Method::Arithmetic);
        let gol = compress::encode_with(&mask, compress::Method::Golomb);
        let best = compress::encode(&mask);
        // One timing loop for the whole repo (util::bench): the same
        // helper drives the cargo-bench harness and its JSON emitter.
        let pair = fedsrn::util::bench::time_pair(
            0.25,
            50,
            || {
                std::hint::black_box(compress::encode_with(&mask, compress::Method::Arithmetic));
            },
            || {
                std::hint::black_box(compress::encode_with(&mask, compress::Method::Golomb));
            },
        );
        let mbs = |t: &fedsrn::util::bench::Timing| n as f64 / 8.0 / 1e6 / t.mean_s;
        println!(
            "{:>8.3} {:>12.4} {:>12.4} {:>12.4} {:>10} {:>12.1} {:>12.1}",
            p,
            h,
            arith.bpp(n),
            gol.bpp(n),
            format!("{:?}", best.method),
            mbs(&pair.a),
            mbs(&pair.b)
        );
    }
    Ok(())
}
