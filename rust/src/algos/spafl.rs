//! SpaFL: communication-efficient FL with trainable per-filter
//! pruning thresholds (arxiv 2406.00431).
//!
//! The extreme point of the strategy family's Bpp spectrum: devices
//! never upload parameters at all. Each structured *filter* (a Dense
//! column or a Conv2d output channel, derived from the manifest's
//! [`LayerSlice`] telemetry) owns one trainable threshold tau_f; a
//! parameter survives pruning iff |w| >= tau of its filter. Only the
//! thresholds travel:
//!
//!   1. DL: `begin_round` broadcasts the n_filters global thresholds
//!      through the standard [`DownlinkEncoder`] (float32 or qdelta —
//!      the chain state is the tau vector, so delta framing applies
//!      unchanged).
//!   2. Each device ([`SpaFlClientTask`]) prunes the frozen reference
//!      weights under the received tau, runs dense local SGD on the
//!      surviving entries, then refits per-filter thresholds so each
//!      filter keeps the `topk_frac` largest-|w| entries
//!      ([`fit_thresholds`] — deterministic total-order sort).
//!   3. UL: an [`UplinkPayload::Thresholds`] envelope (v2-only wire
//!      kind) carrying n_filters floats — for conv stacks that is
//!      orders of magnitude below even a 1-Bpp mask, so the estimated
//!      source rate is `32 * n_filters / n_params` Bpp.
//!   4. Server: `fold_uplink` streams the |D_i|-weighted threshold sum
//!      (O(n_filters) state); `end_round` averages; the edge tier folds
//!      the same sum under [`AggKind::ThresholdSum`].
//!
//! The paper's devices keep personalized local models; this
//! reproduction evaluates the global pruned *reference* model (frozen
//! init weights under the averaged thresholds), which is the shared
//! skeleton all devices communicate about — the wire/Bpp story, which
//! is what the comparative figures measure, is exact.
//!
//! audit: wire-decode, deterministic

use anyhow::{bail, ensure, Result};

use crate::compress::{DownlinkEncoder, DownlinkMode};
use crate::data::Dataset;
use crate::fl::protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload};
use crate::fl::{Client, RoundComm};
use crate::mask::{LayerSlice, LayerSpec};
use crate::runtime::ModelRuntime;

use super::{AggKind, AggregateMsg, ClientTask, EvalModel, RoundStats, ServerLogic};

/// One prunable filter: `count` strided entries of the flat parameter
/// vector, at `offset + phase + i * stride`. A Dense K x N layer
/// (row-major) yields N column filters (phase = column, stride = N);
/// a Conv2d `[k, k, in_ch, out_ch]` block yields out_ch channel
/// filters (phase = channel, stride = out_ch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FilterSlice {
    pub offset: usize,
    pub phase: usize,
    pub stride: usize,
    pub count: usize,
}

impl FilterSlice {
    /// Flat-vector indices of this filter's entries, ascending.
    pub fn entries(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.count).map(move |i| self.offset + self.phase + i * self.stride)
    }
}

/// Derive the filter structure from the manifest layout. Structural
/// nodes (relu/pool/flatten) own no filters. A model with no
/// parameterized layer telemetry degrades to ONE whole-vector filter,
/// so SpaFL stays runnable (with a weaker, global threshold) on
/// layout-less manifests.
pub fn filters_from_layers(layers: &[LayerSlice], n_params: usize) -> Vec<FilterSlice> {
    let mut out = Vec::new();
    for l in layers {
        match l.spec {
            LayerSpec::Dense { k, n } => {
                for c in 0..n {
                    out.push(FilterSlice { offset: l.offset, phase: c, stride: n, count: k });
                }
            }
            LayerSpec::Conv2d { in_ch, out_ch, kernel, .. } => {
                for co in 0..out_ch {
                    out.push(FilterSlice {
                        offset: l.offset,
                        phase: co,
                        stride: out_ch,
                        count: kernel * kernel * in_ch,
                    });
                }
            }
            _ => {}
        }
    }
    if out.is_empty() && n_params > 0 {
        out.push(FilterSlice { offset: 0, phase: 0, stride: 1, count: n_params });
    }
    out
}

/// Zero every entry whose magnitude falls below its filter's threshold.
pub fn prune(w: &mut [f32], filters: &[FilterSlice], tau: &[f32]) {
    for (f, &t) in filters.iter().zip(tau) {
        for i in f.entries() {
            if w[i].abs() < t {
                w[i] = 0.0;
            }
        }
    }
}

/// Refit per-filter thresholds so each filter keeps its `keep_frac`
/// largest-|w| entries: tau = the largest dropped magnitude (entries
/// strictly below tau are pruned, so ties at the cut survive).
/// Deterministic: `f32::total_cmp` is a total order, and the strided
/// entry walk is fixed by the manifest.
pub fn fit_thresholds(w: &[f32], filters: &[FilterSlice], keep_frac: f64) -> Vec<f32> {
    let keep = keep_frac.clamp(0.0, 1.0);
    filters
        .iter()
        .map(|f| {
            let mut mags: Vec<f32> = f.entries().map(|i| w[i].abs()).collect();
            mags.sort_by(f32::total_cmp);
            let cut = ((f.count as f64) * (1.0 - keep)).floor() as usize;
            let cut = cut.min(f.count);
            if cut == 0 {
                0.0
            } else {
                mags[cut - 1]
            }
        })
        .collect()
}

/// SpaFL server logic: global per-filter thresholds over a frozen
/// dense reference.
pub struct SpaFl {
    /// Frozen dense reference weights (the runtime checkpoint).
    init_weights: Vec<f32>,
    filters: Vec<FilterSlice>,
    /// Global thresholds, one per filter. Round 1 starts at 0.0
    /// (nothing pruned) so the first local phase sees the full model.
    tau: Vec<f32>,
    /// Downlink codec state: the tau reconstruction the fleet holds.
    dl: DownlinkEncoder,
    /// Streaming |D_i|-weighted threshold sum (O(n_filters) state).
    acc: Vec<f64>,
    weight_sum: f64,
    /// Summed (not running-mean) client losses: a plain sum merges with
    /// edge-tier partial sums in any grouping, unlike a running mean.
    loss_sum: f64,
    reporters: usize,
}

impl SpaFl {
    pub fn new(init_weights: Vec<f32>, layers: &[LayerSlice], downlink: DownlinkMode) -> Self {
        let filters = filters_from_layers(layers, init_weights.len());
        let n_filters = filters.len();
        Self {
            init_weights,
            filters,
            tau: vec![0.0; n_filters],
            dl: DownlinkEncoder::new(downlink),
            acc: vec![0.0; n_filters],
            weight_sum: 0.0,
            loss_sum: 0.0,
            reporters: 0,
        }
    }

    pub fn thresholds(&self) -> &[f32] {
        &self.tau
    }

    pub fn n_filters(&self) -> usize {
        self.filters.len()
    }
}

/// Device half: prune under the received thresholds, dense SGD on the
/// survivors, refit and upload thresholds only.
pub struct SpaFlClientTask;

impl ClientTask for SpaFlClientTask {
    fn run(
        &self,
        rt: &ModelRuntime,
        data: &Dataset,
        client: &mut Client,
        msg: &DownlinkMsg,
        prev_state: Option<&[f32]>,
        plan: &RoundPlan,
    ) -> Result<UplinkMsg> {
        if matches!(msg, DownlinkMsg::Theta(_) | DownlinkMsg::NoiseTheta { .. }) {
            bail!("spafl client expects a threshold broadcast, got {}", msg.kind_name());
        }
        let filters = filters_from_layers(&rt.manifest.layers, rt.manifest.n_params);
        // The chain state devices track is the tau vector (n_filters
        // floats), so qdelta framing applies to it unchanged.
        let tau = msg.decode_state(prev_state)?;
        ensure!(
            tau.len() == filters.len(),
            "threshold broadcast for {} filters, model derives {}",
            tau.len(),
            filters.len()
        );
        let mut w = rt.weights().to_vec();
        prune(&mut w, &filters, &tau);
        let batch = rt.manifest.batch;
        let lr = plan.server_lr;
        let steps = client.steps_per_round(batch, plan.local_epochs).max(1);
        let mut last_loss = 0.0f32;
        for _ in 0..steps {
            let (xs, ys) = client.gather_call_batches(data, 1, batch);
            let (grads, loss, _c) = rt.dense_grad(&w, &xs, &ys)?;
            for (wi, g) in w.iter_mut().zip(&grads) {
                *wi -= lr * g;
            }
            last_loss = loss;
        }
        let tau_next = fit_thresholds(&w, &filters, plan.topk_frac);
        Ok(UplinkMsg {
            weight: client.weight(),
            train_loss: last_loss,
            trained_round: plan.round as u64,
            payload: UplinkPayload::Thresholds(tau_next),
        })
    }
}

impl ServerLogic for SpaFl {
    fn name(&self) -> &'static str {
        "spafl"
    }

    fn begin_round(&mut self, _plan: &RoundPlan) -> Result<DownlinkMsg> {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        self.weight_sum = 0.0;
        self.loss_sum = 0.0;
        self.reporters = 0;
        Ok(DownlinkMsg::broadcast(&mut self.dl, &self.tau, false))
    }

    fn fold_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()> {
        let UplinkPayload::Thresholds(tau) = &msg.payload else {
            bail!(
                "spafl server expects a thresholds uplink, got {}",
                msg.payload.kind_name()
            );
        };
        ensure!(
            tau.len() == self.tau.len(),
            "thresholds uplink carries {} filters, model has {}",
            tau.len(),
            self.tau.len()
        );
        // Estimated source rate: n_filters floats amortized over the
        // whole parameter vector — the sub-0.01-Bpp headline number.
        let est_bpp = 32.0 * self.tau.len() as f64 / comm.n_params.max(1) as f64;
        comm.add_uplink(msg.wire_bits(), est_bpp);
        for (a, &t) in self.acc.iter_mut().zip(tau) {
            *a += msg.weight * t as f64;
        }
        self.weight_sum += msg.weight;
        self.reporters += 1;
        self.loss_sum += msg.train_loss as f64;
        Ok(())
    }

    fn agg_kind(&self) -> AggKind {
        AggKind::ThresholdSum
    }

    fn fold_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()> {
        ensure!(
            msg.kind == AggKind::ThresholdSum,
            "spafl server expects a threshold-sum aggregate, got {:?}",
            msg.kind
        );
        ensure!(
            msg.acc.len() == self.tau.len(),
            "aggregate covers {} filters, model has {}",
            msg.acc.len(),
            self.tau.len()
        );
        comm.add_uplinks(msg.ul_bits, msg.est_bpp_sum, msg.reporters as usize);
        for (a, &p) in self.acc.iter_mut().zip(&msg.acc) {
            *a += p;
        }
        self.weight_sum += msg.weight_sum;
        self.reporters += msg.reporters as usize;
        self.loss_sum += msg.loss_sum;
        Ok(())
    }

    fn end_round(&mut self, _plan: &RoundPlan) -> Result<RoundStats> {
        ensure!(self.weight_sum > 0.0, "no uplinks received this round");
        for (t, &a) in self.tau.iter_mut().zip(&self.acc) {
            *t = (a / self.weight_sum) as f32;
        }
        let mut w = self.init_weights.clone();
        prune(&mut w, &self.filters, &self.tau);
        let kept = w.iter().filter(|&&v| v != 0.0).count();
        let mean_tau =
            self.tau.iter().map(|&t| t as f64).sum::<f64>() / self.tau.len().max(1) as f64;
        Ok(RoundStats {
            train_loss: self.loss_sum / self.reporters.max(1) as f64,
            // mean_theta reports the mean threshold — the strategy's
            // scalar state summary, as theta's mean is for mask families.
            mean_theta: mean_tau,
            mask_density: kept as f64 / self.init_weights.len().max(1) as f64,
        })
    }

    fn client_task(&self) -> Box<dyn ClientTask> {
        Box::new(SpaFlClientTask)
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        // The global model is the frozen reference pruned under the tau
        // devices would reconstruct from the wire (quantized under
        // qdelta, exact under float32).
        let tau = self.dl.preview(&self.tau);
        let mut w = self.init_weights.clone();
        prune(&mut w, &self.filters, &tau);
        EvalModel::Dense(w)
    }

    fn storage_bits(&self) -> u64 {
        // The frozen dense reference is the shipped model artifact every
        // strategy reads; the server's learned state is tau alone.
        self.tau.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> RoundPlan {
        RoundPlan {
            round: 1,
            seed: 7,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.5,
            server_lr: 0.1,
            adam: false,
        }
    }

    fn dense_layout(k: usize, n: usize) -> Vec<LayerSlice> {
        vec![LayerSlice { index: 0, spec: LayerSpec::Dense { k, n }, offset: 0 }]
    }

    fn tau_msg(tau: Vec<f32>, weight: f64) -> UplinkMsg {
        UplinkMsg {
            weight,
            train_loss: 0.5,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::Thresholds(tau),
        }
    }

    #[test]
    fn dense_layers_split_into_column_filters() {
        // 2x3 row-major: column c owns entries {c, c+3}
        let filters = filters_from_layers(&dense_layout(2, 3), 6);
        assert_eq!(filters.len(), 3);
        for (c, f) in filters.iter().enumerate() {
            assert_eq!(f.entries().collect::<Vec<_>>(), vec![c, c + 3]);
        }
    }

    #[test]
    fn conv_layers_split_into_channel_filters() {
        // [k,k,in,out] = [3,3,2,4]: channel co owns entries co + t*4
        let layers = vec![LayerSlice {
            index: 0,
            spec: LayerSpec::Conv2d { in_ch: 2, out_ch: 4, kernel: 3, stride: 1, pad: 1 },
            offset: 10,
        }];
        let filters = filters_from_layers(&layers, 82);
        assert_eq!(filters.len(), 4);
        for (co, f) in filters.iter().enumerate() {
            assert_eq!(f.count, 18);
            let idx: Vec<usize> = f.entries().collect();
            assert_eq!(idx[0], 10 + co);
            assert_eq!(idx[17], 10 + co + 17 * 4);
        }
        // every parameter belongs to exactly one filter
        let mut seen = vec![0u8; 82];
        for f in &filters {
            for i in f.entries() {
                seen[i] += 1;
            }
        }
        assert_eq!(seen[10..].iter().filter(|&&c| c == 1).count(), 72);
    }

    #[test]
    fn layoutless_manifest_degrades_to_one_global_filter() {
        let filters = filters_from_layers(&[], 12);
        assert_eq!(
            filters,
            vec![FilterSlice { offset: 0, phase: 0, stride: 1, count: 12 }]
        );
    }

    #[test]
    fn fit_thresholds_keeps_the_topk_fraction() {
        // one 4-entry filter, keep half: drop the two smallest |w|
        let filters = vec![FilterSlice { offset: 0, phase: 0, stride: 1, count: 4 }];
        let w = [0.5f32, -0.1, 0.3, -0.9];
        let tau = fit_thresholds(&w, &filters, 0.5);
        assert_eq!(tau, vec![0.3]);
        let mut pruned = w.to_vec();
        prune(&mut pruned, &filters, &tau);
        // ties at the cut survive (|0.3| >= tau), strictly-below dies
        assert_eq!(pruned, vec![0.5, 0.0, 0.3, -0.9]);
        // keep everything -> threshold 0
        assert_eq!(fit_thresholds(&w, &filters, 1.0), vec![0.0]);
    }

    #[test]
    fn streaming_fold_is_weighted_threshold_mean() {
        let mut srv = SpaFl::new(vec![1.0; 6], &dense_layout(2, 3), DownlinkMode::Float32);
        let mut comm = RoundComm::new(6);
        srv.begin_round(&plan()).unwrap();
        srv.fold_uplink(&tau_msg(vec![0.4, 0.0, 0.8], 1.0), &mut comm).unwrap();
        srv.fold_uplink(&tau_msg(vec![0.8, 0.4, 0.0], 3.0), &mut comm).unwrap();
        srv.end_round(&plan()).unwrap();
        // tau = (1*t1 + 3*t2) / 4
        assert_eq!(srv.thresholds(), &[0.7, 0.3, 0.2]);
        assert_eq!(comm.clients, 2);
        // est Bpp: 3 filters over 6 params = 16 bits/param per client
        assert!((comm.est_bpp() - 32.0 * 3.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn fold_rejects_wrong_payload_len_and_empty_round() {
        let mut srv = SpaFl::new(vec![1.0; 6], &dense_layout(2, 3), DownlinkMode::Float32);
        let mut comm = RoundComm::new(6);
        srv.begin_round(&plan()).unwrap();
        assert!(
            srv.fold_uplink(&tau_msg(vec![0.1; 4], 1.0), &mut comm).is_err(),
            "filter-count mismatch must not fold"
        );
        let wrong = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; 6]),
        };
        assert!(srv.fold_uplink(&wrong, &mut comm).is_err());
        assert!(srv.end_round(&plan()).is_err(), "zero uplinks cannot average");
    }

    #[test]
    fn eval_model_is_the_pruned_reference() {
        // 2x3 reference, column magnitudes differ per row
        let init = vec![0.9f32, 0.1, 0.5, -0.2, 0.8, -0.5];
        let mut srv = SpaFl::new(init.clone(), &dense_layout(2, 3), DownlinkMode::Float32);
        let mut comm = RoundComm::new(6);
        srv.begin_round(&plan()).unwrap();
        srv.fold_uplink(&tau_msg(vec![0.5, 0.5, 0.5], 1.0), &mut comm).unwrap();
        srv.end_round(&plan()).unwrap();
        let EvalModel::Dense(w) = srv.eval_model(1) else {
            panic!("spafl evaluates the dense pruned reference")
        };
        // column 0 = {0.9, -0.2}: -0.2 pruned; column 1 = {0.1, 0.8}:
        // 0.1 pruned; column 2 = {0.5, -0.5}: both survive (ties keep)
        assert_eq!(w, vec![0.9, 0.0, 0.5, 0.0, 0.8, -0.5]);
    }

    #[test]
    fn client_task_rejects_theta_broadcasts() {
        let srv = SpaFl::new(vec![0.0; 16], &dense_layout(4, 4), DownlinkMode::Float32);
        let task = srv.client_task();
        let data = crate::data::Synthetic::new(crate::data::SynthSpec::tiny(), 1)
            .generate(40, 1);
        let shards = crate::data::partition_iid(&data, 1, 1);
        let mut client = Client::new(shards[0].clone(), 5);
        let rt = ModelRuntime::load(std::path::Path::new("artifacts"), "mlp_tiny").unwrap();
        let msg = DownlinkMsg::Theta(vec![0.5; rt.manifest.n_params]);
        assert!(task.run(&rt, &data, &mut client, &msg, None, &plan()).is_err());
    }

    #[test]
    fn storage_is_thresholds_only() {
        let srv = SpaFl::new(vec![0.0; 4096], &dense_layout(64, 64), DownlinkMode::Float32);
        assert_eq!(srv.n_filters(), 64);
        assert_eq!(srv.storage_bits(), 64 * 32);
    }
}
