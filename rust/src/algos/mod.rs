//! Federated algorithms: the paper's method + every baseline it
//! compares against (sec. IV).
//!
//! * [`MaskStrategy`] — the FedPM family over frozen random weights:
//!   stochastic masks with the entropy-proxy regularizer (**ours**,
//!   lambda > 0), plain FedPM (lambda = 0), FedMask-style deterministic
//!   masking, and Top-k score masking. One implementation, four uplink /
//!   sampling modes — exactly how the paper frames them.
//! * [`SignSgd`] — Majority-Vote SignSGD (Bernstein et al. '18): dense
//!   weights, 1-bit sign uplink, majority-vote server step.
//! * [`FedAvg`] — dense float FedAvg as the 32 Bpp reference point.
//!
//! Each strategy owns its round semantics behind the [`Strategy`] trait;
//! the coordinator drives rounds and evaluation uniformly.

pub mod fedavg;
pub mod mask_training;
pub mod signsgd;

pub use fedavg::FedAvg;
pub use mask_training::{MaskMode, MaskStrategy};
pub use signsgd::SignSgd;

use anyhow::Result;

use crate::config::{Algorithm, ExperimentConfig};
use crate::data::Dataset;
use crate::fl::{Client, RoundComm};
use crate::fl::server::AggMode;
use crate::runtime::ModelRuntime;

/// Aggregation mode from config: bayes_prior > 0 turns on the
/// Beta-posterior server (FedPM's Bayesian aggregation ablation).
fn agg_mode(cfg: &ExperimentConfig) -> AggMode {
    if cfg.bayes_prior > 0.0 {
        AggMode::Bayes { prior: cfg.bayes_prior }
    } else {
        AggMode::Mean
    }
}

/// What the evaluator should run this round.
pub enum EvalModel {
    /// Binary mask (f32 0/1) over the frozen random weights.
    Masked(Vec<f32>),
    /// Dense weight vector (baselines).
    Dense(Vec<f32>),
}

/// Per-round training statistics surfaced to the metrics sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    /// Mean client train loss (incl. regularizer where applicable).
    pub train_loss: f64,
    /// Mean global keep-probability after aggregation (mask algos).
    pub mean_theta: f64,
    /// Density of the current global mask (mask algos; signs for MV).
    pub mask_density: f64,
}

/// Everything a strategy needs to run one communication round.
pub struct RoundCtx<'a> {
    pub rt: &'a ModelRuntime,
    pub data: &'a Dataset,
    pub clients: &'a mut [Client],
    pub round: usize,
    pub comm: &'a mut RoundComm,
    /// Shards per-client work across worker threads; strategies MUST
    /// route all client execution through it (DESIGN.md §Parallel round
    /// engine) so the sequential and parallel paths share one code path.
    pub engine: &'a crate::coordinator::RoundEngine,
    pub lambda: f32,
    pub lr: f32,
    pub local_epochs: usize,
    pub topk_frac: f64,
    pub server_lr: f32,
    /// Optimize scores with Adam (FedPM practice) vs plain SGD.
    pub adam: bool,
    /// Participation/failure model (fraction=1, dropout=0 = the paper).
    pub participation: crate::fl::Participation,
    /// Root experiment seed (participation sampling etc.).
    pub seed: u64,
}

/// A federated training algorithm.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Execute one communication round (DL broadcast, local training,
    /// UL aggregation, server update).
    fn run_round(&mut self, ctx: &mut RoundCtx) -> Result<RoundStats>;

    /// The current global model for evaluation.
    fn eval_model(&self, round: usize) -> EvalModel;

    /// Bits needed to persist the final model (the paper's storage
    /// claim: seed + coded mask vs dense floats).
    fn storage_bits(&self) -> u64;
}

/// Instantiate the strategy an experiment config asks for.
pub fn build_strategy(
    cfg: &ExperimentConfig,
    n_params: usize,
    init_weights: &[f32],
) -> Box<dyn Strategy> {
    match cfg.algorithm {
        Algorithm::FedPMReg | Algorithm::FedPM => Box::new(MaskStrategy::with_agg(
            n_params,
            cfg.seed,
            MaskMode::Stochastic,
            agg_mode(cfg),
            cfg.downlink,
        )),
        Algorithm::FedMask => Box::new(MaskStrategy::with_agg(
            n_params,
            cfg.seed,
            MaskMode::Deterministic,
            agg_mode(cfg),
            cfg.downlink,
        )),
        Algorithm::TopK => Box::new(MaskStrategy::with_agg(
            n_params,
            cfg.seed,
            MaskMode::TopK { frac: cfg.topk_frac },
            agg_mode(cfg),
            cfg.downlink,
        )),
        Algorithm::SignSGD => Box::new(SignSgd::new(init_weights.to_vec(), cfg.downlink)),
        Algorithm::FedAvg => Box::new(FedAvg::new(init_weights.to_vec(), cfg.downlink)),
    }
}
