//! Federated algorithms: the paper's method, every baseline it
//! compares against (sec. IV), and the related strategy families that
//! speak the same envelope protocol at other points of the Bpp
//! spectrum (`fedsrn figures --compare` runs all five side by side).
//!
//! * [`MaskStrategy`] — the FedPM family over frozen random weights:
//!   stochastic masks with the entropy-proxy regularizer (**ours**,
//!   lambda > 0), plain FedPM (lambda = 0), FedMask-style deterministic
//!   masking, and Top-k score masking. One implementation, four uplink /
//!   sampling modes — exactly how the paper frames them. ~1 Bpp up.
//! * [`FedMrn`] — masked random noise (arxiv 2408.03220): the mask
//!   selects entries of a seeded frozen *noise* tensor; the downlink
//!   carries theta plus the 64-bit noise seed, never the tensor. ~1 Bpp
//!   up, distinct reconstruction contract.
//! * [`SpaFl`] — trainable per-filter pruning thresholds
//!   (arxiv 2406.00431) over the manifest's layer telemetry: only
//!   n_filters floats travel, orders of magnitude below 1 Bpp.
//! * [`SignSgd`] — Majority-Vote SignSGD (Bernstein et al. '18): dense
//!   weights, 1-bit sign uplink, majority-vote server step.
//! * [`FedAvg`] — dense float FedAvg as the 32 Bpp reference point.
//!
//! DESIGN.md §Strategy-family states the contract each entry satisfies
//! (envelope variant, fold semantics, staleness behavior, Bpp
//! accounting, edge-fold associativity conditions).
//!
//! Since the protocol redesign (DESIGN.md §Protocol) a strategy no
//! longer "runs a round" — it **speaks the wire protocol** of
//! [`crate::fl::protocol`], split into two halves:
//!
//! * [`ServerLogic`] — owns the global model. `begin_round` emits one
//!   [`DownlinkMsg`]; `fold_uplink` consumes [`UplinkMsg`] envelopes one
//!   at a time **as they land** (streaming aggregation: server memory is
//!   O(n_params), never O(cohort × n_params)); `end_round` closes the
//!   round and reports [`RoundStats`].
//! * [`ClientTask`] — the pure device-side computation
//!   `(DownlinkMsg, shard, plan) -> UplinkMsg`, free of server state so
//!   the round engine ([`crate::coordinator::RoundEngine`]) can shard it
//!   across worker threads.
//!
//! The round driver lives in `coordinator::engine`; nothing but typed,
//! serializable messages crosses between the two halves.

pub mod fedavg;
pub mod fedmrn;
pub mod mask_training;
pub mod signsgd;
pub mod spafl;

pub use fedavg::FedAvg;
pub use fedmrn::FedMrn;
pub use mask_training::{MaskMode, MaskStrategy};
pub use signsgd::SignSgd;
pub use spafl::SpaFl;

use anyhow::Result;

use crate::config::{Algorithm, ExperimentConfig};
use crate::data::Dataset;
use crate::fl::aggregator::{staleness_scale, AggKind, AggregateMsg};
use crate::fl::protocol::{DownlinkMsg, RoundPlan, UplinkMsg};
use crate::fl::server::AggMode;
use crate::fl::{Client, RoundComm};
use crate::mask::LayerSlice;
use crate::runtime::ModelRuntime;

/// Aggregation mode from config: bayes_prior > 0 turns on the
/// Beta-posterior server (FedPM's Bayesian aggregation ablation).
fn agg_mode(cfg: &ExperimentConfig) -> AggMode {
    if cfg.bayes_prior > 0.0 {
        AggMode::Bayes { prior: cfg.bayes_prior }
    } else {
        AggMode::Mean
    }
}

/// What the evaluator should run this round.
pub enum EvalModel {
    /// Binary mask (f32 0/1) over the frozen random weights.
    Masked(Vec<f32>),
    /// Dense weight vector (baselines).
    Dense(Vec<f32>),
}

/// Per-round training statistics surfaced to the metrics sink.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    /// Mean client train loss (incl. regularizer where applicable).
    pub train_loss: f64,
    /// Mean global keep-probability after aggregation (mask algos).
    pub mean_theta: f64,
    /// Density of the current global mask (mask algos; signs for MV).
    pub mask_density: f64,
}

/// The server half of a federation strategy: owns the global model and
/// speaks the wire protocol. One round is
/// `begin_round -> (fold_uplink)* -> end_round`; the driver may call
/// `fold_uplink` in any cohort order it can reproduce (the engine uses
/// cohort order — DESIGN.md §Parallel round engine).
///
/// # Example
///
/// One streaming round driven by hand — exactly the calls the round
/// engine makes, minus the worker threads:
///
/// ```
/// use fedsrn::algos::{FedMrn, ServerLogic};
/// use fedsrn::compress;
/// use fedsrn::fl::{RoundComm, RoundPlan, UplinkMsg, UplinkPayload};
/// use fedsrn::util::BitVec;
///
/// let mut server = FedMrn::new(8, 42);
/// let plan = RoundPlan { round: 1, seed: 42, lambda: 0.0, lr: 0.1,
///     local_epochs: 1, topk_frac: 0.3, server_lr: 0.1, adam: false };
/// let mut comm = RoundComm::new(8);
///
/// let broadcast = server.begin_round(&plan).unwrap();
/// assert_eq!(broadcast.n(), 8);
///
/// // One device's envelope lands and folds immediately (O(n) state).
/// let mask = BitVec::from_bools(&[true; 8]);
/// let up = UplinkMsg {
///     weight: 1.0,
///     train_loss: 0.3,
///     trained_round: 1,
///     payload: UplinkPayload::NoiseMask(compress::encode(&mask)),
/// };
/// server.fold_uplink(&up, &mut comm).unwrap();
///
/// let stats = server.end_round(&plan).unwrap();
/// assert_eq!(stats.mask_density, 1.0);
/// assert_eq!(comm.clients, 1);
/// ```
pub trait ServerLogic {
    fn name(&self) -> &'static str;

    /// Open round `plan.round`: reset per-round fold state and emit the
    /// broadcast every participating device will receive.
    fn begin_round(&mut self, plan: &RoundPlan) -> Result<DownlinkMsg>;

    /// Ingest one uplink envelope as it lands. Implementations fold the
    /// payload into O(n_params) accumulators immediately — they never
    /// retain the message — and record its actual serialized size into
    /// `comm` (the streaming-fold memory contract, DESIGN.md §Protocol).
    fn fold_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()>;

    /// Staleness-discounted fold (buffered-async mode, DESIGN.md §Fleet):
    /// an uplink trained against round `msg.trained_round` but landing in
    /// round `plan.round` folds with its weight scaled by
    /// [`staleness_scale`] — `1/(1+gap)^beta`. A fresh envelope (gap 0,
    /// including every v1 envelope tagged [`UplinkMsg::FRESH`]) takes the
    /// plain [`ServerLogic::fold_uplink`] path unchanged.
    fn fold_uplink_stale(
        &mut self,
        msg: &UplinkMsg,
        plan: &RoundPlan,
        beta: f64,
        comm: &mut RoundComm,
    ) -> Result<()> {
        let gap = (plan.round as u64).saturating_sub(msg.trained_round);
        if gap == 0 {
            return self.fold_uplink(msg, comm);
        }
        let mut discounted = msg.clone();
        discounted.weight *= staleness_scale(gap, beta);
        self.fold_uplink(&discounted, comm)
    }

    /// The associative accumulator shape this strategy's edge tier folds
    /// (hierarchical aggregation, DESIGN.md §Fleet).
    fn agg_kind(&self) -> AggKind;

    /// Fold one edge tier's merged partial sums — what an
    /// [`crate::fl::EdgeAggregator`] produced from `msg.reporters`
    /// constituent uplinks. Must be bit-identical to folding those
    /// uplinks directly in order whenever the constituent terms are
    /// grouping-exact f64 sums (the §Fleet associativity argument).
    fn fold_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()>;

    /// Close the round: advance the global model from the folded state.
    fn end_round(&mut self, plan: &RoundPlan) -> Result<RoundStats>;

    /// The device-side half of this strategy. The returned task owns
    /// copies of whatever configuration it needs (never references into
    /// the server), so the engine can run it on worker threads while the
    /// server folds on the coordinator thread.
    fn client_task(&self) -> Box<dyn ClientTask>;

    /// The current global model for evaluation.
    fn eval_model(&self, round: usize) -> EvalModel;

    /// Bits needed to persist the final model (the paper's storage
    /// claim: seed + coded mask vs dense floats).
    fn storage_bits(&self) -> u64;
}

/// The device half of a federation strategy: a pure function from the
/// broadcast (plus the device's own shard state and the round plan) to
/// one uplink envelope. `prev_state` is the state this device
/// reconstructed from the previous broadcast — required to decode a
/// `downlink=qdelta` frame chain, shape-checked otherwise.
///
/// # Example
///
/// A device runs the task its server half hands out; the result is the
/// envelope the server's `fold_uplink` expects:
///
/// ```
/// use fedsrn::algos::{FedAvg, ServerLogic};
/// use fedsrn::compress::DownlinkMode;
/// use fedsrn::data::{partition_iid, SynthSpec, Synthetic};
/// use fedsrn::fl::{Client, RoundPlan};
/// use fedsrn::runtime::ModelRuntime;
///
/// let rt = ModelRuntime::load(std::path::Path::new("artifacts"), "mlp_tiny").unwrap();
/// let data = Synthetic::new(SynthSpec::tiny(), 1).generate(64, 1);
/// let shards = partition_iid(&data, 1, 1);
/// let mut client = Client::new(shards[0].clone(), 7);
///
/// let mut server = FedAvg::new(rt.weights().to_vec(), DownlinkMode::Float32);
/// let plan = RoundPlan { round: 1, seed: 7, lambda: 0.0, lr: 0.1,
///     local_epochs: 1, topk_frac: 0.3, server_lr: 0.1, adam: false };
/// let broadcast = server.begin_round(&plan).unwrap();
///
/// let task = server.client_task();
/// let up = task.run(&rt, &data, &mut client, &broadcast, None, &plan).unwrap();
/// assert_eq!(up.payload.kind_name(), "dense_delta");
/// ```
pub trait ClientTask: Send + Sync {
    fn run(
        &self,
        rt: &ModelRuntime,
        data: &Dataset,
        client: &mut Client,
        msg: &DownlinkMsg,
        prev_state: Option<&[f32]>,
        plan: &RoundPlan,
    ) -> Result<UplinkMsg>;
}

/// Instantiate the server logic an experiment config asks for.
/// `layers` is the manifest's layout telemetry — SpaFL derives its
/// filter structure from it; every other strategy ignores it.
pub fn build_server(
    cfg: &ExperimentConfig,
    n_params: usize,
    init_weights: &[f32],
    layers: &[LayerSlice],
) -> Box<dyn ServerLogic> {
    match cfg.algorithm {
        Algorithm::FedPMReg | Algorithm::FedPM => Box::new(MaskStrategy::with_agg(
            n_params,
            cfg.seed,
            MaskMode::Stochastic,
            agg_mode(cfg),
            cfg.downlink,
        )),
        Algorithm::FedMask => Box::new(MaskStrategy::with_agg(
            n_params,
            cfg.seed,
            MaskMode::Deterministic,
            agg_mode(cfg),
            cfg.downlink,
        )),
        Algorithm::TopK => Box::new(MaskStrategy::with_agg(
            n_params,
            cfg.seed,
            MaskMode::TopK { frac: cfg.topk_frac },
            agg_mode(cfg),
            cfg.downlink,
        )),
        Algorithm::SignSGD => Box::new(SignSgd::new(init_weights.to_vec(), cfg.downlink)),
        Algorithm::FedAvg => Box::new(FedAvg::new(init_weights.to_vec(), cfg.downlink)),
        Algorithm::FedMRN => Box::new(FedMrn::new(n_params, cfg.seed)),
        Algorithm::SpaFL => {
            Box::new(SpaFl::new(init_weights.to_vec(), layers, cfg.downlink))
        }
    }
}
