//! Majority-Vote SignSGD baseline (Bernstein et al. '18; Fig. 2).
//!
//! Dense weights; per round each device computes a minibatch gradient
//! through the AOT `dense_grad` program and uploads only the SIGN of
//! each coordinate (1 bit/param) in an [`UplinkPayload::SignVector`]
//! envelope. The server folds each vote into a weighted tally the moment
//! it lands (streaming, O(n_params) state — never a cohort of sign
//! vectors) and steps `w -= server_lr * sign(tally)` at `end_round`.
//!
//! Communication: uplink is a ~50% dense bit vector (entropy ~1 Bpp,
//! basically incompressible — this is exactly the contrast with the
//! regularized masks). Note the final model still needs float storage,
//! unlike the strong-LTH seed+mask representation (paper's remark).
//!
//! audit: deterministic

use anyhow::{bail, ensure, Result};

use crate::compress::{self, DownlinkEncoder, DownlinkMode};
use crate::data::Dataset;
use crate::fl::protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload};
use crate::fl::{Client, RoundComm};
use crate::mask::empirical_bpp;
use crate::runtime::ModelRuntime;
use crate::util::BitVec;

use super::{AggKind, AggregateMsg, ClientTask, EvalModel, RoundStats, ServerLogic};

/// MV-SignSGD server logic: model state + streaming vote tally.
pub struct SignSgd {
    weights: Vec<f32>,
    /// Downlink codec state: the weight reconstruction the fleet holds.
    dl: DownlinkEncoder,
    /// Weighted sign tally, folded one uplink at a time in cohort order
    /// (`+w` for a 1-bit, `-w` for a 0-bit — identical f64 sums to the
    /// batch `majority_vote_signs` it replaces).
    tally: Vec<f64>,
    /// Summed (not running-mean) client losses: a plain sum merges with
    /// edge-tier partial sums in any grouping, unlike a running mean.
    loss_sum: f64,
    reporters: usize,
}

impl SignSgd {
    pub fn new(init_weights: Vec<f32>, downlink: DownlinkMode) -> Self {
        let n = init_weights.len();
        Self {
            weights: init_weights,
            dl: DownlinkEncoder::new(downlink),
            tally: vec![0.0; n],
            loss_sum: 0.0,
            reporters: 0,
        }
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    fn apply_vote(&mut self, vote: &BitVec, lr: f32) {
        for (w, bit) in self.weights.iter_mut().zip(vote.iter()) {
            *w -= if bit { lr } else { -lr };
        }
    }
}

/// Device half: one minibatch gradient, sign-coded.
pub struct SignSgdClientTask;

impl ClientTask for SignSgdClientTask {
    fn run(
        &self,
        rt: &ModelRuntime,
        data: &Dataset,
        client: &mut Client,
        msg: &DownlinkMsg,
        prev_state: Option<&[f32]>,
        plan: &RoundPlan,
    ) -> Result<UplinkMsg> {
        if let DownlinkMsg::Theta(_) = msg {
            bail!("signsgd client expects a weight broadcast, got {}", msg.kind_name());
        }
        // Gradient at the weights the device actually decoded off the
        // wire (quantized under qdelta, exact under float32).
        let weights = msg.decode_state(prev_state)?;
        let batch = rt.manifest.batch;
        let (xs, ys) = client.gather_call_batches(data, 1, batch);
        let (grads, loss, _correct) = rt.dense_grad(&weights, &xs, &ys)?;
        // UL: sign bits (1 = positive gradient step direction).
        let sign_bits =
            BitVec::from_iter_len(grads.iter().map(|&g| g > 0.0), weights.len());
        Ok(UplinkMsg {
            weight: client.weight(),
            train_loss: loss,
            trained_round: plan.round as u64,
            payload: UplinkPayload::SignVector(compress::encode(&sign_bits)),
        })
    }
}

impl ServerLogic for SignSgd {
    fn name(&self) -> &'static str {
        "mv_signsgd"
    }

    fn begin_round(&mut self, _plan: &RoundPlan) -> Result<DownlinkMsg> {
        self.tally.iter_mut().for_each(|t| *t = 0.0);
        self.loss_sum = 0.0;
        self.reporters = 0;
        Ok(DownlinkMsg::broadcast(&mut self.dl, &self.weights, false))
    }

    fn fold_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()> {
        let UplinkPayload::SignVector(enc) = &msg.payload else {
            bail!(
                "signsgd server expects a sign-vector uplink, got {}",
                msg.payload.kind_name()
            );
        };
        let signs = compress::decode(enc, self.weights.len())?;
        comm.add_uplink(msg.wire_bits(), empirical_bpp(&signs));
        for (i, bit) in signs.iter().enumerate() {
            self.tally[i] += if bit { msg.weight } else { -msg.weight };
        }
        self.reporters += 1;
        self.loss_sum += msg.train_loss as f64;
        Ok(())
    }

    fn agg_kind(&self) -> AggKind {
        AggKind::SignTally
    }

    fn fold_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()> {
        ensure!(
            msg.kind == AggKind::SignTally,
            "signsgd server expects a sign-tally aggregate, got {:?}",
            msg.kind
        );
        ensure!(
            msg.acc.len() == self.tally.len(),
            "aggregate covers {} params, model has {}",
            msg.acc.len(),
            self.tally.len()
        );
        comm.add_uplinks(msg.ul_bits, msg.est_bpp_sum, msg.reporters as usize);
        for (t, &p) in self.tally.iter_mut().zip(&msg.acc) {
            *t += p;
        }
        self.reporters += msg.reporters as usize;
        self.loss_sum += msg.loss_sum;
        Ok(())
    }

    fn end_round(&mut self, plan: &RoundPlan) -> Result<RoundStats> {
        ensure!(self.reporters > 0, "no uplinks received this round");
        let vote = BitVec::from_iter_len(
            self.tally.iter().map(|&t| t > 0.0),
            self.tally.len(),
        );
        let density = vote.density();
        self.apply_vote(&vote, plan.server_lr);
        Ok(RoundStats {
            train_loss: self.loss_sum / self.reporters as f64,
            mean_theta: 0.0,
            mask_density: density,
        })
    }

    fn client_task(&self) -> Box<dyn ClientTask> {
        Box::new(SignSgdClientTask)
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        // Evaluate the weights a device would reconstruct from the wire
        // (identical to the server's under float32).
        EvalModel::Dense(self.dl.preview(&self.weights))
    }

    fn storage_bits(&self) -> u64 {
        // dense float model — the paper's storage contrast
        self.weights.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vote_moves_weights_opposite_to_majority_gradient_sign() {
        let mut s = SignSgd::new(vec![0.0; 4], DownlinkMode::Float32);
        let vote = BitVec::from_bools(&[true, false, true, false]);
        s.apply_vote(&vote, 0.5);
        assert_eq!(s.weights(), &[-0.5, 0.5, -0.5, 0.5]);
    }

    #[test]
    fn storage_is_dense() {
        let s = SignSgd::new(vec![0.0; 1000], DownlinkMode::Float32);
        assert_eq!(s.storage_bits(), 32_000);
    }

    #[test]
    fn eval_model_is_dense() {
        let s = SignSgd::new(vec![1.0; 8], DownlinkMode::Float32);
        match s.eval_model(0) {
            EvalModel::Dense(w) => assert_eq!(w, vec![1.0; 8]),
            _ => panic!("signsgd evaluates dense weights"),
        }
    }

    #[test]
    fn streaming_fold_matches_batch_majority_vote() {
        use crate::mask::aggregate::majority_vote_signs;
        use crate::util::Xoshiro256;
        let n = 257;
        let plan = RoundPlan {
            round: 1,
            seed: 1,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.5,
            adam: false,
        };
        let mut rng = Xoshiro256::new(17);
        let signs: Vec<BitVec> = (0..5)
            .map(|_| BitVec::from_iter_len((0..n).map(|_| rng.next_f64() < 0.5), n))
            .collect();
        let weights: Vec<f64> = (0..5).map(|i| (i + 1) as f64 * 3.0).collect();

        let mut srv = SignSgd::new(vec![0.0; n], DownlinkMode::Float32);
        let mut comm = RoundComm::new(n);
        srv.begin_round(&plan).unwrap();
        for (s, &w) in signs.iter().zip(&weights) {
            let msg = UplinkMsg {
                weight: w,
                train_loss: 0.25,
                trained_round: UplinkMsg::FRESH,
                payload: UplinkPayload::SignVector(compress::encode(s)),
            };
            srv.fold_uplink(&msg, &mut comm).unwrap();
        }
        srv.end_round(&plan).unwrap();

        // reference: batch vote, then the same step
        let vote = majority_vote_signs(&signs, &weights);
        let mut reference = SignSgd::new(vec![0.0; n], DownlinkMode::Float32);
        reference.apply_vote(&vote, 0.5);
        let got: Vec<u32> = srv.weights().iter().map(|w| w.to_bits()).collect();
        let want: Vec<u32> = reference.weights().iter().map(|w| w.to_bits()).collect();
        assert_eq!(got, want, "streaming fold must reproduce the batch vote exactly");
        assert_eq!(comm.clients, 5);
    }

    #[test]
    fn fold_rejects_wrong_payload_and_empty_round() {
        let plan = RoundPlan {
            round: 1,
            seed: 1,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.1,
            adam: false,
        };
        let mut srv = SignSgd::new(vec![0.0; 8], DownlinkMode::Float32);
        let mut comm = RoundComm::new(8);
        srv.begin_round(&plan).unwrap();
        let msg = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; 8]),
        };
        assert!(srv.fold_uplink(&msg, &mut comm).is_err());
        assert!(srv.end_round(&plan).is_err(), "a round with zero uplinks cannot vote");
    }
}
