//! Majority-Vote SignSGD baseline (Bernstein et al. '18; Fig. 2).
//!
//! Dense weights; per round each device computes a minibatch gradient
//! through the AOT `dense_grad` program and uploads only the SIGN of
//! each coordinate (1 bit/param). The server takes the dataset-weighted
//! majority vote and steps `w -= server_lr * sign(vote)`.
//!
//! Communication: uplink is a ~50% dense bit vector (entropy ~1 Bpp,
//! basically incompressible — this is exactly the contrast with the
//! regularized masks). Note the final model still needs float storage,
//! unlike the strong-LTH seed+mask representation (paper's remark).

use anyhow::Result;

use crate::compress::{self, DownlinkEncoder, DownlinkMode};
use crate::mask::aggregate::majority_vote_signs;
use crate::util::BitVec;

use super::{EvalModel, RoundCtx, RoundStats, Strategy};

/// MV-SignSGD server + model state.
pub struct SignSgd {
    weights: Vec<f32>,
    /// Downlink codec state: the weight reconstruction the fleet holds.
    dl: DownlinkEncoder,
}

impl SignSgd {
    pub fn new(init_weights: Vec<f32>, downlink: DownlinkMode) -> Self {
        Self { weights: init_weights, dl: DownlinkEncoder::new(downlink) }
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    fn apply_vote(&mut self, vote: &BitVec, lr: f32) {
        for (w, bit) in self.weights.iter_mut().zip(vote.iter()) {
            *w -= if bit { lr } else { -lr };
        }
    }
}

impl Strategy for SignSgd {
    fn name(&self) -> &'static str {
        "mv_signsgd"
    }

    fn run_round(&mut self, ctx: &mut RoundCtx) -> Result<RoundStats> {
        let n = self.weights.len();
        let batch = ctx.rt.manifest.batch;
        let cohort: Vec<usize> = (0..ctx.clients.len()).collect();
        let (rt, data) = (ctx.rt, ctx.data);
        // DL: broadcast the weights through the downlink codec; devices
        // compute their gradients at the reconstruction they received.
        let wire_bits = self.dl.broadcast(&self.weights);
        let bweights = self.dl.recon().to_vec();
        let weights = &bweights;

        // Parallel phase: one minibatch gradient + sign coding per device
        // (parallel SignSGD semantics).
        let reports = ctx.engine.run_cohort(ctx.clients, &cohort, |_pos, client| {
            let (xs, ys) = client.gather_call_batches(data, 1, batch);
            let (grads, loss, _correct) = rt.dense_grad(weights, &xs, &ys)?;
            // UL: sign bits (1 = positive gradient step direction).
            let sign_bits = BitVec::from_iter_len(grads.iter().map(|&g| g > 0.0), n);
            let enc = compress::encode(&sign_bits);
            Ok((sign_bits, enc, client.weight(), loss))
        })?;

        // Ordered reduction: account + vote in cohort order.
        let mut signs: Vec<BitVec> = Vec::with_capacity(reports.len());
        let mut weights_of: Vec<f64> = Vec::with_capacity(reports.len());
        let mut train_loss = 0.0f64;
        for (i, (sign_bits, enc, weight, loss)) in reports.into_iter().enumerate() {
            // DL: one broadcast per device (measured wire bits).
            ctx.comm.add_downlink_bits(wire_bits);
            ctx.comm.add_mask_uplink(&sign_bits, &enc);
            train_loss += (loss as f64 - train_loss) / (i + 1) as f64;
            signs.push(sign_bits);
            weights_of.push(weight);
        }

        let vote = majority_vote_signs(&signs, &weights_of);
        let density = vote.density();
        self.apply_vote(&vote, ctx.server_lr);

        Ok(RoundStats { train_loss, mean_theta: 0.0, mask_density: density })
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        // Evaluate the weights a device would reconstruct from the wire
        // (identical to the server's under float32).
        EvalModel::Dense(self.dl.preview(&self.weights))
    }

    fn storage_bits(&self) -> u64 {
        // dense float model — the paper's storage contrast
        self.weights.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vote_moves_weights_opposite_to_majority_gradient_sign() {
        let mut s = SignSgd::new(vec![0.0; 4], DownlinkMode::Float32);
        let vote = BitVec::from_bools(&[true, false, true, false]);
        s.apply_vote(&vote, 0.5);
        assert_eq!(s.weights(), &[-0.5, 0.5, -0.5, 0.5]);
    }

    #[test]
    fn storage_is_dense() {
        let s = SignSgd::new(vec![0.0; 1000], DownlinkMode::Float32);
        assert_eq!(s.storage_bits(), 32_000);
    }

    #[test]
    fn eval_model_is_dense() {
        let s = SignSgd::new(vec![1.0; 8], DownlinkMode::Float32);
        match s.eval_model(0) {
            EvalModel::Dense(w) => assert_eq!(w, vec![1.0; 8]),
            _ => panic!("signsgd evaluates dense weights"),
        }
    }
}
