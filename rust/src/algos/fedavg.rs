//! Dense FedAvg baseline (McMahan et al. '17): the 32 bit-per-parameter
//! reference point every compression scheme is measured against.
//!
//! Each device runs `local_epochs` of minibatch SGD on a local copy of
//! the dense weights (through the AOT `dense_grad` program) and uploads
//! the full float vector; the server takes the |D_i|-weighted average.

use anyhow::Result;

use super::{EvalModel, RoundCtx, RoundStats, Strategy};

/// FedAvg server + model state. The dense local SGD learning rate is
/// taken from `RoundCtx.server_lr` (distinct from the score lr).
pub struct FedAvg {
    weights: Vec<f32>,
}

impl FedAvg {
    pub fn new(init_weights: Vec<f32>) -> Self {
        Self { weights: init_weights }
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn run_round(&mut self, ctx: &mut RoundCtx) -> Result<RoundStats> {
        let n = self.weights.len();
        let batch = ctx.rt.manifest.batch;
        let mut acc = vec![0.0f64; n];
        let mut weight_sum = 0.0f64;
        let mut train_loss = 0.0f64;
        let lr = ctx.server_lr;

        for (i, client) in ctx.clients.iter_mut().enumerate() {
            ctx.comm.add_float_downlink();
            let mut w_local = self.weights.clone();
            let steps = client.steps_per_round(batch, ctx.local_epochs).max(1);
            let mut last_loss = 0.0f32;
            for _ in 0..steps {
                let (xs, ys) = client.gather_call_batches(ctx.data, 1, batch);
                let (grads, loss, _c) = ctx.rt.dense_grad(&w_local, &xs, &ys)?;
                for (w, g) in w_local.iter_mut().zip(&grads) {
                    *w -= lr * g;
                }
                last_loss = loss;
            }
            train_loss += (last_loss as f64 - train_loss) / (i + 1) as f64;
            // UL: full dense floats.
            ctx.comm.add_dense_uplink();
            let cw = client.weight();
            for (a, &w) in acc.iter_mut().zip(&w_local) {
                *a += cw * w as f64;
            }
            weight_sum += cw;
        }
        for (w, &a) in self.weights.iter_mut().zip(&acc) {
            *w = (a / weight_sum) as f32;
        }
        Ok(RoundStats { train_loss, mean_theta: 0.0, mask_density: 1.0 })
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        EvalModel::Dense(self.weights.clone())
    }

    fn storage_bits(&self) -> u64 {
        self.weights.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_and_eval_shape() {
        let f = FedAvg::new(vec![0.5; 100]);
        assert_eq!(f.storage_bits(), 3200);
        match f.eval_model(0) {
            EvalModel::Dense(w) => assert_eq!(w.len(), 100),
            _ => panic!(),
        }
    }
}
