//! Dense FedAvg baseline (McMahan et al. '17): the 32 bit-per-parameter
//! reference point every compression scheme is measured against.
//!
//! Each device runs `local_epochs` of minibatch SGD on a local copy of
//! the dense weights (through the AOT `dense_grad` program) and uploads
//! the full float vector; the server takes the |D_i|-weighted average.

use anyhow::Result;

use crate::compress::{DownlinkEncoder, DownlinkMode};

use super::{EvalModel, RoundCtx, RoundStats, Strategy};

/// FedAvg server + model state. The dense local SGD learning rate is
/// taken from `RoundCtx.server_lr` (distinct from the score lr).
pub struct FedAvg {
    weights: Vec<f32>,
    /// Downlink codec state: the weight reconstruction the fleet holds.
    dl: DownlinkEncoder,
}

impl FedAvg {
    pub fn new(init_weights: Vec<f32>, downlink: DownlinkMode) -> Self {
        Self { weights: init_weights, dl: DownlinkEncoder::new(downlink) }
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn run_round(&mut self, ctx: &mut RoundCtx) -> Result<RoundStats> {
        let n = self.weights.len();
        let batch = ctx.rt.manifest.batch;
        let lr = ctx.server_lr;
        let local_epochs = ctx.local_epochs;
        let cohort: Vec<usize> = (0..ctx.clients.len()).collect();
        let (rt, data) = (ctx.rt, ctx.data);

        let mut acc = vec![0.0f64; n];
        let mut weight_sum = 0.0f64;
        let mut train_loss = 0.0f64;
        let mut done = 0usize;

        // DL: broadcast the weights through the downlink codec; devices
        // start local SGD from the reconstruction they received.
        let wire_bits = self.dl.broadcast(&self.weights);
        let bweights = self.dl.recon().to_vec();

        // The fleet is processed in waves so at most one wave of dense
        // local weight vectors is resident at a time (O(wave * n), not
        // O(clients * n)). The fold still walks cohort order — waves are
        // consumed sequentially and folded in order — so results stay
        // bit-identical at any thread count and any wave size.
        let wave = ctx.engine.threads().max(4) * 2;
        for ids in cohort.chunks(wave) {
            let global = &bweights;
            // Parallel phase: each device trains a local copy of the
            // dense weights for `local_epochs` of minibatch SGD.
            let reports = ctx.engine.run_cohort(ctx.clients, ids, |_pos, client| {
                let mut w_local = global.clone();
                let steps = client.steps_per_round(batch, local_epochs).max(1);
                let mut last_loss = 0.0f32;
                for _ in 0..steps {
                    let (xs, ys) = client.gather_call_batches(data, 1, batch);
                    let (grads, loss, _c) = rt.dense_grad(&w_local, &xs, &ys)?;
                    for (w, g) in w_local.iter_mut().zip(&grads) {
                        *w -= lr * g;
                    }
                    last_loss = loss;
                }
                Ok((w_local, client.weight(), last_loss))
            })?;

            // Ordered reduction: |D_i|-weighted average in cohort order.
            for (w_local, cw, last_loss) in reports {
                // DL: one broadcast per device (measured wire bits).
                ctx.comm.add_downlink_bits(wire_bits);
                // UL: full dense floats.
                ctx.comm.add_dense_uplink();
                done += 1;
                train_loss += (last_loss as f64 - train_loss) / done as f64;
                for (a, &w) in acc.iter_mut().zip(&w_local) {
                    *a += cw * w as f64;
                }
                weight_sum += cw;
            }
        }
        for (w, &a) in self.weights.iter_mut().zip(&acc) {
            *w = (a / weight_sum) as f32;
        }
        Ok(RoundStats { train_loss, mean_theta: 0.0, mask_density: 1.0 })
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        // Evaluate the weights a device would reconstruct from the wire
        // (identical to the server's under float32).
        EvalModel::Dense(self.dl.preview(&self.weights))
    }

    fn storage_bits(&self) -> u64 {
        self.weights.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_and_eval_shape() {
        let f = FedAvg::new(vec![0.5; 100], DownlinkMode::Float32);
        assert_eq!(f.storage_bits(), 3200);
        match f.eval_model(0) {
            EvalModel::Dense(w) => assert_eq!(w.len(), 100),
            _ => panic!(),
        }
    }
}
