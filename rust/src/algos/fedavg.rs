//! Dense FedAvg baseline (McMahan et al. '17): the 32 bit-per-parameter
//! reference point every compression scheme is measured against.
//!
//! Each device runs `local_epochs` of minibatch SGD on a local copy of
//! the dense weights (through the AOT `dense_grad` program) and uploads
//! the full float vector in an [`UplinkPayload::DenseDelta`] envelope;
//! the server folds each into its |D_i|-weighted running sum the moment
//! it lands. Combined with the engine's wave scheduling this keeps the
//! coordinator at O(wave × n_params) resident uplinks and the server at
//! O(n_params) fold state — never O(cohort × n_params).
//!
//! audit: deterministic

use anyhow::{bail, ensure, Result};

use crate::compress::{DownlinkEncoder, DownlinkMode};
use crate::data::Dataset;
use crate::fl::protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload};
use crate::fl::{Client, RoundComm};
use crate::runtime::ModelRuntime;

use super::{AggKind, AggregateMsg, ClientTask, EvalModel, RoundStats, ServerLogic};

/// FedAvg server logic. The dense local SGD learning rate is taken from
/// `RoundPlan.server_lr` (distinct from the score lr).
pub struct FedAvg {
    weights: Vec<f32>,
    /// Downlink codec state: the weight reconstruction the fleet holds.
    dl: DownlinkEncoder,
    /// Streaming |D_i|-weighted sum of landed uplinks (eq. 8 shape).
    acc: Vec<f64>,
    weight_sum: f64,
    /// Summed (not running-mean) client losses: a plain sum merges with
    /// edge-tier partial sums in any grouping, unlike a running mean.
    loss_sum: f64,
    reporters: usize,
}

impl FedAvg {
    pub fn new(init_weights: Vec<f32>, downlink: DownlinkMode) -> Self {
        let n = init_weights.len();
        Self {
            weights: init_weights,
            dl: DownlinkEncoder::new(downlink),
            acc: vec![0.0; n],
            weight_sum: 0.0,
            loss_sum: 0.0,
            reporters: 0,
        }
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }
}

/// Device half: `local_epochs` of dense minibatch SGD from the decoded
/// broadcast, full float vector back up.
pub struct FedAvgClientTask;

impl ClientTask for FedAvgClientTask {
    fn run(
        &self,
        rt: &ModelRuntime,
        data: &Dataset,
        client: &mut Client,
        msg: &DownlinkMsg,
        prev_state: Option<&[f32]>,
        plan: &RoundPlan,
    ) -> Result<UplinkMsg> {
        if let DownlinkMsg::Theta(_) = msg {
            bail!("fedavg client expects a weight broadcast, got {}", msg.kind_name());
        }
        // Local SGD starts from the weights the device actually decoded
        // off the wire (quantized under qdelta, exact under float32).
        let mut w_local = msg.decode_state(prev_state)?;
        let batch = rt.manifest.batch;
        let lr = plan.server_lr;
        let steps = client.steps_per_round(batch, plan.local_epochs).max(1);
        let mut last_loss = 0.0f32;
        for _ in 0..steps {
            let (xs, ys) = client.gather_call_batches(data, 1, batch);
            let (grads, loss, _c) = rt.dense_grad(&w_local, &xs, &ys)?;
            for (w, g) in w_local.iter_mut().zip(&grads) {
                *w -= lr * g;
            }
            last_loss = loss;
        }
        Ok(UplinkMsg {
            weight: client.weight(),
            train_loss: last_loss,
            trained_round: plan.round as u64,
            payload: UplinkPayload::DenseDelta(w_local),
        })
    }
}

impl ServerLogic for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn begin_round(&mut self, _plan: &RoundPlan) -> Result<DownlinkMsg> {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        self.weight_sum = 0.0;
        self.loss_sum = 0.0;
        self.reporters = 0;
        Ok(DownlinkMsg::broadcast(&mut self.dl, &self.weights, false))
    }

    fn fold_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()> {
        let UplinkPayload::DenseDelta(w_local) = &msg.payload else {
            bail!(
                "fedavg server expects a dense uplink, got {}",
                msg.payload.kind_name()
            );
        };
        ensure!(
            w_local.len() == self.weights.len(),
            "dense uplink for {} params, model has {}",
            w_local.len(),
            self.weights.len()
        );
        // UL: full dense floats (est = the source's 32 Bpp; measured =
        // the serialized envelope).
        comm.add_uplink(msg.wire_bits(), 32.0);
        self.reporters += 1;
        self.loss_sum += msg.train_loss as f64;
        for (a, &w) in self.acc.iter_mut().zip(w_local) {
            *a += msg.weight * w as f64;
        }
        self.weight_sum += msg.weight;
        Ok(())
    }

    fn agg_kind(&self) -> AggKind {
        AggKind::DenseSum
    }

    fn fold_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()> {
        ensure!(
            msg.kind == AggKind::DenseSum,
            "fedavg server expects a dense-sum aggregate, got {:?}",
            msg.kind
        );
        ensure!(
            msg.acc.len() == self.weights.len(),
            "aggregate covers {} params, model has {}",
            msg.acc.len(),
            self.weights.len()
        );
        comm.add_uplinks(msg.ul_bits, msg.est_bpp_sum, msg.reporters as usize);
        for (a, &p) in self.acc.iter_mut().zip(&msg.acc) {
            *a += p;
        }
        self.weight_sum += msg.weight_sum;
        self.reporters += msg.reporters as usize;
        self.loss_sum += msg.loss_sum;
        Ok(())
    }

    fn end_round(&mut self, _plan: &RoundPlan) -> Result<RoundStats> {
        ensure!(self.weight_sum > 0.0, "no uplinks received this round");
        for (w, &a) in self.weights.iter_mut().zip(&self.acc) {
            *w = (a / self.weight_sum) as f32;
        }
        Ok(RoundStats {
            train_loss: self.loss_sum / self.reporters as f64,
            mean_theta: 0.0,
            mask_density: 1.0,
        })
    }

    fn client_task(&self) -> Box<dyn ClientTask> {
        Box::new(FedAvgClientTask)
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        // Evaluate the weights a device would reconstruct from the wire
        // (identical to the server's under float32).
        EvalModel::Dense(self.dl.preview(&self.weights))
    }

    fn storage_bits(&self) -> u64 {
        self.weights.len() as u64 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> RoundPlan {
        RoundPlan {
            round: 1,
            seed: 1,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.1,
            adam: false,
        }
    }

    #[test]
    fn storage_and_eval_shape() {
        let f = FedAvg::new(vec![0.5; 100], DownlinkMode::Float32);
        assert_eq!(f.storage_bits(), 3200);
        match f.eval_model(0) {
            EvalModel::Dense(w) => assert_eq!(w.len(), 100),
            _ => panic!(),
        }
    }

    #[test]
    fn streaming_fold_is_weighted_average() {
        let mut srv = FedAvg::new(vec![0.0; 3], DownlinkMode::Float32);
        let mut comm = RoundComm::new(3);
        srv.begin_round(&plan()).unwrap();
        for (w, values) in [(1.0, vec![1.0f32; 3]), (3.0, vec![5.0f32; 3])] {
            let msg = UplinkMsg {
                weight: w,
                train_loss: 0.5,
                trained_round: UplinkMsg::FRESH,
                payload: UplinkPayload::DenseDelta(values),
            };
            srv.fold_uplink(&msg, &mut comm).unwrap();
        }
        srv.end_round(&plan()).unwrap();
        // (1*1 + 3*5) / 4 = 4.0
        assert!(srv.weights().iter().all(|&w| (w - 4.0).abs() < 1e-6));
        assert_eq!(comm.clients, 2);
        assert_eq!(comm.est_bpp(), 32.0);
    }

    #[test]
    fn fold_rejects_wrong_payload_and_length() {
        let mut srv = FedAvg::new(vec![0.0; 4], DownlinkMode::Float32);
        let mut comm = RoundComm::new(4);
        srv.begin_round(&plan()).unwrap();
        let wrong_len = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::DenseDelta(vec![0.0; 5]),
        };
        assert!(srv.fold_uplink(&wrong_len, &mut comm).is_err());
        assert!(srv.end_round(&plan()).is_err(), "zero uplinks cannot average");
    }
}
