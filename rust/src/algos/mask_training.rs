//! The FedPM family: stochastic / deterministic / top-k mask training
//! over a frozen random network (paper sec. II-III).
//!
//! One round, in protocol messages (DESIGN.md §Protocol):
//!   1. DL: `begin_round` broadcasts theta(t) — a [`DownlinkMsg::Theta`]
//!      under `downlink=float32`, a coded [`DownlinkMsg::Frame`] under
//!      `downlink=qdelta` (DESIGN.md §Downlink); devices derive scores
//!      s = logit(theta) from the reconstruction they actually decoded.
//!   2. Each device ([`MaskClientTask`]) runs local STE-SGD on its score
//!      vector with loss eq. 12 (cross-entropy + (lambda/n) sum sigmoid(s)).
//!   3. UL: the device ships ONE binary mask derived from its local
//!      theta-hat:  m ~ Bern(theta-hat)        (Stochastic — FedPM/ours)
//!                  m  = 1[theta-hat > 1/2]    (Deterministic — FedMask)
//!                  m  = top-k(s)              (TopK baseline)
//!      entropy-coded in an [`UplinkPayload::CodedMask`] envelope.
//!   4. Server: `fold_uplink` decodes and weighted-averages each envelope
//!      into the eq. 8 accumulator the moment it lands (O(n_params)
//!      state); `end_round` finalizes theta(t+1).
//!
//! The paper's algorithm is Stochastic with lambda > 0; lambda comes
//! from the round plan so the same strategy object runs FedPM (0)
//! and FedPM+reg (>0).
//!
//! audit: deterministic

use anyhow::{bail, Result};

use crate::compress::{self, DownlinkEncoder, DownlinkMode};
use crate::data::Dataset;
use crate::fl::protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload};
use crate::fl::{Client, RoundComm, Server};
use crate::mask::{sample_mask, topk_mask, ProbMask};
use crate::runtime::ModelRuntime;
use crate::util::{logit, BitVec, SeedSequence};

use super::{AggKind, AggregateMsg, ClientTask, EvalModel, RoundStats, ServerLogic};

/// Uplink mask construction mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MaskMode {
    /// m ~ Bernoulli(sigma(s)) — FedPM / FedPM+reg (the paper).
    Stochastic,
    /// m = 1[sigma(s) > 0.5]; local training also masks
    /// deterministically (FedMask's biased updates).
    Deterministic,
    /// m = top-k(|scores| by value); local training stochastic.
    TopK { frac: f64 },
}

/// FedPM-family server logic.
pub struct MaskStrategy {
    server: Server,
    mode: MaskMode,
    seed: u64,
    /// Downlink codec state: the theta reconstruction the fleet holds.
    dl: DownlinkEncoder,
    /// Round-in-progress fold state: summed train loss over the uplinks
    /// that actually landed (a plain sum merges with edge-tier partial
    /// sums in any grouping, unlike a running mean).
    loss_sum: f64,
    reporters: usize,
}

impl MaskStrategy {
    pub fn new(n_params: usize, seed: u64, mode: MaskMode) -> Self {
        Self::with_agg(
            n_params,
            seed,
            mode,
            crate::fl::server::AggMode::Mean,
            DownlinkMode::Float32,
        )
    }

    pub fn with_agg(
        n_params: usize,
        seed: u64,
        mode: MaskMode,
        agg: crate::fl::server::AggMode,
        downlink: DownlinkMode,
    ) -> Self {
        Self {
            server: Server::with_agg(n_params, seed, agg),
            mode,
            seed,
            dl: DownlinkEncoder::new(downlink),
            loss_sum: 0.0,
            reporters: 0,
        }
    }

    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Build this client's uplink mask from its updated scores.
    fn uplink_mask(&self, scores: &[f32], client: usize, round: usize) -> BitVec {
        build_uplink(self.mode, mask_stream(self.seed), scores, client, round)
    }

    /// Theta as the fleet would see it after a broadcast of the current
    /// server state: exact under float32, quantized under qdelta. Used
    /// for evaluation so reported accuracy reflects the wire, not the
    /// server's private precision.
    fn broadcast_theta_view(&self) -> ProbMask {
        let view = self.dl.preview(self.server.theta().theta());
        ProbMask::from_theta(view.iter().map(|&t| t.clamp(0.0, 1.0)).collect())
    }
}

/// Root of the uplink mask-sampling seed tree for one experiment.
fn mask_stream(seed: u64) -> SeedSequence {
    SeedSequence::new(seed).child(0xA24B)
}

/// Uplink mask construction as a pure function, so the round engine's
/// worker threads can build masks without borrowing the server: the
/// sampled mask depends only on (mode, seed tree, scores, client, round).
fn build_uplink(
    mode: MaskMode,
    stream: SeedSequence,
    scores: &[f32],
    client: usize,
    round: usize,
) -> BitVec {
    match mode {
        MaskMode::Stochastic => {
            let theta = ProbMask::from_scores(scores);
            sample_mask(&theta, stream.child(round as u64).child(client as u64).seed())
        }
        MaskMode::Deterministic => ProbMask::from_scores(scores).threshold(),
        MaskMode::TopK { frac } => topk_mask(scores, frac),
    }
}

/// The device half: local STE-SGD + mask construction + entropy coding.
/// Owns only copies of the strategy configuration — nothing borrowed
/// from the server — so the engine can run it on worker threads.
pub struct MaskClientTask {
    mode: MaskMode,
    stream: SeedSequence,
}

impl ClientTask for MaskClientTask {
    fn run(
        &self,
        rt: &ModelRuntime,
        data: &Dataset,
        client: &mut Client,
        msg: &DownlinkMsg,
        prev_state: Option<&[f32]>,
        plan: &RoundPlan,
    ) -> Result<UplinkMsg> {
        if let DownlinkMsg::RawF32(_) = msg {
            bail!("mask client expects a theta broadcast, got {}", msg.kind_name());
        }
        // The device works from the theta it actually decoded off the
        // wire — under qdelta that is the quantized reconstruction,
        // never the server's exact vector (DESIGN.md §Downlink).
        let theta = msg.decode_state(prev_state)?;
        let scores: Vec<f32> = theta.iter().map(|&t| logit(t)).collect();
        let deterministic = self.mode == MaskMode::Deterministic;
        let (s_i, met) = client.local_phase(
            rt,
            data,
            scores,
            plan.round,
            plan.lambda,
            plan.lr,
            plan.local_epochs,
            deterministic,
            plan.adam,
        )?;
        // The round plan owns the per-round knobs: a TopK device keeps
        // the fraction the server shipped, not a baked-in copy.
        let mode = match self.mode {
            MaskMode::TopK { .. } => MaskMode::TopK { frac: plan.topk_frac },
            m => m,
        };
        let mask = build_uplink(mode, self.stream, &s_i, client.id, plan.round);
        Ok(UplinkMsg {
            weight: client.weight(),
            train_loss: met.mean_loss,
            trained_round: plan.round as u64,
            payload: UplinkPayload::CodedMask(compress::encode(&mask)),
        })
    }
}

impl ServerLogic for MaskStrategy {
    fn name(&self) -> &'static str {
        match self.mode {
            MaskMode::Stochastic => "fedpm_family",
            MaskMode::Deterministic => "fedmask",
            MaskMode::TopK { .. } => "topk",
        }
    }

    fn begin_round(&mut self, _plan: &RoundPlan) -> Result<DownlinkMsg> {
        self.loss_sum = 0.0;
        self.reporters = 0;
        Ok(DownlinkMsg::broadcast(&mut self.dl, self.server.theta().theta(), true))
    }

    fn fold_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()> {
        self.server.receive_uplink(msg, comm)?;
        self.reporters += 1;
        self.loss_sum += msg.train_loss as f64;
        Ok(())
    }

    fn agg_kind(&self) -> AggKind {
        AggKind::MaskSum
    }

    fn fold_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()> {
        self.server.receive_aggregate(msg, comm)?;
        self.reporters += msg.reporters as usize;
        self.loss_sum += msg.loss_sum;
        Ok(())
    }

    fn end_round(&mut self, plan: &RoundPlan) -> Result<RoundStats> {
        self.server.finish_round()?;
        let theta = self.server.theta();
        Ok(RoundStats {
            train_loss: self.loss_sum / self.reporters.max(1) as f64,
            mean_theta: theta.mean_theta(),
            mask_density: self.server.eval_mask_sampled(plan.round).density(),
        })
    }

    fn client_task(&self) -> Box<dyn ClientTask> {
        Box::new(MaskClientTask { mode: self.mode, stream: mask_stream(self.seed) })
    }

    fn eval_model(&self, round: usize) -> EvalModel {
        // Evaluate the theta a device would reconstruct from the wire
        // (identical to the server's theta under float32).
        let view = self.broadcast_theta_view();
        EvalModel::Masked(self.server.eval_mask_sampled_from(&view, round).to_f32())
    }

    fn storage_bits(&self) -> u64 {
        // seed (64b) + structure id (negligible) + coded threshold mask.
        64 + self.server.checkpoint_mask().wire_bytes() as u64 * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uplink_modes_differ_as_specified() {
        let strat_s = MaskStrategy::new(100, 1, MaskMode::Stochastic);
        let strat_d = MaskStrategy::new(100, 1, MaskMode::Deterministic);
        let strat_k = MaskStrategy::new(100, 1, MaskMode::TopK { frac: 0.25 });
        // scores: first 30 strongly positive, rest strongly negative
        let scores: Vec<f32> =
            (0..100).map(|i| if i < 30 { 8.0 } else { -8.0 }).collect();
        let det = strat_d.uplink_mask(&scores, 0, 0);
        assert_eq!(det.count_ones(), 30);
        let sto = strat_s.uplink_mask(&scores, 0, 0);
        assert_eq!(sto.count_ones(), 30); // saturated sigmoid: same as det
        let top = strat_k.uplink_mask(&scores, 0, 0);
        assert_eq!(top.count_ones(), 25); // exactly k
        assert!((0..25).all(|i| top.get(i) == (i < 25) || scores[i] > 0.0));
    }

    #[test]
    fn stochastic_sampling_is_seeded_per_client_round() {
        let strat = MaskStrategy::new(1000, 9, MaskMode::Stochastic);
        let scores = vec![0.0f32; 1000]; // theta = 0.5
        let a = strat.uplink_mask(&scores, 0, 0);
        let b = strat.uplink_mask(&scores, 0, 0);
        assert_eq!(a, b, "same client+round must resample identically");
        assert_ne!(a, strat.uplink_mask(&scores, 1, 0));
        assert_ne!(a, strat.uplink_mask(&scores, 0, 1));
    }

    #[test]
    fn storage_bits_scale_with_sparsity() {
        // a server whose theta is mostly 0 stores a much smaller mask
        let dense = MaskStrategy::new(50_000, 1, MaskMode::Stochastic);
        let bits_uniform = dense.storage_bits();
        // uniform theta -> threshold density ~0.5 -> ~1 bpp
        assert!(bits_uniform > 40_000, "{bits_uniform}");
        assert!(bits_uniform < 60_000, "{bits_uniform}");
    }

    #[test]
    fn eval_model_is_binary() {
        let strat = MaskStrategy::new(500, 2, MaskMode::Stochastic);
        let EvalModel::Masked(m) = strat.eval_model(0) else {
            panic!("mask strategies evaluate masked models")
        };
        assert_eq!(m.len(), 500);
        assert!(m.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn begin_round_broadcasts_theta_by_wire_mode() {
        let plan = RoundPlan {
            round: 1,
            seed: 3,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.001,
            adam: true,
        };
        let mut f32_strat = MaskStrategy::new(200, 3, MaskMode::Stochastic);
        match f32_strat.begin_round(&plan).unwrap() {
            DownlinkMsg::Theta(t) => {
                assert_eq!(t, f32_strat.server().theta().theta());
            }
            other => panic!("float32 must broadcast theta, got {}", other.kind_name()),
        }
        let mut q_strat = MaskStrategy::with_agg(
            200,
            3,
            MaskMode::Stochastic,
            crate::fl::server::AggMode::Mean,
            DownlinkMode::QDelta { bits: 8 },
        );
        assert!(matches!(q_strat.begin_round(&plan).unwrap(), DownlinkMsg::Frame(_)));
    }

    #[test]
    fn mask_task_rejects_raw_weight_broadcasts() {
        let strat = MaskStrategy::new(16, 1, MaskMode::Stochastic);
        let task = strat.client_task();
        let data = crate::data::Synthetic::new(crate::data::SynthSpec::tiny(), 1)
            .generate(40, 1);
        let shards = crate::data::partition_iid(&data, 1, 1);
        let mut client = Client::new(shards[0].clone(), 5);
        let rt = ModelRuntime::load(std::path::Path::new("artifacts"), "mlp_tiny").unwrap();
        let plan = RoundPlan {
            round: 1,
            seed: 1,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.001,
            adam: true,
        };
        let msg = DownlinkMsg::RawF32(vec![0.0; rt.manifest.n_params]);
        assert!(task.run(&rt, &data, &mut client, &msg, None, &plan).is_err());
    }
}
