//! The FedPM family: stochastic / deterministic / top-k mask training
//! over a frozen random network (paper sec. II-III).
//!
//! One round:
//!   1. DL: server broadcasts theta(t) through the downlink codec
//!      (raw f32, or quantized sparse deltas under `downlink=qdelta` —
//!      DESIGN.md §Downlink); devices derive scores s = logit(theta)
//!      from the reconstruction they actually received.
//!   2. Each device runs local STE-SGD on its score vector with loss
//!      eq. 12 (cross-entropy + (lambda/n) * sum sigmoid(s)).
//!   3. UL: the device ships ONE binary mask derived from its local
//!      theta-hat:  m ~ Bern(theta-hat)        (Stochastic — FedPM/ours)
//!                  m  = 1[theta-hat > 1/2]    (Deterministic — FedMask)
//!                  m  = top-k(s)              (TopK baseline)
//!      entropy-coded through the MaskCodec.
//!   4. Server decodes, weighted-averages into theta(t+1) (eq. 8).
//!
//! The paper's algorithm is Stochastic with lambda > 0; lambda comes
//! from the round context so the same strategy object runs FedPM (0)
//! and FedPM+reg (>0).

use anyhow::Result;

use crate::compress::{self, DownlinkEncoder, DownlinkMode, Encoded};
use crate::fl::Server;
use crate::mask::{sample_mask, topk_mask, ProbMask};
use crate::util::{logit, BitVec, SeedSequence};

use super::{EvalModel, RoundCtx, RoundStats, Strategy};

/// Uplink mask construction mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MaskMode {
    /// m ~ Bernoulli(sigma(s)) — FedPM / FedPM+reg (the paper).
    Stochastic,
    /// m = 1[sigma(s) > 0.5]; local training also masks
    /// deterministically (FedMask's biased updates).
    Deterministic,
    /// m = top-k(|scores| by value); local training stochastic.
    TopK { frac: f64 },
}

/// FedPM-family strategy state.
pub struct MaskStrategy {
    server: Server,
    mode: MaskMode,
    seed: u64,
    /// Downlink codec state: the theta reconstruction the fleet holds.
    dl: DownlinkEncoder,
}

impl MaskStrategy {
    pub fn new(n_params: usize, seed: u64, mode: MaskMode) -> Self {
        Self::with_agg(
            n_params,
            seed,
            mode,
            crate::fl::server::AggMode::Mean,
            DownlinkMode::Float32,
        )
    }

    pub fn with_agg(
        n_params: usize,
        seed: u64,
        mode: MaskMode,
        agg: crate::fl::server::AggMode,
        downlink: DownlinkMode,
    ) -> Self {
        Self {
            server: Server::with_agg(n_params, seed, agg),
            mode,
            seed,
            dl: DownlinkEncoder::new(downlink),
        }
    }

    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Build this client's uplink mask from its updated scores.
    fn uplink_mask(&self, scores: &[f32], client: usize, round: usize) -> BitVec {
        build_uplink(self.mode, mask_stream(self.seed), scores, client, round)
    }

    /// Theta as the fleet would see it after a broadcast of the current
    /// server state: exact under float32, quantized under qdelta. Used
    /// for evaluation so reported accuracy reflects the wire, not the
    /// server's private precision.
    fn broadcast_theta_view(&self) -> ProbMask {
        let view = self.dl.preview(self.server.theta().theta());
        ProbMask::from_theta(view.iter().map(|&t| t.clamp(0.0, 1.0)).collect())
    }
}

/// Root of the uplink mask-sampling seed tree for one experiment.
fn mask_stream(seed: u64) -> SeedSequence {
    SeedSequence::new(seed).child(0xA24B)
}

/// Uplink mask construction as a pure function, so the round engine's
/// worker threads can build masks without borrowing the strategy: the
/// sampled mask depends only on (mode, seed tree, scores, client, round).
fn build_uplink(
    mode: MaskMode,
    stream: SeedSequence,
    scores: &[f32],
    client: usize,
    round: usize,
) -> BitVec {
    match mode {
        MaskMode::Stochastic => {
            let theta = ProbMask::from_scores(scores);
            sample_mask(&theta, stream.child(round as u64).child(client as u64).seed())
        }
        MaskMode::Deterministic => ProbMask::from_scores(scores).threshold(),
        MaskMode::TopK { frac } => topk_mask(scores, frac),
    }
}

/// One client's contribution, produced on a worker thread and merged in
/// cohort order by the calling thread.
struct Uplink {
    /// |D_i| aggregation weight.
    weight: f64,
    /// Coded mask, or `None` when the failure model dropped the uplink.
    payload: Option<Encoded>,
    mean_loss: f32,
}

impl Strategy for MaskStrategy {
    fn name(&self) -> &'static str {
        match self.mode {
            MaskMode::Stochastic => "fedpm_family",
            MaskMode::Deterministic => "fedmask",
            MaskMode::TopK { .. } => "topk",
        }
    }

    fn run_round(&mut self, ctx: &mut RoundCtx) -> Result<RoundStats> {
        let deterministic = self.mode == MaskMode::Deterministic;
        let round = ctx.round;
        // Partial participation: sample this round's cohort (the paper's
        // setting is fraction=1 / dropout=0 -> everyone, no drops).
        let cohort = ctx.participation.sample_round(ctx.clients.len(), ctx.seed, round);
        // DL: broadcast theta through the downlink codec. Devices derive
        // their working scores from the reconstruction they actually
        // received — under qdelta that is the quantized theta, never the
        // server's exact vector (DESIGN.md §Downlink).
        let wire_bits = self.dl.broadcast(self.server.theta().theta());
        // float32 frames are stateless, so only the sampled cohort needs
        // one; a qdelta frame is a link in a stateful delta chain and
        // must reach EVERY device (a device that missed a frame could
        // not decode the next one), so the whole fleet is accounted.
        let receivers = match self.dl.mode() {
            DownlinkMode::Float32 => cohort.len(),
            DownlinkMode::QDelta { .. } => ctx.clients.len(),
        };
        for _ in 0..receivers {
            ctx.comm.add_downlink_bits(wire_bits);
        }
        let scores: Vec<f32> = self.dl.recon().iter().map(|&t| logit(t)).collect();

        // Parallel phase: local training + uplink construction + entropy
        // coding per client, sharded by the round engine. Only copies of
        // the strategy's configuration cross into the workers; all shared
        // state stays on this thread.
        let (mode, stream) = (self.mode, mask_stream(self.seed));
        let (rt, data) = (ctx.rt, ctx.data);
        let (lambda, lr, local_epochs, adam) = (ctx.lambda, ctx.lr, ctx.local_epochs, ctx.adam);
        let (participation, seed) = (ctx.participation, ctx.seed);
        let scores_ref = &scores;
        let uplinks: Vec<Uplink> =
            ctx.engine.run_cohort(ctx.clients, &cohort, |pos, client| {
                let (s_i, met) = client.local_phase(
                    rt,
                    data,
                    scores_ref.clone(),
                    round,
                    lambda,
                    lr,
                    local_epochs,
                    deterministic,
                    adam,
                )?;
                // Failure injection: the device trained but its uplink
                // never arrives; the server must tolerate the gap.
                let payload = if participation.drops(pos, seed, round, client.id) {
                    None
                } else {
                    let mask = build_uplink(mode, stream, &s_i, client.id, round);
                    Some(compress::encode(&mask))
                };
                Ok(Uplink { weight: client.weight(), payload, mean_loss: met.mean_loss })
            })?;

        // Ordered reduction: aggregate + account in cohort order, so the
        // result is independent of worker scheduling.
        let mut train_loss = 0.0f64;
        let mut reporters = 0usize;
        for up in &uplinks {
            let Some(enc) = &up.payload else { continue };
            reporters += 1;
            train_loss += (up.mean_loss as f64 - train_loss) / reporters as f64;
            self.server.receive_mask(enc, up.weight, ctx.comm)?;
        }
        self.server.finish_round()?;

        let theta = self.server.theta();
        Ok(RoundStats {
            train_loss,
            mean_theta: theta.mean_theta(),
            mask_density: self.server.eval_mask_sampled(round).density(),
        })
    }

    fn eval_model(&self, round: usize) -> EvalModel {
        // Evaluate the theta a device would reconstruct from the wire
        // (identical to the server's theta under float32).
        let view = self.broadcast_theta_view();
        EvalModel::Masked(self.server.eval_mask_sampled_from(&view, round).to_f32())
    }

    fn storage_bits(&self) -> u64 {
        // seed (64b) + structure id (negligible) + coded threshold mask.
        64 + self.server.checkpoint_mask().wire_bytes() as u64 * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uplink_modes_differ_as_specified() {
        let strat_s = MaskStrategy::new(100, 1, MaskMode::Stochastic);
        let strat_d = MaskStrategy::new(100, 1, MaskMode::Deterministic);
        let strat_k = MaskStrategy::new(100, 1, MaskMode::TopK { frac: 0.25 });
        // scores: first 30 strongly positive, rest strongly negative
        let scores: Vec<f32> =
            (0..100).map(|i| if i < 30 { 8.0 } else { -8.0 }).collect();
        let det = strat_d.uplink_mask(&scores, 0, 0);
        assert_eq!(det.count_ones(), 30);
        let sto = strat_s.uplink_mask(&scores, 0, 0);
        assert_eq!(sto.count_ones(), 30); // saturated sigmoid: same as det
        let top = strat_k.uplink_mask(&scores, 0, 0);
        assert_eq!(top.count_ones(), 25); // exactly k
        assert!((0..25).all(|i| top.get(i) == (i < 25) || scores[i] > 0.0));
    }

    #[test]
    fn stochastic_sampling_is_seeded_per_client_round() {
        let strat = MaskStrategy::new(1000, 9, MaskMode::Stochastic);
        let scores = vec![0.0f32; 1000]; // theta = 0.5
        let a = strat.uplink_mask(&scores, 0, 0);
        let b = strat.uplink_mask(&scores, 0, 0);
        assert_eq!(a, b, "same client+round must resample identically");
        assert_ne!(a, strat.uplink_mask(&scores, 1, 0));
        assert_ne!(a, strat.uplink_mask(&scores, 0, 1));
    }

    #[test]
    fn storage_bits_scale_with_sparsity() {
        // a server whose theta is mostly 0 stores a much smaller mask
        let dense = MaskStrategy::new(50_000, 1, MaskMode::Stochastic);
        let bits_uniform = dense.storage_bits();
        // uniform theta -> threshold density ~0.5 -> ~1 bpp
        assert!(bits_uniform > 40_000, "{bits_uniform}");
        assert!(bits_uniform < 60_000, "{bits_uniform}");
    }

    #[test]
    fn eval_model_is_binary() {
        let strat = MaskStrategy::new(500, 2, MaskMode::Stochastic);
        let EvalModel::Masked(m) = strat.eval_model(0) else {
            panic!("mask strategies evaluate masked models")
        };
        assert_eq!(m.len(), 500);
        assert!(m.iter().all(|&v| v == 0.0 || v == 1.0));
    }
}
