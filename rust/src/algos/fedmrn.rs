//! FedMRN: federated masked random noise (arxiv 2408.03220).
//!
//! Where the FedPM family masks the runtime's frozen *weights*, FedMRN
//! masks a frozen random *noise* tensor that exists only as a 64-bit
//! seed: the effective model is `m ⊙ noise(seed)`. The reconstruction
//! contract is therefore different from every other strategy —
//!
//!   1. DL: `begin_round` emits a [`DownlinkMsg::NoiseTheta`] (v2-only
//!      wire kind) carrying the global mask probabilities AND the noise
//!      seed; the device expands [`noise_from_seed`] locally, so the
//!      n-element noise tensor never crosses the wire.
//!   2. Each device ([`FedMrnClientTask`]) runs STE score-SGD against
//!      the masked noise through the dense-gradient program: per step
//!      the forward mask is `1[s >= 0]`, the score update is
//!      `s -= lr * g ⊙ noise` (straight-through estimator).
//!   3. UL: one Bernoulli(sigma(s)) mask, entropy-coded in an
//!      [`UplinkPayload::NoiseMask`] envelope (~1 Bpp) sampled from its
//!      own seed stream (tag [`NOISE_MASK_STREAM`], disjoint from the
//!      FedPM family's 0xA24B tree).
//!   4. Server: `fold_uplink` decodes and folds the |D_i|-weighted mask
//!      sum the moment the envelope lands (eq. 8 shape, O(n_params)
//!      state); `end_round` sets theta(t+1) = acc / weight_sum.
//!
//! The downlink is always a `NoiseTheta` envelope: `downlink=qdelta`
//! is rejected at config validation because the seed must ride every
//! broadcast (a delta chain has nowhere to carry it).
//!
//! Noise values live on the dyadic grid k/4096, k in [-1024, 1024), so
//! every weighted fold over them is grouping-exact (the §Fleet edge
//! associativity condition) and magnitudes sit near the signed-constant
//! Kaiming scale of the small models this repo ships.
//!
//! audit: wire-decode, deterministic

use anyhow::{bail, ensure, Result};

use crate::compress::{self};
use crate::data::Dataset;
use crate::fl::protocol::{DownlinkMsg, RoundPlan, UplinkMsg, UplinkPayload};
use crate::fl::{Client, RoundComm};
use crate::mask::{empirical_bpp, sample_mask, ProbMask};
use crate::runtime::ModelRuntime;
use crate::util::{logit, BitVec, SeedSequence, Xoshiro256};

use super::{AggKind, AggregateMsg, ClientTask, EvalModel, RoundStats, ServerLogic};

/// Seed-tree tag of the frozen noise tensor (child of the experiment
/// seed, disjoint from every other reserved stream).
const NOISE_CHILD: u64 = 0x4015E;
/// Seed-tree tag of the uplink mask-sampling stream — deliberately NOT
/// the FedPM family's 0xA24B so the two families never share draws.
const NOISE_MASK_STREAM: u64 = 0x4E4D;

/// Expand a noise seed into the frozen noise tensor. Pure in
/// `(seed, n)`: server and every device reconstruct the identical
/// tensor from the 8 bytes on the wire. Values are dyadic
/// (k/4096, |k| <= 1024) so weighted f64 folds over masked noise are
/// grouping-exact.
pub fn noise_from_seed(seed: u64, n: usize) -> Vec<f32> {
    let s = SeedSequence::new(seed).child(NOISE_CHILD).seed();
    let mut rng = Xoshiro256::new(s);
    (0..n).map(|_| (rng.below(2048) as f32 - 1024.0) / 4096.0).collect()
}

/// FedMRN server logic: global mask probabilities over seeded noise.
pub struct FedMrn {
    /// Global keep-probabilities theta in [0,1]^n.
    theta: Vec<f32>,
    /// Seed of the frozen noise tensor — the only "weights" shipped.
    noise_seed: u64,
    /// The expanded noise tensor (server-side copy for evaluation).
    noise: Vec<f32>,
    /// Streaming |D_i|-weighted mask sum (eq. 8 shape).
    acc: Vec<f64>,
    weight_sum: f64,
    /// Summed (not running-mean) client losses: a plain sum merges with
    /// edge-tier partial sums in any grouping, unlike a running mean.
    loss_sum: f64,
    reporters: usize,
}

impl FedMrn {
    /// `seed` is the experiment seed; the noise seed is derived from it
    /// via the reserved [`NOISE_CHILD`] stream.
    pub fn new(n_params: usize, seed: u64) -> Self {
        let noise_seed = SeedSequence::new(seed).child(NOISE_CHILD).seed();
        Self {
            theta: vec![0.5; n_params],
            noise_seed,
            noise: noise_from_seed(noise_seed, n_params),
            acc: vec![0.0; n_params],
            weight_sum: 0.0,
            loss_sum: 0.0,
            reporters: 0,
        }
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// The deterministic evaluation mask: keep where theta >= 1/2.
    fn eval_mask(&self) -> BitVec {
        BitVec::from_iter_len(self.theta.iter().map(|&t| t >= 0.5), self.theta.len())
    }
}

/// Device half: STE score-SGD against masked seeded noise.
pub struct FedMrnClientTask;

impl ClientTask for FedMrnClientTask {
    fn run(
        &self,
        rt: &ModelRuntime,
        data: &Dataset,
        client: &mut Client,
        msg: &DownlinkMsg,
        prev_state: Option<&[f32]>,
        plan: &RoundPlan,
    ) -> Result<UplinkMsg> {
        let DownlinkMsg::NoiseTheta { noise_seed, .. } = msg else {
            bail!("fedmrn client expects a noise-theta broadcast, got {}", msg.kind_name());
        };
        // The device works from the theta it decoded off the wire and
        // expands the noise tensor from the seed that rode the envelope.
        let theta = msg.decode_state(prev_state)?;
        ensure!(
            theta.len() == rt.manifest.n_params,
            "noise-theta broadcast for {} params, model has {}",
            theta.len(),
            rt.manifest.n_params
        );
        let noise = noise_from_seed(*noise_seed, theta.len());
        let mut scores: Vec<f32> = theta.iter().map(|&t| logit(t)).collect();
        let batch = rt.manifest.batch;
        let steps = client.steps_per_round(batch, plan.local_epochs).max(1);
        let mut w = vec![0.0f32; scores.len()];
        let mut last_loss = 0.0f32;
        for _ in 0..steps {
            // Deterministic forward mask m = 1[s >= 0] (sigma(s) >= 1/2).
            for ((wi, &s), &nz) in w.iter_mut().zip(&scores).zip(&noise) {
                *wi = if s >= 0.0 { nz } else { 0.0 };
            }
            let (xs, ys) = client.gather_call_batches(data, 1, batch);
            let (grads, loss, _correct) = rt.dense_grad(&w, &xs, &ys)?;
            // Straight-through estimator: d loss / d s = g * noise.
            for ((s, &g), &nz) in scores.iter_mut().zip(&grads).zip(&noise) {
                *s -= plan.lr * g * nz;
            }
            last_loss = loss;
        }
        // One Bernoulli(sigma(s)) mask per (round, client), sampled from
        // the FedMRN-reserved stream so the aggregate is unbiased and
        // the draw replays at any thread count.
        let theta_hat = ProbMask::from_scores(&scores);
        let mask_seed = SeedSequence::new(plan.seed)
            .child(NOISE_MASK_STREAM)
            .child(plan.round as u64)
            .child(client.id as u64)
            .seed();
        let mask = sample_mask(&theta_hat, mask_seed);
        Ok(UplinkMsg {
            weight: client.weight(),
            train_loss: last_loss,
            trained_round: plan.round as u64,
            payload: UplinkPayload::NoiseMask(compress::encode(&mask)),
        })
    }
}

impl ServerLogic for FedMrn {
    fn name(&self) -> &'static str {
        "fedmrn"
    }

    fn begin_round(&mut self, _plan: &RoundPlan) -> Result<DownlinkMsg> {
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        self.weight_sum = 0.0;
        self.loss_sum = 0.0;
        self.reporters = 0;
        Ok(DownlinkMsg::NoiseTheta { noise_seed: self.noise_seed, theta: self.theta.clone() })
    }

    fn fold_uplink(&mut self, msg: &UplinkMsg, comm: &mut RoundComm) -> Result<()> {
        let UplinkPayload::NoiseMask(enc) = &msg.payload else {
            bail!(
                "fedmrn server expects a noise-mask uplink, got {}",
                msg.payload.kind_name()
            );
        };
        let mask = compress::decode(enc, self.theta.len())?;
        comm.add_uplink(msg.wire_bits(), empirical_bpp(&mask));
        for (i, bit) in mask.iter().enumerate() {
            if bit {
                self.acc[i] += msg.weight;
            }
        }
        self.weight_sum += msg.weight;
        self.reporters += 1;
        self.loss_sum += msg.train_loss as f64;
        Ok(())
    }

    fn agg_kind(&self) -> AggKind {
        AggKind::NoiseMaskSum
    }

    fn fold_aggregate(&mut self, msg: &AggregateMsg, comm: &mut RoundComm) -> Result<()> {
        ensure!(
            msg.kind == AggKind::NoiseMaskSum,
            "fedmrn server expects a noise-mask-sum aggregate, got {:?}",
            msg.kind
        );
        ensure!(
            msg.acc.len() == self.theta.len(),
            "aggregate covers {} params, model has {}",
            msg.acc.len(),
            self.theta.len()
        );
        comm.add_uplinks(msg.ul_bits, msg.est_bpp_sum, msg.reporters as usize);
        for (a, &p) in self.acc.iter_mut().zip(&msg.acc) {
            *a += p;
        }
        self.weight_sum += msg.weight_sum;
        self.reporters += msg.reporters as usize;
        self.loss_sum += msg.loss_sum;
        Ok(())
    }

    fn end_round(&mut self, _plan: &RoundPlan) -> Result<RoundStats> {
        ensure!(self.weight_sum > 0.0, "no uplinks received this round");
        for (t, &a) in self.theta.iter_mut().zip(&self.acc) {
            // A weighted mean of 0/1 terms lands in [0,1]; the clamp
            // pins the wire invariant against last-ulp rounding.
            *t = ((a / self.weight_sum) as f32).clamp(0.0, 1.0);
        }
        let mean_theta =
            self.theta.iter().map(|&t| t as f64).sum::<f64>() / self.theta.len().max(1) as f64;
        Ok(RoundStats {
            train_loss: self.loss_sum / self.reporters.max(1) as f64,
            mean_theta,
            mask_density: self.eval_mask().density(),
        })
    }

    fn client_task(&self) -> Box<dyn ClientTask> {
        Box::new(FedMrnClientTask)
    }

    fn eval_model(&self, _round: usize) -> EvalModel {
        // The deployed model is m ⊙ noise — dense values, so the
        // evaluator runs the dense path (the mask selects noise entries,
        // not the runtime's frozen weights).
        let mask = self.eval_mask();
        let w: Vec<f32> = self
            .noise
            .iter()
            .enumerate()
            .map(|(i, &nz)| if mask.get(i) { nz } else { 0.0 })
            .collect();
        EvalModel::Dense(w)
    }

    fn storage_bits(&self) -> u64 {
        // noise seed (64b) + coded threshold mask — the strong-LTH
        // seed+mask storage story, with noise instead of weights.
        64 + compress::encode(&self.eval_mask()).wire_bytes() as u64 * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> RoundPlan {
        RoundPlan {
            round: 1,
            seed: 7,
            lambda: 0.0,
            lr: 0.1,
            local_epochs: 1,
            topk_frac: 0.3,
            server_lr: 0.1,
            adam: false,
        }
    }

    fn mask_msg(bits: &[bool], weight: f64) -> UplinkMsg {
        let m = BitVec::from_bools(bits);
        UplinkMsg {
            weight,
            train_loss: 0.5,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::NoiseMask(compress::encode(&m)),
        }
    }

    #[test]
    fn noise_is_pure_dyadic_and_seed_sensitive() {
        let a = noise_from_seed(9, 512);
        assert_eq!(a, noise_from_seed(9, 512), "noise must be pure in (seed, n)");
        assert_ne!(a, noise_from_seed(10, 512), "the seed must matter");
        for &v in &a {
            assert!((-0.25..0.25).contains(&v), "{v}");
            let scaled = v * 4096.0;
            assert_eq!(scaled, scaled.trunc(), "noise must sit on the dyadic grid");
        }
    }

    #[test]
    fn begin_round_ships_the_noise_seed() {
        let mut srv = FedMrn::new(64, 3);
        match srv.begin_round(&plan()).unwrap() {
            DownlinkMsg::NoiseTheta { noise_seed, theta } => {
                assert_eq!(theta, vec![0.5; 64]);
                assert_eq!(
                    noise_from_seed(noise_seed, 64),
                    srv.noise,
                    "devices must expand the server's exact noise tensor"
                );
            }
            other => panic!("fedmrn must broadcast noise-theta, got {}", other.kind_name()),
        }
    }

    #[test]
    fn streaming_fold_is_weighted_mask_mean() {
        let mut srv = FedMrn::new(4, 1);
        let mut comm = RoundComm::new(4);
        srv.begin_round(&plan()).unwrap();
        srv.fold_uplink(&mask_msg(&[true, true, false, false], 1.0), &mut comm).unwrap();
        srv.fold_uplink(&mask_msg(&[true, false, true, false], 3.0), &mut comm).unwrap();
        srv.end_round(&plan()).unwrap();
        // theta = (1*m1 + 3*m2) / 4
        assert_eq!(srv.theta(), &[1.0, 0.25, 0.75, 0.0]);
        assert_eq!(comm.clients, 2);
    }

    #[test]
    fn fold_rejects_wrong_payload_and_empty_round() {
        let mut srv = FedMrn::new(8, 1);
        let mut comm = RoundComm::new(8);
        srv.begin_round(&plan()).unwrap();
        let wrong = UplinkMsg {
            weight: 1.0,
            train_loss: 0.0,
            trained_round: UplinkMsg::FRESH,
            payload: UplinkPayload::CodedMask(compress::encode(&BitVec::zeros(8))),
        };
        assert!(
            srv.fold_uplink(&wrong, &mut comm).is_err(),
            "a coded-mask uplink must not fold as a noise mask"
        );
        assert!(srv.end_round(&plan()).is_err(), "zero uplinks cannot average");
    }

    #[test]
    fn eval_model_is_masked_noise() {
        let mut srv = FedMrn::new(6, 5);
        let mut comm = RoundComm::new(6);
        srv.begin_round(&plan()).unwrap();
        srv.fold_uplink(&mask_msg(&[true, false, true, false, true, false], 2.0), &mut comm)
            .unwrap();
        srv.end_round(&plan()).unwrap();
        let EvalModel::Dense(w) = srv.eval_model(1) else {
            panic!("fedmrn evaluates dense masked noise")
        };
        for (i, &v) in w.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(v, srv.noise[i], "kept entries must equal the noise");
            } else {
                assert_eq!(v, 0.0, "dropped entries must be zero");
            }
        }
    }

    #[test]
    fn client_task_rejects_other_broadcast_kinds() {
        let srv = FedMrn::new(16, 1);
        let task = srv.client_task();
        let data = crate::data::Synthetic::new(crate::data::SynthSpec::tiny(), 1)
            .generate(40, 1);
        let shards = crate::data::partition_iid(&data, 1, 1);
        let mut client = Client::new(shards[0].clone(), 5);
        let rt = ModelRuntime::load(std::path::Path::new("artifacts"), "mlp_tiny").unwrap();
        let msg = DownlinkMsg::Theta(vec![0.5; rt.manifest.n_params]);
        assert!(task.run(&rt, &data, &mut client, &msg, None, &plan()).is_err());
    }

    #[test]
    fn storage_is_seed_plus_coded_mask() {
        let srv = FedMrn::new(50_000, 1);
        let bits = srv.storage_bits();
        // uniform theta -> threshold density ~1 -> about 1 Bpp coded,
        // and always the 64-bit seed on top
        assert!(bits > 64, "{bits}");
        assert!(bits < 64 + 60_000, "{bits}");
    }
}
