//! # fedsrn — Communication-Efficient FL via Regularized Sparse Random Networks
//!
//! A full-system reproduction of Mestoukirdi et al. 2023: federated
//! training of binary masks over frozen random networks, with an
//! entropy-proxy regularizer that drives uplink cost far below the
//! 1 bit-per-parameter bound.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: parameter server, simulated
//!   device fleet, parallel round engine, mask aggregation, entropy
//!   coding, metrics.
//! * **L2 (python/compile/model.py)** — JAX score-network programs,
//!   AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas masked-matmul kernels
//!   fused into the L2 programs.
//!
//! Python never runs at experiment time: the [`runtime`] module either
//! executes the AOT artifacts through PJRT (`--features pjrt`) or runs
//! the built-in pure-Rust re-implementation of the same programs
//! (DESIGN.md §Substitutions), and the whole federation runs natively.
//! A round is an exchange of typed, versioned wire messages
//! ([`fl::protocol`]): the server half of a strategy emits one
//! [`fl::DownlinkMsg`] and stream-folds [`fl::UplinkMsg`] envelopes as
//! they land; the pure client half is sharded across worker threads by
//! the parallel round engine ([`coordinator::RoundEngine`]), with
//! results bit-identical to the sequential path at any thread count
//! (DESIGN.md §Protocol, §Parallel round engine).
//!
//! The contracts above are enforced by tooling, not convention: the
//! [`analysis`] module implements `fedsrn audit`, a zero-dependency
//! invariant linter run as a required CI gate (DESIGN.md
//! §Static-analysis). `unsafe` is budgeted to `runtime/pjrt.rs` (FFI)
//! and `runtime/packed.rs` (`std::arch` SIMD) — denied crate-wide
//! here, allowed on those modules with per-impl `SAFETY:`
//! justifications — and clippy's `disallowed_methods` /
//! `disallowed_types` (clippy.toml) police the determinism contract
//! from the compiler's side.

#![deny(unsafe_code)]
#![warn(clippy::disallowed_methods, clippy::disallowed_types)]

pub mod algos;
pub mod analysis;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fl;
pub mod mask;
pub mod runtime;
pub mod util;
