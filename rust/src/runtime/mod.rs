//! Model runtime: one facade, two execution backends.
//!
//! The coordinator always talks to [`ModelRuntime`]; which engine
//! actually runs the three L2 programs (local_train / eval / dense_grad)
//! is an implementation detail resolved at load time:
//!
//! * **native** (default) — the pure-Rust layer-graph core: manifest
//!   layouts compile to a [`graph::Plan`] executed by the blocked
//!   kernels in [`kernels`] (DESIGN.md §Compute-core). No Python, no
//!   XLA, no artifacts required: the MLP *and* conv model families are
//!   built in, and exported artifact manifests with a `layers=` layout
//!   also run natively. See DESIGN.md §Substitutions.
//! * **pjrt** (`--features pjrt`) — the AOT path: HLO text emitted by
//!   `python/compile/aot.py`, compiled through the PJRT C API, with the
//!   frozen weight vector staged on-device once per model. Python never
//!   runs at experiment time.
//!
//! All methods take `&self` and the facade is `Sync`: the parallel round
//! engine (DESIGN.md §Parallel round engine) shares one runtime across
//! its worker threads. Wall-clock per program is accumulated into
//! `timers` (thread-sharded, merged on read — workers never serialize
//! on telemetry) for the perf pass (`FEDSRN_TIMERS=1`).

pub mod artifacts;
pub mod graph;
pub mod kernels;
pub mod native;
// The crate denies `unsafe_code`; the budgeted exceptions are the PJRT
// FFI boundary and the `std::arch` SIMD intrinsics in `packed`, and
// `fedsrn audit` additionally requires every `unsafe` in either file to
// carry a `SAFETY:` justification.
#[allow(unsafe_code)]
pub mod packed;
#[cfg(feature = "pjrt")]
#[allow(unsafe_code)]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

pub use artifacts::{available_models, Manifest};
pub use packed::Compute;

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::util::ShardedTimers;

use native::NativeBackend;

/// Metrics returned by one local_train call (see model.make_local_train).
#[derive(Debug, Clone, Copy)]
pub struct TrainMetrics {
    /// Mean minibatch loss over the call's scan steps (incl. regularizer).
    pub mean_loss: f32,
    /// Total correct predictions across all steps (train accuracy proxy).
    pub correct: f32,
    /// sum_j sigmoid(s_j) after the call — the regularizer numerator.
    pub sum_sigma: f32,
    /// Ones in a mask sampled from the updated scores (sparsity probe).
    pub active: f32,
}

/// Result of an eval pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalMetrics {
    pub correct: f64,
    pub loss_sum: f64,
    pub examples: usize,
}

impl EvalMetrics {
    pub fn accuracy(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct / self.examples as f64
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.loss_sum / self.examples as f64
        }
    }
}

enum Backend {
    Native(NativeBackend),
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtBackend),
}

/// A loaded model: manifest + executing backend + host weights.
pub struct ModelRuntime {
    pub manifest: Manifest,
    backend: Backend,
    /// Host copy (used by baselines that mutate weights, e.g. SignSGD).
    weights_host: Vec<f32>,
    /// Forward implementation for masked eval (`compute=` config key).
    compute: Compute,
    /// Per-program wall-clock accounting for the perf pass. Sharded by
    /// calling thread so the parallel round engine's workers accumulate
    /// without contending; read with [`ShardedTimers::snapshot`].
    pub timers: ShardedTimers,
}

impl ModelRuntime {
    /// Load `<model>` from `<dir>`; falls back to the built-in native
    /// model registry when no artifact manifest exists on disk. A
    /// manifest that exists but fails to parse is a hard error — never
    /// silently substituted, since the built-in model has different
    /// weights and hyperparameters than whatever the user exported.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let meta_present = dir.join(format!("{model}.meta")).exists();
        let manifest = if meta_present {
            Manifest::load(dir, model)?
        } else if let Some(m) = Manifest::builtin(model) {
            eprintln!(
                "artifacts for '{model}' not found in {dir:?}; \
                 using the built-in native model"
            );
            m
        } else {
            // produce the standard "missing manifest" error
            Manifest::load(dir, model)?
        };
        Self::from_manifest(manifest)
    }

    /// Build a runtime from an already-resolved manifest.
    pub fn from_manifest(manifest: Manifest) -> Result<Self> {
        let weights_host = manifest.load_weights()?;
        let backend = Self::build_backend(&manifest, &weights_host)?;
        Ok(Self {
            manifest,
            backend,
            weights_host,
            compute: Compute::Blocked,
            timers: ShardedTimers::new(),
        })
    }

    /// Select the forward implementation for masked eval. `Blocked` is
    /// the default; `Packed` is the bit-packed sign-select tier, which
    /// falls back to blocked per call whenever the (mask, weights) pair
    /// is not packable. Training is unaffected either way.
    pub fn set_compute(&mut self, compute: Compute) {
        self.compute = compute;
    }

    /// The currently selected compute tier (telemetry / tests).
    pub fn compute(&self) -> Compute {
        self.compute
    }

    #[cfg(feature = "pjrt")]
    fn build_backend(man: &Manifest, weights: &[f32]) -> Result<Backend> {
        if man.builtin {
            Ok(Backend::Native(NativeBackend::from_manifest(man)?))
        } else {
            Ok(Backend::Pjrt(pjrt::PjrtBackend::load(man, weights)?))
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn build_backend(man: &Manifest, _weights: &[f32]) -> Result<Backend> {
        Ok(Backend::Native(NativeBackend::from_manifest(man)?))
    }

    /// Which backend executes this model (telemetry / logging).
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native(_) => "native",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights_host
    }

    pub fn has_dense_grad(&self) -> bool {
        match &self.backend {
            Backend::Native(_) => true,
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.has_dense_grad(),
        }
    }

    fn time(&self, label: &str, t0: Instant) {
        self.timers.add(label, t0.elapsed());
    }

    /// One client local phase: `steps` minibatches of STE-SGD.
    ///
    /// `xs` is (steps*batch*input_dim) row-major, `ys` (steps*batch).
    /// Returns the updated score vector and the call metrics.
    #[allow(clippy::too_many_arguments)]
    pub fn local_train(
        &self,
        scores: &[f32],
        xs: &[f32],
        ys: &[i32],
        seed: i32,
        lambda: f32,
        lr: f32,
        deterministic: bool,
        adam: bool,
    ) -> Result<(Vec<f32>, TrainMetrics)> {
        let m = &self.manifest;
        ensure!(scores.len() == m.n_params, "scores length mismatch");
        ensure!(
            xs.len() == m.steps * m.batch * m.input_dim,
            "xs must be steps*batch*input_dim = {}",
            m.steps * m.batch * m.input_dim
        );
        ensure!(ys.len() == m.steps * m.batch, "ys must be steps*batch");

        let t0 = Instant::now();
        let out = match &self.backend {
            Backend::Native(b) => b.local_train(
                m,
                &self.weights_host,
                scores,
                xs,
                ys,
                seed,
                lambda,
                lr,
                deterministic,
                adam,
            ),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                b.local_train(m, scores, xs, ys, seed, lambda, lr, deterministic, adam)
            }
        };
        self.time("local_train", t0);
        out
    }

    /// Evaluate a binary mask (as f32 0/1) over an arbitrary-size test
    /// set against the frozen weights.
    pub fn eval_mask(&self, mask_f32: &[f32], x: &[f32], y: &[i32]) -> Result<EvalMetrics> {
        let m = &self.manifest;
        ensure!(mask_f32.len() == m.n_params, "mask length mismatch");
        ensure!(x.len() == y.len() * m.input_dim, "x/y size mismatch");
        let t0 = Instant::now();
        let out = match &self.backend {
            Backend::Native(b) => b.eval_mask(mask_f32, &self.weights_host, x, y, self.compute),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.eval_padded(m, mask_f32, None, x, y),
        };
        self.time("eval", t0);
        out
    }

    /// Evaluate with explicit weights (dense baselines: pass the trained
    /// weight vector and an all-ones mask).
    pub fn eval_with_weights(
        &self,
        mask_f32: &[f32],
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<EvalMetrics> {
        let m = &self.manifest;
        ensure!(weights.len() == m.n_params, "weights length mismatch");
        ensure!(mask_f32.len() == m.n_params, "mask length mismatch");
        ensure!(x.len() == y.len() * m.input_dim, "x/y size mismatch");
        let t0 = Instant::now();
        let out = match &self.backend {
            // dense baselines pass trained (non-constant) weights — the
            // packed contract can't hold, so don't even probe it.
            Backend::Native(b) => b.eval_mask(mask_f32, weights, x, y, Compute::Blocked),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => b.eval_padded(m, mask_f32, Some(weights), x, y),
        };
        self.time("eval", t0);
        out
    }

    /// Dense forward/backward for the SignSGD / FedAvg baselines.
    ///
    /// `x` is (rows*input_dim). The native graph accepts any row count;
    /// only the PJRT path is bound to the exported fixed-batch program
    /// (rows <= batch, padded with y = -1 behind the feature gate).
    /// Returns (grads, mean_loss, correct).
    pub fn dense_grad(
        &self,
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<f32>, f32, f32)> {
        let m = &self.manifest;
        ensure!(weights.len() == m.n_params, "weights length mismatch");
        ensure!(x.len() == y.len() * m.input_dim, "x/y size mismatch");
        let t0 = Instant::now();
        let out = match &self.backend {
            Backend::Native(b) => b.dense_grad(weights, x, y),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(b) => {
                // the exported program takes a fixed batch: pad with y=-1
                ensure!(
                    y.len() <= m.batch,
                    "at most {} rows per pjrt dense_grad call",
                    m.batch
                );
                let mut xb = vec![0.0f32; m.batch * m.input_dim];
                xb[..x.len()].copy_from_slice(x);
                let mut yb = vec![-1i32; m.batch];
                yb[..y.len()].copy_from_slice(y);
                b.dense_grad(m, weights, &xb, &yb)
            }
        };
        self.time("dense_grad", t0);
        out
    }
}
