//! PJRT runtime: load AOT artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API) exactly the way the production hot
//! path needs it:
//!   HLO text --parse--> HloModuleProto --compile--> PjRtLoadedExecutable
//! with the frozen weight vector staged on-device once per model and
//! reused across every client call of every round (weights never change
//! in the strong-LTH setting — re-uploading them per call would dominate
//! the round loop).
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;

pub use artifacts::{available_models, Manifest};

use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::util::Timers;

/// Metrics returned by one local_train call (see model.make_local_train).
#[derive(Debug, Clone, Copy)]
pub struct TrainMetrics {
    /// Mean minibatch loss over the call's scan steps (incl. regularizer).
    pub mean_loss: f32,
    /// Total correct predictions across all steps (train accuracy proxy).
    pub correct: f32,
    /// sum_j sigmoid(s_j) after the call — the regularizer numerator.
    pub sum_sigma: f32,
    /// Ones in a mask sampled from the updated scores (sparsity probe).
    pub active: f32,
}

/// Result of an eval pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct EvalMetrics {
    pub correct: f64,
    pub loss_sum: f64,
    pub examples: usize,
}

impl EvalMetrics {
    pub fn accuracy(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct / self.examples as f64
        }
    }

    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.loss_sum / self.examples as f64
        }
    }
}

/// A loaded model: compiled executables + device-resident weights.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: PjRtClient,
    local_train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    dense_grad: Option<PjRtLoadedExecutable>,
    /// Host copy (used by baselines that mutate weights, e.g. SignSGD).
    weights_host: Vec<f32>,
    /// Device copy reused across all masked-path calls.
    weights_dev: PjRtBuffer,
    /// Per-program wall-clock accounting for the perf pass.
    pub timers: RefCell<Timers>,
}

fn compile_hlo(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e}"))
}

impl ModelRuntime {
    /// Load `<model>` from `<dir>` on a fresh CPU PJRT client.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
        Self::load_with_client(client, dir, model)
    }

    /// Load on an existing client (sharing one client across models keeps
    /// a single thread pool).
    pub fn load_with_client(client: PjRtClient, dir: &Path, model: &str) -> Result<Self> {
        let manifest = Manifest::load(dir, model)?;
        let local_train = compile_hlo(&client, &manifest.local_train_file)?;
        let eval = compile_hlo(&client, &manifest.eval_file)?;
        let dense_grad = match &manifest.dense_grad_file {
            Some(p) => Some(compile_hlo(&client, p)?),
            None => None,
        };
        let weights_host = manifest.load_weights()?;
        let weights_dev = client
            .buffer_from_host_buffer(&weights_host, &[weights_host.len()], None)
            .map_err(|e| anyhow!("staging weights: {e}"))?;
        Ok(Self {
            manifest,
            client,
            local_train,
            eval,
            dense_grad,
            weights_host,
            weights_dev,
            timers: RefCell::new(Timers::new()),
        })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights_host
    }

    pub fn has_dense_grad(&self) -> bool {
        self.dense_grad.is_some()
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device f32 transfer: {e}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device i32 transfer: {e}"))
    }

    fn scalar_f32(&self, v: f32) -> Result<PjRtBuffer> {
        self.buf_f32(&[v], &[])
    }

    fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.buf_i32(&[v], &[])
    }

    /// One client local phase: `steps` minibatches of STE-SGD.
    ///
    /// `xs` is (steps*batch*input_dim) row-major, `ys` (steps*batch).
    /// Returns the updated score vector and the call metrics.
    pub fn local_train(
        &self,
        scores: &[f32],
        xs: &[f32],
        ys: &[i32],
        seed: i32,
        lambda: f32,
        lr: f32,
        deterministic: bool,
        adam: bool,
    ) -> Result<(Vec<f32>, TrainMetrics)> {
        let m = &self.manifest;
        ensure!(scores.len() == m.n_params, "scores length mismatch");
        ensure!(
            xs.len() == m.steps * m.batch * m.input_dim,
            "xs must be steps*batch*input_dim = {}",
            m.steps * m.batch * m.input_dim
        );
        ensure!(ys.len() == m.steps * m.batch, "ys must be steps*batch");

        let t0 = Instant::now();
        let scores_b = self.buf_f32(scores, &[m.n_params])?;
        let xs_b = self.buf_f32(xs, &[m.steps, m.batch, m.input_dim])?;
        let ys_b = self.buf_i32(ys, &[m.steps, m.batch])?;
        let seed_b = self.scalar_i32(seed)?;
        let lam_b = self.scalar_f32(lambda)?;
        let lr_b = self.scalar_f32(lr)?;
        let det_b = self.scalar_f32(if deterministic { 1.0 } else { 0.0 })?;
        let opt_b = self.scalar_f32(if adam { 1.0 } else { 0.0 })?;
        // weights stay device-resident for the whole run: pass by ref.
        let args: [&PjRtBuffer; 9] = [
            &scores_b,
            &self.weights_dev,
            &xs_b,
            &ys_b,
            &seed_b,
            &lam_b,
            &lr_b,
            &det_b,
            &opt_b,
        ];
        self.timers.borrow_mut().add("local_train.h2d", t0.elapsed());

        let t1 = Instant::now();
        let result = self
            .local_train
            .execute_b(&args)
            .map_err(|e| anyhow!("local_train execute: {e}"))?;
        self.timers.borrow_mut().add("local_train.execute", t1.elapsed());

        let t2 = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("local_train d2h: {e}"))?;
        let (s_out, metrics) =
            tuple.to_tuple2().map_err(|e| anyhow!("local_train tuple: {e}"))?;
        let new_scores = s_out.to_vec::<f32>().map_err(|e| anyhow!("scores d2h: {e}"))?;
        let met = metrics.to_vec::<f32>().map_err(|e| anyhow!("metrics d2h: {e}"))?;
        self.timers.borrow_mut().add("local_train.d2h", t2.elapsed());
        ensure!(met.len() == 4, "expected 4 metrics");
        Ok((
            new_scores,
            TrainMetrics {
                mean_loss: met[0],
                correct: met[1],
                sum_sigma: met[2],
                active: met[3],
            },
        ))
    }

    /// Evaluate a binary mask (as f32 0/1) over an arbitrary-size test
    /// set, chunking to the exported eval_chunk and padding the tail with
    /// y = -1 rows (ignored by the program).
    pub fn eval_mask(&self, mask_f32: &[f32], x: &[f32], y: &[i32]) -> Result<EvalMetrics> {
        let m = &self.manifest;
        ensure!(mask_f32.len() == m.n_params, "mask length mismatch");
        ensure!(x.len() == y.len() * m.input_dim, "x/y size mismatch");
        let t = m.eval_chunk;
        let mut out = EvalMetrics { examples: y.len(), ..Default::default() };

        let mut xc = vec![0.0f32; t * m.input_dim];
        let mut yc = vec![-1i32; t];
        let mut start = 0;
        while start < y.len() {
            let take = (y.len() - start).min(t);
            xc[..take * m.input_dim]
                .copy_from_slice(&x[start * m.input_dim..(start + take) * m.input_dim]);
            xc[take * m.input_dim..].iter_mut().for_each(|v| *v = 0.0);
            yc[..take].copy_from_slice(&y[start..start + take]);
            yc[take..].iter_mut().for_each(|v| *v = -1);

            let t1 = Instant::now();
            let mask_b = self.buf_f32(mask_f32, &[m.n_params])?;
            let x_b = self.buf_f32(&xc, &[t, m.input_dim])?;
            let y_b = self.buf_i32(&yc, &[t])?;
            let args: [&PjRtBuffer; 4] = [&mask_b, &self.weights_dev, &x_b, &y_b];
            let result = self.eval.execute_b(&args).map_err(|e| anyhow!("eval execute: {e}"))?;
            let lit =
                result[0][0].to_literal_sync().map_err(|e| anyhow!("eval d2h: {e}"))?;
            let inner = lit.to_tuple1().map_err(|e| anyhow!("eval tuple: {e}"))?;
            let v = inner.to_vec::<f32>().map_err(|e| anyhow!("eval vec: {e}"))?;
            self.timers.borrow_mut().add("eval.chunk", t1.elapsed());
            out.correct += v[0] as f64;
            out.loss_sum += v[1] as f64;
            start += take;
        }
        Ok(out)
    }

    /// Evaluate with explicit weights (dense baselines: pass the trained
    /// weight vector and an all-ones mask).
    pub fn eval_with_weights(
        &self,
        mask_f32: &[f32],
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<EvalMetrics> {
        let m = &self.manifest;
        ensure!(weights.len() == m.n_params, "weights length mismatch");
        ensure!(mask_f32.len() == m.n_params, "mask length mismatch");
        ensure!(x.len() == y.len() * m.input_dim, "x/y size mismatch");
        let t = m.eval_chunk;
        let mut out = EvalMetrics { examples: y.len(), ..Default::default() };
        let mut xc = vec![0.0f32; t * m.input_dim];
        let mut yc = vec![-1i32; t];
        let mut start = 0;
        while start < y.len() {
            let take = (y.len() - start).min(t);
            xc[..take * m.input_dim]
                .copy_from_slice(&x[start * m.input_dim..(start + take) * m.input_dim]);
            xc[take * m.input_dim..].iter_mut().for_each(|v| *v = 0.0);
            yc[..take].copy_from_slice(&y[start..start + take]);
            yc[take..].iter_mut().for_each(|v| *v = -1);
            let args = [
                self.buf_f32(mask_f32, &[m.n_params])?,
                self.buf_f32(weights, &[m.n_params])?,
                self.buf_f32(&xc, &[t, m.input_dim])?,
                self.buf_i32(&yc, &[t])?,
            ];
            let result = self.eval.execute_b(&args).map_err(|e| anyhow!("eval execute: {e}"))?;
            let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("eval d2h: {e}"))?;
            let inner = lit.to_tuple1().map_err(|e| anyhow!("eval tuple: {e}"))?;
            let v = inner.to_vec::<f32>().map_err(|e| anyhow!("eval vec: {e}"))?;
            out.correct += v[0] as f64;
            out.loss_sum += v[1] as f64;
            start += take;
        }
        Ok(out)
    }

    /// Dense forward/backward for the SignSGD / FedAvg baselines.
    ///
    /// `x` is (rows*input_dim) with rows <= exported batch; the tail is
    /// padded internally with ignored y = -1 rows. Returns
    /// (grads, mean_loss, correct).
    pub fn dense_grad(
        &self,
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<f32>, f32, f32)> {
        let m = &self.manifest;
        let exe = self
            .dense_grad
            .as_ref()
            .ok_or_else(|| anyhow!("model {} exported without dense_grad", m.model))?;
        ensure!(weights.len() == m.n_params, "weights length mismatch");
        ensure!(y.len() <= m.batch, "at most {} rows per dense_grad call", m.batch);
        ensure!(x.len() == y.len() * m.input_dim, "x/y size mismatch");

        let mut xb = vec![0.0f32; m.batch * m.input_dim];
        xb[..x.len()].copy_from_slice(x);
        let mut yb = vec![-1i32; m.batch];
        yb[..y.len()].copy_from_slice(y);

        let t1 = Instant::now();
        let args = [
            self.buf_f32(weights, &[m.n_params])?,
            self.buf_f32(&xb, &[m.batch, m.input_dim])?,
            self.buf_i32(&yb, &[m.batch])?,
        ];
        let result = exe.execute_b(&args).map_err(|e| anyhow!("dense_grad execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("dense_grad d2h: {e}"))?;
        let (g, met) = lit.to_tuple2().map_err(|e| anyhow!("dense_grad tuple: {e}"))?;
        let grads = g.to_vec::<f32>().map_err(|e| anyhow!("grads d2h: {e}"))?;
        let metv = met.to_vec::<f32>().map_err(|e| anyhow!("met d2h: {e}"))?;
        self.timers.borrow_mut().add("dense_grad", t1.elapsed());
        Ok((grads, metv[0], metv[1]))
    }
}
