//! Bit-packed popcount compute tier for masked inference
//! (DESIGN.md §Compute-core, §Packed-tier).
//!
//! The paper's model *is* a binary mask over signed-constant weights:
//! within one layer every weight is `±scale` with a single per-layer
//! magnitude, so the masked forward contraction
//!
//! ```text
//! Σ_i w_i · m_i · x_i  =  scale · Σ_{i ∈ keep} ±x_i
//! ```
//!
//! is sign-select + accumulate, not general f32 GEMM. This module stores
//! each parameterized node as two bitplanes — `keep` (the mask) and
//! `neg` (the weight sign, a subset of `keep`) — and evaluates the
//! contraction by iterating set bits with `trailing_zeros()` /
//! `count_ones()` per 64-lane word, applying the magnitude once per
//! output in a scale epilogue. For the all-ones-activation case the
//! per-word contribution collapses to the popcount identity
//! `signed_popcount(keep, neg) = popcount(keep) − 2·popcount(keep & neg)`
//! (see [`signed_popcount`], which the tests pin against the float path).
//!
//! The blocked f32 kernels in [`super::kernels`] remain the default and
//! the bit-exact reference; the packed tier is an *eval-only* fast path
//! (`compute=packed`) that is numerically equivalent within f32
//! reassociation tolerance (`scale · Σ ±x` vs `Σ ±scale·x`). The STE
//! gradient always runs in float — training numerics never change.
//!
//! This module also hosts [`SimdTier`]: the runtime-detected
//! `std::arch` x86-64 SSE2/AVX2 dispatch used by the blocked GEMM
//! kernels. Every SIMD form preserves the documented
//! ascending-contraction accumulation order lanewise, so the f32 tier
//! is bit-identical to the scalar loops it replaces (no FMA — a lane is
//! one multiply then one add, exactly like the scalar form).
//!
//! `unsafe` here is confined to the `#[target_feature]` intrinsic
//! functions and their guarded call sites; `fedsrn audit` budgets this
//! file and requires a `SAFETY:` justification within 8 lines of every
//! occurrence.
//!
//! audit: deterministic

use anyhow::{bail, Result};

use crate::mask::layers::LayerSpec;
use crate::util::BitVec;

use super::graph::Plan;

/// Which forward implementation evaluation uses (`compute=` config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Compute {
    /// Blocked f32 kernels — the default and the reference path.
    #[default]
    Blocked,
    /// Bit-packed sign-select kernels for masked eval; falls back to
    /// blocked whenever the (mask, weights) pair is not packable.
    Packed,
}

impl Compute {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "blocked" => Compute::Blocked,
            "packed" => Compute::Packed,
            other => bail!("compute must be blocked | packed, got '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compute::Blocked => "blocked",
            Compute::Packed => "packed",
        }
    }
}

/// Runtime-detected SIMD capability for the f32 kernels.
///
/// Detection is a cached `std::arch` feature probe: `Avx2` on machines
/// with AVX2, otherwise `Sse2` on any x86-64 (SSE2 is baseline there),
/// and `Scalar` everywhere else. Every tier computes bit-identical
/// results — the enum only selects how many independent lanes run the
/// same mul-then-add per element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdTier {
    Scalar,
    Sse2,
    Avx2,
}

impl SimdTier {
    /// Probe the running CPU (cached by std after the first call).
    #[inline]
    pub fn detect() -> Self {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                SimdTier::Avx2
            } else {
                SimdTier::Sse2
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            SimdTier::Scalar
        }
    }

    // audit:no-alloc-begin
    /// `c[i] += a * b[i]` over `c.len()` elements — the saxpy inner loop
    /// of `gemm_nn`/`gemm_tn`. Lanes are independent and each element is
    /// one multiply then one add, so every tier is bit-identical.
    #[inline]
    pub fn axpy(self, a: f32, b: &[f32], c: &mut [f32]) {
        debug_assert!(b.len() >= c.len());
        match self {
            SimdTier::Scalar => axpy_scalar(a, b, c),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Sse2 tier is only produced by detect() on
            // x86-64, where SSE2 is an architectural baseline.
            SimdTier::Sse2 => unsafe { axpy_sse2(a, b, c) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the Avx2 tier is only produced by detect() after
            // is_x86_feature_detected!("avx2") returned true.
            SimdTier::Avx2 => unsafe { axpy_avx2(a, b, c) },
            #[cfg(not(target_arch = "x86_64"))]
            _ => axpy_scalar(a, b, c),
        }
    }

    /// Four simultaneous dot products `Σ_j g[j] * b_r[j]` (ascending
    /// `j`), the 4-column block of `gemm_nt`. Lane `r` accumulates its
    /// own chain in the scalar order, so the result is bit-identical to
    /// four scalar passes.
    #[inline]
    pub fn dot4(self, g: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
        debug_assert!(
            b0.len() >= g.len() && b1.len() >= g.len() && b2.len() >= g.len() && b3.len() >= g.len()
        );
        match self {
            SimdTier::Scalar => dot4_scalar(g, b0, b1, b2, b3),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: both tiers imply SSE2 (baseline on x86-64, and a
            // strict subset of AVX2); detect() never returns them
            // elsewhere. dot4 stays 4-wide on AVX2 machines on purpose:
            // widening would split each column's accumulation chain.
            SimdTier::Sse2 | SimdTier::Avx2 => unsafe { dot4_sse2(g, b0, b1, b2, b3) },
            #[cfg(not(target_arch = "x86_64"))]
            _ => dot4_scalar(g, b0, b1, b2, b3),
        }
    }
}

#[inline]
fn axpy_scalar(a: f32, b: &[f32], c: &mut [f32]) {
    for (cv, &bv) in c.iter_mut().zip(b) {
        *cv += a * bv;
    }
}

#[inline]
fn dot4_scalar(g: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let mut s = [0.0f32; 4];
    for (j, &gv) in g.iter().enumerate() {
        s[0] += gv * b0[j];
        s[1] += gv * b1[j];
        s[2] += gv * b2[j];
        s[3] += gv * b3[j];
    }
    s
}

/// 4-lane saxpy with a scalar tail; per-element math identical to
/// [`axpy_scalar`] (loadu/mul/add/storeu, no FMA).
// SAFETY: caller guarantees SSE2; loads/stores stay within the slices.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn axpy_sse2(a: f32, b: &[f32], c: &mut [f32]) {
    use std::arch::x86_64::{_mm_add_ps, _mm_loadu_ps, _mm_mul_ps, _mm_set1_ps, _mm_storeu_ps};
    let n = c.len();
    let av = _mm_set1_ps(a);
    let mut i = 0;
    while i + 4 <= n {
        let bv = _mm_loadu_ps(b.as_ptr().add(i));
        let cv = _mm_loadu_ps(c.as_ptr().add(i));
        _mm_storeu_ps(c.as_mut_ptr().add(i), _mm_add_ps(cv, _mm_mul_ps(av, bv)));
        i += 4;
    }
    while i < n {
        c[i] += a * b[i];
        i += 1;
    }
}

/// 8-lane saxpy with a scalar tail; per-element math identical to
/// [`axpy_scalar`] (loadu/mul/add/storeu, no FMA).
// SAFETY: caller guarantees AVX2; loads/stores stay within the slices.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(a: f32, b: &[f32], c: &mut [f32]) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };
    let n = c.len();
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i + 8 <= n {
        let bv = _mm256_loadu_ps(b.as_ptr().add(i));
        let cv = _mm256_loadu_ps(c.as_ptr().add(i));
        _mm256_storeu_ps(c.as_mut_ptr().add(i), _mm256_add_ps(cv, _mm256_mul_ps(av, bv)));
        i += 8;
    }
    while i < n {
        c[i] += a * b[i];
        i += 1;
    }
}

/// Four dot products, one per lane: lane `r` accumulates
/// `g[j] * b_r[j]` over ascending `j` — the same chain as the scalar
/// column loop, so the result is bit-identical to it.
// SAFETY: caller guarantees SSE2; all lane gathers are in-bounds reads.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot4_sse2(g: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    use std::arch::x86_64::{
        _mm_add_ps, _mm_mul_ps, _mm_set1_ps, _mm_set_ps, _mm_setzero_ps, _mm_storeu_ps,
    };
    let mut acc = _mm_setzero_ps();
    for (j, &gv) in g.iter().enumerate() {
        // _mm_set_ps lists lanes high-to-low: lane 0 carries b0.
        let bv = _mm_set_ps(b3[j], b2[j], b1[j], b0[j]);
        acc = _mm_add_ps(acc, _mm_mul_ps(_mm_set1_ps(gv), bv));
    }
    let mut out = [0.0f32; 4];
    _mm_storeu_ps(out.as_mut_ptr(), acc);
    out
}
// audit:no-alloc-end

/// `popcount(keep) − 2·popcount(keep & neg)`: the sum of the ±1 signs
/// selected by one word of the two bitplanes — the popcount identity
/// the packed kernel realizes when every activation is 1.
#[inline]
pub fn signed_popcount(keep: u64, neg: u64) -> i64 {
    keep.count_ones() as i64 - 2 * (keep & neg).count_ones() as i64
}

/// One parameterized node's weights × mask, packed as row-aligned
/// bitplanes over the output dimension.
///
/// Layout: contraction row `r` (dense input feature / conv patch
/// element) owns words `keep[r*wpr .. (r+1)*wpr]`, bit `j % 64` of word
/// `j / 64` standing for output lane `j`. Slack bits of each row's last
/// word are zero, so whole-word scans never need re-masking. `neg` is a
/// subset of `keep`: a set bit means the kept weight is `−scale`.
#[derive(Debug, Clone)]
pub struct PackedBlock {
    /// Contraction length (dense `k` / conv `patch()`).
    k: usize,
    /// Output lanes (dense `n` / conv `cout`).
    n: usize,
    /// Words per bitplane row: `ceil(n / 64)`.
    wpr: usize,
    /// The single weight magnitude of this block.
    scale: f32,
    keep: Vec<u64>,
    neg: Vec<u64>,
}

impl PackedBlock {
    /// Pack one `[k, n]` weight block against the global mask bits.
    /// Returns `None` unless every weight in the block has the same
    /// finite nonzero magnitude (the signed-constant contract).
    fn build(bits: &BitVec, w: &[f32], offset: usize, k: usize, n: usize) -> Option<Self> {
        let scale = w.first()?.abs();
        if !(scale.is_finite() && scale > 0.0) {
            return None;
        }
        let scale_bits = scale.to_bits();
        if w.iter().any(|v| v.abs().to_bits() != scale_bits) {
            return None;
        }
        let wpr = n.div_ceil(64);
        let mut keep = vec![0u64; k * wpr];
        let mut neg = vec![0u64; k * wpr];
        for r in 0..k {
            let krow = &mut keep[r * wpr..(r + 1) * wpr];
            copy_bits(bits.words(), offset + r * n, n, krow);
            for (wi, &kw) in krow.iter().enumerate() {
                let mut rest = kw;
                let mut nw = 0u64;
                while rest != 0 {
                    let bit = rest.trailing_zeros() as usize;
                    if w[r * n + wi * 64 + bit].is_sign_negative() {
                        nw |= 1 << bit;
                    }
                    rest &= rest - 1;
                }
                neg[r * wpr + wi] = nw;
            }
        }
        Some(Self { k, n, wpr, scale, keep, neg })
    }

    /// Output lanes (tests/benches).
    pub fn out_dim(&self) -> usize {
        self.n
    }

    /// The block's weight magnitude (tests/benches).
    pub fn scale(&self) -> f32 {
        self.scale
    }
}

/// Bitplane packing of a whole plan's (weights, mask) pair, indexed
/// parallel to `plan.nodes` (non-parameterized nodes hold `None`).
#[derive(Debug, Clone)]
pub struct PackedModel {
    blocks: Vec<Option<PackedBlock>>,
}

impl PackedModel {
    /// Pack `mask ⊙ weights` for every parameterized node of `plan`.
    ///
    /// Returns `None` — caller falls back to the blocked path — unless
    /// the inputs satisfy the packed contract: vectors cover exactly
    /// `plan.n_params`, the mask is strictly binary (every entry `0.0`
    /// or `1.0`; `-0.0` counts as zero, which is safe because the
    /// blocked kernels multiply it away identically), and each block's
    /// weights share one finite nonzero magnitude.
    pub fn try_build(plan: &Plan, weights: &[f32], mask_f32: &[f32]) -> Option<Self> {
        if weights.len() != plan.n_params || mask_f32.len() != plan.n_params {
            return None;
        }
        if !mask_f32.iter().all(|&m| m == 0.0 || m == 1.0) {
            return None;
        }
        let bits = BitVec::from_f32_threshold(mask_f32);
        let mut blocks = Vec::with_capacity(plan.nodes.len());
        for node in &plan.nodes {
            let kn = match node.spec {
                LayerSpec::Dense { k, n } => Some((k, n)),
                LayerSpec::Conv2d { .. } => {
                    let g = node.geom.expect("conv node carries geometry");
                    Some((g.patch(), g.cout))
                }
                _ => None,
            };
            match kn {
                Some((k, n)) => {
                    let w = &weights[node.offset..node.offset + k * n];
                    blocks.push(Some(PackedBlock::build(&bits, w, node.offset, k, n)?));
                }
                None => blocks.push(None),
            }
        }
        Some(Self { blocks })
    }

    /// The packed block for plan node `ni` (`None` for structural nodes).
    pub fn block(&self, ni: usize) -> Option<&PackedBlock> {
        self.blocks.get(ni).and_then(|b| b.as_ref())
    }
}

/// Copy `len` bits starting at absolute bit `start` of `src`
/// (little-endian bit order within each word) into `dst`, zeroing the
/// slack bits of the last destination word. `dst.len()` must be
/// `len.div_ceil(64)`.
fn copy_bits(src: &[u64], start: usize, len: usize, dst: &mut [u64]) {
    debug_assert_eq!(dst.len(), len.div_ceil(64));
    debug_assert!(src.len() * 64 >= start + len);
    let s = start % 64;
    for (d, out) in dst.iter_mut().enumerate() {
        let wi = start / 64 + d;
        // Shift counts of 64 are rejected by Rust, so the word-aligned
        // case must read directly instead of shifting by zero/64.
        *out = if s == 0 {
            src[wi]
        } else {
            (src[wi] >> s) | (src.get(wi + 1).copied().unwrap_or(0) << (64 - s))
        };
    }
    let rem = len % 64;
    if rem != 0 {
        if let Some(last) = dst.last_mut() {
            *last &= (1u64 << rem) - 1;
        }
    }
}

/// Left-operand rows processed per AVX2 pass (they share each word's
/// lane-mask expansion).
const PMR: usize = 4;

// audit:no-alloc-begin
/// `out[rows × n] = scale · Σ_kk ±a[i, kk]`, signs and lanes selected
/// by the bitplanes of `blk` — the packed replacement for
/// `out.fill(0); gemm_nn(a, w_eff, out, ..)` on a sign-select block.
///
/// Accumulation per output element runs over `kk` ascending with the
/// magnitude applied once in the epilogue, so the scalar and AVX2 forms
/// are bit-identical to each other (and equivalent to the blocked f32
/// reference within reassociation tolerance — `scale·Σ±x` vs `Σ±sx`).
pub fn packed_gemm(a: &[f32], blk: &PackedBlock, out: &mut [f32], rows: usize) {
    debug_assert!(a.len() >= rows * blk.k && out.len() >= rows * blk.n);
    let out = &mut out[..rows * blk.n];
    out.fill(0.0);
    let tier = SimdTier::detect();
    let mut i0 = 0;
    while i0 < rows {
        let rb = PMR.min(rows - i0);
        if rb == PMR && tier == SimdTier::Avx2 {
            packed_rows4(a, blk, out, i0);
        } else {
            packed_rows_scalar(a, blk, out, i0, rb);
        }
        i0 += rb;
    }
    for v in out.iter_mut() {
        *v *= blk.scale;
    }
}

/// Scalar sign-select accumulate for `rb` rows: iterate set bits of
/// each keep word (positives then negatives — each output lane is
/// touched at most once per `kk`, so intra-word order is free).
fn packed_rows_scalar(a: &[f32], blk: &PackedBlock, out: &mut [f32], i0: usize, rb: usize) {
    let (k, n, wpr) = (blk.k, blk.n, blk.wpr);
    for r in 0..rb {
        let i = i0 + r;
        let a_row = &a[i * k..i * k + k];
        let o_row = &mut out[i * n..i * n + n];
        for (kk, &v) in a_row.iter().enumerate() {
            // Post-ReLU activations are mostly zero: skipping them here
            // is bitwise-neutral because a +0.0-seeded accumulator can
            // never be -0.0 (see the kernels.rs zero-skip note).
            if v == 0.0 {
                continue;
            }
            let keep = &blk.keep[kk * wpr..kk * wpr + wpr];
            let neg = &blk.neg[kk * wpr..kk * wpr + wpr];
            for (wi, (&kw, &nw)) in keep.iter().zip(neg).enumerate() {
                if kw == 0 {
                    continue;
                }
                let base = wi * 64;
                let mut pos = kw & !nw;
                while pos != 0 {
                    o_row[base + pos.trailing_zeros() as usize] += v;
                    pos &= pos - 1;
                }
                let mut sub = kw & nw;
                while sub != 0 {
                    o_row[base + sub.trailing_zeros() as usize] -= v;
                    sub &= sub - 1;
                }
            }
        }
    }
}

/// Four-row AVX2 pass: the rows share each word's byte→lane-mask
/// expansion. Falls back to the scalar form off x86-64 (unreachable in
/// practice: the Avx2 tier is never detected there).
#[inline]
fn packed_rows4(a: &[f32], blk: &PackedBlock, out: &mut [f32], i0: usize) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: only reached when SimdTier::detect() returned Avx2, i.e.
    // after is_x86_feature_detected!("avx2") succeeded on this CPU.
    unsafe {
        packed_rows4_avx2(a, blk, out, i0)
    }
    #[cfg(not(target_arch = "x86_64"))]
    packed_rows_scalar(a, blk, out, i0, PMR);
}

/// Expand each keep/neg byte to eight 32-bit lane masks, then add
/// `±v` to the selected lanes of four output rows per load/store pair.
/// Per output element this is the same ascending-`kk`, once-per-`kk`
/// ±v accumulation as [`packed_rows_scalar`], hence bit-identical.
// SAFETY: caller guarantees AVX2. Vector loads/stores only touch
// chunks with `j0 + 8 <= n`, inside the `out` row; the row tail falls
// back to in-bounds scalar indexing.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn packed_rows4_avx2(a: &[f32], blk: &PackedBlock, out: &mut [f32], i0: usize) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_and_ps, _mm256_and_si256, _mm256_castsi256_ps, _mm256_cmpeq_epi32,
        _mm256_loadu_ps, _mm256_set1_epi32, _mm256_set1_ps, _mm256_set_epi32, _mm256_storeu_ps,
        _mm256_xor_ps,
    };
    let (k, n, wpr) = (blk.k, blk.n, blk.wpr);
    // lane L of bitsv carries 1 << L: comparing (byte & bitsv) == bitsv
    // expands a keep/neg byte into eight all-ones/all-zeros lane masks.
    let bitsv = _mm256_set_epi32(128, 64, 32, 16, 8, 4, 2, 1);
    let signv = _mm256_set1_epi32(i32::MIN);
    for kk in 0..k {
        let row = kk * wpr;
        for wi in 0..wpr {
            let kw = blk.keep[row + wi];
            if kw == 0 {
                continue;
            }
            let nw = blk.neg[row + wi];
            for c in 0..8usize {
                let j0 = wi * 64 + c * 8;
                if j0 >= n {
                    break;
                }
                let kb = (kw >> (c * 8)) & 0xFF;
                if kb == 0 {
                    continue;
                }
                if j0 + 8 <= n {
                    let km = _mm256_cmpeq_epi32(
                        _mm256_and_si256(_mm256_set1_epi32(kb as i32), bitsv),
                        bitsv,
                    );
                    let nb = (nw >> (c * 8)) & 0xFF;
                    let nm = _mm256_cmpeq_epi32(
                        _mm256_and_si256(_mm256_set1_epi32(nb as i32), bitsv),
                        bitsv,
                    );
                    // A kept lane contributes v with its sign bit
                    // flipped where neg is set; dropped lanes add +0.0,
                    // which is bitwise-neutral on a never--0.0 sum.
                    let flip = _mm256_castsi256_ps(_mm256_and_si256(nm, signv));
                    let keepm = _mm256_castsi256_ps(km);
                    for r in 0..PMR {
                        let v = a[(i0 + r) * k + kk];
                        if v == 0.0 {
                            continue;
                        }
                        let addend = _mm256_and_ps(_mm256_xor_ps(_mm256_set1_ps(v), flip), keepm);
                        let p = out.as_mut_ptr().add((i0 + r) * n + j0);
                        _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), addend));
                    }
                } else {
                    // Row tail (n % 8 lanes, e.g. 10-class logits):
                    // scalar bit loop so lanes past n are never touched.
                    let nb = (nw >> (c * 8)) & 0xFF;
                    for r in 0..PMR {
                        let v = a[(i0 + r) * k + kk];
                        if v == 0.0 {
                            continue;
                        }
                        let o = (i0 + r) * n + j0;
                        let mut rest = kb;
                        while rest != 0 {
                            let bit = rest.trailing_zeros() as usize;
                            if (nb >> bit) & 1 == 1 {
                                out[o + bit] -= v;
                            } else {
                                out[o + bit] += v;
                            }
                            rest &= rest - 1;
                        }
                    }
                }
            }
        }
    }
}
// audit:no-alloc-end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use crate::util::Xoshiro256;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..n).map(|_| rng.next_normal() as f32).collect()
    }

    /// Signed-constant weights: ±scale with a seeded sign pattern.
    fn sign_weights(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| if rng.next_u64() & 1 == 1 { -scale } else { scale })
            .collect()
    }

    fn rand_mask(n: usize, p: f64, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::new(seed);
        (0..n)
            .map(|_| if (rng.next_u64() as f64 / u64::MAX as f64) < p { 1.0 } else { 0.0 })
            .collect()
    }

    #[test]
    fn compute_parses_and_defaults_to_blocked() {
        assert_eq!(Compute::default(), Compute::Blocked);
        assert_eq!(Compute::parse("blocked").unwrap(), Compute::Blocked);
        assert_eq!(Compute::parse("Packed").unwrap(), Compute::Packed);
        assert!(Compute::parse("simd").is_err());
        assert_eq!(Compute::Packed.name(), "packed");
    }

    #[test]
    fn axpy_tiers_are_bitwise_identical() {
        let tier = SimdTier::detect();
        for n in [0, 1, 3, 4, 7, 8, 15, 64, 257] {
            let b = rand_vec(n, 10 + n as u64);
            let mut c_ref = rand_vec(n, 20 + n as u64);
            let mut c_simd = c_ref.clone();
            axpy_scalar(0.37, &b, &mut c_ref);
            tier.axpy(0.37, &b, &mut c_simd);
            assert_eq!(
                c_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                c_simd.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "n={n} tier={tier:?}"
            );
        }
    }

    #[test]
    fn dot4_tiers_are_bitwise_identical() {
        let tier = SimdTier::detect();
        for n in [1, 2, 5, 16, 33, 100] {
            let g = rand_vec(n, 30 + n as u64);
            let bs: Vec<Vec<f32>> = (0..4).map(|r| rand_vec(n, 40 + r + n as u64)).collect();
            let s_ref = dot4_scalar(&g, &bs[0], &bs[1], &bs[2], &bs[3]);
            let s_simd = tier.dot4(&g, &bs[0], &bs[1], &bs[2], &bs[3]);
            assert_eq!(
                s_ref.map(f32::to_bits),
                s_simd.map(f32::to_bits),
                "n={n} tier={tier:?}"
            );
        }
    }

    #[test]
    fn copy_bits_matches_per_bit_extraction() {
        let src: Vec<u64> = (0..6).map(|i| 0x9E37_79B9_7F4A_7C15u64.rotate_left(i * 11)).collect();
        for start in [0, 1, 63, 64, 65, 100, 127, 128] {
            for len in [1, 7, 63, 64, 65, 128, 200] {
                if start + len > src.len() * 64 {
                    continue;
                }
                let mut dst = vec![0u64; len.div_ceil(64)];
                copy_bits(&src, start, len, &mut dst);
                for j in 0..len {
                    let want = (src[(start + j) / 64] >> ((start + j) % 64)) & 1;
                    let got = (dst[j / 64] >> (j % 64)) & 1;
                    assert_eq!(got, want, "start={start} len={len} bit {j}");
                }
                // slack bits of the last word are zero
                let rem = len % 64;
                if rem != 0 {
                    assert_eq!(dst[len / 64] & !((1u64 << rem) - 1), 0, "slack start={start}");
                }
            }
        }
    }

    #[test]
    fn signed_popcount_identity() {
        let mut rng = Xoshiro256::new(99);
        for _ in 0..100 {
            let keep = rng.next_u64();
            let neg = rng.next_u64() & keep;
            let mut want = 0i64;
            for b in 0..64 {
                if (keep >> b) & 1 == 1 {
                    want += if (neg >> b) & 1 == 1 { -1 } else { 1 };
                }
            }
            assert_eq!(signed_popcount(keep, neg), want);
            // the docs' form of the identity: popcount(AND) over
            // positives = popcount(keep) - popcount(keep & neg)
            let pos = (keep & !neg).count_ones() as i64;
            assert_eq!(signed_popcount(keep, neg), 2 * pos - keep.count_ones() as i64);
        }
    }

    /// Dense reference: out = a · (mask ⊙ w) in full f64 (the packed
    /// path reassociates, so comparisons are tolerance-based).
    fn masked_gemm_ref(
        a: &[f32],
        w: &[f32],
        mask: &[f32],
        rows: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f64; rows * n];
        for i in 0..rows {
            for kk in 0..k {
                let av = a[i * k + kk] as f64;
                for j in 0..n {
                    out[i * n + j] += av * (w[kk * n + j] * mask[kk * n + j]) as f64;
                }
            }
        }
        out.iter().map(|&v| v as f32).collect()
    }

    #[test]
    fn packed_gemm_matches_masked_reference() {
        let (k, n) = (37, 70); // odd word/lane tails on purpose
        let w = sign_weights(k * n, 0.125, 5);
        for p in [0.0, 0.01, 0.5, 1.0] {
            for rows in [1, 3, 4, 5, 9] {
                let mask = rand_mask(k * n, p, 60 + (p * 100.0) as u64);
                let a = rand_vec(rows * k, 70 + rows as u64);
                let bits = BitVec::from_f32_threshold(&mask);
                let blk = PackedBlock::build(&bits, &w, 0, k, n).unwrap();
                let mut out = vec![7.0f32; rows * n];
                packed_gemm(&a, &blk, &mut out, rows);
                let want = masked_gemm_ref(&a, &w, &mask, rows, k, n);
                for (i, (&got, &exp)) in out.iter().zip(&want).enumerate() {
                    assert!(
                        (got - exp).abs() <= 1e-3 + 1e-3 * exp.abs(),
                        "p={p} rows={rows} out[{i}]: {got} vs {exp}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_gemm_rows_are_independent_of_blocking() {
        // The 4-row AVX2 pass (when detected) must be bit-identical to
        // the scalar path: evaluate 8 rows at once (vector blocks) and
        // one row at a time (always scalar), compare bitwise.
        let (k, n) = (29, 130);
        let w = sign_weights(k * n, 0.25, 8);
        let mask = rand_mask(k * n, 0.5, 9);
        let bits = BitVec::from_f32_threshold(&mask);
        let blk = PackedBlock::build(&bits, &w, 0, k, n).unwrap();
        let rows = 8;
        let mut a = rand_vec(rows * k, 10);
        // sprinkle zeros to exercise the skip in both paths
        for v in a.iter_mut().step_by(5) {
            *v = 0.0;
        }
        let mut all = vec![0.0f32; rows * n];
        packed_gemm(&a, &blk, &mut all, rows);
        for i in 0..rows {
            let mut one = vec![0.0f32; n];
            packed_gemm(&a[i * k..(i + 1) * k], &blk, &mut one, 1);
            assert_eq!(
                all[i * n..(i + 1) * n].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                one.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row {i}"
            );
        }
    }

    #[test]
    fn try_build_accepts_builtin_models() {
        for model in ["mlp_tiny", "conv_tiny"] {
            let man = Manifest::builtin(model).unwrap();
            let plan = Plan::build(&man).unwrap();
            let w = man.load_weights().unwrap();
            let mask = rand_mask(man.n_params, 0.5, 11);
            let pm = PackedModel::try_build(&plan, &w, &mask).expect("builtin packs");
            let packed_nodes = (0..plan.nodes.len()).filter(|&ni| pm.block(ni).is_some()).count();
            let param_nodes =
                plan.nodes.iter().filter(|nd| nd.spec.params() > 0).count();
            assert_eq!(packed_nodes, param_nodes, "{model}");
        }
    }

    #[test]
    fn try_build_rejects_unpackable_inputs() {
        let man = Manifest::builtin("mlp_tiny").unwrap();
        let plan = Plan::build(&man).unwrap();
        let w = man.load_weights().unwrap();
        let ones = vec![1.0f32; man.n_params];
        // wrong lengths
        assert!(PackedModel::try_build(&plan, &w[1..], &ones).is_none());
        assert!(PackedModel::try_build(&plan, &w, &ones[1..]).is_none());
        // non-binary mask (trained probabilities)
        let mut soft = ones.clone();
        soft[3] = 0.7;
        assert!(PackedModel::try_build(&plan, &w, &soft).is_none());
        // non-constant magnitudes (trained dense weights)
        let mut trained = w.clone();
        trained[0] *= 1.5;
        assert!(PackedModel::try_build(&plan, &trained, &ones).is_none());
        // zero / non-finite magnitude
        let zeros = vec![0.0f32; man.n_params];
        let nans = vec![f32::NAN; man.n_params];
        assert!(PackedModel::try_build(&plan, &zeros, &ones).is_none());
        assert!(PackedModel::try_build(&plan, &nans, &ones).is_none());
        // -0.0 mask entries count as zero, not as a reject
        let mut mz = ones;
        mz[5] = -0.0;
        let pm = PackedModel::try_build(&plan, &w, &mz).expect("-0.0 is a valid zero");
        assert!(pm.block(0).is_some());
    }

    #[test]
    fn packed_block_reports_scale_and_dims() {
        let w = sign_weights(8 * 64, 0.5, 12);
        let mask = vec![1.0f32; 8 * 64];
        let bits = BitVec::from_f32_threshold(&mask);
        let blk = PackedBlock::build(&bits, &w, 0, 8, 64).unwrap();
        assert_eq!(blk.out_dim(), 64);
        assert_eq!(blk.scale(), 0.5);
        // all-ones mask at p=1: every keep word of a full row is !0
        assert!(blk.keep.iter().all(|&kw| kw == u64::MAX));
    }
}
