//! PJRT backend: load AOT artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate (PJRT C API) exactly the way the production hot
//! path needs it:
//!   HLO text --parse--> HloModuleProto --compile--> PjRtLoadedExecutable
//! with the frozen weight vector staged on-device once per model and
//! reused across every client call of every round (weights never change
//! in the strong-LTH setting — re-uploading them per call would dominate
//! the round loop).
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Only compiled with `--features pjrt` (DESIGN.md §Substitutions): the
//! default build runs the pure-Rust [`super::native`] backend instead,
//! so the coordinator is testable on machines without an XLA toolchain.

use std::path::Path;

use anyhow::{anyhow, ensure, Result};
// Swap this line to `use xla::{...};` when the real bindings are
// vendored (see runtime/xla_stub.rs for the linking instructions). The
// stub carries the identical API surface so `--features pjrt` always
// compiles — the CI gate for this backend.
use super::xla_stub::{
    HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation,
};

use super::artifacts::Manifest;
use super::{EvalMetrics, TrainMetrics};

/// Compiled executables + device-resident weights for one model.
pub struct PjrtBackend {
    client: PjRtClient,
    local_train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    dense_grad: Option<PjRtLoadedExecutable>,
    /// Device copy reused across all masked-path calls.
    weights_dev: PjRtBuffer,
}

// SAFETY: every handle in PjrtBackend (client, loaded executables,
// staged buffer) is an owned pointer into the PJRT runtime, which the
// PJRT C API contract allows to be *used from* any thread — handles
// carry no thread-affine state, so moving the struct to another thread
// cannot violate an API precondition.
unsafe impl Send for PjrtBackend {}

// SAFETY: all shared access goes through `&self` methods, and the PJRT
// runtime synchronizes those entry points internally: executions on one
// loaded executable are serialized by the runtime, host-to-device
// transfers are independent, and the staged weight buffer is immutable
// after creation. Concurrent `&self` calls (the parallel round engine's
// worker threads) therefore cannot race on the underlying objects.
unsafe impl Sync for PjrtBackend {}

fn compile_hlo(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
    let comp = XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {path:?}: {e}"))
}

impl PjrtBackend {
    /// Compile the manifest's programs on a fresh CPU PJRT client and
    /// stage `weights` on the device.
    pub fn load(manifest: &Manifest, weights: &[f32]) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
        let local_train = compile_hlo(&client, &manifest.local_train_file)?;
        let eval = compile_hlo(&client, &manifest.eval_file)?;
        let dense_grad = match &manifest.dense_grad_file {
            Some(p) => Some(compile_hlo(&client, p)?),
            None => None,
        };
        let weights_dev = client
            .buffer_from_host_buffer(weights, &[weights.len()], None)
            .map_err(|e| anyhow!("staging weights: {e}"))?;
        Ok(Self { client, local_train, eval, dense_grad, weights_dev })
    }

    pub fn has_dense_grad(&self) -> bool {
        self.dense_grad.is_some()
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device f32 transfer: {e}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device i32 transfer: {e}"))
    }

    fn scalar_f32(&self, v: f32) -> Result<PjRtBuffer> {
        self.buf_f32(&[v], &[])
    }

    fn scalar_i32(&self, v: i32) -> Result<PjRtBuffer> {
        self.buf_i32(&[v], &[])
    }

    /// One client local phase: `steps` minibatches of STE-SGD.
    #[allow(clippy::too_many_arguments)]
    pub fn local_train(
        &self,
        man: &Manifest,
        scores: &[f32],
        xs: &[f32],
        ys: &[i32],
        seed: i32,
        lambda: f32,
        lr: f32,
        deterministic: bool,
        adam: bool,
    ) -> Result<(Vec<f32>, TrainMetrics)> {
        let scores_b = self.buf_f32(scores, &[man.n_params])?;
        let xs_b = self.buf_f32(xs, &[man.steps, man.batch, man.input_dim])?;
        let ys_b = self.buf_i32(ys, &[man.steps, man.batch])?;
        let seed_b = self.scalar_i32(seed)?;
        let lam_b = self.scalar_f32(lambda)?;
        let lr_b = self.scalar_f32(lr)?;
        let det_b = self.scalar_f32(if deterministic { 1.0 } else { 0.0 })?;
        let opt_b = self.scalar_f32(if adam { 1.0 } else { 0.0 })?;
        // weights stay device-resident for the whole run: pass by ref.
        let args: [&PjRtBuffer; 9] = [
            &scores_b,
            &self.weights_dev,
            &xs_b,
            &ys_b,
            &seed_b,
            &lam_b,
            &lr_b,
            &det_b,
            &opt_b,
        ];
        let result = self
            .local_train
            .execute_b(&args)
            .map_err(|e| anyhow!("local_train execute: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("local_train d2h: {e}"))?;
        let (s_out, metrics) =
            tuple.to_tuple2().map_err(|e| anyhow!("local_train tuple: {e}"))?;
        let new_scores = s_out.to_vec::<f32>().map_err(|e| anyhow!("scores d2h: {e}"))?;
        let met = metrics.to_vec::<f32>().map_err(|e| anyhow!("metrics d2h: {e}"))?;
        ensure!(met.len() == 4, "expected 4 metrics");
        Ok((
            new_scores,
            TrainMetrics {
                mean_loss: met[0],
                correct: met[1],
                sum_sigma: met[2],
                active: met[3],
            },
        ))
    }

    /// One padded eval chunk: exactly `eval_chunk` rows (y = -1 padding).
    /// Returns (correct, loss_sum) over the valid rows.
    pub fn eval_chunk(
        &self,
        man: &Manifest,
        mask_f32: &[f32],
        weights: Option<&[f32]>,
        xc: &[f32],
        yc: &[i32],
    ) -> Result<(f64, f64)> {
        let t = man.eval_chunk;
        let mask_b = self.buf_f32(mask_f32, &[man.n_params])?;
        let x_b = self.buf_f32(xc, &[t, man.input_dim])?;
        let y_b = self.buf_i32(yc, &[t])?;
        let w_b;
        let weights_ref = match weights {
            Some(w) => {
                w_b = self.buf_f32(w, &[man.n_params])?;
                &w_b
            }
            None => &self.weights_dev,
        };
        let args: [&PjRtBuffer; 4] = [&mask_b, weights_ref, &x_b, &y_b];
        let result = self.eval.execute_b(&args).map_err(|e| anyhow!("eval execute: {e}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("eval d2h: {e}"))?;
        let inner = lit.to_tuple1().map_err(|e| anyhow!("eval tuple: {e}"))?;
        let v = inner.to_vec::<f32>().map_err(|e| anyhow!("eval vec: {e}"))?;
        Ok((v[0] as f64, v[1] as f64))
    }

    /// Dense forward/backward for the SignSGD / FedAvg baselines.
    /// Inputs are pre-padded to the exported batch (y = -1 padding).
    pub fn dense_grad(
        &self,
        man: &Manifest,
        weights: &[f32],
        xb: &[f32],
        yb: &[i32],
    ) -> Result<(Vec<f32>, f32, f32)> {
        let exe = self
            .dense_grad
            .as_ref()
            .ok_or_else(|| anyhow!("model {} exported without dense_grad", man.model))?;
        let args = [
            self.buf_f32(weights, &[man.n_params])?,
            self.buf_f32(xb, &[man.batch, man.input_dim])?,
            self.buf_i32(yb, &[man.batch])?,
        ];
        let result = exe.execute_b(&args).map_err(|e| anyhow!("dense_grad execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("dense_grad d2h: {e}"))?;
        let (g, met) = lit.to_tuple2().map_err(|e| anyhow!("dense_grad tuple: {e}"))?;
        let grads = g.to_vec::<f32>().map_err(|e| anyhow!("grads d2h: {e}"))?;
        let metv = met.to_vec::<f32>().map_err(|e| anyhow!("met d2h: {e}"))?;
        Ok((grads, metv[0], metv[1]))
    }

    /// Evaluate metrics over already-padded rows — helper for the facade.
    pub fn eval_padded(
        &self,
        man: &Manifest,
        mask_f32: &[f32],
        weights: Option<&[f32]>,
        x: &[f32],
        y: &[i32],
    ) -> Result<EvalMetrics> {
        let t = man.eval_chunk;
        // Count only valid rows (y >= 0), matching the native backend:
        // padding must not inflate accuracy/mean_loss denominators.
        let valid = y.iter().filter(|&&v| v >= 0).count();
        let mut out = EvalMetrics { examples: valid, ..Default::default() };
        let mut xc = vec![0.0f32; t * man.input_dim];
        let mut yc = vec![-1i32; t];
        let mut start = 0;
        while start < y.len() {
            let take = (y.len() - start).min(t);
            xc[..take * man.input_dim]
                .copy_from_slice(&x[start * man.input_dim..(start + take) * man.input_dim]);
            xc[take * man.input_dim..].iter_mut().for_each(|v| *v = 0.0);
            yc[..take].copy_from_slice(&y[start..start + take]);
            yc[take..].iter_mut().for_each(|v| *v = -1);
            let (correct, loss_sum) = self.eval_chunk(man, mask_f32, weights, &xc, &yc)?;
            out.correct += correct;
            out.loss_sum += loss_sum;
            start += take;
        }
        Ok(out)
    }
}
