//! API-compatible stub for the `xla` PJRT bindings crate.
//!
//! The real bindings (xla_extension 0.5.x) are a *path* dependency that
//! cannot live on crates.io, so an offline checkout cannot resolve it —
//! which used to mean `--features pjrt` did not even compile and the
//! backend had no CI gate at all. This module mirrors exactly the slice
//! of the `xla` API that [`super::pjrt`] uses, with every fallible call
//! returning a "runtime not linked" error; `cargo check --features
//! pjrt` now type-checks the whole backend on any machine.
//!
//! To execute real HLO: vendor the bindings, uncomment the `xla` path
//! dependency in `rust/Cargo.toml`, and swap the one `use` line at the
//! top of `runtime/pjrt.rs` from `super::xla_stub` to `xla`. Nothing
//! else changes — the signatures below are the contract.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

const NOT_LINKED: &str = "PJRT runtime not linked: this build compiled the `pjrt` feature \
     against the API stub (runtime/xla_stub.rs); vendor the `xla` bindings \
     crate and swap the import in runtime/pjrt.rs to execute HLO";

/// Error type standing in for `xla::Error` (Display only — the backend
/// wraps everything in `anyhow` immediately).
#[derive(Debug)]
pub struct XlaError;

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(NOT_LINKED)
    }
}

impl std::error::Error for XlaError {}

type XlaResult<T> = Result<T, XlaError>;

/// Stub for `xla::PjRtClient`.
pub struct PjRtClient;

/// Stub for `xla::PjRtBuffer` (device-resident array).
pub struct PjRtBuffer;

/// Stub for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

/// Stub for `xla::HloModuleProto`.
pub struct HloModuleProto;

/// Stub for `xla::XlaComputation`.
pub struct XlaComputation;

/// Stub for `xla::Literal` (host-side array, possibly a tuple).
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> XlaResult<Self> {
        Err(XlaError)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(XlaError)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> XlaResult<PjRtBuffer> {
        Err(XlaError)
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> XlaResult<Self> {
        Err(XlaError)
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError)
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(XlaError)
    }
}

impl Literal {
    pub fn to_tuple1(self) -> XlaResult<Literal> {
        Err(XlaError)
    }

    pub fn to_tuple2(self) -> XlaResult<(Literal, Literal)> {
        Err(XlaError)
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(XlaError)
    }
}
