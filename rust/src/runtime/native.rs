//! Native backend: the L2 programs re-implemented in pure Rust.
//!
//! Mirrors `python/compile/model.py` for the MLP model family — masked
//! STE local training (paper eq. 5-7 + eq. 12), masked evaluation and
//! the dense forward/backward used by the baselines — with no Python,
//! XLA or artifact dependency. This is the default execution backend
//! (DESIGN.md §Substitutions): the AOT/PJRT path compiles the exact same
//! math from the JAX source when the `pjrt` feature is enabled, and the
//! conv models only exist there.
//!
//! Semantics held in common with the Pallas kernels (see
//! `python/compile/kernels/ref.py`):
//!     theta = sigmoid(s)            per-parameter keep probability
//!     m     = 1[u < theta]          sampled mask, u ~ U[0,1)
//!     y     = x @ (m * w)           masked affine transform
//!     ds    = (x^T g) * w * sigmoid'(s)      (straight-through)
//!
//! Everything is `&self`: the backend is freely shared across the worker
//! threads of the parallel round engine (DESIGN.md §Parallel round
//! engine). Per-step Bernoulli draws come from counter-based Philox
//! streams keyed by a [`SeedSequence`] path, so results depend only on
//! the call's seed — never on thread count or call order.

use anyhow::{ensure, Result};

use crate::mask::layers::LayerSlice;
use crate::util::{sigmoid, SeedSequence};

use super::artifacts::Manifest;
use super::{EvalMetrics, TrainMetrics};

/// One dense layer's slice of the flat parameter vector.
#[derive(Debug, Clone, Copy)]
struct Layer {
    /// Input width K.
    k: usize,
    /// Output width N.
    n: usize,
    /// Offset into the flat vector (row-major K x N).
    offset: usize,
}

/// Pure-Rust MLP executor over the manifest's flat parameter layout.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    layers: Vec<Layer>,
    n_params: usize,
    input_dim: usize,
    n_classes: usize,
}

impl NativeBackend {
    /// Build from a manifest's `layers=` layout (artifact or built-in).
    pub fn from_manifest(man: &Manifest) -> Result<Self> {
        ensure!(
            !man.layers.is_empty(),
            "model '{}' has no layer layout in its manifest; the native \
             backend needs one (re-export artifacts, or build with \
             --features pjrt to run the compiled HLO instead)",
            man.model
        );
        let layers: Vec<Layer> = man
            .layers
            .iter()
            .map(|l: &LayerSlice| Layer { k: l.rows, n: l.cols, offset: l.offset })
            .collect();
        ensure!(layers[0].k == man.input_dim, "first layer width != input_dim");
        for w in layers.windows(2) {
            ensure!(w[0].n == w[1].k, "layer widths must chain (MLP layout)");
        }
        let last = layers.last().unwrap();
        ensure!(last.n == man.n_classes, "last layer width != n_classes");
        let total: usize = layers.iter().map(|l| l.k * l.n).sum();
        ensure!(total == man.n_params, "layer layout does not cover n_params");
        Ok(Self {
            layers,
            n_params: man.n_params,
            input_dim: man.input_dim,
            n_classes: man.n_classes,
        })
    }

    /// Forward through effective weights `w_eff` for `rows` inputs.
    /// Returns one output per layer (`outs[L-1]` is the logits); hidden
    /// outputs carry ReLU already applied. The input is read in place —
    /// never copied — so eval over large test sets costs no extra
    /// input-sized allocation.
    fn forward(&self, w_eff: &[f32], x: &[f32], rows: usize) -> Vec<Vec<f32>> {
        let n_layers = self.layers.len();
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
        for (li, layer) in self.layers.iter().enumerate() {
            let a: &[f32] = if li == 0 { x } else { &outs[li - 1] };
            let mut z = vec![0.0f32; rows * layer.n];
            for b in 0..rows {
                let arow = &a[b * layer.k..(b + 1) * layer.k];
                let zrow = &mut z[b * layer.n..(b + 1) * layer.n];
                for (k, &av) in arow.iter().enumerate() {
                    if av != 0.0 {
                        let wrow = &w_eff[layer.offset + k * layer.n..][..layer.n];
                        for (zv, &wv) in zrow.iter_mut().zip(wrow) {
                            *zv += av * wv;
                        }
                    }
                }
            }
            if li + 1 < n_layers {
                z.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            outs.push(z);
        }
        outs
    }

    /// Per-row stable log-softmax CE + correctness on `logits`.
    /// Rows with y < 0 are padding and contribute nothing.
    /// Returns (loss_sum, correct, valid_rows).
    fn ce_stats(&self, logits: &[f32], y: &[i32]) -> (f64, f64, usize) {
        let c = self.n_classes;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut valid = 0usize;
        for (b, &yb) in y.iter().enumerate() {
            if yb < 0 {
                continue;
            }
            valid += 1;
            let row = &logits[b * c..(b + 1) * c];
            let (mut amax, mut imax) = (f32::NEG_INFINITY, 0);
            for (i, &v) in row.iter().enumerate() {
                if v > amax {
                    amax = v;
                    imax = i;
                }
            }
            let lse =
                amax + row.iter().map(|&v| (v - amax).exp()).sum::<f32>().ln();
            loss_sum += (lse - row[yb as usize]) as f64;
            if imax == yb as usize {
                correct += 1.0;
            }
        }
        (loss_sum, correct, valid)
    }

    /// dL/dlogits for mean-CE over the valid rows: (softmax - onehot) / denom.
    fn logit_grad(&self, logits: &[f32], y: &[i32], denom: f32) -> Vec<f32> {
        let c = self.n_classes;
        let mut g = vec![0.0f32; logits.len()];
        for (b, &yb) in y.iter().enumerate() {
            if yb < 0 {
                continue;
            }
            let row = &logits[b * c..(b + 1) * c];
            let grow = &mut g[b * c..(b + 1) * c];
            let amax = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for (gv, &v) in grow.iter_mut().zip(row) {
                *gv = (v - amax).exp();
                sum += *gv;
            }
            let inv = 1.0 / (sum * denom);
            for gv in grow.iter_mut() {
                *gv *= inv;
            }
            grow[yb as usize] -= 1.0 / denom;
        }
        g
    }

    /// Backprop `g_logits` through a forward pass's layer outputs,
    /// producing the gradient w.r.t. the (effective) flat weight vector.
    /// `x` is the original input (layer 0's activations).
    fn backward_weights(
        &self,
        x: &[f32],
        outs: &[Vec<f32>],
        w_eff: &[f32],
        g_logits: Vec<f32>,
        rows: usize,
    ) -> Vec<f32> {
        let mut dw = vec![0.0f32; self.n_params];
        let mut g = g_logits;
        for li in (0..self.layers.len()).rev() {
            let layer = self.layers[li];
            let a: &[f32] = if li == 0 { x } else { &outs[li - 1] };
            // dW = a^T g
            for b in 0..rows {
                let arow = &a[b * layer.k..(b + 1) * layer.k];
                let grow = &g[b * layer.n..(b + 1) * layer.n];
                for (k, &av) in arow.iter().enumerate() {
                    if av != 0.0 {
                        let drow = &mut dw[layer.offset + k * layer.n..][..layer.n];
                        for (dv, &gv) in drow.iter_mut().zip(grow) {
                            *dv += av * gv;
                        }
                    }
                }
            }
            if li == 0 {
                break;
            }
            // g_prev = (g @ W^T) ⊙ relu'(z_{l-1});  relu' == (a > 0)
            let mut gprev = vec![0.0f32; rows * layer.k];
            for b in 0..rows {
                let arow = &a[b * layer.k..(b + 1) * layer.k];
                let grow = &g[b * layer.n..(b + 1) * layer.n];
                let prow = &mut gprev[b * layer.k..(b + 1) * layer.k];
                for (k, pv) in prow.iter_mut().enumerate() {
                    if arow[k] > 0.0 {
                        let wrow = &w_eff[layer.offset + k * layer.n..][..layer.n];
                        let mut s = 0.0f32;
                        for (&gv, &wv) in grow.iter().zip(wrow) {
                            s += gv * wv;
                        }
                        *pv = s;
                    }
                }
            }
            g = gprev;
        }
        dw
    }

    /// One client local phase: `steps` minibatches of STE training on
    /// the score vector (mirrors `model.make_local_train`).
    #[allow(clippy::too_many_arguments)]
    pub fn local_train(
        &self,
        man: &Manifest,
        weights: &[f32],
        scores: &[f32],
        xs: &[f32],
        ys: &[i32],
        seed: i32,
        lambda: f32,
        lr: f32,
        deterministic: bool,
        adam: bool,
    ) -> Result<(Vec<f32>, TrainMetrics)> {
        let n = self.n_params;
        let (batch, steps) = (man.batch, man.steps);
        let root = SeedSequence::new(seed as u32 as u64);
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);

        let mut s = scores.to_vec();
        let mut m1 = vec![0.0f32; n];
        let mut v2 = vec![0.0f32; n];
        let mut u = vec![0.5f32; n];
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f32;

        for h in 0..steps {
            if !deterministic {
                root.child(h as u64).philox().fill_uniform(0, &mut u);
            }
            // m = 1[u < sigmoid(s)], w_eff = m * w
            let mut w_eff = vec![0.0f32; n];
            let mut sum_sigma_step = 0.0f64;
            for j in 0..n {
                let th = sigmoid(s[j]);
                sum_sigma_step += th as f64;
                if u[j] < th {
                    w_eff[j] = weights[j];
                }
            }
            let x = &xs[h * batch * self.input_dim..(h + 1) * batch * self.input_dim];
            let y = &ys[h * batch..(h + 1) * batch];
            let acts = self.forward(&w_eff, x, batch);
            let logits = acts.last().unwrap();
            let (ce_sum, corr, valid) = self.ce_stats(logits, y);
            let denom = valid.max(1) as f32;
            loss_sum += ce_sum / denom as f64
                + (lambda as f64) * sum_sigma_step / n as f64;
            correct += corr as f32;
            let g_logits = self.logit_grad(logits, y, denom);
            let dw = self.backward_weights(x, &acts, &w_eff, g_logits, batch);
            // STE to scores + regularizer gradient, then Adam/SGD step.
            let t = (h + 1) as f32;
            let bc1 = 1.0 - b1.powf(t);
            let bc2 = 1.0 - b2.powf(t);
            for j in 0..n {
                let th = sigmoid(s[j]);
                let dsig = th * (1.0 - th);
                let g = dw[j] * weights[j] * dsig + (lambda / n as f32) * dsig;
                let step = if adam {
                    m1[j] = b1 * m1[j] + (1.0 - b1) * g;
                    v2[j] = b2 * v2[j] + (1.0 - b2) * g * g;
                    (m1[j] / bc1) / ((v2[j] / bc2).sqrt() + eps)
                } else {
                    g
                };
                s[j] -= lr * step;
            }
        }

        // Final sparsity stats on the updated scores.
        let mut u_fin = vec![0.5f32; n];
        if !deterministic {
            root.child(0x5EED).philox().fill_uniform(0, &mut u_fin);
        }
        let mut sum_sigma = 0.0f32;
        let mut active = 0.0f32;
        for j in 0..n {
            let th = sigmoid(s[j]);
            sum_sigma += th;
            if u_fin[j] < th {
                active += 1.0;
            }
        }
        Ok((
            s,
            TrainMetrics {
                mean_loss: (loss_sum / steps.max(1) as f64) as f32,
                correct,
                sum_sigma,
                active,
            },
        ))
    }

    /// Masked evaluation over arbitrary-size inputs (y < 0 rows are
    /// padding and ignored, as in the exported eval program). Processed
    /// in row chunks so peak activation memory is bounded regardless of
    /// test-set size.
    pub fn eval_mask(
        &self,
        mask_f32: &[f32],
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<EvalMetrics> {
        const CHUNK_ROWS: usize = 1024;
        let rows = y.len();
        let w_eff: Vec<f32> =
            mask_f32.iter().zip(weights).map(|(&m, &w)| m * w).collect();
        let mut out = EvalMetrics { examples: rows, ..Default::default() };
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(CHUNK_ROWS);
            let xc = &x[start * self.input_dim..(start + take) * self.input_dim];
            let outs = self.forward(&w_eff, xc, take);
            let (loss_sum, correct, _valid) =
                self.ce_stats(outs.last().unwrap(), &y[start..start + take]);
            out.loss_sum += loss_sum;
            out.correct += correct;
            start += take;
        }
        Ok(out)
    }

    /// Dense forward/backward (SignSGD / FedAvg). `y.len()` rows, no
    /// padding needed natively. Returns (grads, mean loss, correct).
    pub fn dense_grad(
        &self,
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<f32>, f32, f32)> {
        let rows = y.len();
        let acts = self.forward(weights, x, rows);
        let logits = acts.last().unwrap();
        let (loss_sum, correct, valid) = self.ce_stats(logits, y);
        let denom = valid.max(1) as f32;
        let g_logits = self.logit_grad(logits, y, denom);
        let grads = self.backward_weights(x, &acts, weights, g_logits, rows);
        Ok((grads, (loss_sum / denom as f64) as f32, correct as f32))
    }
}
