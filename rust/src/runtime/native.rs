//! Native backend: the L2 programs re-implemented in pure Rust.
//!
//! Mirrors `python/compile/model.py` for the built-in model zoo — masked
//! STE local training (paper eq. 5-7 + eq. 12), masked evaluation and
//! the dense forward/backward used by the baselines — with no Python,
//! XLA or artifact dependency. This is the default execution backend
//! (DESIGN.md §Substitutions) and it executes the full layer-graph
//! model family: chained MLPs *and* the conv stacks (conv_tiny / conv4
//! / conv6) via the compiled [`Plan`] + blocked kernels in
//! `runtime/graph.rs` / `runtime/kernels.rs` (DESIGN.md §Compute-core).
//!
//! Semantics held in common with the Pallas kernels (see
//! `python/compile/kernels/ref.py`):
//!     theta = sigmoid(s)            per-parameter keep probability
//!     m     = 1[u < theta]          sampled mask, u ~ U[0,1)
//!     y     = f(x; m * w)           masked layer-graph forward
//!     ds    = dL/dw_eff * w * sigmoid'(s)     (straight-through)
//!
//! The masked-STE inner loop performs **zero heap allocation per step**:
//! all activation/gradient/scratch buffers live in a [`Workspace`]
//! allocated once per `local_train` call, and sigmoid(s) is computed
//! once per step into a reused buffer shared by the mask draw and the
//! score update.
//!
//! Everything is `&self`: the backend is freely shared across the worker
//! threads of the parallel round engine (DESIGN.md §Parallel round
//! engine). Per-step Bernoulli draws come from counter-based Philox
//! streams keyed by a [`SeedSequence`] path, so results depend only on
//! the call's seed — never on thread count or call order.

use anyhow::Result;

use crate::util::{sigmoid, SeedSequence};

use super::artifacts::Manifest;
use super::graph::{Plan, Workspace};
use super::kernels::{softmax_xent_grad, softmax_xent_stats};
use super::packed::{Compute, PackedModel};
use super::{EvalMetrics, TrainMetrics};

/// Reserved [`SeedSequence`] child tag for the end-of-call sparsity
/// probe. Per-step Bernoulli streams use `root.child(h)` with `h` a
/// step index, so the probe must live outside every reachable step
/// index — a `local_train` call can never run `u64::MAX` steps. (The
/// seed's `child(0x5EED)` probe collided with step 0x5EED whenever a
/// call ran more than 23277 steps.)
pub const SPARSITY_PROBE_CHILD: u64 = u64::MAX;

/// Pure-Rust layer-graph executor over the manifest's flat parameters.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    plan: Plan,
    n_params: usize,
    input_dim: usize,
    n_classes: usize,
}

impl NativeBackend {
    /// Compile the manifest's `layers=` layout (artifact or built-in)
    /// into an execution plan.
    pub fn from_manifest(man: &Manifest) -> Result<Self> {
        let plan = Plan::build(man)?;
        Ok(Self {
            plan,
            n_params: man.n_params,
            input_dim: man.input_dim,
            n_classes: man.n_classes,
        })
    }

    /// The compiled execution plan (tests / benches).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// One client local phase: `steps` minibatches of STE training on
    /// the score vector (mirrors `model.make_local_train`).
    #[allow(clippy::too_many_arguments)]
    pub fn local_train(
        &self,
        man: &Manifest,
        weights: &[f32],
        scores: &[f32],
        xs: &[f32],
        ys: &[i32],
        seed: i32,
        lambda: f32,
        lr: f32,
        deterministic: bool,
        adam: bool,
    ) -> Result<(Vec<f32>, TrainMetrics)> {
        let n = self.n_params;
        let (batch, steps) = (man.batch, man.steps);
        let root = SeedSequence::new(seed as u32 as u64);
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);

        // Everything the step loop touches is allocated here, once.
        let mut ws = Workspace::for_train(&self.plan, batch);
        let mut s = scores.to_vec();
        let mut th = vec![0.0f32; n]; // sigmoid(s), shared mask/update
        let mut w_eff = vec![0.0f32; n];
        let mut dw = vec![0.0f32; n];
        let mut m1 = vec![0.0f32; n];
        let mut v2 = vec![0.0f32; n];
        let mut u = vec![0.5f32; n];
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f32;
        let logits_buf = self.plan.logits_buf();

        for h in 0..steps {
            if !deterministic {
                root.child(h as u64).philox().fill_uniform(0, &mut u);
            }
            // theta = sigmoid(s) once per step; m = 1[u < theta];
            // w_eff = m * w — one fused pass.
            let mut sum_sigma_step = 0.0f64;
            for j in 0..n {
                let t = sigmoid(s[j]);
                th[j] = t;
                sum_sigma_step += t as f64;
                w_eff[j] = if u[j] < t { weights[j] } else { 0.0 };
            }
            let x = &xs[h * batch * self.input_dim..(h + 1) * batch * self.input_dim];
            let y = &ys[h * batch..(h + 1) * batch];
            self.plan.forward(&w_eff, x, batch, &mut ws);
            let logits = &ws.acts[logits_buf][..batch * self.n_classes];
            let (ce_sum, corr, valid) = softmax_xent_stats(logits, y, self.n_classes);
            let denom = valid.max(1) as f32;
            loss_sum += ce_sum / denom as f64
                + (lambda as f64) * sum_sigma_step / n as f64;
            correct += corr as f32;
            {
                let (acts, grads) = (&ws.acts, &mut ws.grads);
                softmax_xent_grad(
                    &acts[logits_buf][..batch * self.n_classes],
                    y,
                    self.n_classes,
                    denom,
                    &mut grads[logits_buf][..batch * self.n_classes],
                );
            }
            dw.fill(0.0);
            self.plan.backward(&w_eff, x, batch, &mut ws, &mut dw);
            // STE to scores + regularizer gradient, then Adam/SGD step,
            // reusing the step's sigmoid values.
            let t = (h + 1) as f32;
            let bc1 = 1.0 - b1.powf(t);
            let bc2 = 1.0 - b2.powf(t);
            for j in 0..n {
                let dsig = th[j] * (1.0 - th[j]);
                let g = dw[j] * weights[j] * dsig + (lambda / n as f32) * dsig;
                let step = if adam {
                    m1[j] = b1 * m1[j] + (1.0 - b1) * g;
                    v2[j] = b2 * v2[j] + (1.0 - b2) * g * g;
                    (m1[j] / bc1) / ((v2[j] / bc2).sqrt() + eps)
                } else {
                    g
                };
                s[j] -= lr * step;
            }
        }

        // Final sparsity stats on the updated scores, from the reserved
        // probe stream (domain-separated from every per-step stream).
        let mut u_fin = vec![0.5f32; n];
        if !deterministic {
            root.child(SPARSITY_PROBE_CHILD).philox().fill_uniform(0, &mut u_fin);
        }
        let mut sum_sigma = 0.0f32;
        let mut active = 0.0f32;
        for j in 0..n {
            let t = sigmoid(s[j]);
            sum_sigma += t;
            if u_fin[j] < t {
                active += 1.0;
            }
        }
        Ok((
            s,
            TrainMetrics {
                mean_loss: (loss_sum / steps.max(1) as f64) as f32,
                correct,
                sum_sigma,
                active,
            },
        ))
    }

    /// Masked evaluation over arbitrary-size inputs (y < 0 rows are
    /// padding: they contribute nothing and are not counted in
    /// `examples`, so accuracy/mean_loss denominators stay correct on
    /// padded batches). Processed in row chunks so peak activation
    /// memory is bounded regardless of test-set size.
    ///
    /// `compute` selects the forward implementation: `Packed` routes
    /// through the bit-packed sign-select tier when the `(mask,
    /// weights)` pair satisfies the packed contract (strictly binary
    /// mask, per-block constant magnitude — see
    /// [`PackedModel::try_build`]), silently falling back to the blocked
    /// reference path otherwise, so the key is safe to set on any model.
    pub fn eval_mask(
        &self,
        mask_f32: &[f32],
        weights: &[f32],
        x: &[f32],
        y: &[i32],
        compute: Compute,
    ) -> Result<EvalMetrics> {
        if compute == Compute::Packed {
            if let Some(pm) = PackedModel::try_build(&self.plan, weights, mask_f32) {
                return self.eval_packed(&pm, x, y);
            }
        }
        // Chunk rows to a scratch budget, not a fixed count: a conv
        // plan's per-row im2col + activation footprint is orders of
        // magnitude bigger than an MLP's (conv4: ~67k floats/row).
        let chunk_rows = self.scratch_chunk_rows(false);
        let rows = y.len();
        let w_eff: Vec<f32> =
            mask_f32.iter().zip(weights).map(|(&m, &w)| m * w).collect();
        let mut ws = Workspace::for_eval(&self.plan, rows.min(chunk_rows).max(1));
        let mut out = EvalMetrics::default();
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(chunk_rows);
            let xc = &x[start * self.input_dim..(start + take) * self.input_dim];
            self.plan.forward(&w_eff, xc, take, &mut ws);
            let logits = &ws.acts[self.plan.logits_buf()][..take * self.n_classes];
            let (loss_sum, correct, valid) =
                softmax_xent_stats(logits, &y[start..start + take], self.n_classes);
            out.loss_sum += loss_sum;
            out.correct += correct;
            out.examples += valid;
            start += take;
        }
        Ok(out)
    }

    /// Packed-tier twin of the blocked eval loop above: same chunking,
    /// same metric accumulation, but the forward runs over bitplanes
    /// instead of an effective-weight vector (no `w_eff` materialized).
    fn eval_packed(&self, pm: &PackedModel, x: &[f32], y: &[i32]) -> Result<EvalMetrics> {
        let chunk_rows = self.scratch_chunk_rows(false);
        let rows = y.len();
        let mut ws = Workspace::for_eval(&self.plan, rows.min(chunk_rows).max(1));
        let mut out = EvalMetrics::default();
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(chunk_rows);
            let xc = &x[start * self.input_dim..(start + take) * self.input_dim];
            self.plan.forward_packed(pm, xc, take, &mut ws);
            let logits = &ws.acts[self.plan.logits_buf()][..take * self.n_classes];
            let (loss_sum, correct, valid) =
                softmax_xent_stats(logits, &y[start..start + take], self.n_classes);
            out.loss_sum += loss_sum;
            out.correct += correct;
            out.examples += valid;
            start += take;
        }
        Ok(out)
    }

    /// Dense forward/backward (SignSGD / FedAvg). Any number of rows —
    /// the native graph has no fixed-batch program, so no padding is
    /// ever needed; large row counts are processed in workspace-budget
    /// chunks (the mean-CE gradient uses the total valid-row
    /// denominator, so chunked accumulation into `dw` reproduces the
    /// single-pass result exactly). Returns (grads, mean loss, correct).
    pub fn dense_grad(
        &self,
        weights: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(Vec<f32>, f32, f32)> {
        let rows = y.len();
        let chunk_rows = self.scratch_chunk_rows(true);
        let mut ws = Workspace::for_train(&self.plan, rows.min(chunk_rows).max(1));
        let logits_buf = self.plan.logits_buf();
        // Mean-CE normalizes by the valid rows of the WHOLE call, so
        // per-chunk gradients can accumulate without reweighting.
        let total_valid = y.iter().filter(|&&v| v >= 0).count();
        let denom = total_valid.max(1) as f32;
        let mut grads_out = vec![0.0f32; self.n_params];
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(chunk_rows);
            let xc = &x[start * self.input_dim..(start + take) * self.input_dim];
            let yc = &y[start..start + take];
            self.plan.forward(weights, xc, take, &mut ws);
            let (ls, corr, _valid) = softmax_xent_stats(
                &ws.acts[logits_buf][..take * self.n_classes],
                yc,
                self.n_classes,
            );
            loss_sum += ls;
            correct += corr;
            {
                let (acts, grads) = (&ws.acts, &mut ws.grads);
                softmax_xent_grad(
                    &acts[logits_buf][..take * self.n_classes],
                    yc,
                    self.n_classes,
                    denom,
                    &mut grads[logits_buf][..take * self.n_classes],
                );
            }
            self.plan.backward(weights, xc, take, &mut ws, &mut grads_out);
            start += take;
        }
        Ok((grads_out, (loss_sum / denom as f64) as f32, correct as f32))
    }

    /// Row count that keeps one workspace's scratch near the float
    /// budget — conv plans carry a far bigger per-row footprint
    /// (im2col + activations) than MLPs. Counts what the workspace
    /// actually allocates: buffer 0 (the caller's input) is never
    /// allocated, and a training workspace mirrors every activation
    /// buffer with a gradient buffer and `col` with `dcol`.
    fn scratch_chunk_rows(&self, train: bool) -> usize {
        const CHUNK_BUDGET_FLOATS: usize = 1 << 24; // ~64 MB of f32
        let acts: usize = self.plan.buf_elems().iter().skip(1).sum();
        let per_row =
            (self.plan.col_elems_per_row() + acts) * if train { 2 } else { 1 };
        (CHUNK_BUDGET_FLOATS / per_row.max(1)).clamp(32, 1024)
    }
}
