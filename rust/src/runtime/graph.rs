//! Layer-graph planner: shape inference, plan validation, buffer
//! assignment and the forward/backward drivers over the kernels in
//! [`super::kernels`] (DESIGN.md §Compute-core).
//!
//! A [`Plan`] is compiled once per model from the manifest's `layers=`
//! layout: every node gets its input/output shape, its slice of the
//! flat parameter vector, and an activation-buffer id. Structural nodes
//! are cheap by construction — `relu` runs in place on its input
//! buffer, `flatten` is pure metadata (NHWC rows are already
//! contiguous) — so a conv stack allocates one activation buffer per
//! dense/conv/pool node and nothing else.
//!
//! A layout written in the bare v1 `KxN@offset` syntax is the legacy
//! MLP form: the planner inserts the implicit inter-layer ReLUs the
//! native backend always applied (keyed on `Manifest::layers_v1`, i.e.
//! the syntax), so old manifests keep their exact semantics and
//! numerics — while an explicit v2 `dense:` chain executes as written.
//!
//! All per-call scratch lives in a [`Workspace`] sized from the plan
//! once per runtime call; the step loop then runs allocation-free.
//!
//! audit: deterministic

use anyhow::{bail, ensure, Result};

use crate::mask::layers::LayerSpec;

use super::artifacts::Manifest;
use super::kernels::{
    col2im_add, gemm_nn, gemm_nt, gemm_tn, im2col, maxpool_bwd, maxpool_fwd, relu_bwd,
    relu_fwd, ConvGeom,
};
use super::packed::{packed_gemm, PackedModel};

/// Activation geometry between nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    Flat(usize),
    /// NHWC spatial activations.
    Spatial { h: usize, w: usize, c: usize },
}

impl Shape {
    pub fn elems(&self) -> usize {
        match *self {
            Shape::Flat(d) => d,
            Shape::Spatial { h, w, c } => h * w * c,
        }
    }
}

/// One compiled graph node.
#[derive(Debug, Clone)]
pub struct Node {
    pub spec: LayerSpec,
    /// Offset of this node's weights in the flat vector (param nodes).
    pub offset: usize,
    pub in_shape: Shape,
    pub out_shape: Shape,
    /// Buffer id holding this node's input (0 = the caller's `x`).
    pub in_buf: usize,
    /// Buffer id holding this node's output. Equal to `in_buf` for
    /// in-place (`relu`) and aliasing (`flatten`) nodes.
    pub buf: usize,
    /// Resolved conv geometry (conv nodes only).
    pub geom: Option<ConvGeom>,
}

/// A validated, buffer-assigned execution plan for one model.
#[derive(Debug, Clone)]
pub struct Plan {
    pub nodes: Vec<Node>,
    pub n_params: usize,
    pub input_dim: usize,
    pub n_classes: usize,
    /// Per-row element count of each activation buffer (id 0 = input).
    buf_elems: Vec<usize>,
    /// Per-row im2col scratch elements (max over conv nodes).
    col_elems_per_row: usize,
}

impl Plan {
    /// Compile and validate the manifest's layer layout.
    pub fn build(man: &Manifest) -> Result<Self> {
        ensure!(
            !man.layers.is_empty(),
            "model '{}' has no layer layout in its manifest; the native \
             backend needs one (re-export artifacts, or build with \
             --features pjrt to run the compiled HLO instead)",
            man.model
        );
        // v1 MLP form: bare `KxN@off` layouts carry the implicit
        // inter-layer ReLUs the chained-MLP backend always applied.
        // Keyed on the manifest *syntax* (`layers_v1`), never on the
        // node kinds: an explicit v2 `dense:...,dense:...` chain is a
        // linear stack and must execute as written.
        let mut specs: Vec<(LayerSpec, usize)> = Vec::new();
        for (i, l) in man.layers.iter().enumerate() {
            specs.push((l.spec, l.offset));
            if man.layers_v1 && i + 1 < man.layers.len() {
                specs.push((LayerSpec::Relu, 0));
            }
        }

        let mut shape = match man.input_shape {
            Some((h, w, c)) => {
                ensure!(
                    h * w * c == man.input_dim,
                    "input_shape {h}x{w}x{c} does not cover input_dim {}",
                    man.input_dim
                );
                Shape::Spatial { h, w, c }
            }
            None => Shape::Flat(man.input_dim),
        };
        let mut nodes = Vec::with_capacity(specs.len());
        let mut buf_elems = vec![man.input_dim]; // id 0 = input x
        let mut cur_buf = 0usize;
        let mut params = 0usize;
        let mut col_elems_per_row = 0usize;
        for (i, &(spec, offset)) in specs.iter().enumerate() {
            let in_shape = shape;
            let in_buf = cur_buf;
            let mut geom = None;
            let out_shape = match spec {
                LayerSpec::Dense { k, n } => {
                    ensure!(
                        in_shape.elems() == k,
                        "node {i}: dense layer expects {k} inputs, gets {} \
                         (shape {:?})",
                        in_shape.elems(),
                        in_shape
                    );
                    Shape::Flat(n)
                }
                LayerSpec::Conv2d { in_ch, out_ch, kernel, stride, pad } => {
                    let Shape::Spatial { h, w, c } = in_shape else {
                        bail!(
                            "node {i}: conv layer needs spatial input — set \
                             `input_shape=HxWxC` in the manifest"
                        );
                    };
                    ensure!(
                        c == in_ch,
                        "node {i}: conv expects {in_ch} input channels, gets {c}"
                    );
                    ensure!(
                        h + 2 * pad >= kernel && w + 2 * pad >= kernel,
                        "node {i}: {kernel}x{kernel} kernel larger than padded \
                         {h}x{w} input"
                    );
                    let oh = (h + 2 * pad - kernel) / stride + 1;
                    let ow = (w + 2 * pad - kernel) / stride + 1;
                    let g = ConvGeom {
                        h,
                        w,
                        cin: in_ch,
                        cout: out_ch,
                        kernel,
                        stride,
                        pad,
                        oh,
                        ow,
                    };
                    col_elems_per_row = col_elems_per_row.max(oh * ow * g.patch());
                    geom = Some(g);
                    Shape::Spatial { h: oh, w: ow, c: out_ch }
                }
                LayerSpec::MaxPool { size } => {
                    let Shape::Spatial { h, w, c } = in_shape else {
                        bail!("node {i}: pool needs spatial input");
                    };
                    ensure!(
                        h % size == 0 && w % size == 0,
                        "node {i}: pool {size}x{size} does not tile {h}x{w} \
                         (non-overlapping pooling needs divisible extents)"
                    );
                    Shape::Spatial { h: h / size, w: w / size, c }
                }
                LayerSpec::Flatten => Shape::Flat(in_shape.elems()),
                LayerSpec::Relu => in_shape,
            };
            // Buffer assignment: relu runs in place, flatten aliases;
            // everything else gets its own buffer. A leading relu on the
            // caller's read-only input still needs somewhere to write.
            let buf = match spec {
                LayerSpec::Flatten => in_buf,
                LayerSpec::Relu if in_buf != 0 => in_buf,
                _ => {
                    buf_elems.push(out_shape.elems());
                    buf_elems.len() - 1
                }
            };
            if spec.params() > 0 {
                ensure!(offset == params, "node {i}: non-contiguous parameter offset");
                params += spec.params();
            }
            nodes.push(Node { spec, offset, in_shape, out_shape, in_buf, buf, geom });
            shape = out_shape;
            cur_buf = buf;
        }
        ensure!(
            params == man.n_params,
            "layer layout covers {params} params, manifest says {}",
            man.n_params
        );
        ensure!(params > 0, "layer layout has no parameterized nodes");
        ensure!(
            shape.elems() == man.n_classes,
            "final layer produces {} outputs, model has {} classes",
            shape.elems(),
            man.n_classes
        );
        Ok(Self {
            nodes,
            n_params: params,
            input_dim: man.input_dim,
            n_classes: man.n_classes,
            buf_elems,
            col_elems_per_row,
        })
    }

    /// Buffer id holding the logits after a forward pass (never 0: a
    /// valid plan has at least one parameterized node).
    pub fn logits_buf(&self) -> usize {
        self.nodes.last().expect("validated plan is non-empty").buf
    }

    /// Per-row element counts of the activation buffers (for sizing).
    pub fn buf_elems(&self) -> &[usize] {
        &self.buf_elems
    }

    /// Per-row im2col scratch element count (0 for conv-free plans).
    pub fn col_elems_per_row(&self) -> usize {
        self.col_elems_per_row
    }

    // audit:no-alloc-begin
    /// Forward through effective weights `w` for `rows` inputs taken
    /// from `x` (read in place, never copied). Afterwards the logits
    /// sit in `ws.acts[self.logits_buf()][..rows * n_classes]`.
    pub fn forward(&self, w: &[f32], x: &[f32], rows: usize, ws: &mut Workspace) {
        debug_assert!(rows <= ws.rows, "workspace sized for {} rows", ws.rows);
        let acts = &mut ws.acts;
        let col = &mut ws.col;
        let col_node = &mut ws.col_node;
        let pool_idx = &mut ws.pool_idx;
        for (ni, node) in self.nodes.iter().enumerate() {
            let out_elems = node.out_shape.elems();
            match node.spec {
                LayerSpec::Dense { k, n } => {
                    let (a, out) = in_out(acts, node.in_buf, node.buf, x);
                    let out = &mut out[..rows * n];
                    out.fill(0.0);
                    gemm_nn(&a[..rows * k], &w[node.offset..node.offset + k * n], out, rows, k, n);
                }
                LayerSpec::Conv2d { .. } => {
                    let g = node.geom.expect("conv node carries geometry");
                    let (a, out) = in_out(acts, node.in_buf, node.buf, x);
                    let m = g.col_rows(rows);
                    let cw = &mut col[..m * g.patch()];
                    im2col(&a[..rows * g.h * g.w * g.cin], cw, g, rows);
                    *col_node = Some((ni, rows));
                    let out = &mut out[..m * g.cout];
                    out.fill(0.0);
                    gemm_nn(
                        cw,
                        &w[node.offset..node.offset + g.patch() * g.cout],
                        out,
                        m,
                        g.patch(),
                        g.cout,
                    );
                }
                LayerSpec::MaxPool { size } => {
                    let Shape::Spatial { h, w: iw, c } = node.in_shape else {
                        unreachable!("validated at plan build")
                    };
                    let (a, out) = in_out(acts, node.in_buf, node.buf, x);
                    maxpool_fwd(
                        &a[..rows * h * iw * c],
                        &mut out[..rows * out_elems],
                        &mut pool_idx[ni][..rows * out_elems],
                        h,
                        iw,
                        c,
                        size,
                        rows,
                    );
                }
                LayerSpec::Flatten => {}
                LayerSpec::Relu => {
                    if node.in_buf == node.buf {
                        relu_fwd(&mut acts[node.buf][..rows * out_elems]);
                    } else {
                        // leading relu: input buffer is the caller's x
                        let out = &mut acts[node.buf][..rows * out_elems];
                        out.copy_from_slice(&x[..rows * out_elems]);
                        relu_fwd(out);
                    }
                }
            }
        }
    }

    /// Packed-tier forward: identical graph walk to [`Plan::forward`],
    /// but dense/conv matmuls run as sign-select popcount-style
    /// accumulation over the bitplanes in `pm` instead of f32 GEMM over
    /// effective weights. Structural nodes (pool/flatten/relu) and the
    /// im2col unfold are shared with the reference path. Results are
    /// tolerance-equivalent (not bitwise) to the blocked path: the
    /// magnitude scale is applied once per output element instead of
    /// per product. Eval-only — the STE gradient stays on the f32 path.
    pub fn forward_packed(&self, pm: &PackedModel, x: &[f32], rows: usize, ws: &mut Workspace) {
        debug_assert!(rows <= ws.rows, "workspace sized for {} rows", ws.rows);
        let acts = &mut ws.acts;
        let col = &mut ws.col;
        let col_node = &mut ws.col_node;
        let pool_idx = &mut ws.pool_idx;
        for (ni, node) in self.nodes.iter().enumerate() {
            let out_elems = node.out_shape.elems();
            match node.spec {
                LayerSpec::Dense { k, n } => {
                    let blk = pm.block(ni).expect("packed model built from this plan");
                    let (a, out) = in_out(acts, node.in_buf, node.buf, x);
                    packed_gemm(&a[..rows * k], blk, &mut out[..rows * n], rows);
                }
                LayerSpec::Conv2d { .. } => {
                    let blk = pm.block(ni).expect("packed model built from this plan");
                    let g = node.geom.expect("conv node carries geometry");
                    let (a, out) = in_out(acts, node.in_buf, node.buf, x);
                    let m = g.col_rows(rows);
                    let cw = &mut col[..m * g.patch()];
                    im2col(&a[..rows * g.h * g.w * g.cin], cw, g, rows);
                    *col_node = Some((ni, rows));
                    packed_gemm(cw, blk, &mut out[..m * g.cout], m);
                }
                LayerSpec::MaxPool { size } => {
                    let Shape::Spatial { h, w: iw, c } = node.in_shape else {
                        unreachable!("validated at plan build")
                    };
                    let (a, out) = in_out(acts, node.in_buf, node.buf, x);
                    maxpool_fwd(
                        &a[..rows * h * iw * c],
                        &mut out[..rows * out_elems],
                        &mut pool_idx[ni][..rows * out_elems],
                        h,
                        iw,
                        c,
                        size,
                        rows,
                    );
                }
                LayerSpec::Flatten => {}
                LayerSpec::Relu => {
                    if node.in_buf == node.buf {
                        relu_fwd(&mut acts[node.buf][..rows * out_elems]);
                    } else {
                        let out = &mut acts[node.buf][..rows * out_elems];
                        out.copy_from_slice(&x[..rows * out_elems]);
                        relu_fwd(out);
                    }
                }
            }
        }
    }

    /// Backprop through the recorded forward pass. The caller seeds
    /// `ws.grads[self.logits_buf()]` with dL/dlogits; `dw` receives the
    /// gradient w.r.t. the flat (effective) weight vector and must be
    /// zeroed by the caller. No gradient w.r.t. `x` is produced.
    pub fn backward(&self, w: &[f32], x: &[f32], rows: usize, ws: &mut Workspace, dw: &mut [f32]) {
        debug_assert_eq!(dw.len(), self.n_params);
        let acts = &ws.acts;
        let grads = &mut ws.grads;
        let col = &mut ws.col;
        let col_node = &mut ws.col_node;
        let dcol = &mut ws.dcol;
        let pool_idx = &ws.pool_idx;
        for (ni, node) in self.nodes.iter().enumerate().rev() {
            match node.spec {
                LayerSpec::Dense { k, n } => {
                    let a = if node.in_buf == 0 { x } else { acts[node.in_buf].as_slice() };
                    let (g_out, g_in) = grad_pair(grads, node.buf, node.in_buf);
                    let g_out = &g_out[..rows * n];
                    gemm_tn(
                        &a[..rows * k],
                        g_out,
                        &mut dw[node.offset..node.offset + k * n],
                        rows,
                        k,
                        n,
                    );
                    if let Some(g_in) = g_in {
                        let g_in = &mut g_in[..rows * k];
                        g_in.fill(0.0);
                        gemm_nt(g_out, &w[node.offset..node.offset + k * n], g_in, rows, n, k);
                    }
                }
                LayerSpec::Conv2d { .. } => {
                    let g = node.geom.expect("conv node carries geometry");
                    let a = if node.in_buf == 0 { x } else { acts[node.in_buf].as_slice() };
                    let a = &a[..rows * g.h * g.w * g.cin];
                    let m = g.col_rows(rows);
                    let cw = &mut col[..m * g.patch()];
                    // the deepest conv's patches are still resident
                    // from this pass's forward; earlier convs recompute
                    if *col_node != Some((ni, rows)) {
                        im2col(a, cw, g, rows);
                        *col_node = Some((ni, rows));
                    }
                    let (g_out, g_in) = grad_pair(grads, node.buf, node.in_buf);
                    let g_out = &g_out[..m * g.cout];
                    gemm_tn(
                        cw,
                        g_out,
                        &mut dw[node.offset..node.offset + g.patch() * g.cout],
                        m,
                        g.patch(),
                        g.cout,
                    );
                    if let Some(g_in) = g_in {
                        let dc = &mut dcol[..m * g.patch()];
                        dc.fill(0.0);
                        gemm_nt(
                            g_out,
                            &w[node.offset..node.offset + g.patch() * g.cout],
                            dc,
                            m,
                            g.cout,
                            g.patch(),
                        );
                        let g_in = &mut g_in[..rows * g.h * g.w * g.cin];
                        g_in.fill(0.0);
                        col2im_add(dc, g_in, g, rows);
                    }
                }
                LayerSpec::MaxPool { .. } => {
                    let out_elems = node.out_shape.elems();
                    let (g_out, g_in) = grad_pair(grads, node.buf, node.in_buf);
                    if let Some(g_in) = g_in {
                        let g_in = &mut g_in[..rows * node.in_shape.elems()];
                        g_in.fill(0.0);
                        maxpool_bwd(
                            &g_out[..rows * out_elems],
                            &pool_idx[ni][..rows * out_elems],
                            g_in,
                        );
                    }
                }
                LayerSpec::Flatten => {}
                LayerSpec::Relu => {
                    // in place on the shared buffer; a leading relu
                    // (own buffer over x) needs no input gradient.
                    let elems = rows * node.out_shape.elems();
                    relu_bwd(&mut grads[node.buf][..elems], &acts[node.buf][..elems]);
                }
            }
        }
    }
    // audit:no-alloc-end
}

/// Disjoint (input, output) views over the activation buffers; buffer 0
/// resolves to the caller's `x`.
fn in_out<'a>(
    acts: &'a mut [Vec<f32>],
    in_buf: usize,
    out_buf: usize,
    x: &'a [f32],
) -> (&'a [f32], &'a mut [f32]) {
    debug_assert_ne!(in_buf, out_buf, "in-place nodes never come through here");
    if in_buf == 0 {
        (x, &mut acts[out_buf])
    } else if in_buf < out_buf {
        let (lo, hi) = acts.split_at_mut(out_buf);
        (&lo[in_buf], &mut hi[0])
    } else {
        let (lo, hi) = acts.split_at_mut(in_buf);
        (&hi[0], &mut lo[out_buf])
    }
}

/// (read gradient of `out_buf`, writable gradient of `in_buf`); `None`
/// when the input is the caller's `x` (no gradient needed).
fn grad_pair(
    grads: &mut [Vec<f32>],
    out_buf: usize,
    in_buf: usize,
) -> (&[f32], Option<&mut [f32]>) {
    if in_buf == 0 {
        (&grads[out_buf], None)
    } else {
        debug_assert_ne!(in_buf, out_buf);
        if in_buf < out_buf {
            let (lo, hi) = grads.split_at_mut(out_buf);
            (&hi[0], Some(&mut lo[in_buf]))
        } else {
            let (lo, hi) = grads.split_at_mut(in_buf);
            (&lo[out_buf], Some(&mut hi[0]))
        }
    }
}

/// Preallocated per-call scratch for one plan at a fixed row capacity:
/// activation buffers, matching gradient buffers (training only),
/// im2col scratch and pool argmax indices. Allocated once per runtime
/// call; the step loop reuses it with zero further heap allocation.
#[derive(Debug)]
pub struct Workspace {
    /// Row capacity the buffers are sized for.
    pub rows: usize,
    /// Activation buffers indexed by buffer id (id 0 stays empty — the
    /// input is read from the caller's slice).
    pub acts: Vec<Vec<f32>>,
    /// Gradient buffers, same geometry as `acts` (empty for eval).
    pub grads: Vec<Vec<f32>>,
    /// im2col scratch (forward + dW recompute).
    pub col: Vec<f32>,
    /// Which `(node index, rows)` the `col` contents belong to, from
    /// the current forward pass. The backward pass recomputes patches
    /// for every conv EXCEPT the one still resident here — on a conv
    /// stack that is the deepest (largest-patch) conv, saved every
    /// step. Forward always rewrites `col` (activations change per
    /// step), so only backward consults the tag.
    pub col_node: Option<(usize, usize)>,
    /// Gradient of the im2col matrix (training only).
    pub dcol: Vec<f32>,
    /// Per-node argmax indices for pool nodes (empty for other nodes).
    pub pool_idx: Vec<Vec<u32>>,
}

impl Workspace {
    fn alloc(plan: &Plan, rows: usize, train: bool) -> Self {
        let mut acts = Vec::with_capacity(plan.buf_elems.len());
        acts.push(Vec::new()); // id 0 = caller's input
        for &e in &plan.buf_elems[1..] {
            acts.push(vec![0.0f32; rows * e]);
        }
        let grads = if train {
            acts.iter().map(|a| vec![0.0f32; a.len()]).collect()
        } else {
            Vec::new()
        };
        let col = vec![0.0f32; rows * plan.col_elems_per_row];
        let col_node = None;
        let dcol = if train { vec![0.0f32; rows * plan.col_elems_per_row] } else { Vec::new() };
        let pool_idx = plan
            .nodes
            .iter()
            .map(|n| match n.spec {
                LayerSpec::MaxPool { .. } => vec![0u32; rows * n.out_shape.elems()],
                _ => Vec::new(),
            })
            .collect();
        Self { rows, acts, grads, col, col_node, dcol, pool_idx }
    }

    /// Forward-only workspace (eval).
    pub fn for_eval(plan: &Plan, rows: usize) -> Self {
        Self::alloc(plan, rows, false)
    }

    /// Forward + backward workspace (training / dense_grad).
    pub fn for_train(plan: &Plan, rows: usize) -> Self {
        Self::alloc(plan, rows, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::layers::parse_layout;

    fn mk_man(
        layout: &str,
        input_dim: usize,
        n_classes: usize,
        input_shape: Option<(usize, usize, usize)>,
    ) -> Manifest {
        let layers = parse_layout(layout).unwrap();
        let n_params = layers.iter().map(|l| l.len()).sum();
        Manifest {
            model: "test".into(),
            layers_v1: crate::mask::layers::layout_is_v1(layout),
            n_params,
            input_dim,
            n_classes,
            batch: 4,
            steps: 2,
            eval_chunk: 8,
            weight_seed: 1,
            has_dense_grad: true,
            layers,
            input_shape,
            weights_file: Default::default(),
            local_train_file: Default::default(),
            eval_file: Default::default(),
            dense_grad_file: None,
            builtin: true,
        }
    }

    #[test]
    fn v1_mlp_gets_implicit_relus() {
        let man = Manifest::builtin("mlp_tiny").unwrap();
        let plan = Plan::build(&man).unwrap();
        let kinds: Vec<&str> = plan.nodes.iter().map(|n| n.spec.kind_name()).collect();
        assert_eq!(kinds, vec!["dense", "relu", "dense"]);
        // relu runs in place on the first dense output
        assert_eq!(plan.nodes[1].buf, plan.nodes[0].buf);
        assert_eq!(plan.logits_buf(), plan.nodes[2].buf);
        assert_eq!(plan.n_params, man.n_params);
    }

    #[test]
    fn v2_dense_layout_stays_linear() {
        // Explicit v2 grammar executes as written: no implicit ReLU is
        // injected between `dense:` nodes, so a linear stack is
        // expressible (the pjrt backend runs the same HLO as written).
        let plan = Plan::build(&mk_man("dense:8x4@0,dense:4x2@32", 8, 2, None)).unwrap();
        let kinds: Vec<&str> = plan.nodes.iter().map(|n| n.spec.kind_name()).collect();
        assert_eq!(kinds, vec!["dense", "dense"]);
        // ...while the bare v1 spelling of the same chain keeps its
        // historical implicit activation.
        let plan = Plan::build(&mk_man("8x4@0,4x2@32", 8, 2, None)).unwrap();
        let kinds: Vec<&str> = plan.nodes.iter().map(|n| n.spec.kind_name()).collect();
        assert_eq!(kinds, vec!["dense", "relu", "dense"]);
    }

    #[test]
    fn conv4_plan_shapes_chain() {
        let man = Manifest::builtin("conv4").unwrap();
        let plan = Plan::build(&man).unwrap();
        assert_eq!(plan.n_params, man.n_params);
        // conv(32x32x16) -> pool -> conv(16x16x32) -> pool -> 8*8*32 = 2048
        let flat = plan
            .nodes
            .iter()
            .find(|n| matches!(n.spec, LayerSpec::Flatten))
            .unwrap();
        assert_eq!(flat.out_shape, Shape::Flat(2048));
        let last = plan.nodes.last().unwrap();
        assert_eq!(last.out_shape.elems(), 10);
        // col scratch: the second conv dominates (16*16 patches of
        // 3*3*16 = 144 beats 32*32 patches of 27)
        assert_eq!(plan.col_elems_per_row(), 16 * 16 * 144);
        // no explicit relu was inserted (graph already has them)
        assert_eq!(
            plan.nodes.iter().filter(|n| matches!(n.spec, LayerSpec::Relu)).count(),
            3
        );
    }

    #[test]
    fn invalid_graphs_rejected() {
        // conv without spatial input
        assert!(Plan::build(&mk_man("conv:1x4:k3:s1:p1@0,flatten,dense:256x10@36", 64, 10, None))
            .is_err());
        // wrong channel count
        assert!(Plan::build(&mk_man(
            "conv:3x4:k3:s1:p1@0,flatten,dense:256x10@108",
            64,
            10,
            Some((8, 8, 1))
        ))
        .is_err());
        // dense width mismatch after flatten
        assert!(Plan::build(&mk_man(
            "conv:1x4:k3:s1:p1@0,flatten,dense:100x10@36",
            64,
            10,
            Some((8, 8, 1))
        ))
        .is_err());
        // pool that does not tile the extent
        assert!(Plan::build(&mk_man(
            "conv:1x4:k3:s1:p1@0,pool:3,flatten,dense:16x10@36",
            64,
            10,
            Some((8, 8, 1))
        ))
        .is_err());
        // final width != n_classes
        assert!(Plan::build(&mk_man("8x8@0", 8, 10, None)).is_err());
        // kernel larger than padded input
        assert!(Plan::build(&mk_man(
            "conv:1x4:k9:s1:p0@0,flatten,dense:4x10@324",
            64,
            10,
            Some((8, 8, 1))
        ))
        .is_err());
    }

    #[test]
    fn workspace_sizing_matches_plan() {
        let man = Manifest::builtin("conv_tiny").unwrap();
        let plan = Plan::build(&man).unwrap();
        let ws = Workspace::for_train(&plan, 3);
        assert_eq!(ws.acts.len(), plan.buf_elems().len());
        assert!(ws.acts[0].is_empty());
        assert_eq!(ws.grads.len(), ws.acts.len());
        assert_eq!(ws.col.len(), 3 * plan.col_elems_per_row());
        // pool node stores one index per output element
        let (ni, pool) = plan
            .nodes
            .iter()
            .enumerate()
            .find(|(_, n)| matches!(n.spec, LayerSpec::MaxPool { .. }))
            .unwrap();
        assert_eq!(ws.pool_idx[ni].len(), 3 * pool.out_shape.elems());
        let ev = Workspace::for_eval(&plan, 3);
        assert!(ev.grads.is_empty() && ev.dcol.is_empty());
    }

    #[test]
    fn forward_backward_smoke_on_conv_tiny() {
        // numerics are covered by the finite-difference integration
        // test; here: shapes line up and gradients are finite/nonzero.
        let man = Manifest::builtin("conv_tiny").unwrap();
        let plan = Plan::build(&man).unwrap();
        let w = man.load_weights().unwrap();
        let rows = 2;
        let mut ws = Workspace::for_train(&plan, rows);
        let x: Vec<f32> = (0..rows * man.input_dim)
            .map(|i| ((i * 37 % 11) as f32 - 5.0) / 5.0)
            .collect();
        plan.forward(&w, &x, rows, &mut ws);
        let logits = &ws.acts[plan.logits_buf()][..rows * man.n_classes];
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(logits.iter().any(|&v| v != 0.0));
        let lb = plan.logits_buf();
        for (i, g) in ws.grads[lb][..rows * man.n_classes].iter_mut().enumerate() {
            *g = if i % 3 == 0 { 1.0 } else { -0.5 };
        }
        let mut dw = vec![0.0f32; man.n_params];
        plan.backward(&w, &x, rows, &mut ws, &mut dw);
        assert!(dw.iter().all(|v| v.is_finite()));
        assert!(dw[..72].iter().any(|&v| v != 0.0), "conv weights get gradient");
        assert!(dw[72..].iter().any(|&v| v != 0.0), "dense weights get gradient");
    }
}
