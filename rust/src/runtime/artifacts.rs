//! AOT artifact manifest: parse `<model>.meta` + load weight blobs.
//!
//! The Python exporter (`python/compile/aot.py`) writes one manifest per
//! model; this is the Rust half of that contract. Everything the
//! coordinator needs to know about a model's exported programs (shapes,
//! file names, parameter count) comes from here — layer structure never
//! crosses the language boundary.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::mask::layers::{layout_is_v1, parse_layout, LayerSlice, LayerSpec};
use crate::util::SeedSequence;

/// Parsed `<model>.meta` manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub n_params: usize,
    pub input_dim: usize,
    pub n_classes: usize,
    /// Minibatch rows per local_train step.
    pub batch: usize,
    /// Scan steps per local_train call.
    pub steps: usize,
    /// Rows per eval call.
    pub eval_chunk: usize,
    pub weight_seed: u64,
    pub has_dense_grad: bool,
    /// Per-layer flat layout (empty for manifests without `layers=`).
    pub layers: Vec<LayerSlice>,
    /// True when `layers=` used the bare v1 `KxN@off` grammar, whose
    /// semantics include implicit inter-layer ReLUs; v2 layouts list
    /// every activation explicitly (`runtime/graph.rs`).
    pub layers_v1: bool,
    /// Spatial input geometry `(height, width, channels)` for layer
    /// graphs that open with conv/pool nodes (`input_shape=HxWxC`);
    /// `None` for flat (MLP) inputs. Rows are NHWC, matching the
    /// synthetic generator's `(y * width + x) * channels + c` layout.
    pub input_shape: Option<(usize, usize, usize)>,
    pub weights_file: PathBuf,
    pub local_train_file: PathBuf,
    pub eval_file: PathBuf,
    pub dense_grad_file: Option<PathBuf>,
    /// True for manifests synthesized from the built-in registry (no
    /// on-disk artifacts; weights are generated from `weight_seed`).
    pub builtin: bool,
}

impl Manifest {
    /// Load and validate `<dir>/<model>.meta`.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let path = dir.join(format!("{model}.meta"));
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("malformed manifest line '{line}' in {path:?}");
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).ok_or_else(|| anyhow::anyhow!("manifest {path:?} missing key '{k}'"))
        };
        let parse_usize =
            |k: &str| -> Result<usize> { Ok(get(k)?.parse().with_context(|| format!("key {k}"))?) };
        let has_dense = parse_usize("has_dense_grad")? != 0;
        let (layers, layers_v1) = match kv.get("layers") {
            Some(l) => (parse_layout(l)?, layout_is_v1(l)),
            None => (Vec::new(), false),
        };
        let input_shape = match kv.get("input_shape") {
            Some(s) => Some(parse_input_shape(s)?),
            None => None,
        };
        let man = Self {
            model: get("model")?.clone(),
            layers,
            layers_v1,
            input_shape,
            n_params: parse_usize("n_params")?,
            input_dim: parse_usize("input_dim")?,
            n_classes: parse_usize("n_classes")?,
            batch: parse_usize("batch")?,
            steps: parse_usize("steps")?,
            eval_chunk: parse_usize("eval_chunk")?,
            weight_seed: get("weight_seed")?.parse()?,
            has_dense_grad: has_dense,
            weights_file: dir.join(get("weights_file")?),
            local_train_file: dir.join(get("local_train_file")?),
            eval_file: dir.join(get("eval_file")?),
            dense_grad_file: if has_dense {
                Some(dir.join(get("dense_grad_file")?))
            } else {
                None
            },
            builtin: false,
        };
        ensure!(man.model == model, "manifest model name mismatch");
        ensure!(man.n_params > 0 && man.input_dim > 0, "degenerate manifest");
        if let Some((h, w, c)) = man.input_shape {
            ensure!(
                h * w * c == man.input_dim,
                "input_shape {h}x{w}x{c} does not cover input_dim {}",
                man.input_dim
            );
        }
        Ok(man)
    }

    /// Synthesize a manifest for one of the built-in models — the same
    /// registry as `python/compile/model.py`, so a checkout with no
    /// exported artifacts still runs every experiment natively
    /// (DESIGN.md §Substitutions). The MLP family is the v1 dense
    /// layout; `conv_tiny` / `conv4` / `conv6` are layer graphs in the
    /// v2 grammar (DESIGN.md §Compute-core), channel-scaled from the
    /// paper's Conv4/Conv6 stacks to CPU-tractable size.
    pub fn builtin(model: &str) -> Option<Self> {
        // MLP family: chained dense layers over a flat input.
        let dims: Option<&[usize]> = match model {
            "mlp_tiny" => Some(&[64, 64, 10]),
            "mlp_mnist" => Some(&[784, 256, 256, 10]),
            "mlp_cifar10" => Some(&[3072, 256, 256, 10]),
            "mlp_cifar100" => Some(&[3072, 512, 256, 100]),
            _ => None,
        };
        if let Some(dims) = dims {
            let mut layers = Vec::with_capacity(dims.len() - 1);
            let mut offset = 0usize;
            for (index, pair) in dims.windows(2).enumerate() {
                let (k, n) = (pair[0], pair[1]);
                layers.push(LayerSlice { index, spec: LayerSpec::Dense { k, n }, offset });
                offset += k * n;
            }
            return Some(Self::builtin_from(
                model,
                layers,
                true, // programmatic dense chain = v1 semantics
                offset,
                dims[0],
                *dims.last().unwrap(),
                None,
            ));
        }
        // Conv family: layer graphs in the v2 `layers=` grammar.
        let (shape, layout): ((usize, usize, usize), &str) = match model {
            "conv_tiny" => (
                (8, 8, 1),
                "conv:1x8:k3:s1:p1@0,relu,pool:2,flatten,dense:128x10@72",
            ),
            "conv4" => (
                (32, 32, 3),
                "conv:3x16:k3:s1:p1@0,relu,pool:2,conv:16x32:k3:s1:p1@432,relu,pool:2,\
                 flatten,dense:2048x64@5040,relu,dense:64x10@136112",
            ),
            "conv6" => (
                (32, 32, 3),
                "conv:3x16:k3:s1:p1@0,relu,conv:16x16:k3:s1:p1@432,relu,pool:2,\
                 conv:16x32:k3:s1:p1@2736,relu,conv:32x32:k3:s1:p1@7344,relu,pool:2,\
                 flatten,dense:2048x64@16560,relu,dense:64x10@147632",
            ),
            _ => return None,
        };
        let layers = parse_layout(layout).expect("built-in conv layout must parse");
        let n_params: usize = layers.iter().map(|l| l.len()).sum();
        let (h, w, c) = shape;
        Some(Self::builtin_from(model, layers, false, n_params, h * w * c, 10, Some(shape)))
    }

    fn builtin_from(
        model: &str,
        layers: Vec<LayerSlice>,
        layers_v1: bool,
        n_params: usize,
        input_dim: usize,
        n_classes: usize,
        input_shape: Option<(usize, usize, usize)>,
    ) -> Self {
        Self {
            model: model.to_string(),
            n_params,
            input_dim,
            n_classes,
            batch: 32,
            steps: 6,
            eval_chunk: 512,
            weight_seed: 2023,
            has_dense_grad: true,
            layers,
            layers_v1,
            input_shape,
            weights_file: PathBuf::new(),
            local_train_file: PathBuf::new(),
            eval_file: PathBuf::new(),
            dense_grad_file: None,
            builtin: true,
        }
    }

    /// Names in the built-in native registry (artifact-free models).
    pub fn builtin_models() -> &'static [&'static str] {
        &[
            "mlp_tiny",
            "mlp_mnist",
            "mlp_cifar10",
            "mlp_cifar100",
            "conv_tiny",
            "conv4",
            "conv6",
        ]
    }

    /// Load the frozen weight vector. Built-in manifests synthesize the
    /// signed-constant distribution U{-sc, +sc} with sc = sqrt(2/fan_in)
    /// (paper sec. IV; conv fan-in = in_ch * k * k) deterministically
    /// from `weight_seed`; artifact manifests read the exporter's flat
    /// f32 little-endian blob.
    pub fn load_weights(&self) -> Result<Vec<f32>> {
        if self.builtin {
            let root = SeedSequence::new(self.weight_seed);
            let mut w = vec![0.0f32; self.n_params];
            for l in self.layers.iter().filter(|l| !l.is_empty()) {
                let sc = (2.0 / l.spec.fan_in() as f64).sqrt() as f32;
                let mut u = vec![0.0f32; l.len()];
                root.child(l.index as u64).philox().fill_uniform(0, &mut u);
                for (j, &uv) in u.iter().enumerate() {
                    w[l.offset + j] = if uv < 0.5 { -sc } else { sc };
                }
            }
            return Ok(w);
        }
        let bytes = fs::read(&self.weights_file)
            .with_context(|| format!("reading weights {:?}", self.weights_file))?;
        ensure!(
            bytes.len() == self.n_params * 4,
            "weight blob is {} bytes, expected {} (n_params={})",
            bytes.len(),
            self.n_params * 4,
            self.n_params
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Rows consumed by one local_train call.
    pub fn rows_per_call(&self) -> usize {
        self.batch * self.steps
    }
}

/// Parse `HxWxC` (e.g. `32x32x3`) from the `input_shape=` manifest key.
fn parse_input_shape(s: &str) -> Result<(usize, usize, usize)> {
    let parts: Vec<&str> = s.split('x').map(str::trim).collect();
    ensure!(parts.len() == 3, "input_shape must be HxWxC, got '{s}'");
    let h: usize = parts[0].parse().context("input_shape height")?;
    let w: usize = parts[1].parse().context("input_shape width")?;
    let c: usize = parts[2].parse().context("input_shape channels")?;
    ensure!(h > 0 && w > 0 && c > 0, "degenerate input_shape '{s}'");
    Ok((h, w, c))
}

/// List models with manifests present in an artifacts directory.
pub fn available_models(dir: &Path) -> Vec<String> {
    let Ok(rd) = fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut out: Vec<String> = rd
        .filter_map(|e| {
            let name = e.ok()?.file_name().into_string().ok()?;
            name.strip_suffix(".meta").map(str::to_string)
        })
        .collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; exported artifacts are optional
        // (the built-in native registry covers the no-artifacts case).
        PathBuf::from("artifacts")
    }

    fn artifacts_present() -> bool {
        artifacts_dir().join("mlp_tiny.meta").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not exported (run `make artifacts`)");
            return;
        }
        let man = Manifest::load(&artifacts_dir(), "mlp_tiny").unwrap();
        assert_eq!(man.n_params, 4736);
        assert_eq!(man.input_dim, 64);
        assert_eq!(man.n_classes, 10);
        assert!(man.local_train_file.exists());
        assert!(man.eval_file.exists());
        assert!(man.has_dense_grad);
        assert!(!man.builtin);
        assert_eq!(man.rows_per_call(), man.batch * man.steps);
    }

    #[test]
    fn weights_match_manifest_count() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not exported (run `make artifacts`)");
            return;
        }
        let man = Manifest::load(&artifacts_dir(), "mlp_tiny").unwrap();
        let w = man.load_weights().unwrap();
        assert_eq!(w.len(), man.n_params);
        // signed Kaiming constant: |w| is one of a few discrete levels
        assert!(w.iter().all(|v| v.abs() > 0.0 && v.abs() < 1.0));
    }

    #[test]
    fn missing_model_errors() {
        assert!(Manifest::load(&artifacts_dir(), "no_such_model").is_err());
        assert!(Manifest::builtin("no_such_model").is_none());
    }

    #[test]
    fn lists_available_models() {
        if !artifacts_present() {
            eprintln!("skipping: artifacts not exported (run `make artifacts`)");
            return;
        }
        let models = available_models(&artifacts_dir());
        assert!(models.contains(&"mlp_tiny".to_string()));
    }

    #[test]
    fn builtin_manifest_matches_exported_geometry() {
        let man = Manifest::builtin("mlp_tiny").unwrap();
        assert!(man.builtin);
        assert_eq!(man.n_params, 4736); // 64*64 + 64*10
        assert_eq!(man.input_dim, 64);
        assert_eq!(man.n_classes, 10);
        assert_eq!(man.layers.len(), 2);
        assert_eq!(man.layers[1].offset, 64 * 64);
        assert!(man.input_shape.is_none());
        let mnist = Manifest::builtin("mlp_mnist").unwrap();
        assert_eq!(mnist.n_params, 784 * 256 + 256 * 256 + 256 * 10);
    }

    #[test]
    fn builtin_conv_registry_geometry() {
        use crate::mask::layers::LayerSpec;
        let tiny = Manifest::builtin("conv_tiny").unwrap();
        assert_eq!(tiny.input_dim, 64);
        assert_eq!(tiny.input_shape, Some((8, 8, 1)));
        assert_eq!(tiny.n_params, 72 + 128 * 10);
        let c4 = Manifest::builtin("conv4").unwrap();
        assert_eq!(c4.input_dim, 3072);
        assert_eq!(c4.input_shape, Some((32, 32, 3)));
        assert_eq!(c4.n_params, 432 + 4608 + 2048 * 64 + 640);
        assert_eq!(
            c4.layers.iter().filter(|l| !l.is_empty()).count(),
            4,
            "conv4 = 2 conv + 2 dense parameterized layers"
        );
        let c6 = Manifest::builtin("conv6").unwrap();
        assert_eq!(c6.n_params, 432 + 2304 + 4608 + 9216 + 2048 * 64 + 640);
        assert_eq!(c6.layers.iter().filter(|l| !l.is_empty()).count(), 6);
        assert!(matches!(c6.layers[0].spec, LayerSpec::Conv2d { in_ch: 3, out_ch: 16, .. }));
        for name in Manifest::builtin_models() {
            assert!(Manifest::builtin(name).is_some(), "{name} must resolve");
        }
    }

    #[test]
    fn builtin_weights_are_signed_constant_and_deterministic() {
        let man = Manifest::builtin("mlp_tiny").unwrap();
        let w = man.load_weights().unwrap();
        assert_eq!(w.len(), man.n_params);
        let sc0 = (2.0f64 / 64.0).sqrt() as f32;
        assert!(w[..64 * 64].iter().all(|&v| v == sc0 || v == -sc0));
        // both signs occur, roughly balanced
        let pos = w.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > man.n_params / 3 && pos < 2 * man.n_params / 3);
        assert_eq!(w, man.load_weights().unwrap(), "weights must replay");
    }

    #[test]
    fn conv_weights_use_conv_fan_in() {
        let man = Manifest::builtin("conv_tiny").unwrap();
        let w = man.load_weights().unwrap();
        // conv 1->8 k3: fan_in = 1*3*3 = 9
        let sc_conv = (2.0f64 / 9.0).sqrt() as f32;
        assert!(w[..72].iter().all(|&v| v == sc_conv || v == -sc_conv));
        // dense 128x10: fan_in = 128
        let sc_fc = (2.0f64 / 128.0).sqrt() as f32;
        assert!(w[72..].iter().all(|&v| v == sc_fc || v == -sc_fc));
        assert_eq!(w, man.load_weights().unwrap(), "weights must replay");
    }

    #[test]
    fn input_shape_key_parses_and_validates() {
        assert_eq!(parse_input_shape("32x32x3").unwrap(), (32, 32, 3));
        assert!(parse_input_shape("32x32").is_err());
        assert!(parse_input_shape("0x4x1").is_err());
        assert!(parse_input_shape("axbxc").is_err());
    }
}
