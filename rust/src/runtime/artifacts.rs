//! AOT artifact manifest: parse `<model>.meta` + load weight blobs.
//!
//! The Python exporter (`python/compile/aot.py`) writes one manifest per
//! model; this is the Rust half of that contract. Everything the
//! coordinator needs to know about a model's exported programs (shapes,
//! file names, parameter count) comes from here — layer structure never
//! crosses the language boundary.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::mask::layers::{parse_layout, LayerSlice};

/// Parsed `<model>.meta` manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    pub n_params: usize,
    pub input_dim: usize,
    pub n_classes: usize,
    /// Minibatch rows per local_train step.
    pub batch: usize,
    /// Scan steps per local_train call.
    pub steps: usize,
    /// Rows per eval call.
    pub eval_chunk: usize,
    pub weight_seed: u64,
    pub has_dense_grad: bool,
    /// Per-layer flat layout (empty for manifests without `layers=`).
    pub layers: Vec<LayerSlice>,
    pub weights_file: PathBuf,
    pub local_train_file: PathBuf,
    pub eval_file: PathBuf,
    pub dense_grad_file: Option<PathBuf>,
}

impl Manifest {
    /// Load and validate `<dir>/<model>.meta`.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let path = dir.join(format!("{model}.meta"));
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("malformed manifest line '{line}' in {path:?}");
            };
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).ok_or_else(|| anyhow::anyhow!("manifest {path:?} missing key '{k}'"))
        };
        let parse_usize =
            |k: &str| -> Result<usize> { Ok(get(k)?.parse().with_context(|| format!("key {k}"))?) };
        let has_dense = parse_usize("has_dense_grad")? != 0;
        let layers = match kv.get("layers") {
            Some(l) => parse_layout(l)?,
            None => Vec::new(),
        };
        let man = Self {
            model: get("model")?.clone(),
            layers,
            n_params: parse_usize("n_params")?,
            input_dim: parse_usize("input_dim")?,
            n_classes: parse_usize("n_classes")?,
            batch: parse_usize("batch")?,
            steps: parse_usize("steps")?,
            eval_chunk: parse_usize("eval_chunk")?,
            weight_seed: get("weight_seed")?.parse()?,
            has_dense_grad: has_dense,
            weights_file: dir.join(get("weights_file")?),
            local_train_file: dir.join(get("local_train_file")?),
            eval_file: dir.join(get("eval_file")?),
            dense_grad_file: if has_dense {
                Some(dir.join(get("dense_grad_file")?))
            } else {
                None
            },
        };
        ensure!(man.model == model, "manifest model name mismatch");
        ensure!(man.n_params > 0 && man.input_dim > 0, "degenerate manifest");
        Ok(man)
    }

    /// Load the frozen weight vector (flat f32 little-endian).
    pub fn load_weights(&self) -> Result<Vec<f32>> {
        let bytes = fs::read(&self.weights_file)
            .with_context(|| format!("reading weights {:?}", self.weights_file))?;
        ensure!(
            bytes.len() == self.n_params * 4,
            "weight blob is {} bytes, expected {} (n_params={})",
            bytes.len(),
            self.n_params * 4,
            self.n_params
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Rows consumed by one local_train call.
    pub fn rows_per_call(&self) -> usize {
        self.batch * self.steps
    }
}

/// List models with manifests present in an artifacts directory.
pub fn available_models(dir: &Path) -> Vec<String> {
    let Ok(rd) = fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut out: Vec<String> = rd
        .filter_map(|e| {
            let name = e.ok()?.file_name().into_string().ok()?;
            name.strip_suffix(".meta").map(str::to_string)
        })
        .collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; `make artifacts` must have run.
        PathBuf::from("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let man = Manifest::load(&artifacts_dir(), "mlp_tiny").unwrap();
        assert_eq!(man.n_params, 4736);
        assert_eq!(man.input_dim, 64);
        assert_eq!(man.n_classes, 10);
        assert!(man.local_train_file.exists());
        assert!(man.eval_file.exists());
        assert!(man.has_dense_grad);
        assert_eq!(man.rows_per_call(), man.batch * man.steps);
    }

    #[test]
    fn weights_match_manifest_count() {
        let man = Manifest::load(&artifacts_dir(), "mlp_tiny").unwrap();
        let w = man.load_weights().unwrap();
        assert_eq!(w.len(), man.n_params);
        // signed Kaiming constant: |w| is one of a few discrete levels
        assert!(w.iter().all(|v| v.abs() > 0.0 && v.abs() < 1.0));
    }

    #[test]
    fn missing_model_errors() {
        assert!(Manifest::load(&artifacts_dir(), "no_such_model").is_err());
    }

    #[test]
    fn lists_available_models() {
        let models = available_models(&artifacts_dir());
        assert!(models.contains(&"mlp_tiny".to_string()));
    }
}
